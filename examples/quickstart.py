"""Quickstart: the paper's mechanism in 60 seconds.

1. Simulate an 8-node cluster training a KGE-like sparse workload under
   AdaPM and the standard baselines (paper Figure 1 / Figure 6 in
   miniature).
2. Run a few training steps of a real (reduced) LM with intent-managed
   embeddings — the data loader signals intent, the planner replicates the
   multi-shard-hot rows, training runs with the managed lookup.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.api import CostModel
from repro.core.baselines import StaticFullReplication, StaticPartitioning
from repro.core.manager import AdaPM
from repro.core.simulator import (SimConfig, simulate,
                                  single_node_epoch_time)
from repro.data.workloads import make_workload


def part1_cluster_simulation():
    print("=" * 64)
    print("Part 1: AdaPM vs standard parameter management (simulated)")
    print("=" * 64)
    cost = CostModel()
    wl = make_workload("KGE", n_nodes=8, wpn=4, scale=0.5)
    t1 = single_node_epoch_time(wl, cost)
    print(f"single-node epoch: {t1*1e3:.1f} ms (shared memory)")
    for policy in (AdaPM(8, cost),
                   StaticFullReplication(8, cost, wl.n_keys),
                   StaticPartitioning(8, cost)):
        m = simulate(policy, wl, SimConfig(signal_offset=100))
        print(f"{policy.name:22s} speedup {t1/m.epoch_time:5.2f}x   "
              f"remote {m.remote_fraction*100:5.2f}%   "
              f"staleness {m.mean_staleness*1e3:6.2f} ms   "
              f"{m.bytes_per_node/1e6:7.1f} MB/node")
    print("-> AdaPM: near-zero remote accesses, low staleness, no tuning.\n")


def part2_intent_managed_training():
    print("=" * 64)
    print("Part 2: intent-managed embeddings in a real training loop")
    print("=" * 64)
    from repro.configs.registry import get_config
    from repro.train.loop import LoopConfig, train_loop

    cfg = get_config("smollm-135m", smoke=True)
    res = train_loop(cfg, LoopConfig(steps=20, batch=4, seq=32, pm=True,
                                     cache_capacity=128, n_shards=4,
                                     log_every=5))
    print(f"-> loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} over "
          f"{len(res.losses)} steps; {res.plans} placement plans; "
          f"{res.recompiles} compiled miss-capacity buckets")


if __name__ == "__main__":
    part1_cluster_simulation()
    part2_intent_managed_training()
