"""End-to-end LM training driver with intent-managed parameter management.

This is the "train a ~100M model for a few hundred steps" driver: with
``--full`` it trains the real smollm-135m (135M params) — sized for real
accelerators; the default ``--smoke`` profile trains the reduced variant
for 300 steps on CPU in a couple of minutes, exercising the identical
code path (intent-signaling loader -> Algorithm-1 planner -> replica-cache
refresh -> managed train step -> checkpointing).

Examples:
  PYTHONPATH=src python examples/train_lm.py                  # CPU demo
  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --full --batch 32 --seq 1024
"""

import argparse

from repro.configs.registry import get_config
from repro.train.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--full", action="store_true",
                    help="full config (135M params; use real accelerators)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--no-pm", dest="pm", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    n = cfg.param_count()
    print(f"training {cfg.arch_id} ({'full' if args.full else 'smoke'}): "
          f"{n/1e6:.1f}M params, pm={'on' if args.pm else 'off'}")
    res = train_loop(cfg, LoopConfig(
        steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
        pm=args.pm, cache_capacity=256, n_shards=4,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(50, args.steps // 4),
        log_every=max(1, args.steps // 20)))
    k = max(1, len(res.losses) // 10)
    first = sum(res.losses[:k]) / k
    last = sum(res.losses[-k:]) / k
    print(f"done: loss {first:.3f} -> {last:.3f} "
          f"({len(res.losses)} steps, {res.wall_s:.0f}s, "
          f"{res.plans} plans, {res.recompiles} buckets)")


if __name__ == "__main__":
    main()
