"""Full parameter-management study on the simulated cluster: all five
paper tasks, AdaPM vs tuned/untuned baselines, with the Figure-15-style
per-key management trace.  A narrated version of `benchmarks/`.

Run:  PYTHONPATH=src python examples/pm_simulation.py [--task MF] [--nodes 8]
"""

import argparse

import numpy as np

from repro.core.api import CostModel
from repro.core.baselines import (NuPSStatic, SelectiveReplicationSSP,
                                  StaticFullReplication, StaticPartitioning)
from repro.core.manager import AdaPM
from repro.core.simulator import (SimConfig, simulate,
                                  single_node_epoch_time)
from repro.data.workloads import TASKS, make_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", choices=tuple(TASKS), default="KGE")
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--scale", type=float, default=0.5)
    args = ap.parse_args()

    cost = CostModel()
    wl = make_workload(args.task, n_nodes=args.nodes, wpn=4,
                       scale=args.scale)
    t1 = single_node_epoch_time(wl, cost)
    print(f"task={args.task} nodes={args.nodes} keys={wl.n_keys} "
          f"single-node epoch {t1*1e3:.1f} ms\n")
    print(f"{'policy':28s} {'speedup':>8s} {'remote%':>8s} "
          f"{'MB/node':>8s} {'stale ms':>9s}")

    policies = [
        ("AdaPM (zero tuning)", lambda: AdaPM(args.nodes, cost)),
        ("AdaPM w/o relocation",
         lambda: AdaPM(args.nodes, cost, relocation=False)),
        ("AdaPM w/o replication",
         lambda: AdaPM(args.nodes, cost, replication=False)),
        ("NuPS hot=1% off=64", lambda: NuPSStatic(
            args.nodes, cost, wl.n_keys, wl.hot_keys(0.01), 64)),
        ("NuPS hot=.05% off=512", lambda: NuPSStatic(
            args.nodes, cost, wl.n_keys, wl.hot_keys(0.0005), 512)),
        ("Full replication", lambda: StaticFullReplication(
            args.nodes, cost, wl.n_keys)),
        ("Static partitioning",
         lambda: StaticPartitioning(args.nodes, cost)),
        ("SSP (bound=20)", lambda: SelectiveReplicationSSP(
            args.nodes, cost, 20)),
    ]
    for name, mk in policies:
        m = simulate(mk(), wl, SimConfig(signal_offset=100))
        print(f"{name:28s} {t1/m.epoch_time:8.2f} "
              f"{m.remote_fraction*100:8.3f} "
              f"{m.bytes_per_node/1e6:8.1f} {m.mean_staleness*1e3:9.3f}")

    # Figure-15-style trace of a hot and a cold key
    freq = wl.key_frequencies()
    order = np.argsort(-freq)
    picks = {"hottest": int(order[0]), "warm": int(order[len(order)//50]),
             "cold": int(order[np.nonzero(freq[order])[0][-1]])}
    pol = AdaPM(args.nodes, cost, trace_keys=set(picks.values()))
    simulate(pol, wl, SimConfig(signal_offset=100))
    print("\nper-key management trace (paper Fig. 15):")
    for name, key in picks.items():
        evs = [(round(t*1e3, 1), n, e) for (t, k, n, e) in pol.trace
               if k == key][:12]
        print(f"  {name} (key {key}, {int(freq[key])} accesses): {evs}")


if __name__ == "__main__":
    main()
