"""Batched decode serving across the architecture families.

Prefills a batch of requests, then decodes autoregressively with the
family-appropriate state: KV caches for dense/MoE/VLM, O(1) recurrent
state for the SSM, hybrid state (mamba2 + shared-attention KV) for
zamba2, and encoder output + decoder KV for whisper.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch falcon-mamba-7b]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.batches import make_batch
from repro.models.model import forward, init_cache, init_model
from repro.train.steps import make_prefill_decode_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P = args.batch, args.prompt_len
    batch = make_batch(cfg, B, P, rng)
    max_seq = P + args.new_tokens + 1
    cache = init_cache(cfg, B, max_seq=max_seq)

    # fused prefill: the whole prompt in ONE jit entry (chunked attention
    # for kv-cache families, in-jit scan for recurrent state) — the old
    # token-by-token loop re-entered jit P times and dominated wall-clock
    # at --prompt-len 64+
    serve = jax.jit(make_serve_step(cfg))
    prefill = jax.jit(make_prefill_decode_step(cfg))
    if cfg.family == "encdec":
        from repro.models.model import _encoder
        cache["enc_out"] = _encoder(params, cfg, batch["frames"])
    t0 = time.time()
    logits, cache = prefill(params, cache, batch["tokens"])
    logits = jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)[:, 0]]
    t0 = time.time()
    for _ in range(args.new_tokens):
        logits, cache = serve(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0

    gen = np.stack(out, axis=1)
    print(f"{cfg.arch_id} ({cfg.family}): served {B} requests, "
          f"prefill {P} toks in {t_prefill:.2f}s, "
          f"decoded {args.new_tokens} toks in {dt:.2f}s "
          f"({B*args.new_tokens/max(dt,1e-9):.1f} tok/s on CPU smoke config)")
    print("generated token ids (req 0):", gen[0].tolist())
    state_keys = {k: tuple(v.shape) for k, v in cache.items()
                  if hasattr(v, "shape") and k != "len"}
    print("decode state:", state_keys)


if __name__ == "__main__":
    main()
