"""Sharding-aware numpy checkpointing.

Each leaf is stored as one ``.npy`` under the checkpoint directory with a
JSON manifest recording the tree structure, dtypes, and step metadata.
Restore rebuilds the exact pytree (optionally re-placing leaves under a
mesh via device_put with the caller's shardings).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [
        "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return leaves, paths, treedef


def save(ckpt_dir: str, tree: Any, step: int,
         extra: Optional[Dict] = None) -> None:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, paths, _ = _flatten(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (leaf, path) in enumerate(zip(leaves, paths)):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(ckpt_dir, fname), arr)
        manifest["leaves"].append(
            {"path": path, "file": fname, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})
    tmp = os.path.join(ckpt_dir, "manifest.json.tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, os.path.join(ckpt_dir, "manifest.json"))


def load(ckpt_dir: str, like: Any, shardings: Any = None):
    """Restore into the structure of ``like``.  Returns (tree, step)."""
    with open(os.path.join(ckpt_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, paths, treedef = _flatten(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for leaf, path in zip(leaves, paths):
        ent = by_path.get(path)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {path!r}")
        arr = np.load(os.path.join(ckpt_dir, ent["file"]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {path}: ckpt {arr.shape} vs "
                f"model {np.shape(leaf)}")
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, manifest["step"]


def latest_step(base_dir: str) -> Optional[str]:
    """Newest ``step_*`` checkpoint directory under ``base_dir``."""
    if not os.path.isdir(base_dir):
        return None
    cands = sorted(d for d in os.listdir(base_dir) if d.startswith("step_"))
    return os.path.join(base_dir, cands[-1]) if cands else None
