"""Request generation + queueing for the online serving runtime (§9).

A serving request names the embedding rows it will touch (a user/session
feature lookup, the prompt's token set, a GNN neighborhood — anything the
frontend knows at admission time).  That is exactly an intent signal: the
moment a request is *enqueued* its key set enters the
`StreamingIntentBuffer`, so by the time the scheduler forms a batch the
planner already knows every row the queued horizon needs — the serving
analogue of the training loader signaling on batch preparation.

`DriftingZipfStream` generates the latency-bound skewed-read scenarios the
paper-style fixed training window cannot express: Zipf access with a
rotating hot set ("rotate"), arrival-rate bursts ("burst"), and a flash
crowd piling onto one previously-cold key ("flash").
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Iterable, List, Optional

import numpy as np

from repro.core.engine import StreamingIntentBuffer
from repro.data.pipeline import DriftingZipfCorpus

SCENARIOS = ("steady", "rotate", "burst", "flash")


@dataclass(eq=False)
class ServeRequest:
    """One enqueued lookup request: ``keys`` are the embedding rows it
    will read when scheduled (fixed length per stream for static batch
    shapes; duplicates allowed — the lookup dedups)."""

    rid: int
    keys: np.ndarray
    t_enqueue: float = 0.0
    attempts: int = 0
    tenant: str = "default"      # accounting label only (no admission
    #   policy): per-tenant serve.requests/latency/requeued telemetry


class DriftingZipfStream:
    """Per-round request arrivals over a drifting-hot-set Zipf workload.

    scenario:
      steady : fixed Zipf head, ``arrival_rate`` requests per round;
      rotate : the hot set rotates every ``rotate_every`` rounds
               (``rotation_rounds`` records when, for drift tests);
      burst  : every ``burst_every`` rounds the arrival count multiplies
               by ``burst_mult`` for one round (queue-depth shock);
      flash  : every ``flash_every`` rounds a previously-cold key is drawn
               and injected into ``flash_frac`` of arrivals for
               ``flash_len`` rounds (flash crowd on one entity).
    """

    def __init__(self, vocab: int, keys_per_request: int = 16, *,
                 zipf_a: float = 1.1, arrival_rate: int = 32,
                 scenario: str = "steady", rotate_every: int = 32,
                 burst_every: int = 16, burst_mult: int = 4,
                 flash_every: int = 32, flash_len: int = 8,
                 flash_frac: float = 0.5, seed: int = 0):
        if scenario not in SCENARIOS:
            raise ValueError(f"unknown scenario {scenario!r}")
        self.V = vocab
        self.K = keys_per_request
        self.rate = arrival_rate
        self.scenario = scenario
        self.rotate_every = rotate_every
        self.burst_every = burst_every
        self.burst_mult = burst_mult
        self.flash_every = flash_every
        self.flash_len = flash_len
        self.flash_frac = flash_frac
        self.corpus = DriftingZipfCorpus(vocab, zipf_a=zipf_a, seed=seed)
        self.rng = np.random.default_rng(seed + 11)
        self.rotation_rounds: List[int] = []
        self._flash_key: Optional[int] = None
        self._flash_until = -1
        self._next_rid = 0

    def _make(self, n: int) -> List[ServeRequest]:
        toks = self.corpus.tokens((n, self.K)).astype(np.int64)
        if self._flash_key is not None:
            crowd = self.rng.random(n) < self.flash_frac
            toks[crowd, 0] = self._flash_key
        reqs = [ServeRequest(self._next_rid + i, toks[i])
                for i in range(n)]
        self._next_rid += n
        return reqs

    def arrivals(self, rnd: int) -> List[ServeRequest]:
        """Requests arriving during round ``rnd`` (call once per round)."""
        n = self.rate
        if self.scenario == "rotate" and rnd > 0 \
                and rnd % self.rotate_every == 0:
            self.corpus.rotate()
            self.rotation_rounds.append(rnd)
        elif self.scenario == "burst" and rnd > 0 \
                and rnd % self.burst_every == 0:
            n *= self.burst_mult
        elif self.scenario == "flash":
            if rnd >= self._flash_until:
                self._flash_key = None
            if rnd > 0 and rnd % self.flash_every == 0:
                # a cold key (deep tail of the live perm) catches fire
                self._flash_key = int(
                    self.corpus.perm[self.rng.integers(self.V // 2, self.V)])
                self._flash_until = rnd + self.flash_len
        return self._make(n)


class ReplayStream:
    """Fixed pre-generated arrival schedule — replays the same trace into
    several runtimes so managed-vs-plain comparisons serve identical
    requests (each replay deep-copies the requests: timing/attempt fields
    are per-run state)."""

    def __init__(self, per_round: List[List[ServeRequest]],
                 rotation_rounds: Optional[List[int]] = None):
        self.per_round = per_round
        self.rotation_rounds = list(rotation_rounds or [])

    @classmethod
    def record(cls, stream: DriftingZipfStream, rounds: int
               ) -> "ReplayStream":
        per_round = [stream.arrivals(r) for r in range(rounds)]
        return cls(per_round, stream.rotation_rounds)

    def arrivals(self, rnd: int) -> List[ServeRequest]:
        if rnd >= len(self.per_round):
            return []
        return [ServeRequest(r.rid, r.keys, tenant=r.tenant)
                for r in self.per_round[rnd]]


class RequestQueue:
    """FIFO request queue whose enqueue path *signals intent*: admission
    is the intent signal (paper §3 — information is provided where it is
    naturally known).  Overflowed requests re-enter at the front
    (``requeue``) with their intent still live — it only expires when the
    request is actually served."""

    def __init__(self, intent: Optional[StreamingIntentBuffer] = None):
        self.intent = intent
        self._q: Deque[ServeRequest] = deque()

    def __len__(self) -> int:
        return len(self._q)

    def enqueue(self, req: ServeRequest, now: float) -> None:
        req.t_enqueue = now
        self._q.append(req)
        if self.intent is not None:
            self.intent.ingest(req.rid, req.keys)

    def enqueue_many(self, reqs: List[ServeRequest], now: float) -> None:
        """One vectorized intent ingest for a whole arrival wave."""
        if not reqs:
            return
        for req in reqs:
            req.t_enqueue = now
            self._q.append(req)
        if self.intent is not None:
            self.intent.ingest_batch(
                np.repeat(np.asarray([r.rid for r in reqs], np.int64),
                          [len(r.keys) for r in reqs]),
                np.concatenate([r.keys for r in reqs]))

    def requeue(self, reqs: Iterable[ServeRequest]) -> None:
        """Front-insert (preserving relative order) — overflowed requests
        are already the oldest work in the system."""
        for req in reversed(list(reqs)):
            req.attempts += 1
            self._q.appendleft(req)

    def pop_batch(self, n: int) -> List[ServeRequest]:
        return [self._q.popleft() for _ in range(min(n, len(self._q)))]

    def order_ids(self) -> np.ndarray:
        """Queued request ids front-to-back (the planner's horizon)."""
        return np.fromiter((r.rid for r in self._q), np.int64, len(self._q))

    def served(self, reqs: Iterable[ServeRequest]) -> None:
        """Expire the served requests' intent."""
        if self.intent is not None:
            self.intent.expire(np.asarray([r.rid for r in reqs], np.int64))
