"""Online serving runtime over the intent-managed embedding (§9, §13).

The loop that closes the paper's adaptation story *online*: enqueued
requests have already signaled intent for the rows they will touch
(`RequestQueue.enqueue` -> `StreamingIntentBuffer`), the planner
continuously re-plans the replica cache from that streaming intent
(`IntentPlanner.replan_from_queue` over the queued horizon), and batches
execute through the read-only serving data path — jnp or Pallas-backed
(`ServeConfig.kernel`), over the emulated or the mesh-real collective
backend (`ServeConfig.collective`, DESIGN.md §10), no VJP, no optimizer.

Re-planning is feedback-driven: a plan carries its own predicted miss
rate (exact over the horizon it was built from), and the runtime replans
early the moment observed misses say the workload drifted away from the
plan —

    replan  iff  rounds_since_plan >= replan_every        (cadence floor)
             or  batch overflowed its miss buffer          (hard signal)
             or  miss_rate > drift_factor * predicted      (soft signal)

Zero-tuning (DESIGN.md §13): every runtime knob accepts ``"auto"`` — the
default for capacity and cadence — and is then owned by the online
controller (`pm.controller.OnlineController`) instead of an operator:

  cache_capacity   steered by the *intent signal* at every replan: the
                   queued horizon's cache-worthy demand
                   (`PlacementPlan.demand`) picks the power-of-two bucket
                   (grow immediately, shrink with hysteresis).  Mid-run
                   resizes are exact — the new plan, cache ids and cache
                   rows are installed atomically at a replan boundary, so
                   no batch ever sees a mixed capacity (tested
                   byte-identical across resize boundaries).
  replan_every /   epsilon-greedy hill-climb on measured epoch throughput
  batch_requests   (requests/s between replan boundaries), one knob in
                   flight at a time.
  double_buffer    auto-enabled when the measured admission/execute
                   overlap ratio pays (`controller.overlap_pays`); the
                   calibration that used to print one ad-hoc line at
                   startup now records ``serve.overlap_*`` telemetry
                   gauges benches and tests assert on, and the single
                   human-readable line moved to the shutdown summary.

Every adaptation signal the runtime acts on — miss rate, overflow and
requeue counts, replan causes, capacity resizes, per-round latency — is
published to the `repro.obs.telemetry` bus (``serve.*`` records); the
controller consumes the bus at replan boundaries, so benches, tests and
the controller all read the same source of truth.

Because the whole index stage runs on the host at admission
(`probe_host`), every drift signal is known *before* the batch executes —
which is what makes the admission loop double-bufferable: the runtime
dispatches batch t to the device and, while it executes, enqueues /
replans / probes batch t+1 on the host; batch t is only blocked one
round later.  Semantics are identical to the serial loop (tested).

Overflowed requests are NEVER served zeros: their rows come back flagged,
the requests re-enter the queue front, and the overflow itself is the
drift signal that triggers the replan that will fit them.  Replica
refresh follows the table's declared mutability: with ``refresh_every >
0`` the cache is re-gathered on every replan and every ``refresh_every``
rounds in between, so an out-of-band table update (e.g. a trainer
checkpoint swap) reaches replicas within one refresh round — the serving
analogue of the training loop's bounded staleness.  With ``refresh_every
== 0`` (read-only table, the serving default) a replan that kept the
cache contents skips the (C, D) re-gather entirely
(``serve.refresh_skipped``) — steady-state replans then cost plan
arithmetic only.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import StreamingIntentBuffer
from repro.obs.attribution import PlanAttribution
from repro.obs.telemetry import Telemetry
from repro.obs.trace import SpanTracer, make_tracer
from repro.pm.collectives import resolve
from repro.pm.controller import (AUTO, Knob, OnlineController, capacity_ladder,
                                 is_auto, overlap_pays, pow2_ladder,
                                 resolve_knob)
from repro.pm.embedding import (CacheProbeView, plain_serve_lookup,
                                planned_serve_lookup, probe_host)
from repro.pm.planner import IntentPlanner, PlacementPlan
from repro.serve.requests import RequestQueue
from repro.serve.scheduler import MicroBatchScheduler


@dataclass
class ServeConfig:
    vocab: int
    batch_requests: Union[int, str] = 32   # requests per micro-batch;
    #   "auto": hill-climbed over a power-of-two ladder
    keys_per_request: int = 16
    cache_capacity: Union[int, str] = AUTO  # replica-cache rows; "auto"
    #   (the default): intent-steered power-of-two buckets, resized
    #   mid-run at replan boundaries (DESIGN.md §13)
    managed: bool = True         # False: plain vocab-parallel baseline
    n_shards: int = 1            # emulated vocab shards (collective cost)
    collective: str = "emulated"  # "emulated" | "mesh": collective backend
    #   for the lookup data path ("mesh" shards the table over a real
    #   device mesh and runs the shard_map psum — n_shards is then the
    #   mesh size, not a cost model)
    model_shards: int = 0        # mesh size for collective="mesh"
    #   (0 = every local device)
    kernel: bool = False         # Pallas-backed lookup data path
    double_buffer: Union[bool, str] = AUTO  # back-compat alias for the
    #   one-slot pipeline: explicit True/False pins ``pipeline_depth`` to
    #   1/0 when that field is left "auto"; with both "auto" the depth
    #   defaults below.  Reads of `runtime.double_buffer` stay valid
    #   (derived: pipeline_depth >= 1); semantics are identical at every
    #   depth (tested).
    pipeline_depth: Union[int, str] = AUTO  # N-deep admission->probe->
    #   prefetch->dispatch pipeline (DESIGN.md §15): up to N batches stay
    #   dispatched-but-unblocked while the host stages the next rounds,
    #   and each plan tenure prefetches its queued horizon's miss rows
    #   into a staging buffer so steady-state batches pay only the
    #   residual collective gather.  0 = the fully synchronous pre-ISSUE-9
    #   loop.  "auto" (default): starts at 1 (the staging prefetch is pure
    #   work elimination); the controller hill-climbs the depth and the
    #   overlap calibration force-raises it where measured overlap pays.
    replan_every: Union[int, str] = AUTO  # cadence floor (rounds between
    #   replans); "auto": hill-climbed.  0 = feedback-only mode: replan
    #   solely on drift signals (overflow / miss-rate), never on cadence
    #   or window exhaustion
    refresh_every: Union[int, str] = AUTO  # extra replica re-gathers
    #   between replans.  "auto" resolves to 0 — replan rounds only, the
    #   right value for a read-only serving table (set >0 explicitly when
    #   a trainer swaps the table out-of-band)
    drift_factor: float = 2.0    # soft replan: observed > factor*predicted
    max_attempts: int = 8        # loud failure, never a silent zero row
    summary: bool = True         # print the one-line telemetry summary at
    #   the end of the runtime's first run (the shutdown line)
    trace: bool = False          # span tracing (DESIGN.md §14): default
    #   OFF — disabled call sites cost one early-return branch; enabled
    #   at trace_sample=1.0 the serve bench pins the cost under 2%
    trace_sample: float = 1.0    # deterministic per-rid sampling for
    #   request spans (phase spans always record when tracing is on)
    trace_capacity: int = 1 << 15  # span ring size (oldest spans evicted)
    seed: int = 0


@dataclass
class ServeResult:
    served: int = 0
    rounds: int = 0
    replans: int = 0
    refreshes: int = 0
    requeues: int = 0            # requests re-queued after overflow
    overflow_batches: int = 0    # batches whose unique misses exceeded M
    zero_served: int = 0         # MUST stay 0: served rows with overflow
    capacity_resizes: int = 0    # mid-run replica-cache bucket changes
    throughput_rps: float = 0.0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    mean_ms: float = 0.0
    wall_s: float = 0.0
    miss_trace: List[Tuple[int, float]] = field(default_factory=list)
    #   (round, token-level miss rate) per executed batch
    replan_rounds: List[int] = field(default_factory=list)
    plan_miss_capacities: List[int] = field(default_factory=list)
    capacity_trace: List[Tuple[int, int]] = field(default_factory=list)
    #   (round, cache_capacity) per mid-run resize
    knobs: Dict[str, object] = field(default_factory=dict)
    #   the runtime's knob values at the end of the run (auto knobs land
    #   wherever the controller drove them)
    outputs: Dict[int, np.ndarray] = field(default_factory=dict)
    #   rid -> (K, D) served rows (only when run(collect_outputs=True))

    def steady_miss_rate(self, lo: int, hi: int) -> Optional[float]:
        """Mean batch miss rate over rounds [lo, hi); None when no batch
        executed in the window (callers must not treat an unmeasured
        window as a perfect one)."""
        vals = [m for r, m in self.miss_trace if lo <= r < hi]
        return float(np.mean(vals)) if vals else None


@dataclass
class _InFlight:
    """A dispatched-but-not-yet-blocked batch (double-buffered admission):
    everything bookkeeping needs was decided at dispatch time from the
    host-side probe — blocking only realizes the rows and the clock."""

    out: jnp.ndarray             # device future of the (T, D) rows
    reqs: list                   # the batch's real requests
    served: list                 # probe-decided: requests to serve
    served_mask: np.ndarray      # per-req bool aligned with ``reqs``
    tokens_shape: tuple


class ServingRuntime:
    """Queue -> intent -> plan -> execute, one micro-batch per round."""

    def __init__(self, table, cfg: ServeConfig,
                 telemetry: Optional[Telemetry] = None,
                 tracer: Optional[SpanTracer] = None):
        self.cfg = cfg
        self.table = jnp.asarray(table)
        assert self.table.shape[0] == cfg.vocab
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        # span tracer: an injected instance wins (the bench shares one
        # across runtimes); otherwise built from the cfg — default off
        self.tracer = make_tracer(cfg.trace, cfg.trace_sample,
                                  cfg.trace_capacity, tracer)
        from repro.pm.collectives import make_backend
        self.backend = make_backend(cfg.collective, cfg.model_shards)
        if self.backend is not None:
            self.table = self.backend.place_table(self.table)

        # ---- knob resolution: "auto" fields belong to the controller
        self._auto = {name for name, v in (
            ("cache_capacity", cfg.cache_capacity),
            ("replan_every", cfg.replan_every),
            ("refresh_every", cfg.refresh_every),
            ("batch_requests", cfg.batch_requests),
            ("double_buffer", cfg.double_buffer)) if is_auto(v)}
        cap_ladder = capacity_ladder(cfg.vocab)
        self.cache_capacity = int(resolve_knob(cfg.cache_capacity,
                                               cap_ladder[0]))
        self.replan_every = int(resolve_knob(cfg.replan_every, 4))
        # a read-only serving table never needs refreshes between replans
        self.refresh_every = int(resolve_knob(cfg.refresh_every, 0))
        self.batch_requests = int(resolve_knob(cfg.batch_requests, 16))
        # pipeline depth precedence: an explicit depth wins; else an
        # explicit legacy double_buffer maps to 1/0; else auto (depth 1 —
        # the staging prefetch is work elimination, on by default)
        if not is_auto(cfg.pipeline_depth):
            self.pipeline_depth = int(cfg.pipeline_depth)
        elif not is_auto(cfg.double_buffer):
            self.pipeline_depth = 1 if cfg.double_buffer else 0
        else:
            self.pipeline_depth = 1
            self._auto.add("pipeline_depth")
        self._ctl: Optional[OnlineController] = None
        if cfg.managed and self._auto - {"refresh_every", "double_buffer"}:
            knobs = []
            if "cache_capacity" in self._auto:
                # intent-steered, not hill-climbed (adapt=False): the
                # queued horizon's demand computes the bucket directly
                knobs.append(Knob("cache_capacity", cap_ladder,
                                  index=cap_ladder.index(
                                      self.cache_capacity),
                                  adapt=False, prefer_low=True))
            if "replan_every" in self._auto:
                ladder = (2, 4, 8, 16, 32)
                knobs.append(Knob("replan_every", ladder,
                                  index=ladder.index(self.replan_every)))
            if "batch_requests" in self._auto:
                ladder = pow2_ladder(8, 256)
                knobs.append(Knob("batch_requests", ladder,
                                  index=ladder.index(self.batch_requests)))
            if "pipeline_depth" in self._auto:
                # the lookup is exact at every depth (the pipeline only
                # moves blocking and staging traffic), so the hill-climb
                # probes freely; `_calibrate_overlap` force-raises it
                # through the same controller when measured overlap pays
                ladder = (0, 1, 2, 4)
                knobs.append(Knob("pipeline_depth", ladder,
                                  index=ladder.index(self.pipeline_depth),
                                  prefer_low=True))
            self._ctl = OnlineController(knobs, self.telemetry,
                                         seed=cfg.seed)

        self.intent = StreamingIntentBuffer() if cfg.managed else None
        self.queue = RequestQueue(self.intent)
        self.scheduler = MicroBatchScheduler(self.batch_requests,
                                             cfg.keys_per_request,
                                             telemetry=self.telemetry)
        # mesh collective: admission is additionally bounded PER OWNER
        # SHARD — the planner publishes `route_capacity` (the exact
        # per-(step,owner) unique-miss bound over the queued horizon) and
        # the device lookup routes per-owner blocks of exactly that size
        # (DESIGN.md §12), so what admission admits is what the routed
        # collective can carry.  (The per-shard bound lives on owner
        # shards, not on the signaling nodes below.)
        self._owner_shards = (self.backend.n_shards
                              if self.backend is not None
                              and self.backend.mesh_real else 0)
        # n_nodes = REQUESTER SLOTS within a micro-batch, NOT vocab
        # shards: serving maps §4.1's "nodes" onto batch positions (a key
        # wanted by >= 2 queued requests in the same batch is concurrent
        # intent), so the node count is the micro-batch width
        self.planner = IntentPlanner(
            cfg.vocab, self.cache_capacity,
            n_nodes=self.batch_requests,
            plan_every=self.replan_every,
            owner_shards=self._owner_shards,
            telemetry=self.telemetry) if cfg.managed else None
        # plan-vs-actual audit trail (DESIGN.md §14): only when traced —
        # one record per replan boundary, over the same bus
        self.attribution: Optional[PlanAttribution] = (
            PlanAttribution(owner_shards=self._owner_shards,
                            vocab=cfg.vocab, telemetry=self.telemetry)
            if cfg.managed and self.tracer.enabled else None)
        self.plan: Optional[PlacementPlan] = None
        self._cache_ids = None           # device copy (refresh input)
        self._cache_ids_np = None        # host copy (admission-time probe)
        self._cache_rows = None
        # memoized probe LUTs, rebuilt once per cache generation (the
        # per-batch probe then never re-sorts the cache side)
        self._probe_view: Optional[CacheProbeView] = None
        # staged prefetch (pipeline_depth >= 1): the tenure's predicted
        # miss rows, gathered once per replan/refresh instead of riding
        # every batch's collective
        self._staged_ids: Optional[np.ndarray] = None   # host, sorted asc
        self._staged_ids_dev = None      # V-padded device ids (re-gather)
        self._staging_rows = None        # (S, D) device rows
        self._cache_ext = None           # (C+S, D) cache ++ staging concat
        # accrual top-up state (one tenure's scope): per-id residual-miss
        # counts and the ids that crossed the recurrence threshold since
        # the last merge — see `_note_residual`
        self._miss_counts: Optional[np.ndarray] = None
        self._stage_pending: List[np.ndarray] = []
        self._pending_replan = False     # e.g. an out-of-band resize
        # lifetime round clock: `run()` can be called repeatedly on one
        # runtime (resize segments, drain calls) and the planner's rate
        # estimator requires a monotone clock across those calls
        self._lifetime_rounds = 0
        self._plain_fn = jax.jit(lambda t, toks: plain_serve_lookup(
            t, toks, n_shards=cfg.n_shards, backend=self.backend))
        # one jitted data-path fn; XLA re-specializes per miss bucket
        # (buf_ids shape), per capacity bucket (cache_rows shape) and —
        # on the mesh — per route-capacity bucket: all three ride
        # power-of-two ladders, so a handful of executables, and
        # revisiting a bucket never recompiles (tested).  ``nm`` (the
        # host probe's unique-miss count) rides along as a device scalar;
        # the non-mesh path ignores it.
        self._managed_fns: Dict[int, callable] = {}
        self.overlap_ratio: Optional[float] = None
        self._calibrated = False
        self._summary_printed = False
        # controller reward epochs: measured between replan boundaries
        self._epoch_t0: Optional[float] = None
        self._epoch_served0 = 0

    def _managed_fn(self, route_cap: int = 0):
        """Jitted serving data path, specialized per routed block size
        (0 on non-mesh backends — the router is off without ``n_miss``
        anyway, see `planned_serve_lookup`)."""
        cfg = self.cfg
        fn = self._managed_fns.get(route_cap)
        if fn is None:
            fn = jax.jit(
                lambda t, cr, bi, h, cs, bs, nm: planned_serve_lookup(
                    t, cr, bi, h, cs, bs, n_shards=cfg.n_shards,
                    kernel=cfg.kernel, backend=self.backend,
                    n_miss=(nm if self._owner_shards else None),
                    route_cap=route_cap))
            self._managed_fns[route_cap] = fn
        return fn

    @property
    def double_buffer(self) -> bool:
        """Back-compat view of the pipeline: any depth >= 1 overlaps
        admission with execution (the old one-slot semantics)."""
        return self.pipeline_depth >= 1

    @staticmethod
    def _overlap_backend_ok() -> bool:
        """Overlap only buys parallelism when execution is genuinely
        off-host: on the CPU backend the "device" IS the host cores, so
        deeper pipelining adds contention (measured ~0.98x at a ~1.25x
        predicted ratio) — same backend gate as the kernel autotuner."""
        return jax.default_backend() != "cpu"

    # ----------------------------------------------------------- control
    def current_knobs(self) -> Dict[str, object]:
        """The live knob values (auto knobs: wherever the controller has
        driven them so far)."""
        return {"cache_capacity": self.cache_capacity,
                "replan_every": self.replan_every,
                "refresh_every": self.refresh_every,
                "batch_requests": self.batch_requests,
                "double_buffer": self.double_buffer,
                "pipeline_depth": self.pipeline_depth}

    def _calibrate_overlap(self) -> None:
        """One-shot overlap calibration for double-buffered admission:
        time one representative host-side admission probe against one
        device dispatch on this host and record the wall-clock ratio the
        one-slot pipeline could buy — ``(host + device) / max(host,
        device)``, ~2x when the two sides are balanced, ~1x when either
        dominates.  The measurement lands on the telemetry bus
        (``serve.overlap_ratio`` / ``serve.overlap_host_ms`` /
        ``serve.overlap_device_ms``) so benches and tests can assert on
        it; with ``double_buffer="auto"`` the controller enables the
        pipeline iff the ratio pays.  No startup print — the one
        human-readable line is the shutdown `summary`."""
        self._calibrated = True
        cfg = self.cfg
        try:
            T = self.batch_requests * cfg.keys_per_request
            rng = np.random.default_rng(0)
            tok = rng.integers(0, cfg.vocab, size=T).astype(np.int32)
            cache_ids = np.arange(min(self.cache_capacity, cfg.vocab),
                                  dtype=np.int32)
            M = max(1, min(64, T))   # the planner ladder's floor bucket
            cache_rows = resolve(self.backend).refresh_rows(
                self.table, jnp.asarray(cache_ids))

            def host():
                return probe_host(cache_ids, tok, M)

            def device(p):
                idx = jnp.asarray(np.stack([p.hit.astype(np.int32),
                                            p.cache_slot, p.buf_slot]))
                jax.block_until_ready(self._managed_fn()(
                    self.table, cache_rows, jnp.asarray(p.buf_ids),
                    idx[0], idx[1], idx[2], jnp.int32(p.n_miss)))

            p = host()
            device(p)                # warmup + compile

            def timed(fn, *a):       # min-of-3: the noise-robust timer
                best = float("inf")
                for _ in range(3):
                    t0 = time.perf_counter()
                    fn(*a)
                    best = min(best, time.perf_counter() - t0)
                return best

            th = timed(host)
            td = timed(device, p)
            self.overlap_ratio = (th + td) / max(th, td, 1e-9)
            self.telemetry.set("serve.overlap_ratio", self.overlap_ratio)
            self.telemetry.set("serve.overlap_host_ms", th * 1e3)
            self.telemetry.set("serve.overlap_device_ms", td * 1e3)
            # the measured-overlap force goes through the controller's
            # `force_at_least` — the ONE ctl.force emitter, so every
            # forced move carries the same event schema (knob/value/
            # cause/target) and `obs/report.py`'s knob timeline renders
            # it alongside the demand-steered forces
            if "pipeline_depth" in self._auto and self._ctl is not None \
                    and self._overlap_backend_ok() \
                    and overlap_pays(self.overlap_ratio):
                v = self._ctl.force_at_least("pipeline_depth", 2,
                                             cause="overlap")
                if v is not None:
                    self.pipeline_depth = int(v)
        except Exception as e:       # pragma: no cover — never block a run
            self.telemetry.event("serve.overlap_calibration_skipped",
                                 error=repr(e))

    def summary(self) -> str:
        """The single human-readable shutdown line (replaces the old
        startup calibration print): final knob values, which of them the
        controller owned, and the headline telemetry."""
        t = self.telemetry
        knobs = " ".join(f"{k}={v}" for k, v in
                         self.current_knobs().items())
        auto = ",".join(sorted(self._auto)) or "none"
        ratio = f"{self.overlap_ratio:.2f}x" \
            if self.overlap_ratio is not None else "n/a"
        return (f"[serve] shutdown: {knobs} auto=({auto}) "
                f"overlap~{ratio} "
                f"replans={int(t.counter_value('serve.replans'))} "
                f"resizes={int(t.counter_value('serve.capacity_resizes'))} "
                f"overflows={int(t.counter_value('serve.overflow_batches'))}"
                f" miss_rate~{t.gauge_value('serve.miss_rate', 0.0):.3f}")

    def report(self) -> str:
        """The traced run's full shutdown report (latency/attribution/
        knob-timeline — the same renderer ``python -m repro.obs.report``
        applies to exported files)."""
        from repro.obs.report import render_report
        records = [dict({"kind": "event"}, name=ev.pop("_name"),
                        event_seq=ev.pop("_seq"), fields=ev)
                   for ev in self.telemetry.events()]
        if self.attribution is not None:
            records.extend(dict(r.to_json(), kind="attribution")
                           for r in self.attribution.records)
        return render_report(
            self.tracer.to_chrome()["traceEvents"] or None,
            records or None, title="serve shutdown report")

    def resize_capacity(self, cache_capacity: int) -> None:
        """Mid-run replica-cache resize (the controller's hook; also
        public for operators/tests).  Takes effect atomically at the next
        replan boundary: the new plan, cache ids and cache rows are
        installed together, so no batch ever executes against a mixed
        capacity — results across the resize stay exact."""
        self._set_capacity(int(cache_capacity), rnd=-1)
        self._pending_replan = True

    def _set_capacity(self, cache_capacity: int, rnd: int) -> None:
        if cache_capacity == self.cache_capacity:
            return
        self.cache_capacity = cache_capacity
        self.planner.set_capacity(cache_capacity)
        self.telemetry.inc("serve.capacity_resizes")
        self.telemetry.set("serve.cache_capacity", cache_capacity)
        self.telemetry.event("serve.capacity_resize", round=rnd,
                             capacity=cache_capacity)

    def _set_batch_requests(self, b: int) -> None:
        self.batch_requests = b
        self.scheduler.B = b
        self.telemetry.set("serve.batch_requests", b)

    def _controller_step(self, rnd: int, res: ServeResult) -> None:
        """Measured hill-climb decision at a replan boundary: reward is
        the epoch's served requests/s (the epoch = rounds since the last
        boundary).  Applied BEFORE the new plan is built so the plan sees
        the new cadence/batch width."""
        now = time.perf_counter()
        if self._ctl is not None and self._epoch_t0 is not None:
            wall = now - self._epoch_t0
            served = self.scheduler.n_served - self._epoch_served0
            if wall > 0 and served > 0:
                reward = served / wall
                self.telemetry.set("ctl.reward", reward)
                for name, v in self._ctl.observe(reward).items():
                    self._apply_knob(name, v, rnd, res)
        self._epoch_t0 = now
        self._epoch_served0 = self.scheduler.n_served

    def _apply_knob(self, name: str, v, rnd: int, res: ServeResult) -> None:
        if name == "cache_capacity":
            self._set_capacity(int(v), rnd)
        elif name == "replan_every":
            self.replan_every = int(v)
            self.planner.plan_every = int(v)
            self.telemetry.set("serve.replan_every", v)
        elif name == "batch_requests":
            self._set_batch_requests(int(v))
        elif name == "refresh_every":
            self.refresh_every = int(v)
        elif name == "pipeline_depth":
            self.pipeline_depth = int(v)
            self.telemetry.set("serve.pipeline_depth", v)

    # ---------------------------------------------------------------- plan
    def _replan(self, rnd: int, res: ServeResult, cause: str) -> None:
        old_plan = self.plan     # the tenure the attribution flush closes
        self._controller_step(rnd, res)
        keys, slots, ticks = self.intent.snapshot(
            self.queue.order_ids(), self.batch_requests)
        if len(keys) == 0:
            return
        plan = self.planner.replan_from_queue(keys, slots, ticks)
        if self._ctl is not None and "cache_capacity" in self._auto:
            # intent-signal capacity steering: the plan's demand count IS
            # the bucket; a changed bucket re-plans over the same snapshot
            # so plan/ids/rows stay mutually consistent
            new_cap = self._ctl.steer_capacity("cache_capacity",
                                               plan.demand)
            if new_cap is not None:
                self._set_capacity(int(new_cap), rnd)
                res.capacity_resizes += 1
                res.capacity_trace.append((rnd, int(new_cap)))
                plan = self.planner.replan_from_queue(keys, slots, ticks)
        # a replan that kept the cache contents (sorted ids are canonical,
        # so set-equality IS array-equality) needs no re-gather when the
        # serving table is declared read-only (refresh_every == 0: no
        # out-of-band updates to sync) — steady-state replans then cost
        # plan arithmetic only, not a (C, D) gather
        same_cache = (self._cache_ids_np is not None
                      and self._cache_rows is not None
                      and np.array_equal(plan.cache_ids,
                                         self._cache_ids_np))
        self.plan = plan
        if same_cache and self.refresh_every == 0:
            self.telemetry.inc("serve.refresh_skipped")
        else:
            self._cache_ids_np = self.plan.cache_ids
            self._cache_ids = jnp.asarray(self.plan.cache_ids)
            # new cache generation: rebuild the memoized probe LUTs once
            # (the per-batch probe never re-sorts the cache side again)
            self._probe_view = CacheProbeView(self._cache_ids_np,
                                              self.cfg.vocab)
            self._staged_ids = None      # rebuilt below for the new tenure
            self._refresh(res)
        # per-tenure staged prefetch (DESIGN.md §15): the snapshot's
        # queued-horizon keys the new plan does NOT cache are exactly this
        # tenure's predicted miss set — gather them once into the staging
        # buffer so steady-state batches skip the per-batch collective
        if self.pipeline_depth >= 1:
            with self.tracer.span("prefetch.stage", a=rnd):
                self._stage(keys)
        else:
            self._staged_ids = None
            self._staged_ids_dev = None
            self._staging_rows = None
            self._cache_ext = None
        self._pending_replan = False
        res.replans += 1
        res.replan_rounds.append(rnd)
        res.plan_miss_capacities.append(self.plan.miss_capacity)
        self.telemetry.inc("serve.replans")
        self.telemetry.inc("serve.replans", cause=cause)
        self.telemetry.set("serve.predicted_miss_rate",
                           self.plan.predicted_miss_rate)
        self.telemetry.event("serve.replan", round=rnd, cause=cause,
                             capacity=self.cache_capacity,
                             miss_capacity=self.plan.miss_capacity,
                             demand=self.plan.demand)
        if self.attribution is not None:
            # close the OUTGOING plan's tenure: its promise vs the batches
            # that executed under it (None before the first replan)
            self.attribution.flush(
                rnd=rnd, plan=old_plan, cause=cause,
                knobs=self.current_knobs(), capacity=self.cache_capacity,
                miss_capacity=self.plan.miss_capacity)

    def _stage(self, keys: np.ndarray) -> None:
        """Build the tenure's staging buffer: the queued-horizon keys the
        active plan left uncached AND that recur in the horizon, gathered
        once (locally on the emulated backend — the same cost-model rule
        as the replica refresh; the routed owner-block gather on the
        mesh).  The multiplicity >= 2 gate is the work-elimination
        break-even: a key queued once costs the staging gather exactly
        the one per-batch gather it saves, so prefetching it is pure
        overhead — only recurring misses amortize (a key queued k times
        saves k gathers for one staging row).  Singletons ride the
        residual collective instead; correctness is unaffected either
        way (both paths read the same table rows)."""
        uniq, counts = np.unique(np.asarray(keys, np.int64),
                                 return_counts=True)
        staged = np.setdiff1d(uniq[counts >= 2],
                              np.asarray(self.plan.cache_ids, np.int64))
        # new tenure: the accrual counts and pending top-ups scope to one
        # staging generation (the cache/staged split they counted against
        # just changed)
        if self._miss_counts is None:
            self._miss_counts = np.zeros(self.cfg.vocab, np.int32)
        else:
            self._miss_counts[:] = 0
        self._stage_pending = []
        if staged.size == 0:
            self._staged_ids = None
            self._staged_ids_dev = None
            self._staging_rows = None
            self._cache_ext = None
            return
        self._install_staging(staged)

    def _install_staging(self, staged: np.ndarray) -> None:
        """(Re)build the staging buffer for ``staged`` (sorted unique
        ascending), reusing already-gathered rows where possible: rows
        present in the current buffer are copied device-side; only the
        genuinely new ids are gathered from the table (`refresh_rows` —
        the replica-sync cost rule: a local gather, NOT the per-shard
        collective the residual path pays)."""
        # pow2 bucket with V-pads: static shapes for the jit cache; the
        # pads gather zero rows no probe slot ever points at
        n = max(64, 1 << (int(staged.size) - 1).bit_length())
        ids_p = np.full(n, self.cfg.vocab, np.int32)
        ids_p[:staged.size] = staged
        old = self._staged_ids
        if old is not None and old.size:
            pos = np.searchsorted(old, staged)
            posc = np.minimum(pos, old.size - 1)
            reuse = old[posc] == staged
            new_ids = staged[~reuse]
        else:
            reuse = np.zeros(staged.size, bool)
            new_ids = staged
        if old is None or new_ids.size == staged.size:
            self._staging_rows = resolve(self.backend).refresh_rows(
                self.table, jnp.asarray(ids_p))
        else:
            # merge: one local gather of the new rows + one take over the
            # concatenated (old ++ new ++ zero) source — pads read the
            # zero row, reused rows copy device-side without re-gathering
            nn = max(8, 1 << max(0, int(new_ids.size) - 1).bit_length())
            nids_p = np.full(nn, self.cfg.vocab, np.int32)
            nids_p[:new_ids.size] = new_ids
            new_rows = resolve(self.backend).refresh_rows(
                self.table, jnp.asarray(nids_p))
            # offsets index the DEVICE concat: the old buffer's padded
            # row count, not the real staged-id count
            off = int(self._staging_rows.shape[0])
            src = np.full(n, off + nn, np.int32)            # pad: zero row
            src[:staged.size] = np.where(
                reuse, posc,
                off + np.cumsum(~reuse) - 1).astype(np.int32)
            zero = jnp.zeros((1, self.table.shape[1]),
                             self._staging_rows.dtype)
            self._staging_rows = jnp.take(
                jnp.concatenate([self._staging_rows, new_rows, zero]),
                jnp.asarray(src), axis=0)
        self._staged_ids = staged
        self._staged_ids_dev = jnp.asarray(ids_p)
        # the fold-in concat the staged dispatch reads: staged miss slots
        # address rows [C, C+S) of this buffer (one per-tenure concat in
        # place of per-round staging gathers/masks on the device)
        self._cache_ext = jnp.concatenate([self._cache_rows,
                                           self._staging_rows])
        self.telemetry.set("serve.staged_rows", int(staged.size))

    def _note_residual(self, res_ids: np.ndarray) -> None:
        """Accrual top-up (DESIGN.md §15): count this batch's residual
        misses against the tenure, and once an id has missed the staging
        buffer twice — proven recurring intent the replan snapshot never
        saw (it arrived after the snapshot) — fold it into the staging
        buffer so its later recurrences read locally instead of riding
        the per-shard collective again.  Merges are batched (>= 64 ids)
        to amortize the buffer rebuild; the same multiplicity >= 2
        break-even as the snapshot gate, applied online."""
        if res_ids.size == 0 or self._miss_counts is None:
            return
        self._miss_counts[res_ids] += 1
        crossed = res_ids[self._miss_counts[res_ids] == 2]
        if crossed.size:
            self._stage_pending.append(crossed)
        pending = sum(a.size for a in self._stage_pending)
        if pending < 64:
            return
        new_ids = np.concatenate(self._stage_pending)
        self._stage_pending = []
        base = (self._staged_ids if self._staged_ids is not None
                else np.empty(0, np.int64))
        self._install_staging(np.union1d(base, new_ids))
        self.telemetry.inc("serve.stage_topups")
        self.telemetry.inc("serve.stage_topup_rows", int(new_ids.size))

    def _refresh(self, res: ServeResult) -> None:
        # eager on purpose (emulated): the XLA CPU backend lowers the
        # jitted clip+gather+mask into a far slower fused gather than the
        # op-by-op eager dispatch (measured 35ms vs 2.3ms for a
        # (4096, 512) cache); the mesh backend's refresh is the grouped
        # all-gather shard_map, eager too
        self._cache_rows = resolve(self.backend).refresh_rows(
            self.table, self._cache_ids)
        if self._staged_ids is not None:
            # the staging buffer obeys the same staleness bound as the
            # replica cache: re-gathered on every refresh round, so an
            # out-of-band table update reaches staged rows within one
            self._staging_rows = resolve(self.backend).refresh_rows(
                self.table, self._staged_ids_dev)
            self._cache_ext = jnp.concatenate([self._cache_rows,
                                               self._staging_rows])
        res.refreshes += 1
        self.telemetry.inc("serve.refreshes")

    # ----------------------------------------------------------------- run
    def run(self, stream, rounds: int, *,
            warmup_backlog: Optional[int] = None, measure_from: int = 0,
            collect_outputs: bool = False) -> ServeResult:
        """Serve ``rounds`` scheduling rounds of ``stream`` arrivals.

        ``warmup_backlog`` rounds of arrivals are enqueued up front so the
        planner has a queued horizon before the first batch; the default
        ``replan_every + 2`` keeps the backlog (and with it the signaled
        horizon) deeper than the replan period, so every executed batch
        falls inside the window its miss bound was computed over — the
        serving latency/adaptivity trade: admitted-but-unscheduled work
        is exactly what intent planning can act on.  Stream rounds lead
        runtime rounds by ``warmup_backlog`` (a stream event at stream
        round R lands at runtime round ``R - warmup_backlog`` in
        `miss_trace`).  ``measure_from`` excludes warm-up/compile rounds
        from the latency/throughput accounting (the miss trace always
        covers every round).

        With double-buffered admission the loop is a one-slot pipeline:
        the round's batch is probed and *dispatched*, then the previous
        round's batch is blocked and bookkept — so the device executes
        batch t while the host enqueues, replans and probes batch t+1.
        Serial mode blocks each batch in its own round (identical
        results, no overlap)."""
        cfg = self.cfg
        if cfg.managed and not self._calibrated:
            self._calibrate_overlap()
        if warmup_backlog is None:
            warmup_backlog = self.replan_every + 2
        res = ServeResult()
        drift = False
        last_replan = -10 ** 9
        # N-deep pipeline: dispatched-but-unblocked batches, oldest first;
        # depth 0 drains immediately (the serial loop, bitwise)
        inflight: deque = deque()
        tr = self.tracer

        def finish(fl: _InFlight) -> None:
            with tr.span("serve.served", a=len(fl.served)):
                out = jax.block_until_ready(fl.out)
            now = time.perf_counter()
            if tr.enabled:
                # per-request lifecycle spans (enqueue -> served): t0 is
                # the enqueue stamp — perf_counter and perf_counter_ns
                # share an origin, so the seconds clock converts exactly;
                # the whole batch lands as one batched ring append
                t0s, rids, atts, tids = [], [], [], []
                for r in fl.served:
                    if tr.sampled(r.rid):
                        t0s.append(int(r.t_enqueue * 1e9))
                        rids.append(r.rid)
                        atts.append(r.attempts)
                        tids.append(1 + r.rid % 8)
                if rids:
                    tr.record_many("serve.request", t0s, tr.now_ns(),
                                   tids=tids, a=rids, b=atts)
            self.scheduler.note_served(fl.served, now)
            self.queue.served(fl.served)
            res.served += len(fl.served)
            if collect_outputs:
                out_h = np.asarray(out).reshape(fl.tokens_shape + (-1,))
                for i, req in enumerate(fl.reqs):
                    if fl.served_mask[i]:
                        res.outputs[req.rid] = out_h[i]

        for rnd in range(-warmup_backlog, 0):
            with tr.span("serve.enqueue", a=rnd):
                self.queue.enqueue_many(
                    stream.arrivals(rnd + warmup_backlog),
                    time.perf_counter())
        t0 = time.perf_counter()
        for rnd in range(rounds):
            rnd_t0 = time.perf_counter()
            res.rounds += 1
            with tr.span("serve.enqueue", a=rnd):
                self.queue.enqueue_many(
                    stream.arrivals(rnd + warmup_backlog),
                    time.perf_counter())
            if rnd == measure_from:
                # drain the pipeline before the measurement window opens
                while inflight:
                    finish(inflight.popleft())
                self.scheduler.latency.reset()
                self.scheduler.n_served = 0
                self._epoch_t0 = None
                t0 = time.perf_counter()

            if cfg.managed:
                self.planner.observe_round(self._lifetime_rounds + rnd)
                # replan on: cadence, drift feedback, a pending resize, or
                # window exhaustion (each round consumes one tick of the
                # plan's queued horizon — running past it would serve
                # batches the miss bound never saw, the serving
                # `should_replan` analogue); replan_every=0 disables both
                # scheduled triggers
                window_done = (self.plan is not None
                               and rnd - last_replan
                               >= max(1, self.plan.window[1] - 1))
                scheduled = self.replan_every > 0 and (
                    rnd - last_replan >= self.replan_every or window_done)
                if (self.plan is None or drift or self._pending_replan
                        or scheduled) and len(self.queue):
                    cause = ("initial" if self.plan is None else
                             "drift" if drift else
                             "resize" if self._pending_replan else
                             "window" if window_done else "cadence")
                    with tr.span("serve.plan", a=rnd):
                        self._replan(rnd, res, cause)
                    last_replan = rnd
                    drift = False
                elif self.plan is not None and self.refresh_every > 0 \
                        and rnd - last_replan > 0 \
                        and (rnd - last_replan) % self.refresh_every == 0:
                    self._refresh(res)

            batch = self.scheduler.admit(self.queue)
            if batch is None or (cfg.managed and self.plan is None):
                if batch is not None:        # nothing planned yet: put back
                    self.queue.requeue(batch.reqs)
                while inflight:              # idle round: drain the pipe
                    finish(inflight.popleft())
                continue

            if cfg.managed:
                # admission-time host probe: intent means the batch's miss
                # set is known before the batch runs — the device executes
                # pure data movement, and drift feedback (miss rate,
                # overflow flags) costs zero device readbacks, so every
                # serve/requeue/replan decision below happens pre-execution
                B, K = batch.tokens.shape
                route_cap = (min(self.plan.route_capacity,
                                 self.plan.miss_capacity)
                             if self._owner_shards else 0)
                with tr.span("serve.probe", a=rnd):
                    # memoized LUT probe — byte-identical to `probe_host`
                    # on this cache generation (tests/test_prefetch.py)
                    probe = self._probe_view.probe(
                        batch.tokens.reshape(B * K),
                        self.plan.miss_capacity,
                        owner_shards=self._owner_shards,
                        route_capacity=route_cap)
                staged_split = None
                if (self.pipeline_depth >= 1
                        and self._staged_ids is not None):
                    # fold the staging buffer into the cache side: staged
                    # miss tokens become extended-cache hits (slot C+pos
                    # into the per-tenure ``cache_rows ++ staging_rows``
                    # concat) and only the residual bucket rides the
                    # collective — the device path is then the PLAIN
                    # managed lookup over a smaller miss buffer, with no
                    # extra gathers or masks per round.  All host-side
                    # numpy on the compact (M,) slots plus three (T,)
                    # LUT reads; bookkeeping below (miss rate, overflow,
                    # zero-served) stays on the raw probe, so semantics
                    # are bitwise the sequential loop's (tested).
                    C = self._cache_rows.shape[0]
                    M = probe.buf_ids.shape[0]
                    nm = min(probe.n_miss, M)
                    ids = probe.buf_ids[:nm]
                    pos = np.searchsorted(self._staged_ids, ids)
                    posc = np.minimum(pos, self._staged_ids.size - 1)
                    stg = self._staged_ids[posc] == ids
                    n_res = int(nm - np.count_nonzero(stg))
                    r_cap = max(8, 1 << max(0, n_res - 1).bit_length())
                    res_ids = np.full(r_cap, cfg.vocab, np.int32)
                    res_ids[:n_res] = ids[~stg]
                    # per-slot LUTs: extended-cache slot for staged slots,
                    # residual rank otherwise (pads + trash -> the
                    # residual trash row r_cap)
                    ext_lut = np.zeros(M + 1, np.int32)
                    ext_lut[:nm] = np.where(stg, C + posc, 0)
                    res_lut = np.full(M + 1, r_cap, np.int32)
                    res_lut[:nm] = np.where(
                        stg, r_cap, np.cumsum(~stg) - 1).astype(np.int32)
                    stg_lut = np.zeros(M + 1, bool)
                    stg_lut[:nm] = stg
                    staged_tok = stg_lut[probe.buf_slot]
                    staged_split = (res_ids, staged_tok, ext_lut,
                                    res_lut, n_res)
                    n_hits = int(np.count_nonzero(stg))
                    self.telemetry.inc("serve.prefetch_hits", n_hits)
                    self.telemetry.inc("serve.prefetch_stale", n_res)
                    if self.attribution is not None:
                        self.attribution.note_prefetch(n_hits, n_res)
                    self._note_residual(ids[~stg])
                elif self.pipeline_depth >= 1 and self.plan is not None:
                    # no staging buffer this tenure: every miss is
                    # residual — accrue so the buffer can bootstrap the
                    # moment recurring intent shows up
                    nm = min(probe.n_miss, probe.buf_ids.shape[0])
                    self._note_residual(probe.buf_ids[:nm])
                with tr.span("serve.dispatch", a=rnd):
                    # one packed H2D transfer for the three (T,) index
                    # arrays
                    if staged_split is not None:
                        res_ids, staged_tok, ext_lut, res_lut, n_res = \
                            staged_split
                        idx = jnp.asarray(np.stack([
                            (probe.hit | staged_tok).astype(np.int32),
                            np.where(staged_tok,
                                     ext_lut[probe.buf_slot],
                                     probe.cache_slot),
                            res_lut[probe.buf_slot]]))
                        out = self._managed_fn(route_cap)(
                            self.table, self._cache_ext,
                            jnp.asarray(res_ids), idx[0], idx[1],
                            idx[2], jnp.int32(n_res))
                    else:
                        idx = jnp.asarray(np.stack([
                            probe.hit.astype(np.int32), probe.cache_slot,
                            probe.buf_slot]))
                        out = self._managed_fn(route_cap)(
                            self.table, self._cache_rows,
                            jnp.asarray(probe.buf_ids), idx[0], idx[1],
                            idx[2], jnp.int32(probe.n_miss))
                hit_h = probe.hit.reshape(B, K)
                over_h = probe.overflow.reshape(B, K)
                nv = len(batch.reqs)
                miss_rate = float(1.0 - hit_h[:nv].mean())
                res.miss_trace.append((rnd, miss_rate))
                self.telemetry.set("serve.miss_rate", miss_rate)
                if self.attribution is not None:
                    self.attribution.note_batch(batch.tokens[:nv],
                                                hit_h[:nv])
                row_over = over_h[:nv].any(axis=1)
                served_mask = ~row_over
                served = [r for r, o in zip(batch.reqs, row_over) if not o]
                failed = [r for r, o in zip(batch.reqs, row_over) if o]
                if failed:
                    res.overflow_batches += 1
                    res.requeues += len(failed)
                    self.telemetry.inc("serve.overflow_batches")
                    self.telemetry.inc("serve.requeues", len(failed))
                    for req in failed:
                        self.telemetry.inc("serve.requeued",
                                           tenant=req.tenant)
                        if tr.enabled and tr.sampled(req.rid):
                            tr.point("serve.requeue",
                                     tid=1 + req.rid % 8, a=req.rid,
                                     b=req.attempts + 1)
                        if req.attempts + 1 > cfg.max_attempts:
                            raise RuntimeError(
                                f"request {req.rid} overflowed the miss "
                                f"buffer {req.attempts + 1} times — the "
                                "planner never caught up with the drift")
                    self.queue.requeue(failed)
                    drift = True            # hard drift signal
                elif miss_rate > cfg.drift_factor * max(
                        self.plan.predicted_miss_rate, 1e-3):
                    drift = True            # soft drift signal
                # invariant counter: a served row never contains a token
                # that landed on the trash slot.  Recomputed from the
                # probe's slot arrays — NOT from the row_over mask the
                # served/failed split was derived from — so a future bug
                # in that split shows up as zero_served > 0 instead of
                # passing vacuously (silently served zeros).
                trash_slot = probe.buf_ids.shape[0]
                zeroed = ((probe.buf_slot == trash_slot)
                          & ~probe.hit).reshape(B, K)
                n_zeroed = int(
                    np.count_nonzero(zeroed[:nv].any(axis=1) & served_mask))
                res.zero_served += n_zeroed
                if n_zeroed:
                    self.telemetry.inc("serve.zero_served", n_zeroed)
            else:
                out = self._plain_fn(self.table, jnp.asarray(batch.tokens))
                served_mask = np.ones(len(batch.reqs), bool)
                served = batch.reqs

            # N-deep pipeline: older batches are blocked only AFTER this
            # round's host work (probe + staging split + dispatch above)
            # — while that happened, the device was executing them.  At
            # depth 0 the batch drains immediately (the serial loop)
            inflight.append(_InFlight(
                out, batch.reqs, served, served_mask, batch.tokens.shape))
            while len(inflight) > self.pipeline_depth:
                finish(inflight.popleft())
            self.telemetry.observe(
                "serve.round_ms", (time.perf_counter() - rnd_t0) * 1e3)
            if tr.enabled:
                # the executed round's envelope (idle rounds have no
                # batch and no envelope — the phase spans still show);
                # rnd_t0 converts exactly: shared perf_counter origin
                tr.record("serve.round", int(rnd_t0 * 1e9), tr.now_ns(),
                          a=rnd)

        while inflight:                      # drain the pipeline
            finish(inflight.popleft())
        self._lifetime_rounds += rounds
        res.wall_s = time.perf_counter() - t0
        res.throughput_rps = self.scheduler.n_served / max(res.wall_s, 1e-9)
        lat = self.scheduler.latency
        res.p50_ms = lat.percentile(50) * 1e3
        res.p99_ms = lat.percentile(99) * 1e3
        res.mean_ms = lat.mean() * 1e3
        res.knobs = self.current_knobs()
        self.telemetry.set("serve.throughput_rps", res.throughput_rps)
        if cfg.summary and not self._summary_printed:
            print(self.summary())
            if tr.enabled:
                print(self.report())
            self._summary_printed = True
        return res
