"""Online serving runtime over the intent-managed embedding (§9).

The loop that closes the paper's adaptation story *online*: enqueued
requests have already signaled intent for the rows they will touch
(`RequestQueue.enqueue` -> `StreamingIntentBuffer`), the planner
continuously re-plans the replica cache from that streaming intent
(`IntentPlanner.replan_from_queue` over the queued horizon), and batches
execute through the read-only serving data path — jnp or Pallas-backed
(`ServeConfig.kernel`), over the emulated or the mesh-real collective
backend (`ServeConfig.collective`, DESIGN.md §10), no VJP, no optimizer.

Re-planning is feedback-driven, zero-tuning in spirit: a plan carries its
own predicted miss rate (exact over the horizon it was built from), and
the runtime replans early the moment observed misses say the workload
drifted away from the plan —

    replan  iff  rounds_since_plan >= replan_every        (cadence floor)
             or  batch overflowed its miss buffer          (hard signal)
             or  miss_rate > drift_factor * predicted      (soft signal)

Because the whole index stage runs on the host at admission
(`probe_host`), every drift signal is known *before* the batch executes —
which is what makes the admission loop double-bufferable
(``ServeConfig.double_buffer``): the runtime dispatches batch t to the
device and, while it executes, enqueues/replans/probes batch t+1 on the
host; batch t is only blocked on one round later.  Semantics are
identical to the serial loop (each batch's plan/probe/cache snapshot is
captured at dispatch), only the wall-clock overlap changes
(`BENCH_serve.json` records the measured ratio; see the config field for
why it defaults off on a CPU-only host).

Overflowed requests are NEVER served zeros: their rows come back flagged,
the requests re-enter the queue front, and the overflow itself is the
drift signal that triggers the replan that will fit them.  The replica
cache is refreshed (re-gathered from the table) on every replan round and
every ``refresh_every`` rounds in between, so an out-of-band table update
(e.g. a trainer checkpoint swap) reaches replicas within one refresh
round — the serving analogue of the training loop's bounded staleness.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import StreamingIntentBuffer
from repro.pm.collectives import resolve
from repro.pm.embedding import (plain_serve_lookup, planned_serve_lookup,
                                probe_host)
from repro.pm.planner import IntentPlanner, PlacementPlan
from repro.serve.requests import RequestQueue
from repro.serve.scheduler import MicroBatchScheduler


@dataclass
class ServeConfig:
    vocab: int
    batch_requests: int = 32
    keys_per_request: int = 16
    cache_capacity: int = 512
    managed: bool = True         # False: plain vocab-parallel baseline
    n_shards: int = 1            # emulated vocab shards (collective cost)
    collective: str = "emulated"  # "emulated" | "mesh": collective backend
    #   for the lookup data path ("mesh" shards the table over a real
    #   device mesh and runs the shard_map psum — n_shards is then the
    #   mesh size, not a cost model)
    model_shards: int = 0        # mesh size for collective="mesh"
    #   (0 = every local device)
    kernel: bool = False         # Pallas-backed lookup data path
    double_buffer: bool = False  # overlap admission with execution: probe
    #   batch t+1 on the host while the device executes batch t (the
    #   probe-at-admission split makes this free of device readbacks).
    #   Semantics are identical either way (tested); the overlap pays
    #   when execution is off-host (TPU) — on this repo's 2-core CPU
    #   container the "device" shares the host cores, so the pipeline
    #   buys contention instead of parallelism (the same reason
    #   ``kernel`` defaults off on CPU); BENCH_serve.json's ``overlap``
    #   entry records the measured ratio either way
    replan_every: int = 8        # cadence floor (rounds between replans);
    #   0 = feedback-only mode: replan solely on drift signals (overflow /
    #   miss-rate), never on cadence or window exhaustion
    refresh_every: int = 0       # extra replica re-gathers between replans
    #   (0: replan rounds only — the right default for a read-only table;
    #   set >0 when a trainer swaps the table out-of-band)
    drift_factor: float = 2.0    # soft replan: observed > factor*predicted
    max_attempts: int = 8        # loud failure, never a silent zero row
    seed: int = 0


@dataclass
class ServeResult:
    served: int = 0
    rounds: int = 0
    replans: int = 0
    refreshes: int = 0
    requeues: int = 0            # requests re-queued after overflow
    overflow_batches: int = 0    # batches whose unique misses exceeded M
    zero_served: int = 0         # MUST stay 0: served rows with overflow
    throughput_rps: float = 0.0
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    mean_ms: float = 0.0
    wall_s: float = 0.0
    miss_trace: List[Tuple[int, float]] = field(default_factory=list)
    #   (round, token-level miss rate) per executed batch
    replan_rounds: List[int] = field(default_factory=list)
    plan_miss_capacities: List[int] = field(default_factory=list)
    outputs: Dict[int, np.ndarray] = field(default_factory=dict)
    #   rid -> (K, D) served rows (only when run(collect_outputs=True))

    def steady_miss_rate(self, lo: int, hi: int) -> Optional[float]:
        """Mean batch miss rate over rounds [lo, hi); None when no batch
        executed in the window (callers must not treat an unmeasured
        window as a perfect one)."""
        vals = [m for r, m in self.miss_trace if lo <= r < hi]
        return float(np.mean(vals)) if vals else None


@dataclass
class _InFlight:
    """A dispatched-but-not-yet-blocked batch (double-buffered admission):
    everything bookkeeping needs was decided at dispatch time from the
    host-side probe — blocking only realizes the rows and the clock."""

    out: jnp.ndarray             # device future of the (T, D) rows
    reqs: list                   # the batch's real requests
    served: list                 # probe-decided: requests to serve
    served_mask: np.ndarray      # per-req bool aligned with ``reqs``
    tokens_shape: tuple


class ServingRuntime:
    """Queue -> intent -> plan -> execute, one micro-batch per round."""

    def __init__(self, table, cfg: ServeConfig):
        self.cfg = cfg
        self.table = jnp.asarray(table)
        assert self.table.shape[0] == cfg.vocab
        from repro.pm.collectives import make_backend
        self.backend = make_backend(cfg.collective, cfg.model_shards)
        if self.backend is not None:
            self.table = self.backend.place_table(self.table)
        self.intent = StreamingIntentBuffer() if cfg.managed else None
        self.queue = RequestQueue(self.intent)
        self.scheduler = MicroBatchScheduler(cfg.batch_requests,
                                             cfg.keys_per_request)
        # mesh collective: admission is additionally bounded PER OWNER
        # SHARD — the planner publishes `route_capacity` (the exact
        # per-owner unique-miss bound over the queued horizon) and the
        # device lookup routes per-owner blocks of exactly that size
        # (DESIGN.md §12), so what admission admits is what the routed
        # collective can carry.  (The per-shard bound lives on owner
        # shards, not on the per-request "nodes" `per_node_bound` counts —
        # request slots hold ~keys_per_request keys each, and a bound that
        # small would starve the shared compact buffer.)
        self._owner_shards = (self.backend.n_shards
                              if self.backend is not None
                              and self.backend.mesh_real else 0)
        self.planner = IntentPlanner(
            cfg.vocab, cfg.cache_capacity, n_shards=cfg.batch_requests,
            plan_every=cfg.replan_every,
            owner_shards=self._owner_shards) if cfg.managed else None
        self.plan: Optional[PlacementPlan] = None
        self._cache_ids = None           # device copy (refresh input)
        self._cache_ids_np = None        # host copy (admission-time probe)
        self._cache_rows = None
        self._plain_fn = jax.jit(lambda t, toks: plain_serve_lookup(
            t, toks, n_shards=cfg.n_shards, backend=self.backend))
        # one jitted data-path fn; XLA re-specializes per miss bucket
        # (buf_ids shape) and — on the mesh — per route-capacity bucket:
        # both ride the planner's power-of-two ladders, so a handful of
        # executables.  ``nm`` (the host probe's unique-miss count) rides
        # along as a device scalar; the non-mesh path ignores it.
        self._managed_fns: Dict[int, callable] = {}
        self.overlap_ratio: Optional[float] = None
        if cfg.managed:
            self._log_overlap_estimate()

    def _managed_fn(self, route_cap: int = 0):
        """Jitted serving data path, specialized per routed block size
        (0 on non-mesh backends — the router is off without ``n_miss``
        anyway, see `planned_serve_lookup`)."""
        cfg = self.cfg
        fn = self._managed_fns.get(route_cap)
        if fn is None:
            fn = jax.jit(
                lambda t, cr, bi, h, cs, bs, nm: planned_serve_lookup(
                    t, cr, bi, h, cs, bs, n_shards=cfg.n_shards,
                    kernel=cfg.kernel, backend=self.backend,
                    n_miss=(nm if self._owner_shards else None),
                    route_cap=route_cap))
            self._managed_fns[route_cap] = fn
        return fn

    def _log_overlap_estimate(self) -> None:
        """One-shot startup calibration for ``double_buffer``: time one
        representative host-side admission probe against one device
        dispatch on this host, and log the wall-clock ratio the one-slot
        pipeline could buy — ``(host + device) / max(host, device)``,
        ~2x when the two sides are balanced, ~1x when either dominates
        (or when the "device" shares the host cores, the reason the flag
        defaults off here).  Measurement and log only; the flag stays
        whatever the config says — this exists so operators can see from
        the startup line whether flipping it on would pay."""
        cfg = self.cfg
        try:
            T = cfg.batch_requests * cfg.keys_per_request
            rng = np.random.default_rng(0)
            tok = rng.integers(0, cfg.vocab, size=T).astype(np.int32)
            cache_ids = np.arange(min(cfg.cache_capacity, cfg.vocab),
                                  dtype=np.int32)
            M = max(1, min(64, T))   # the planner ladder's floor bucket
            cache_rows = resolve(self.backend).refresh_rows(
                self.table, jnp.asarray(cache_ids))

            def host():
                return probe_host(cache_ids, tok, M)

            def device(p):
                idx = jnp.asarray(np.stack([p.hit.astype(np.int32),
                                            p.cache_slot, p.buf_slot]))
                jax.block_until_ready(self._managed_fn()(
                    self.table, cache_rows, jnp.asarray(p.buf_ids),
                    idx[0], idx[1], idx[2], jnp.int32(p.n_miss)))

            p = host()
            device(p)                # warmup + compile
            t0 = time.perf_counter()
            host()
            th = time.perf_counter() - t0
            t0 = time.perf_counter()
            device(p)
            td = time.perf_counter() - t0
            self.overlap_ratio = (th + td) / max(th, td, 1e-9)
            print(f"[serve] double_buffer="
                  f"{'on' if cfg.double_buffer else 'off'}: measured "
                  f"admission/execute overlap ~{self.overlap_ratio:.2f}x "
                  f"(host probe {th * 1e3:.2f} ms, device dispatch "
                  f"{td * 1e3:.2f} ms per batch)")
        except Exception as e:       # pragma: no cover — never block startup
            print(f"[serve] overlap calibration skipped: {e}")

    # ---------------------------------------------------------------- plan
    def _replan(self, rnd: int, res: ServeResult) -> None:
        keys, slots, ticks = self.intent.snapshot(
            self.queue.order_ids(), self.cfg.batch_requests)
        if len(keys) == 0:
            return
        self.plan = self.planner.replan_from_queue(keys, slots, ticks)
        self._cache_ids_np = self.plan.cache_ids
        self._cache_ids = jnp.asarray(self.plan.cache_ids)
        self._refresh(res)
        res.replans += 1
        res.replan_rounds.append(rnd)
        res.plan_miss_capacities.append(self.plan.miss_capacity)

    def _refresh(self, res: ServeResult) -> None:
        # eager on purpose (emulated): the XLA CPU backend lowers the
        # jitted clip+gather+mask into a far slower fused gather than the
        # op-by-op eager dispatch (measured 35ms vs 2.3ms for a
        # (4096, 512) cache); the mesh backend's refresh is the grouped
        # all-gather shard_map, eager too
        self._cache_rows = resolve(self.backend).refresh_rows(
            self.table, self._cache_ids)
        res.refreshes += 1

    # ----------------------------------------------------------------- run
    def run(self, stream, rounds: int, *,
            warmup_backlog: Optional[int] = None, measure_from: int = 0,
            collect_outputs: bool = False) -> ServeResult:
        """Serve ``rounds`` scheduling rounds of ``stream`` arrivals.

        ``warmup_backlog`` rounds of arrivals are enqueued up front so the
        planner has a queued horizon before the first batch; the default
        ``replan_every + 2`` keeps the backlog (and with it the signaled
        horizon) deeper than the replan period, so every executed batch
        falls inside the window its miss bound was computed over — the
        serving latency/adaptivity trade: admitted-but-unscheduled work
        is exactly what intent planning can act on.  Stream rounds lead
        runtime rounds by ``warmup_backlog`` (a stream event at stream
        round R lands at runtime round ``R - warmup_backlog`` in
        `miss_trace`).  ``measure_from`` excludes warm-up/compile rounds
        from the latency/throughput accounting (the miss trace always
        covers every round).

        With ``cfg.double_buffer`` the loop is a one-slot pipeline: the
        round's batch is probed and *dispatched*, then the previous
        round's batch is blocked and bookkept — so the device executes
        batch t while the host enqueues, replans and probes batch t+1.
        ``double_buffer=False`` blocks each batch in its own round (the
        serial reference; identical results, no overlap)."""
        cfg = self.cfg
        if warmup_backlog is None:
            warmup_backlog = cfg.replan_every + 2
        res = ServeResult()
        drift = False
        last_replan = -10 ** 9
        inflight: Optional[_InFlight] = None

        def finish(fl: _InFlight) -> None:
            out = jax.block_until_ready(fl.out)
            now = time.perf_counter()
            self.scheduler.note_served(fl.served, now)
            self.queue.served(fl.served)
            res.served += len(fl.served)
            if collect_outputs:
                out_h = np.asarray(out).reshape(fl.tokens_shape + (-1,))
                for i, req in enumerate(fl.reqs):
                    if fl.served_mask[i]:
                        res.outputs[req.rid] = out_h[i]

        for rnd in range(-warmup_backlog, 0):
            self.queue.enqueue_many(stream.arrivals(rnd + warmup_backlog),
                                    time.perf_counter())
        t0 = time.perf_counter()
        for rnd in range(rounds):
            res.rounds += 1
            self.queue.enqueue_many(stream.arrivals(rnd + warmup_backlog),
                                    time.perf_counter())
            if rnd == measure_from:
                # drain the pipeline before the measurement window opens
                if inflight is not None:
                    finish(inflight)
                    inflight = None
                self.scheduler.latency.reset()
                self.scheduler.n_served = 0
                t0 = time.perf_counter()

            if cfg.managed:
                self.planner.observe_round(rnd)
                # replan on: cadence, drift feedback, or window exhaustion
                # (each round consumes one tick of the plan's queued
                # horizon — running past it would serve batches the miss
                # bound never saw, the serving `should_replan` analogue);
                # replan_every=0 disables both scheduled triggers
                scheduled = cfg.replan_every > 0 and (
                    rnd - last_replan >= cfg.replan_every
                    or (self.plan is not None and rnd - last_replan
                        >= max(1, self.plan.window[1] - 1)))
                if (self.plan is None or drift or scheduled) \
                        and len(self.queue):
                    self._replan(rnd, res)
                    last_replan = rnd
                    drift = False
                elif self.plan is not None and cfg.refresh_every > 0 \
                        and rnd - last_replan > 0 \
                        and (rnd - last_replan) % cfg.refresh_every == 0:
                    self._refresh(res)

            batch = self.scheduler.admit(self.queue)
            if batch is None or (cfg.managed and self.plan is None):
                if batch is not None:        # nothing planned yet: put back
                    self.queue.requeue(batch.reqs)
                if inflight is not None:     # idle round: drain the slot
                    finish(inflight)
                    inflight = None
                continue

            if cfg.managed:
                # admission-time host probe: intent means the batch's miss
                # set is known before the batch runs — the device executes
                # pure data movement, and drift feedback (miss rate,
                # overflow flags) costs zero device readbacks, so every
                # serve/requeue/replan decision below happens pre-execution
                B, K = batch.tokens.shape
                route_cap = (min(self.plan.route_capacity,
                                 self.plan.miss_capacity)
                             if self._owner_shards else 0)
                probe = probe_host(self._cache_ids_np,
                                   batch.tokens.reshape(B * K),
                                   self.plan.miss_capacity,
                                   owner_shards=self._owner_shards,
                                   route_capacity=route_cap,
                                   vocab=cfg.vocab)
                # one packed H2D transfer for the three (T,) index arrays
                idx = jnp.asarray(np.stack([
                    probe.hit.astype(np.int32), probe.cache_slot,
                    probe.buf_slot]))
                out = self._managed_fn(route_cap)(
                    self.table, self._cache_rows,
                    jnp.asarray(probe.buf_ids), idx[0], idx[1], idx[2],
                    jnp.int32(probe.n_miss))
                hit_h = probe.hit.reshape(B, K)
                over_h = probe.overflow.reshape(B, K)
                nv = len(batch.reqs)
                miss_rate = float(1.0 - hit_h[:nv].mean())
                res.miss_trace.append((rnd, miss_rate))
                row_over = over_h[:nv].any(axis=1)
                served_mask = ~row_over
                served = [r for r, o in zip(batch.reqs, row_over) if not o]
                failed = [r for r, o in zip(batch.reqs, row_over) if o]
                if failed:
                    res.overflow_batches += 1
                    res.requeues += len(failed)
                    for req in failed:
                        if req.attempts + 1 > cfg.max_attempts:
                            raise RuntimeError(
                                f"request {req.rid} overflowed the miss "
                                f"buffer {req.attempts + 1} times — the "
                                "planner never caught up with the drift")
                    self.queue.requeue(failed)
                    drift = True            # hard drift signal
                elif miss_rate > cfg.drift_factor * max(
                        self.plan.predicted_miss_rate, 1e-3):
                    drift = True            # soft drift signal
                # invariant counter: a served row never contains a token
                # that landed on the trash slot.  Recomputed from the
                # probe's slot arrays — NOT from the row_over mask the
                # served/failed split was derived from — so a future bug
                # in that split shows up as zero_served > 0 instead of
                # passing vacuously (silently served zeros).
                trash_slot = probe.buf_ids.shape[0]
                zeroed = ((probe.buf_slot == trash_slot)
                          & ~probe.hit).reshape(B, K)
                res.zero_served += int(
                    np.count_nonzero(zeroed[:nv].any(axis=1) & served_mask))
            else:
                out = self._plain_fn(self.table, jnp.asarray(batch.tokens))
                served_mask = np.ones(len(batch.reqs), bool)
                served = batch.reqs

            # one-slot pipeline: the previous batch is blocked only AFTER
            # this round's host work (probe + dispatch above) — while that
            # happened, the device was executing it
            prev, inflight = inflight, _InFlight(
                out, batch.reqs, served, served_mask, batch.tokens.shape)
            if prev is not None:
                finish(prev)
            if not cfg.double_buffer:
                finish(inflight)
                inflight = None

        if inflight is not None:             # drain the pipeline
            finish(inflight)
        res.wall_s = time.perf_counter() - t0
        res.throughput_rps = self.scheduler.n_served / max(res.wall_s, 1e-9)
        lat = self.scheduler.latency
        res.p50_ms = lat.percentile(50) * 1e3
        res.p99_ms = lat.percentile(99) * 1e3
        res.mean_ms = lat.mean() * 1e3
        return res
