"""Micro-batching admission scheduler for the serving runtime (§9).

Groups queued requests into fixed-shape ``(batch_requests,
keys_per_request)`` token batches (static shapes — one compiled
executable per miss-capacity bucket, same discipline as the training
loop), asks the planner for a miss buffer sized by `intent_miss_bound`
over the *queued* horizon, and accounts per-request latency (enqueue ->
served) and throughput.

Host-side and numpy-only on purpose: the scheduler never touches device
state.  `LatencyRecorder` lives in `repro.core.api` (next to `Metrics`)
so `benchmarks.common` can reuse it without pulling JAX into the
simulator benchmarks; it is re-exported here for serving callers.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.core.api import LatencyRecorder  # noqa: F401  (re-export)
from repro.obs.telemetry import Telemetry

from .requests import RequestQueue, ServeRequest


@dataclass
class MicroBatch:
    """One admitted fixed-shape batch (rows past ``len(reqs)`` are
    padding clones)."""

    reqs: List[ServeRequest]     # the real requests (<= batch_requests)
    tokens: np.ndarray           # (batch_requests, keys_per_request) int32


class MicroBatchScheduler:
    """Admission control: fixed-shape micro-batches off the queue.

    Row padding repeats each request's own first key out to
    ``keys_per_request`` and clones the first admitted request's row for
    empty request slots — pad tokens therefore only ever name keys already
    counted in the queued-intent horizon, so they cannot push the batch
    past the planner's exact miss bound."""

    def __init__(self, batch_requests: int, keys_per_request: int,
                 telemetry: Optional[Telemetry] = None):
        self.B = batch_requests
        self.K = keys_per_request
        self.latency = LatencyRecorder()
        self.telemetry = telemetry
        self.n_served = 0
        self.n_batches = 0

    def admit(self, queue: RequestQueue) -> Optional[MicroBatch]:
        reqs = queue.pop_batch(self.B)
        if not reqs:
            return None
        tokens = np.empty((self.B, self.K), np.int32)
        for i, req in enumerate(reqs):
            k = len(req.keys)
            if k > self.K:
                # loud, never silent: truncating would serve a partial
                # request while expiring its full intent (the runtime's
                # never-silently-wrong contract)
                raise ValueError(
                    f"request {req.rid} has {k} keys > keys_per_request="
                    f"{self.K}; split it upstream")
            tokens[i, :k] = req.keys
            tokens[i, k:] = req.keys[0]
        tokens[len(reqs):] = tokens[0]        # clone row, never a new key
        self.n_batches += 1
        return MicroBatch(reqs, tokens)

    def note_served(self, reqs: Sequence[ServeRequest],
                    now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        bus = self.telemetry
        for req in reqs:
            dt = now - req.t_enqueue
            self.latency.record(dt)
            if bus is not None:
                # per-tenant accounting (labels are distinct bus keys;
                # no admission policy reads these — accounting only)
                bus.inc("serve.requests", tenant=req.tenant)
                bus.observe("serve.latency", dt * 1e3, tenant=req.tenant)
        self.n_served += len(reqs)
