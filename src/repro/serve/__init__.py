"""Online serving runtime: intent-signaled request scheduling over the
managed embedding (DESIGN.md §9).

    queue -> intent -> plan -> execute

Requests signal intent for the rows they will touch at *enqueue* time;
the planner re-plans the replica cache continuously from the queued
horizon; batches execute through the read-only managed lookup; miss-rate
and overflow feedback is the drift signal that triggers early replans.
"""

from repro.serve.requests import (DriftingZipfStream, ReplayStream,
                                  RequestQueue, ServeRequest)
from repro.serve.runtime import ServeConfig, ServeResult, ServingRuntime
from repro.serve.scheduler import (LatencyRecorder, MicroBatch,
                                   MicroBatchScheduler)

__all__ = [
    "DriftingZipfStream", "ReplayStream", "RequestQueue", "ServeRequest",
    "ServeConfig", "ServeResult", "ServingRuntime",
    "LatencyRecorder", "MicroBatch", "MicroBatchScheduler",
]
