"""Functional optimizers.  AdaGrad is the paper's optimizer for all five
tasks (§C); Adam is provided for the LM examples.  Both are pytree-generic;
state shards exactly like the parameters (the dry-run relies on this).

The *sparse* AdaGrad row path (embedding tables) goes through the fused
Pallas kernel (`repro.kernels.ops.adagrad_row_update`) in the e2e example;
these dense versions are the pjit'd default used by `train_step`.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdaGradState(NamedTuple):
    accum: Any


def adagrad_init(params) -> AdaGradState:
    return AdaGradState(
        accum=jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))


def adagrad_update(grads, state: AdaGradState, params, *,
                   lr: float = 0.1, eps: float = 1e-8
                   ) -> Tuple[Any, AdaGradState]:
    def upd(p, g, a):
        g32 = g.astype(jnp.float32)
        a_new = a + g32 * g32
        p_new = p.astype(jnp.float32) - lr * g32 / (jnp.sqrt(a_new) + eps)
        return p_new.astype(p.dtype), a_new

    out = jax.tree_util.tree_map(upd, params, grads, state.accum)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_accum = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdaGradState(new_accum)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adam_init(params) -> AdamState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamState(mu=jax.tree_util.tree_map(z, params),
                     nu=jax.tree_util.tree_map(z, params),
                     count=jnp.zeros((), jnp.int32))


def adam_update(grads, state: AdamState, params, *, lr: float = 3e-4,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
                ) -> Tuple[Any, AdamState]:
    c = state.count + 1
    cf = c.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        m_hat = m_new / (1 - b1 ** cf)
        v_hat = v_new / (1 - b2 ** cf)
        p_new = p.astype(jnp.float32) - lr * m_hat / (jnp.sqrt(v_hat) + eps)
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), AdamState(mu=pick(1), nu=pick(2), count=c)
