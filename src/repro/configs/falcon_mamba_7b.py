"""falcon-mamba-7b [arXiv:2410.05355] — pure Mamba-1 SSM, attention-free."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2, ssm_version=1,
    source="arXiv:2410.05355 (Falcon Mamba)",
)
SMOKE = CONFIG.reduced()
