"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936,
    n_experts=128, top_k=8, moe_d_ff=768,
    activation="swiglu", rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)
SMOKE = CONFIG.reduced()
