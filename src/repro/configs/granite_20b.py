"""granite-20b code model [arXiv:2405.04324] — llama-arch, MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    activation="swiglu",
    source="arXiv:2405.04324 (Granite Code Models)",
)
SMOKE = CONFIG.reduced(n_kv_heads=1)
