"""whisper-medium [arXiv:2212.04356] — enc-dec audio transformer backbone.
Conv/mel frontend is a stub: inputs are precomputed frame embeddings."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,
    activation="gelu", norm="layernorm", tie_embeddings=True,
    encoder=EncoderConfig(n_layers=24, n_heads=16, n_frames=1500),
    source="arXiv:2212.04356 (Whisper)",
)
SMOKE = CONFIG.reduced()
