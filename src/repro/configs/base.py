"""Model configuration system.

One `ModelConfig` per architecture; every assigned architecture has its own
module in `repro/configs/` exporting ``CONFIG`` (full size, dry-run only) and
``SMOKE`` (reduced: <=2 layers, d_model<=512, <=4 experts; runs on CPU).
Input shapes are global; see `repro.configs.shapes`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for encoder-decoder models (whisper).  The modality
    frontend (mel+conv) is a stub: inputs are precomputed frame embeddings."""

    n_layers: int
    n_heads: int
    n_frames: int = 1500          # whisper-medium: 30 s of audio


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                  # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0             # expert hidden dim (d_ff of one expert)
    capacity_factor: float = 1.25
    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1          # 1 = mamba1 (falcon-mamba), 2 = mamba2
    ssm_head_dim: int = 64        # mamba2 head dim
    # --- hybrid (zamba2) ---
    attn_every: int = 0           # shared attention block every k ssm blocks
    # --- attention flavor ---
    sliding_window: int = 0       # 0 = full attention
    rope_theta: float = 10_000.0
    mrope: bool = False           # Qwen2-VL multimodal rotary (3 sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w half-dims
    # --- MLP flavor ---
    activation: str = "swiglu"    # swiglu | gelu | relu2
    # --- encoder-decoder ---
    encoder: Optional[EncoderConfig] = None
    # --- vlm ---
    n_img_tokens: int = 0         # patch-embedding stub length (per batch)
    # --- misc ---
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    source: str = ""              # citation for the config

    def __post_init__(self):
        if self.n_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads and self.n_kv_heads:
            assert self.n_heads % self.n_kv_heads == 0, \
                f"{self.arch_id}: GQA needs n_heads % n_kv_heads == 0"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context handling: SSM state, hybrid, or a sliding
        window bound the per-token cost; pure full attention does not."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window > 0)

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family."""
        small = dict(
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=(min(self.n_kv_heads, 2) if self.n_kv_heads else 0),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=32 if self.n_heads else 0,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.family in ("ssm", "hybrid") else 64,
            attn_every=2 if self.attn_every else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
            mrope_sections=(8, 4, 4) if self.mrope else (16, 24, 24),
            n_img_tokens=min(self.n_img_tokens, 16),
            encoder=EncoderConfig(n_layers=2, n_heads=4, n_frames=32)
            if self.encoder else None,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D model FLOPs)."""
        D, V, L = self.d_model, self.vocab_size, self.n_layers
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += D * V
        hd = self.head_dim
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            atn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd \
                + self.n_heads * hd * D
            per_layer += atn + 2 * D
            if self.n_experts:
                ff = self.n_experts * 3 * D * self.moe_d_ff \
                    + D * self.n_experts
            else:
                mult = 3 if self.activation == "swiglu" else 2
                ff = mult * D * self.d_ff
            per_layer += ff
        elif self.family == "ssm":
            di, N = self.d_inner, self.ssm_state
            per_layer += D * 2 * di + di * self.ssm_conv \
                + di * (self.dt_rank + 2 * N) + self.dt_rank * di \
                + di * N + di + di * D + D
        elif self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            per_layer += D * 2 * di + di * self.ssm_conv + 2 * di \
                + di * N + di + di * D + D  # mamba2-ish block
        n += L * per_layer
        if self.family == "hybrid" and self.attn_every:
            hd_ = self.head_dim
            shared = (D * self.n_heads * hd_ + 2 * D * self.n_kv_heads * hd_
                      + self.n_heads * hd_ * D + 3 * D * self.d_ff + 2 * D)
            n += shared  # one shared block, reused
        if self.encoder is not None:
            e = self.encoder
            enc_layer = 4 * D * D + 3 * D * self.d_ff + 2 * D
            n += e.n_layers * enc_layer
            # decoder cross-attention
            n += L * (4 * D * D + D)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        D, L = self.d_model, self.n_layers
        all_experts = L * self.n_experts * 3 * D * self.moe_d_ff
        active = L * self.top_k * 3 * D * self.moe_d_ff
        return self.param_count() - all_experts + active
