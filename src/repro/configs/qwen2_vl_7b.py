"""qwen2-vl-7b [arXiv:2409.12191] — VLM backbone with M-RoPE.
ViT frontend is a stub: inputs include precomputed patch embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    activation="swiglu", mrope=True, mrope_sections=(16, 24, 24),
    n_img_tokens=256, rope_theta=1_000_000.0,
    source="arXiv:2409.12191 (Qwen2-VL)",
)
SMOKE = CONFIG.reduced(n_heads=4, n_kv_heads=2, mrope_sections=(8, 4, 4))
