"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M] — small llama-arch, GQA kv=3."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab_size=49152, tie_embeddings=True,
    activation="swiglu",
    source="hf:HuggingFaceTB/SmolLM-135M",
)
SMOKE = CONFIG.reduced(n_heads=3, n_kv_heads=3)
