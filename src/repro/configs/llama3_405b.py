"""llama3-405b [arXiv:2407.21783] — dense GQA, 128k vocab."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    activation="swiglu", rope_theta=500_000.0,
    source="arXiv:2407.21783 (The Llama 3 Herd of Models)",
)
SMOKE = CONFIG.reduced()
