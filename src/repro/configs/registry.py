"""Architecture registry: ``--arch <id>`` resolution."""
from importlib import import_module

_MODULES = {
    "whisper-medium": "repro.configs.whisper_medium",
    "granite-20b": "repro.configs.granite_20b",
    "smollm-135m": "repro.configs.smollm_135m",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "llama3-405b": "repro.configs.llama3_405b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = import_module(_MODULES[arch_id])
    return mod.SMOKE if smoke else mod.CONFIG
