"""mixtral-8x22b [arXiv:2401.04088] — MoE 8 experts top-2, SWA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768,
    n_experts=8, top_k=2, moe_d_ff=16384,
    sliding_window=4096, activation="swiglu",
    source="arXiv:2401.04088 (Mixtral of Experts)",
)
SMOKE = CONFIG.reduced()
