"""nemotron-4-15b [arXiv:2402.16819] — dense GQA, squared-ReLU MLP,
256k vocab (the largest assigned embedding surface)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab_size=256000,
    activation="relu2", norm="layernorm",
    source="arXiv:2402.16819 (Nemotron-4 15B)",
)
SMOKE = CONFIG.reduced()
