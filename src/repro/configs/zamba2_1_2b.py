"""zamba2-1.2b [arXiv:2411.15242] — Mamba-2 trunk + shared attention block
applied every 6 SSM blocks (weight reuse; simplified: no per-block LoRA)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_version=2,
    ssm_head_dim=64, attn_every=6,
    activation="swiglu",
    source="arXiv:2411.15242 (Zamba2)",
)
SMOKE = CONFIG.reduced()
