"""Vocab-parallel cross-entropy with an explicit collective schedule.

Motivation (EXPERIMENTS.md §Perf, iterations 2-3): with the LM head sharded
over the vocab ("model") axis and tokens sharded over "data", GSPMD's
backward for ``dhead = h^T @ dlogits`` chooses to ALL-GATHER the f32
dlogits over the data axis (67 GB/device for nemotron-4-15b train_4k)
rather than computing token-partial (D, V/shard) products and all-reducing
them (0.8 GB).  This module writes the head matmul + CE loss inside
`shard_map`, so the collective schedule is explicit and the bad choice is
structurally impossible:

  forward per shard:  logits_blk = h_blk @ head_blk          (local MXU)
                      m   = pmax (model)  of row max          (B,S) tiny
                      lse = log(psum(model) sum exp) + m      (B,S) tiny
                      ll  = psum(model) masked label pick     (B,S) tiny
                      loss = psum(data+model) partial mean    scalar
  backward (autodiff of the above): dlogits stays shard-local; the head
  cotangent is a token-partial matmul + psum over "data" (inserted by
  shard_map's transpose rule for the data-replicated head input).
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax.shard_map import shard_map  # type: ignore


@partial(jax.custom_jvp, nondiff_argnums=(1,))
def _pmax_stopgrad(x, axis_name):
    """pmax used purely as the logsumexp stability offset: mathematically
    the offset cancels, so a zero tangent is exact (and pmax has no
    built-in differentiation rule anyway)."""
    return jax.lax.pmax(x, axis_name)


@_pmax_stopgrad.defjvp
def _pmax_stopgrad_jvp(axis_name, primals, tangents):
    (x,) = primals
    return _pmax_stopgrad(x, axis_name), jnp.zeros_like(x)


def vocab_parallel_ce(h, head, labels, mesh, *, batch_axes: Tuple[str, ...],
                      model_axis: str = "model", aux=0.0,
                      aux_weight: float = 0.01):
    """Mean CE over tokens; h (B,S,D) batch-sharded, head (D,V)
    vocab-sharded, labels (B,S) batch-sharded."""
    V = head.shape[-1]
    msize = mesh.shape[model_axis]
    assert V % msize == 0, (V, msize)
    v_shard = V // msize

    def fn(h_blk, head_blk, labels_blk):
        # local logits: (b, s, V/msize)
        lg = (h_blk @ head_blk).astype(jnp.float32)
        idx = jax.lax.axis_index(model_axis)
        lo = idx * v_shard
        # stable logsumexp across the vocab-sharded axis
        m_loc = jnp.max(lg, axis=-1)
        m = _pmax_stopgrad(jax.lax.stop_gradient(m_loc), model_axis)
        se = jnp.sum(jnp.exp(lg - m[..., None]), axis=-1)
        lse = jnp.log(jax.lax.psum(se, model_axis)) + m
        # label pick: only the owning shard contributes
        local_label = labels_blk - lo
        in_shard = (local_label >= 0) & (local_label < v_shard)
        safe = jnp.clip(local_label, 0, v_shard - 1)
        pick = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
        ll = jax.lax.psum(jnp.where(in_shard, pick, 0.0), model_axis)
        # mean over the *global* token count
        n_local = lg.shape[0] * lg.shape[1]
        total = jnp.sum(lse - ll)
        total = jax.lax.psum(total, batch_axes)
        n = n_local * jax.lax.psum(jnp.ones((), jnp.float32), batch_axes)
        return total / n

    loss = shard_map(
        fn, mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, model_axis),
                  P(batch_axes, None)),
        out_specs=P(),
    )(h, head, labels)
    return loss + aux_weight * aux
