"""State-space blocks: Mamba-1 (selective scan, falcon-mamba) and a
simplified Mamba-2 / SSD block (zamba2 trunk).

Training/prefill uses a *chunked* parallel scan: the sequence is split into
chunks; within a chunk the linear recurrence h_t = a_t * h_{t-1} + b_t is
evaluated with `lax.associative_scan`, and a `lax.scan` carries the state
across chunks.  This bounds the materialized (chunk, d_inner, state) tensor
to VMEM-friendly sizes while keeping O(log chunk) depth.  Decode is a
single recurrence step with a (conv ring, h) state — O(1) per token, which
is what makes ``long_500k`` runnable for the SSM/hybrid architectures.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import _dense_init

Params = Dict[str, jnp.ndarray]


def _scan_op(left, right):
    a_l, b_l = left
    a_r, b_r = right
    return a_l * a_r, b_l * a_r + b_r


def _chunked_linear_scan(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t along axis 1 (time).

    a, b: (B, S, ...) with identical trailing dims; h0: (B, ...).
    Returns (h (B, S, ...), h_final (B, ...)).
    """
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        # identity elements: a=1, b=0
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    ac = a.reshape((B, n, chunk) + a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1)))
    bc = b.reshape((B, n, chunk) + b.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, b.ndim + 1)))

    def step(h_carry, inputs):
        a_i, b_i = inputs                       # (B, chunk, ...)
        a_cum, b_cum = lax.associative_scan(_scan_op, (a_i, b_i), axis=1)
        h = b_cum + a_cum * h_carry[:, None]
        return h[:, -1], h

    h_final, hs = lax.scan(step, h0, (ac, bc))  # hs: (n, B, chunk, ...)
    hs = hs.transpose((1, 0, 2) + tuple(range(3, hs.ndim))).reshape(
        (B, n * chunk) + hs.shape[3:])
    return hs[:, :S], h_final


def _causal_conv(x, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time.  x: (B, S, C); w: (C, K); b: (C,).

    With ``state`` (B, K-1, C): single-step decode (S == 1); returns
    (y, new_state).  Without: training path over the full sequence.
    """
    K = w.shape[1]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)          # (B, K, C)
        y = jnp.einsum("bkc,ck->bc", window, w)[:, None] + b
        return y, window[:, 1:]
    B, S, C = x.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + S] * w[:, i] for i in range(K)) + b
    return y, None


# ------------------------------------------------------------------ mamba 1

def init_mamba1(key, d_model: int, d_inner: int, ssm_state: int,
                conv: int, dt_rank: int, dtype) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d_model, 2 * d_inner), dtype),
        "conv_w": _dense_init(ks[1], (d_inner, conv), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_inner,), dtype=dtype),
        "x_proj": _dense_init(ks[2], (d_inner, dt_rank + 2 * ssm_state),
                              dtype),
        "dt_proj": _dense_init(ks[3], (dt_rank, d_inner), dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, dtype=dtype),  # softplus~0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ssm_state + 1, dtype=jnp.float32),
            (d_inner, ssm_state))).astype(dtype),
        "D_skip": jnp.ones((d_inner,), dtype=dtype),
        "out_proj": _dense_init(ks[4], (d_inner, d_model), dtype),
    }


def mamba1_block(x, p: Params, *, ssm_state: int, dt_rank: int,
                 state: Optional[Tuple] = None, scan_chunk: int = 256):
    """x: (B, S, D).  ``state`` = (conv_state (B,K-1,di), h (B,di,N)) for
    single-step decode.  Returns (out, new_state)."""
    B, S, D = x.shape
    N = ssm_state
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                       # (B,S,di)
    di = x_in.shape[-1]

    conv_state = state[0] if state is not None else None
    x_c, new_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], conv_state)
    x_c = jax.nn.silu(x_c)

    dbc = x_c @ p["x_proj"]
    dt, Bmat, Cmat = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])    # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (di,N)

    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A)                           # (B,S,di,N)
    b = (dtf * x_c.astype(jnp.float32))[..., None] \
        * Bmat.astype(jnp.float32)[:, :, None, :]             # (B,S,di,N)

    if state is None:
        h0 = jnp.zeros((B, di, N), dtype=jnp.float32)
        h, h_last = _chunked_linear_scan(a, b, h0, scan_chunk)
        new_h = h_last
    else:
        h_prev = state[1]
        h = a[:, 0] * h_prev + b[:, 0]                        # (B,di,N)
        new_h = h
        h = h[:, None]                                        # (B,1,di,N)

    y = jnp.einsum("bsdn,bsn->bsd", h, Cmat.astype(jnp.float32))
    y = y + p["D_skip"].astype(jnp.float32) * x_c.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    out = y @ p["out_proj"]
    new_state = (new_conv, new_h) if state is not None else None
    return out, new_state


# ------------------------------------------------------------------ mamba 2

def init_mamba2(key, d_model: int, d_inner: int, ssm_state: int,
                conv: int, head_dim: int, dtype) -> Params:
    nh = d_inner // head_dim
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d_model, 2 * d_inner), dtype),
        "conv_w": _dense_init(ks[1], (d_inner, conv), dtype, scale=0.5),
        "conv_b": jnp.zeros((d_inner,), dtype=dtype),
        "dt_proj": _dense_init(ks[2], (d_model, nh), dtype),
        "dt_bias": jnp.full((nh,), -4.6, dtype=dtype),
        "B_proj": _dense_init(ks[3], (d_model, ssm_state), dtype),
        "C_proj": _dense_init(ks[4], (d_model, ssm_state), dtype),
        "A_log": jnp.zeros((nh,), dtype=dtype),
        "D_skip": jnp.ones((nh,), dtype=dtype),
        "out_proj": _dense_init(ks[5], (d_inner, d_model), dtype),
    }


def mamba2_block(x, p: Params, *, ssm_state: int, head_dim: int,
                 state: Optional[Tuple] = None, scan_chunk: int = 64):
    """Simplified SSD: scalar decay per head.  x: (B, S, D).

    ``state`` = (conv_state (B,K-1,di), h (B,nh,hd,N)) for decode."""
    B, S, D = x.shape
    N = ssm_state
    xz = x @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    di = x_in.shape[-1]
    hd = head_dim
    nh = di // hd

    conv_state = state[0] if state is not None else None
    x_c, new_conv = _causal_conv(x_in, p["conv_w"], p["conv_b"], conv_state)
    x_c = jax.nn.silu(x_c)

    dt = jax.nn.softplus(x @ p["dt_proj"] + p["dt_bias"])     # (B,S,nh)
    Bmat = x @ p["B_proj"]                                    # (B,S,N)
    Cmat = x @ p["C_proj"]                                    # (B,S,N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (nh,)

    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf * A)                                      # (B,S,nh)
    xh = x_c.reshape(B, S, nh, hd).astype(jnp.float32)
    # b_t = dt * x_t (outer) B_t : (B,S,nh,hd,N)
    b = (dtf[..., None, None] * xh[..., None]
         * Bmat.astype(jnp.float32)[:, :, None, None, :])
    a_full = jnp.broadcast_to(a[..., None, None], b.shape)

    if state is None:
        h0 = jnp.zeros((B, nh, hd, N), dtype=jnp.float32)
        h, h_last = _chunked_linear_scan(a_full, b, h0, scan_chunk)
        new_h = h_last
    else:
        h_prev = state[1]
        h = a_full[:, 0] * h_prev + b[:, 0]
        new_h = h
        h = h[:, None]

    y = jnp.einsum("bshdn,bsn->bshd", h, Cmat.astype(jnp.float32))
    y = y + p["D_skip"].astype(jnp.float32)[:, None] * xh[:, :S]
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_state = (new_conv, new_h) if state is not None else None
    return out, new_state
