"""Mixture-of-Experts block: top-k router + capacity-bounded dispatch.

Dispatch uses the scatter/gather formulation: tokens are placed into a
per-expert buffer of fixed capacity (position = running count of earlier
assignments to the same expert); overflow tokens are dropped (weight-
renormalized).  The expert FFN is batched over the expert dimension, which
shards naturally: expert-parallel when n_experts divides the model axis,
per-expert tensor-parallel otherwise.

The router's top-k output *is* an intent signal in the paper's sense
(§3): it announces which expert parameters each token will access one
collective ahead of the expert computation.  `repro.pm` consumes it.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import _dense_init

Params = Dict[str, jnp.ndarray]


def init_moe(key, d_model: int, n_experts: int, moe_d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d_model, n_experts), dtype),
        "w_gate": _dense_init(ks[1], (n_experts, d_model, moe_d_ff), dtype),
        "w_up": _dense_init(ks[2], (n_experts, d_model, moe_d_ff), dtype),
        "w_down": _dense_init(ks[3], (n_experts, moe_d_ff, d_model), dtype),
    }


def expert_capacity(n_tokens: int, n_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    c = int(capacity_factor * n_tokens * top_k / n_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_block(x, p: Params, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss, router_topk_idx).

    ``router_topk_idx`` (B*S, k) is exposed as the expert-intent signal.
    """
    B, S, D = x.shape
    T = B * S
    E, K = n_experts, top_k
    xt = x.reshape(T, D)

    logits = (xt @ p["router"]).astype(jnp.float32)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch/Mixtral style)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(topk_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    C = expert_capacity(T, E, K, capacity_factor)
    e_flat = topk_idx.reshape(-1)                              # (T*K,)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)        # (T*K, E)
    pos_in_e = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot,
                       axis=-1)                                # (T*K,)
    keep = pos_in_e < C
    # dropped assignments go to a trash slot E*C
    slot = jnp.where(keep, e_flat * C + pos_in_e, E * C)       # (T*K,)

    x_rep = jnp.repeat(xt, K, axis=0)                          # (T*K, D)
    buf = jnp.zeros((E * C + 1, D), dtype=x.dtype)
    buf = buf.at[slot].add(x_rep)
    expert_in = buf[: E * C].reshape(E, C, D)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])    # (E, C, D)

    out_flat = jnp.concatenate(
        [expert_out.reshape(E * C, D),
         jnp.zeros((1, D), dtype=expert_out.dtype)], axis=0)
    gathered = out_flat[slot]                                  # (T*K, D)
    weighted = gathered * gate_vals.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.sum(weighted.reshape(T, K, D), axis=1)
    return out.reshape(B, S, D), aux.astype(x.dtype), topk_idx
