"""Core transformer layers, written functionally: ``init_*`` builds a param
dict, ``apply``-style functions consume it.  Everything is jit/pjit-friendly
(pure jnp + lax); attention is computed in query/key blocks with an online
softmax (flash-style) so long-context prefill never materializes an
(S x S) score matrix.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, jnp.ndarray]

# --------------------------------------------------------------------- init

def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def init_norm(d: int, dtype, with_bias: bool) -> Params:
    p = {"scale": jnp.ones((d,), dtype=dtype)}
    if with_bias:
        p["bias"] = jnp.zeros((d,), dtype=dtype)
    return p


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": _dense_init(ks[1], (d_model, n_kv_heads * head_dim), dtype),
        "wv": _dense_init(ks[2], (d_model, n_kv_heads * head_dim), dtype),
        "wo": _dense_init(ks[3], (n_heads * head_dim, d_model), dtype),
    }


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "w_gate": _dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": _dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": _dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "w_in": _dense_init(ks[0], (d_model, d_ff), dtype),
        "w_out": _dense_init(ks[1], (d_ff, d_model), dtype),
    }

# -------------------------------------------------------------------- norms

def norm(x, p: Params, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)

# --------------------------------------------------------------------- rope

def rope_angles(positions, head_dim: int, theta: float,
                mrope_sections: Optional[Tuple[int, int, int]] = None):
    """positions: (B, S) ints, or (B, S, 3) for M-RoPE (t/h/w coordinates).

    Returns (cos, sin) of shape (B, S, head_dim//2), float32.
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)[..., None]          # (B,S,1)
        ang = pos * inv_freq                                    # (B,S,half)
    else:
        # M-RoPE (Qwen2-VL): frequency bands are split into three sections
        # driven by the temporal / height / width coordinate respectively.
        assert sum(mrope_sections) == half, (mrope_sections, half)
        sec_id = jnp.repeat(
            jnp.arange(3), jnp.array(mrope_sections),
            total_repeat_length=half)                            # (half,)
        pos3 = positions.astype(jnp.float32)                     # (B,S,3)
        pos = pos3[..., sec_id]                                  # (B,S,half)
        ang = pos * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, head_dim); cos/sin: (B, S, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)

# ---------------------------------------------------------------- attention

def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)) \
        .reshape(b, s, h * n_rep, d)


def _block_attn(q, k, v, mask, scale):
    """One (q-block, kv-block) tile: returns un-normalized (o, m, l)."""
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                   # (B,H,Q)
    # guard fully-masked rows
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)                                   # (B,H,Q)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m_safe, l


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0, q_block: int = 512,
                    kv_block: int = 1024):
    """Blocked attention with online softmax.

    q: (B, Sq, H, d);  k, v: (B, Skv, KvH, d)  (GQA: H % KvH == 0).
    ``q_offset``: absolute position of q[0] (for decode/prefill continuity).
    ``window`` > 0 restricts attention to the last ``window`` positions
    (sliding-window attention).
    """
    B, Sq, H, hd = q.shape
    Skv, KvH = k.shape[1], k.shape[2]
    k = _repeat_kv(k, H // KvH)
    v = _repeat_kv(v, H // KvH)
    scale = 1.0 / math.sqrt(hd)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    nq = -(-Sq // q_block)
    nk = -(-Skv // kv_block)
    pad_q = nq * q_block - Sq
    pad_k = nk * kv_block - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    q_pos = q_offset + jnp.arange(nq * q_block)
    k_pos = jnp.arange(nk * kv_block)
    kv_valid = k_pos < Skv

    qb = q.reshape(B, nq, q_block, H, hd).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, nk, kv_block, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_block, H, hd).transpose(1, 0, 2, 3, 4)

    def q_step(qi):
        q_i = qb[qi]
        qp = lax.dynamic_slice_in_dim(q_pos, qi * q_block, q_block)

        def kv_step(carry, inputs):
            o, m, l = carry
            k_j, v_j, kj = inputs
            kp = lax.dynamic_slice_in_dim(k_pos, kj * kv_block, kv_block)
            kvld = lax.dynamic_slice_in_dim(kv_valid, kj * kv_block, kv_block)
            mask = jnp.ones((q_block, kv_block), dtype=bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window:
                mask &= (qp[:, None] - kp[None, :]) < window
            mask &= kvld[None, :]
            mask = mask[None, None]                            # (1,1,Q,K)
            o_j, m_j, l_j = _block_attn(q_i, k_j, v_j, mask, scale)
            m_new = jnp.maximum(m, m_j)
            a = jnp.exp(m - m_new)
            b = jnp.exp(m_j - m_new)
            o = o * a.transpose(0, 2, 1)[..., None] \
                + o_j * b.transpose(0, 2, 1)[..., None]
            l = l * a + l_j * b
            return (o, m_new, l), None

        o0 = jnp.zeros((B, q_block, H, hd), dtype=jnp.float32)
        # m floored at 0 (matches the m_safe convention in _block_attn);
        # exact as long as exp(s) does not overflow for s <= max score.
        m0 = jnp.zeros((B, H, q_block), dtype=jnp.float32)
        l0 = jnp.zeros((B, H, q_block), dtype=jnp.float32)
        (o, m, l), _ = lax.scan(
            kv_step, (o0, m0, l0), (kb, vb, jnp.arange(nk)))
        l = jnp.maximum(l, 1e-20)
        o = o / l.transpose(0, 2, 1)[..., None]
        return o

    out = lax.map(q_step, jnp.arange(nq))                     # (nq,B,Qb,H,hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, hd)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Chunked attention against a KV cache.

    q: (B, Sq, H, d); caches: (B, S, KvH, d); cache_len: valid prefix
    length (the chunk's k/v must already be written at ``cache_len - Sq``).
    Causal *within* the chunk: query i sits at absolute position
    ``cache_len - Sq + i`` and attends to positions <= its own — for the
    single-token decode case (Sq=1) this reduces to the old
    ``pos < cache_len`` mask; Sq>1 is the fused prefill path.
    """
    B, Sq, H, hd = q.shape
    S, KvH = k_cache.shape[1], k_cache.shape[2]
    k = _repeat_kv(k_cache, H // KvH)
    v = _repeat_kv(v_cache, H // KvH)
    pos = jnp.arange(S)
    q_pos = cache_len - Sq + jnp.arange(Sq)
    valid = pos[None, :] <= q_pos[:, None]
    if window:
        valid &= pos[None, :] > (q_pos[:, None] - window)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    s = jnp.where(valid[None, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def attention_block(x, p: Params, cfg, positions, *, cache=None,
                    cache_len=None, cross_kv=None, causal=True):
    """Full attention sub-layer: projections + rope + attention + output.

    Returns (out, new_cache).  ``cache`` is a dict {k, v} of
    (B, S_cache, KvH, hd) used for decode; ``cross_kv`` provides
    encoder-side (k, v) for cross-attention (no rope, no cache).
    """
    B, S, D = x.shape
    H, KvH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if cross_kv is not None:
        k, v = cross_kv
        o = flash_attention(q, k, v, causal=False)
        return (o.reshape(B, S, H * hd) @ p["wo"]), cache
    k = (x @ p["wk"]).reshape(B, S, KvH, hd)
    v = (x @ p["wv"]).reshape(B, S, KvH, hd)
    sections = cfg.mrope_sections if cfg.mrope else None
    cos, sin = rope_angles(positions, hd, cfg.rope_theta, sections)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if cache is not None:
        # decode: write the S-token chunk at cache_len - S (S=1 for plain
        # decode; S>1 for the fused prefill), attend to the prefix
        idx = cache_len - S
        k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
        o = decode_attention(q, k_cache, v_cache, cache_len,
                             window=cfg.sliding_window)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        o = flash_attention(q, k, v, causal=causal,
                            window=cfg.sliding_window)
        new_cache = None
    return (o.reshape(B, S, H * hd) @ p["wo"]), new_cache

# --------------------------------------------------------------------- mlp

def mlp_block(x, p: Params, activation: str):
    if activation == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_in"]
    if activation == "gelu":
        h = jax.nn.gelu(h)
    elif activation == "relu2":
        h = jnp.square(jax.nn.relu(h))     # Nemotron-4 squared-ReLU
    else:
        raise ValueError(activation)
    return h @ p["w_out"]
