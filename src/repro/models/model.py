"""Model assembly for every assigned architecture family.

Functional API:
  init_model(cfg, key, param_dtype)          -> params pytree
  forward(params, cfg, batch, cache=None)    -> (logits, aux, new_cache)
  init_cache(cfg, batch_size, max_seq, dtype)-> decode cache pytree
  loss_fn(logits, labels)                    -> scalar

Layer stacks are stored with a leading layer dimension and executed with
`lax.scan` (+ remat in training) so the lowered HLO stays compact at
126-layer/512-device scale.  Decode caches ride through the scan as xs/ys.

Family specifics:
  dense / moe  : pre-norm GQA transformer (optional sliding window, MoE FFN)
  ssm          : Mamba-1 trunk (attention-free)
  hybrid       : Mamba-2 trunk + one *shared* attention block applied every
                 ``attn_every`` blocks (zamba2; weight reuse, no per-pass
                 LoRA — documented simplification)
  encdec       : whisper-style encoder-decoder; the audio frontend is a stub
                 (precomputed frame embeddings enter the encoder); RoPE is
                 used in place of learned positions for length generality
  vlm          : decoder-only LM consuming text tokens with patch embeddings
                 (ViT stub) scattered at given positions; M-RoPE positions
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from .layers import (attention_block, init_attention, init_mlp, init_norm,
                     mlp_block, norm, _dense_init)
from .moe import init_moe, moe_block
from .ssm import (init_mamba1, init_mamba2, mamba1_block, mamba2_block)

Params = Dict[str, Any]


# ----------------------------------------------------------------- init

def _init_dense_layer(cfg: ModelConfig, key, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    with_bias = cfg.norm == "layernorm"
    p = {
        "norm1": init_norm(cfg.d_model, dtype, with_bias),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim, dtype),
        "norm2": init_norm(cfg.d_model, dtype, with_bias),
    }
    if cfg.n_experts:
        p["moe"] = init_moe(k2, cfg.d_model, cfg.n_experts, cfg.moe_d_ff,
                            dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def _init_ssm_layer(cfg: ModelConfig, key, dtype) -> Params:
    base = {"norm1": init_norm(cfg.d_model, dtype, False)}
    if cfg.ssm_version == 1:
        base["mamba"] = init_mamba1(key, cfg.d_model, cfg.d_inner,
                                    cfg.ssm_state, cfg.ssm_conv,
                                    cfg.dt_rank, dtype)
    else:
        base["mamba"] = init_mamba2(key, cfg.d_model, cfg.d_inner,
                                    cfg.ssm_state, cfg.ssm_conv,
                                    cfg.ssm_head_dim, dtype)
    return base


def _init_encdec_layers(cfg: ModelConfig, key, dtype):
    e = cfg.encoder
    kenc, kdec = jax.random.split(key)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": init_norm(cfg.d_model, dtype, True),
            "attn": init_attention(k1, cfg.d_model, e.n_heads, e.n_heads,
                                   cfg.d_model // e.n_heads, dtype),
            "norm2": init_norm(cfg.d_model, dtype, True),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation,
                            dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": init_norm(cfg.d_model, dtype, True),
            "attn": init_attention(k1, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim, dtype),
            "norm_x": init_norm(cfg.d_model, dtype, True),
            "cross": init_attention(k2, cfg.d_model, cfg.n_heads,
                                    cfg.n_heads, cfg.head_dim, dtype),
            "norm2": init_norm(cfg.d_model, dtype, True),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.activation,
                            dtype),
        }

    enc_keys = jax.random.split(kenc, e.n_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return (jax.vmap(enc_layer)(enc_keys), jax.vmap(dec_layer)(dec_keys))


def init_model(cfg: ModelConfig, key, param_dtype=jnp.float32) -> Params:
    ke, kl, kh, ks = jax.random.split(key, 4)
    with_bias = cfg.norm == "layernorm"
    params: Params = {
        "embed": _dense_init(ke, (cfg.vocab_size, cfg.d_model), param_dtype,
                             scale=0.02),
        "final_norm": init_norm(cfg.d_model, param_dtype, with_bias),
    }
    if not cfg.tie_embeddings:
        params["head"] = _dense_init(kh, (cfg.d_model, cfg.vocab_size),
                                     param_dtype)

    if cfg.family == "encdec":
        params["enc_layers"], params["layers"] = _init_encdec_layers(
            cfg, kl, param_dtype)
        params["enc_norm"] = init_norm(cfg.d_model, param_dtype, with_bias)
        return params

    layer_keys = jax.random.split(kl, cfg.n_layers)
    if cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = jax.vmap(
            lambda k: _init_dense_layer(cfg, k, param_dtype))(layer_keys)
    elif cfg.family == "ssm":
        params["layers"] = jax.vmap(
            lambda k: _init_ssm_layer(cfg, k, param_dtype))(layer_keys)
    elif cfg.family == "hybrid":
        params["layers"] = jax.vmap(
            lambda k: _init_ssm_layer(cfg, k, param_dtype))(layer_keys)
        params["shared_attn"] = _init_dense_layer(cfg, ks, param_dtype)
    else:
        raise ValueError(cfg.family)
    return params


# ----------------------------------------------------------------- cache

def n_attn_apps(cfg: ModelConfig) -> int:
    """How many times the shared attention block runs (hybrid)."""
    return -(-cfg.n_layers // cfg.attn_every) if cfg.attn_every else 0


def cache_seq_len(cfg: ModelConfig, max_seq: int) -> int:
    """KV caches are bounded by the sliding window when one exists."""
    return min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.float32) -> Params:
    S = cache_seq_len(cfg, max_seq)
    cache: Params = {"len": jnp.zeros((), dtype=jnp.int32)}
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        cache["k"] = jnp.zeros((L, batch, S, kvh, hd), dtype=dtype)
        cache["v"] = jnp.zeros((L, batch, S, kvh, hd), dtype=dtype)
    elif cfg.family == "encdec":
        cache["k"] = jnp.zeros((L, batch, S, kvh, hd), dtype=dtype)
        cache["v"] = jnp.zeros((L, batch, S, kvh, hd), dtype=dtype)
        cache["enc_out"] = jnp.zeros(
            (batch, cfg.encoder.n_frames, cfg.d_model), dtype=dtype)
    elif cfg.family == "ssm":
        di = cfg.d_inner
        cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, di),
                                  dtype=dtype)
        cache["h"] = jnp.zeros((L, batch, di, cfg.ssm_state),
                               dtype=jnp.float32)
    elif cfg.family == "hybrid":
        di = cfg.d_inner
        nh = di // cfg.ssm_head_dim
        A = n_attn_apps(cfg)
        cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, di),
                                  dtype=dtype)
        cache["h"] = jnp.zeros((L, batch, nh, cfg.ssm_head_dim,
                                cfg.ssm_state), dtype=jnp.float32)
        cache["attn_k"] = jnp.zeros((A, batch, S, kvh, hd), dtype=dtype)
        cache["attn_v"] = jnp.zeros((A, batch, S, kvh, hd), dtype=dtype)
    return cache


# ----------------------------------------------------------------- forward

def _constrain(lp, fsdp_spec):
    """FSDP weight gather: re-layout the layer's (ZeRO-sharded) weights to
    their TP-only layout inside the scan body, so XLA gathers the small
    weights once per layer instead of partial-summing full-batch
    activations over the data axis (EXPERIMENTS.md §Perf it. 6)."""
    if fsdp_spec is None:
        return lp
    return jax.tree_util.tree_map(
        lambda w, s: jax.lax.with_sharding_constraint(w, s), lp, fsdp_spec)


def _dense_stack(params, cfg: ModelConfig, h, positions, cache, remat,
                 remat_policy="full", fsdp_spec=None, act_spec=None):
    """Scan the (dense|moe|vlm) decoder stack.  Returns (h, aux, new_kv).

    ``act_spec``: optional sharding for the residual stream *between*
    layers (Megatron-style sequence sharding: P(batch, "model", None)).
    XLA then lowers the TP partial-sum all-reduce after o-proj/down-proj
    as reduce-scatter + all-gather pairs — half the wire bytes."""
    decode = cache is not None
    cache_len = cache["len"] if decode else None

    def body(carry, xs):
        h, aux = carry
        if decode:
            lp, kc, vc = xs
        else:
            lp = xs
        lp = _constrain(lp, fsdp_spec)
        if act_spec is not None:
            h = jax.lax.with_sharding_constraint(h, act_spec)
        kv = {"k": kc, "v": vc} if decode else None
        a, new_kv = attention_block(
            norm(h, lp["norm1"], cfg.norm, cfg.norm_eps), lp["attn"], cfg,
            positions, cache=kv, cache_len=cache_len)
        h = h + a
        hn = norm(h, lp["norm2"], cfg.norm, cfg.norm_eps)
        if cfg.n_experts:
            m, aux_l, _ = moe_block(hn, lp["moe"], n_experts=cfg.n_experts,
                                    top_k=cfg.top_k,
                                    capacity_factor=cfg.capacity_factor)
            aux = aux + aux_l
        else:
            m = mlp_block(hn, lp["mlp"], cfg.activation)
        h = h + m
        if act_spec is not None:
            h = jax.lax.with_sharding_constraint(h, act_spec)
        ys = (new_kv["k"], new_kv["v"]) if decode else None
        return (h, aux), ys

    fn = _remat(body, remat, remat_policy)
    xs = (params["layers"], cache["k"], cache["v"]) if decode \
        else params["layers"]
    (h, aux), ys = lax.scan(fn, (h, jnp.zeros((), dtype=h.dtype)), xs)
    new_kv = {"k": ys[0], "v": ys[1]} if decode else None
    return h, aux, new_kv


def _ssm_stack(params, cfg: ModelConfig, h, cache, remat,
               remat_policy="full", fsdp_spec=None):
    decode = cache is not None

    def body(carry, xs):
        h = carry
        if decode:
            lp, conv_c, h_c = xs
            state = (conv_c, h_c)
        else:
            lp = xs
            state = None
        lp = _constrain(lp, fsdp_spec)
        hn = norm(h, lp["norm1"], cfg.norm, cfg.norm_eps)
        if cfg.ssm_version == 1:
            y, new_state = mamba1_block(hn, lp["mamba"],
                                        ssm_state=cfg.ssm_state,
                                        dt_rank=cfg.dt_rank, state=state)
        else:
            y, new_state = mamba2_block(hn, lp["mamba"],
                                        ssm_state=cfg.ssm_state,
                                        head_dim=cfg.ssm_head_dim,
                                        state=state)
        h = h + y
        ys = new_state if decode else None
        return h, ys

    fn = _remat(body, remat, remat_policy)
    xs = (params["layers"], cache["conv"], cache["h"]) if decode \
        else params["layers"]
    h, ys = lax.scan(fn, h, xs)
    new_states = {"conv": ys[0], "h": ys[1]} if decode else None
    return h, new_states


def _hybrid_stack(params, cfg: ModelConfig, h, positions, cache, remat,
                  remat_policy="full", fsdp_spec=None):
    """Mamba-2 trunk with a shared attention block every ``attn_every``
    blocks.  The shared block's KV caches (one per application) ride in the
    scan carry and are updated with dynamic slices."""
    decode = cache is not None
    shared = params["shared_attn"]
    every = cfg.attn_every
    cache_len = cache["len"] if decode else None

    def attn_branch(args):
        h, ak, av, app_idx = args
        if decode:
            kv = {"k": lax.dynamic_index_in_dim(ak, app_idx, 0,
                                                keepdims=False),
                  "v": lax.dynamic_index_in_dim(av, app_idx, 0,
                                                keepdims=False)}
        else:
            kv = None
        a, new_kv = attention_block(
            norm(h, shared["norm1"], cfg.norm, cfg.norm_eps),
            shared["attn"], cfg, positions, cache=kv, cache_len=cache_len)
        h = h + a
        m = mlp_block(norm(h, shared["norm2"], cfg.norm, cfg.norm_eps),
                      shared["mlp"], cfg.activation)
        h = h + m
        if decode:
            ak = lax.dynamic_update_index_in_dim(ak, new_kv["k"], app_idx, 0)
            av = lax.dynamic_update_index_in_dim(av, new_kv["v"], app_idx, 0)
        return h, ak, av

    def body(carry, xs):
        h, ak, av = carry
        if decode:
            lp, idx, conv_c, h_c = xs
            state = (conv_c, h_c)
        else:
            lp, idx = xs
            state = None
        lp = _constrain(lp, fsdp_spec)
        apply_attn = (idx % every) == 0
        app_idx = idx // every
        h, ak, av = lax.cond(
            apply_attn, attn_branch, lambda args: (args[0], args[1], args[2]),
            (h, ak, av, app_idx))
        hn = norm(h, lp["norm1"], cfg.norm, cfg.norm_eps)
        y, new_state = mamba2_block(hn, lp["mamba"], ssm_state=cfg.ssm_state,
                                    head_dim=cfg.ssm_head_dim, state=state)
        h = h + y
        ys = new_state if decode else None
        return (h, ak, av), ys

    idxs = jnp.arange(cfg.n_layers)
    if decode:
        ak0, av0 = cache["attn_k"], cache["attn_v"]
        xs = (params["layers"], idxs, cache["conv"], cache["h"])
    else:
        A = n_attn_apps(cfg)
        ak0 = jnp.zeros((A, 1, 1, 1, 1), dtype=h.dtype)  # unused
        av0 = ak0
        xs = (params["layers"], idxs)
    fn = _remat(body, remat, remat_policy)
    (h, ak, av), ys = lax.scan(fn, (h, ak0, av0), xs)
    new_cache = None
    if decode:
        new_cache = {"conv": ys[0], "h": ys[1], "attn_k": ak, "attn_v": av}
    return h, new_cache


def _encoder(params, cfg: ModelConfig, frames):
    e = cfg.encoder
    B, F, D = frames.shape
    positions = jnp.broadcast_to(jnp.arange(F), (B, F))
    enc_cfg_heads = e.n_heads

    def body(h, lp):
        import dataclasses
        ecfg = dataclasses.replace(cfg, n_heads=enc_cfg_heads,
                                   n_kv_heads=enc_cfg_heads,
                                   head_dim=cfg.d_model // enc_cfg_heads,
                                   sliding_window=0)
        a, _ = attention_block(norm(h, lp["norm1"], cfg.norm, cfg.norm_eps),
                               lp["attn"], ecfg, positions, causal=False)
        h = h + a
        h = h + mlp_block(norm(h, lp["norm2"], cfg.norm, cfg.norm_eps),
                          lp["mlp"], cfg.activation)
        return h, None

    h, _ = lax.scan(body, frames, params["enc_layers"])
    return norm(h, params["enc_norm"], cfg.norm, cfg.norm_eps)


def _decoder_stack(params, cfg: ModelConfig, h, positions, enc_out, cache,
                   remat, fsdp_spec=None):
    decode = cache is not None
    cache_len = cache["len"] if decode else None
    B = h.shape[0]
    Hh, hd = cfg.n_heads, cfg.head_dim

    def body(carry, xs):
        h = carry
        if decode:
            lp, kc, vc = xs
            kv = {"k": kc, "v": vc}
        else:
            lp = xs
            kv = None
        lp = _constrain(lp, fsdp_spec)
        a, new_kv = attention_block(
            norm(h, lp["norm1"], cfg.norm, cfg.norm_eps), lp["attn"], cfg,
            positions, cache=kv, cache_len=cache_len)
        h = h + a
        # cross-attention to the encoder output (k/v projected per layer)
        F = enc_out.shape[1]
        ck = (enc_out @ lp["cross"]["wk"]).reshape(B, F, Hh, hd)
        cv = (enc_out @ lp["cross"]["wv"]).reshape(B, F, Hh, hd)
        x, _ = attention_block(
            norm(h, lp["norm_x"], cfg.norm, cfg.norm_eps), lp["cross"], cfg,
            positions, cross_kv=(ck, cv))
        h = h + x
        h = h + mlp_block(norm(h, lp["norm2"], cfg.norm, cfg.norm_eps),
                          lp["mlp"], cfg.activation)
        ys = (new_kv["k"], new_kv["v"]) if decode else None
        return h, ys

    fn = jax.checkpoint(body) if remat else body
    xs = (params["layers"], cache["k"], cache["v"]) if decode \
        else params["layers"]
    h, ys = lax.scan(fn, h, xs)
    new_kv = {"k": ys[0], "v": ys[1]} if decode else None
    return h, new_kv


def _remat(fn, remat, policy):
    if not remat:
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def forward(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            cache: Optional[Params] = None, remat: bool = True,
            remat_policy: str = "full",
            pm_miss_capacity: int = 0, pm_strict: bool = False,
            pm_kernel: bool = False, pm_backend=None, pm_residual=None,
            embed_rows=None,
            head_last_only: bool = False, skip_head: bool = False,
            fsdp_spec=None, act_spec=None):
    """Returns (logits, aux_loss, new_cache).

    batch:
      tokens     (B, S) int32
      positions  (B, S) int32, or (B, S, 3) for M-RoPE
      img_embeds (B, n_img, D) + img_pos (B, n_img)   [vlm only]
      frames     (B, n_frames, D)                      [encdec only]
      pm_cache_ids / pm_cache_rows : intent-managed embedding replica
        cache (repro.pm); active when ``pm_miss_capacity > 0``.

    ``pm_residual``: precomputed single-sort step residual for the managed
    lookup (`kernels.pm_forward.step_residual` — the train step computes
    it once and every index consumer reuses it).  ``embed_rows``: already-
    gathered (B, S, D) token rows; skips the embedding lookup entirely
    (the fused sparse train step differentiates w.r.t. these rows instead
    of a dense table gradient).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    if embed_rows is not None:
        h = embed_rows
    elif pm_miss_capacity > 0 and "pm_cache_ids" in batch:
        from repro.pm.embedding import pm_lookup
        h = pm_lookup(params["embed"], batch["pm_cache_ids"],
                      batch["pm_cache_rows"], tokens, pm_miss_capacity,
                      pm_strict, pm_kernel, pm_backend, pm_residual)
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
    if cfg.family == "vlm" and "img_embeds" in batch:
        h = h.at[jnp.arange(B)[:, None], batch["img_pos"]].set(
            batch["img_embeds"].astype(h.dtype))
    positions = batch.get("positions")
    if positions is None:
        if cache is not None:
            # the S-token chunk occupies absolute positions
            # [len - S, len) — S=1 decode keeps the old len - 1
            positions = jnp.broadcast_to(
                cache["len"] - S + jnp.arange(S, dtype=jnp.int32), (B, S))
        else:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[..., None], (B, S, 3))

    aux = jnp.zeros((), dtype=h.dtype)
    new_cache = None
    if cfg.family in ("dense", "moe", "vlm"):
        h, aux, kv = _dense_stack(params, cfg, h, positions, cache,
                                  remat and cache is None, remat_policy,
                                  fsdp_spec, act_spec)
        if cache is not None:
            new_cache = {**cache, **kv}
    elif cfg.family == "ssm":
        h, st = _ssm_stack(params, cfg, h, cache, remat and cache is None,
                           remat_policy, fsdp_spec)
        if cache is not None:
            new_cache = {**cache, **st}
    elif cfg.family == "hybrid":
        h, st = _hybrid_stack(params, cfg, h, positions, cache,
                              remat and cache is None, remat_policy,
                              fsdp_spec)
        if cache is not None:
            new_cache = {**cache, **st}
    elif cfg.family == "encdec":
        if cache is not None:
            enc_out = cache["enc_out"]
        else:
            enc_out = _encoder(params, cfg, batch["frames"])
        h, kv = _decoder_stack(params, cfg, h, positions, enc_out, cache,
                               remat and cache is None, fsdp_spec)
        if cache is not None:
            new_cache = {**cache, **kv, "enc_out": enc_out}
    else:
        raise ValueError(cfg.family)

    h = norm(h, params["final_norm"], cfg.norm, cfg.norm_eps)
    if head_last_only:
        h = h[:, -1:]
    if skip_head:
        return h, aux, new_cache
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = h @ head
    return logits, aux, new_cache


def loss_fn(logits, labels, aux=0.0, aux_weight: float = 0.01):
    """Mean cross-entropy (+ MoE load-balance aux).

    The label log-prob is picked with a one-hot mask-and-reduce instead of
    ``take_along_axis``: under vocab-parallel sharding GSPMD evaluates the
    masked reduction shard-locally and only all-reduces the tiny (B, S)
    partials, whereas a gather on the sharded vocab axis forces an
    all-gather of the full (B, S, V) logits (EXPERIMENTS.md §Perf it. 2:
    67 GB -> 0.03 GB per device on nemotron-4-15b train_4k)."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    onehot = labels[..., None] == jnp.arange(logits.shape[-1])
    ll = jnp.sum(lg * onehot.astype(jnp.float32), axis=-1)
    return jnp.mean(lse - ll) + aux_weight * aux
