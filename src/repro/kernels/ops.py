"""Jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively (``interpret=False``); everywhere
else (this CPU container) the kernel body executes in interpret mode, and a
pure-jnp fallback (`ref.py`) is available for speed-sensitive CPU callers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .adagrad_rows import adagrad_row_update as _adagrad_pallas
from .embed_gather import embed_gather as _gather_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def embed_gather(table, ids, *, use_pallas: bool = True):
    """table[ids] via the blocked Pallas gather (oracle fallback on CPU
    when ``use_pallas=False``)."""
    if not use_pallas:
        return ref.embed_gather_ref(table, ids)
    return _gather_pallas(table, ids, interpret=not _on_tpu())


def adagrad_row_update(table, accum, ids, grads, *, lr=0.1, eps=1e-8,
                       use_pallas: bool = True):
    """Fused sparse AdaGrad row update; ids must be unique (see
    ``segment_rows``)."""
    if not use_pallas:
        return ref.adagrad_row_update_ref(table, accum, ids, grads,
                                          lr=lr, eps=eps)
    return _adagrad_pallas(table, accum, ids, grads, lr=lr, eps=eps,
                           interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("n_slots",))
def segment_rows(ids, grads, n_slots: int):
    """Aggregate duplicate row ids: returns (slot_ids (n_slots,), summed
    grads (n_slots, D)).  Unused slots get id 0 with an all-zero gradient
    (a zero AdaGrad update is NOT a no-op — accum would stay, value moves
    by 0/sqrt(acc) = 0 — so zero rows are safe).

    Static-shape friendly: n_slots >= number of distinct ids expected.
    """
    ids = ids.astype(jnp.int32)
    sorted_idx = jnp.argsort(ids)
    s_ids = ids[sorted_idx]
    s_g = grads[sorted_idx]
    is_new = jnp.concatenate(
        [jnp.ones((1,), jnp.int32), (s_ids[1:] != s_ids[:-1]).astype(jnp.int32)])
    slot = jnp.cumsum(is_new) - 1                     # segment index
    slot = jnp.minimum(slot, n_slots - 1)
    out_g = jnp.zeros((n_slots, grads.shape[1]), dtype=jnp.float32)
    out_g = out_g.at[slot].add(s_g.astype(jnp.float32))
    out_ids = jnp.zeros((n_slots,), dtype=jnp.int32)
    out_ids = out_ids.at[slot].set(s_ids)
    return out_ids, out_g
