"""Jit'd public wrappers for the Pallas kernels.

On TPU the Pallas path compiles natively (``interpret=False``); everywhere
else (this CPU container) the kernel body executes in interpret mode, and a
pure-jnp fallback (`ref.py`) is available for speed-sensitive CPU callers.

Index-side helpers: `sorted_slots` is the shared residual *producer* (one
argsort -> a reusable `SortResidual`), and `segment_rows` / `unique_rows`
are its consumers — pass them a precomputed residual (e.g. the managed
step's `pm_forward.step_residual`) and they do no sorting at all, which is
what keeps the whole train step at a single sort (DESIGN.md §11).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .adagrad_rows import adagrad_row_update as _adagrad_pallas
from .embed_gather import embed_gather as _gather_pallas
from .pm_forward import SortResidual
from .pm_forward import pm_combine as _combine_pallas
from .scatter_rows import scatter_rows as _scatter_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def embed_gather(table, ids, *, use_pallas: bool = True):
    """table[ids] via the blocked Pallas gather (oracle fallback on CPU
    when ``use_pallas=False``)."""
    if not use_pallas:
        return ref.embed_gather_ref(table, ids)
    return _gather_pallas(table, ids, interpret=not _on_tpu())


def masked_embed_gather(table, ids, valid, *, use_pallas: bool = True):
    """Gather with a validity mask: rows for ``ids`` where ``valid``,
    zeros elsewhere.  The per-shard partial of the vocab-parallel
    collectives (`pm.collectives`): each shard gathers its owned rows from
    its local block (``ids`` already localized and clipped by the caller)
    and the mask zeroes everything it does not own before the psum.  Also
    serves the replica-cache refresh, where invalid ids are pad slots."""
    rows = embed_gather(table, ids.astype(jnp.int32), use_pallas=use_pallas)
    return jnp.where(valid[:, None], rows, 0.0)


def adagrad_row_update(table, accum, ids, grads, *, lr=0.1, eps=1e-8,
                       use_pallas: bool = True):
    """Fused sparse AdaGrad row update; ids must be unique (see
    ``segment_rows``)."""
    if not use_pallas:
        return ref.adagrad_row_update_ref(table, accum, ids, grads,
                                          lr=lr, eps=eps)
    return _adagrad_pallas(table, accum, ids, grads, lr=lr, eps=eps,
                           interpret=not _on_tpu())


def pm_combine(hit, cache_slot, buf_slot, cache_rows, buf_rows, *,
               use_pallas: bool = True):
    """Managed-lookup select kernel: hits read the replica cache, misses
    read the compact deduped buffer (trash row last)."""
    if not use_pallas:
        return ref.pm_combine_ref(hit, cache_slot, buf_slot, cache_rows,
                                  buf_rows)
    return _combine_pallas(hit, cache_slot, buf_slot, cache_rows, buf_rows,
                           interpret=not _on_tpu())


def scatter_rows(base, ids, rows, *, use_pallas: bool = True):
    """Blocked row scatter (managed-lookup backward); ids must be unique
    apart from zero-row pad collisions."""
    if not use_pallas:
        return ref.scatter_rows_ref(base, ids, rows)
    return _scatter_pallas(base, ids, rows, interpret=not _on_tpu())


def sorted_slots(ids, n_slots: int,
                 residual: SortResidual | None = None) -> SortResidual:
    """Shared id-compaction residual: sort, flag first-of-group, cumsum to
    dense slot indices (clipped into n_slots).  THE residual producer —
    `segment_rows` / `unique_rows` consume its output, and a caller that
    already holds a step residual (`pm_forward.step_residual`) passes it
    through so no second sort is ever issued."""
    if residual is not None:
        return SortResidual(residual.order, residual.sorted_ids,
                            jnp.minimum(residual.slot, n_slots - 1))
    ids = ids.astype(jnp.int32)
    order = jnp.argsort(ids).astype(jnp.int32)
    s_ids = ids[order]
    is_new = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (s_ids[1:] != s_ids[:-1]).astype(jnp.int32)])
    slot = jnp.minimum(jnp.cumsum(is_new) - 1, n_slots - 1).astype(jnp.int32)
    return SortResidual(order, s_ids, slot)


@functools.partial(jax.jit, static_argnames=("n_slots",))
def segment_rows(ids, grads, n_slots: int, pad_id=0, residual=None):
    """Aggregate duplicate row ids: returns (slot_ids (n_slots,), summed
    grads (n_slots, D)).  Unused slots get id ``pad_id`` (default 0) with an
    all-zero gradient (a zero AdaGrad update is NOT a no-op — accum would
    stay, value moves by 0/sqrt(acc) = 0 — so zero rows are safe); a
    sentinel ``pad_id`` (e.g. the vocab size) lets scatter callers route pad
    slots to a trash row instead.

    ``residual``: a precomputed `SortResidual` for these ids (the managed
    step's single sort) — aggregation then runs sort-free.

    Static-shape friendly: n_slots >= number of distinct ids expected.
    """
    order, s_ids, slot = sorted_slots(ids, n_slots, residual)
    s_g = grads[order]
    out_g = jnp.zeros((n_slots, grads.shape[1]), dtype=jnp.float32)
    out_g = out_g.at[slot].add(s_g.astype(jnp.float32))
    # slots >= the unique count are never scattered to: they keep pad_id
    out_ids = jnp.full((n_slots,), jnp.int32(pad_id))
    out_ids = out_ids.at[slot].set(s_ids)
    return out_ids, out_g


def owner_segments(sorted_ids, n_valid, n_owners: int, block: int):
    """Per-owner segment boundaries of an ascending id list — the index
    stage of the destination-compacted mesh routing (DESIGN.md §12).

    ``sorted_ids`` must be ascending on its first ``n_valid`` entries
    (the probe/compact and segment contracts: unique ids claim slots in
    ascending-id order, so ownership grouping falls out of the step's one
    sort); entries past ``n_valid`` may hold anything.  Returns
    ``(view, seg)``: ``view[i] = sorted_ids[i]`` for ``i < n_valid`` and
    the out-of-vocab sentinel ``n_owners * block`` after, and ``seg``
    (``n_owners + 1`` entries) with ``seg[k]`` the first position owned by
    shard k — per-owner send counts are ``seg[1:] - seg[:-1]``.  Pure
    `searchsorted` over the ascending view: no sort is issued here."""
    sentinel = n_owners * block
    pos = jnp.arange(sorted_ids.shape[0], dtype=jnp.int32)
    view = jnp.where(pos < n_valid, sorted_ids.astype(jnp.int32), sentinel)
    bounds = jnp.arange(n_owners + 1, dtype=jnp.int32) * block
    seg = jnp.searchsorted(view, bounds).astype(jnp.int32)
    return view, seg


@functools.partial(jax.jit, static_argnames=("n_slots",))
def unique_rows(ids, n_slots: int, pad_id=0, residual=None):
    """Unique ids compacted into ``n_slots`` slots (unused slots keep
    ``pad_id``) — the id-only fast path of `segment_rows` for callers that
    already hold aggregated gradients (e.g. a dense autodiff grad).
    ``residual`` reuses a precomputed sort, as in `segment_rows`."""
    _, s_ids, slot = sorted_slots(ids, n_slots, residual)
    return jnp.full((n_slots,), jnp.int32(pad_id)).at[slot].set(s_ids)
