"""Pallas TPU kernels + index residuals: the fused intent-managed
embedding forward path.

The managed lookup (DESIGN.md §3c, §11) is a three-stage pipeline:

  probe   : binary-search every token against the sorted replica-cache ids;
  compact : deduplicate the missed ids and compact them into the planner's
            intent-sized buffer of M slots (per *unique* id — this is what
            makes `engine.intent_miss_bound` an exact bound);
  gather  : move the row data — the M unique missed rows come out of the
            owner-sharded table through the blocked `embed_gather` kernel,
            and the per-token select between cache row and miss-buffer row
            is the `pm_combine` kernel below.

Single-sort step residual (§11): the probe/compact stage used to be
re-derived by every consumer — the forward compaction, the backward
`segment_rows` pre-sum and the fused sparse-optimizer row dedup each ran
their own O(T log T) argsort over the same token ids.  `step_residual`
now computes everything a managed train/serve step needs from ONE argsort:

  * the ProbeCompact fields (hit flags, cache slots, unique-miss buffer);
  * the full-token sort permutation + per-token unique-group slot
    (`SortResidual`) that `ops.segment_rows` / `ops.unique_rows` consume
    instead of re-sorting.

The arithmetic lives in `_compact_math`, written once against a tiny
numpy/jnp shim so the device path and the serving runtime's host-side
admission probe (`pm.embedding.probe_host`) are literally the same code.

The row data-path — the part that is bandwidth-bound — never touches a
dense (T, D) table gather: hits read the replicated cache, misses read
the compact (M+1, D) buffer (slot M is the all-zeros overflow/trash row).
`pm_combine` moves it in (block_r, block_d) multi-row tiles: row indices
are scalar-prefetched into SMEM and each grid program issues one guarded
DMA per row — only the *winning* source row (cache or buffer) is staged
into VMEM, half the bytes of the old stage-both layout.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pad_d, pick_blocks


class ProbeCompact(NamedTuple):
    """Index-stage outputs of the managed lookup (all static shapes)."""

    hit: jnp.ndarray         # (T,) bool, token served by the replica cache
    cache_slot: jnp.ndarray  # (T,) int32 cache row (clipped; valid on hit)
    buf_ids: jnp.ndarray     # (M,) int32 UNIQUE missed ids (pad: 0)
    buf_slot: jnp.ndarray    # (T,) int32 buffer slot per token (M = trash)
    n_miss: jnp.ndarray      # () int32 count of unique missed ids
    overflow: jnp.ndarray    # (T,) bool, unique misses beyond capacity M


class SortResidual(NamedTuple):
    """The reusable product of one token-id argsort: enough to aggregate
    duplicate rows (`ops.segment_rows`) or compact unique ids
    (`ops.unique_rows`) without sorting again."""

    order: jnp.ndarray       # (T,) int32 argsort permutation of the ids
    sorted_ids: jnp.ndarray  # (T,) int32 ids[order]
    slot: jnp.ndarray        # (T,) int32 unique-group index per sorted pos


class StepResidual(NamedTuple):
    """Everything a managed step derives from its token ids, computed from
    a single argsort: the probe/compact index stage (forward) plus the
    full-token sort residual (backward pre-sum + sparse optimizer)."""

    probe: ProbeCompact
    sort: SortResidual
    n_uniq: jnp.ndarray      # () int32 unique token ids in the step


def _jnp_scatter_set(dst, idx, val):
    return dst.at[idx].set(val)


def _np_scatter_set(dst, idx, val):
    dst[idx] = val
    return dst


def _compact_math(xp, scatter_set, cache_ids, tok, miss_capacity: int):
    """THE probe/compact/segment arithmetic, once, for numpy and jnp.

    One argsort of the raw token ids orders every duplicate group; hits
    are identified independently by binary search, so the same sorted
    view yields (a) the unique *missed* ids in ascending order — each
    claims one dense buffer slot, duplicates share it, overflow beyond
    ``miss_capacity`` routes to the trash slot M — and (b) the unique-id
    compaction over ALL tokens that the backward/optimizer reuse.

    Deduplication is load-bearing: the planner's `intent_miss_bound`
    counts unique ids per step, so duplicate missed tokens must share one
    slot for the static capacity to be exact (see ISSUE 2)."""
    M = miss_capacity
    T = tok.shape[0]
    C = cache_ids.shape[0]
    int32 = xp.int32
    if C:
        cache_slot = xp.clip(xp.searchsorted(cache_ids, tok),
                             0, C - 1).astype(int32)
        hit = cache_ids[cache_slot] == tok
    else:
        cache_slot = xp.zeros((T,), int32)
        hit = xp.zeros((T,), bool)

    order = xp.argsort(tok).astype(int32)        # THE step's one sort
    s = tok[order]
    hs = hit[order]
    first = xp.concatenate([xp.ones((1,), bool), s[1:] != s[:-1]])
    # unique-id compaction over all tokens (backward/optimizer residual)
    seg_slot = (xp.cumsum(first.astype(int32)) - 1).astype(int32)
    n_uniq = xp.sum(first.astype(int32))
    # unique MISSED ids claim dense buffer slots in ascending-id order
    # (hit status is constant within a duplicate group)
    miss_first = first & ~hs
    mgrp = (xp.cumsum(miss_first.astype(int32)) - 1).astype(int32)
    n_miss = xp.sum(miss_first.astype(int32))
    in_buf = miss_first & (mgrp < M)
    buf_ids = scatter_set(xp.zeros((M + 1,), int32),
                          xp.where(in_buf, mgrp, M),
                          xp.where(in_buf, s, 0).astype(int32))[:M]
    slot_sorted = xp.where(~hs & (mgrp < M), mgrp, M).astype(int32)
    buf_slot = scatter_set(xp.zeros((T,), int32), order, slot_sorted)
    over_sorted = ~hs & (mgrp >= M)
    overflow = scatter_set(xp.zeros((T,), bool), order, over_sorted)
    return dict(hit=hit, cache_slot=cache_slot, buf_ids=buf_ids,
                buf_slot=buf_slot, n_miss=n_miss, overflow=overflow,
                order=order, sorted_ids=s.astype(int32), seg_slot=seg_slot,
                n_uniq=n_uniq)


@functools.partial(jax.jit, static_argnames=("miss_capacity",))
def step_residual(cache_ids: jnp.ndarray, tok: jnp.ndarray,
                  miss_capacity: int) -> StepResidual:
    """Probe (T,) tokens against the sorted cache and derive the FULL step
    residual — probe/compact index stage plus the reusable sort — from a
    single argsort.  Compute once per managed step; every other consumer
    (backward pre-sum, sparse row optimizer, kernel scalar prefetch) reads
    these arrays instead of re-sorting."""
    r = _compact_math(jnp, _jnp_scatter_set, cache_ids,
                      tok.astype(jnp.int32), miss_capacity)
    return StepResidual(
        probe=ProbeCompact(r["hit"], r["cache_slot"], r["buf_ids"],
                           r["buf_slot"], r["n_miss"], r["overflow"]),
        sort=SortResidual(r["order"], r["sorted_ids"], r["seg_slot"]),
        n_uniq=r["n_uniq"])


def probe_and_compact(cache_ids: jnp.ndarray, tok: jnp.ndarray,
                      miss_capacity: int) -> ProbeCompact:
    """Index-stage-only view of `step_residual` (serving probes and other
    callers that do not need the backward/optimizer sort residual)."""
    return step_residual(cache_ids, tok, miss_capacity).probe


def host_compact(cache_ids: np.ndarray, tok: np.ndarray,
                 miss_capacity: int) -> dict:
    """Numpy twin of `step_residual` for host-side admission probes — the
    SAME `_compact_math`, so device and host can never drift apart."""
    return _compact_math(np, _np_scatter_set, np.asarray(cache_ids),
                         np.asarray(tok, dtype=np.int32), miss_capacity)


# ------------------------------------------------------------- pm_combine

def _combine_kernel(hit_ref, cslot_ref, bslot_ref, cache_ref, buf_ref,
                    out_ref, sem):
    # multi-row tile: one guarded DMA per row, and only the WINNING source
    # row (cache on hit, miss buffer otherwise) ever moves into VMEM
    i, j = pl.program_id(0), pl.program_id(1)
    block_r, block_d = out_ref.shape
    T = hit_ref.shape[0]
    for r in range(block_r):
        row = i * block_r + r

        @pl.when(row < T)
        def _():
            hit = hit_ref[row] != 0

            @pl.when(hit)
            def _():
                dma = pltpu.make_async_copy(
                    cache_ref.at[cslot_ref[row],
                                 pl.ds(j * block_d, block_d)],
                    out_ref.at[r], sem)
                dma.start()
                dma.wait()

            @pl.when(jnp.logical_not(hit))
            def _():
                dma = pltpu.make_async_copy(
                    buf_ref.at[bslot_ref[row],
                               pl.ds(j * block_d, block_d)],
                    out_ref.at[r], sem)
                dma.start()
                dma.wait()


def _pad_cols(x, dp):
    d = x.shape[-1]
    return x if d == dp else jnp.pad(x, ((0, 0), (0, dp - d)))


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_d", "interpret"))
def _pm_combine(hit, cache_slot, buf_slot, cache_rows, buf_rows,
                block_r: int, block_d: int, interpret: bool):
    T = hit.shape[0]
    D = cache_rows.shape[1]
    dp = pad_d(D)
    grid = (-(-T // block_r), dp // block_d)
    out = pl.pallas_call(
        _combine_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            ],
            out_specs=pl.BlockSpec((block_r, block_d),
                                   lambda i, j, h, s, p: (i, j)),
            scratch_shapes=[pltpu.SemaphoreType.DMA],
        ),
        out_shape=jax.ShapeDtypeStruct((T, dp), cache_rows.dtype),
        interpret=interpret,
    )(hit.astype(jnp.int32), cache_slot.astype(jnp.int32),
      buf_slot.astype(jnp.int32), _pad_cols(cache_rows, dp),
      _pad_cols(buf_rows, dp))
    return out if dp == D else out[:, :D]


def pm_combine(hit: jnp.ndarray, cache_slot: jnp.ndarray,
               buf_slot: jnp.ndarray, cache_rows: jnp.ndarray,
               buf_rows: jnp.ndarray, *, block_r: int | None = None,
               block_d: int | None = None,
               interpret: bool = True) -> jnp.ndarray:
    """Per-token select: out[i] = cache_rows[cache_slot[i]] on hit else
    buf_rows[buf_slot[i]].  cache_rows (C, D); buf_rows (M+1, D) with the
    trash row last; returns (T, D).  Tiled (block_r, block_d); the feature
    dim is lane-padded, never shrunk (`kernels.blocking`)."""
    br, bd = pick_blocks("pm_combine", hit.shape[0], cache_rows.shape[1],
                         cache_rows.dtype, table_rows=cache_rows.shape[0],
                         block_r=block_r, block_d=block_d)
    return _pm_combine(hit, cache_slot, buf_slot, cache_rows, buf_rows,
                       block_r=br, block_d=bd, interpret=interpret)
