"""Pallas TPU kernels: the fused intent-managed embedding forward path.

The managed lookup (DESIGN.md §3c) is a three-stage pipeline:

  probe   : binary-search every token against the sorted replica-cache ids;
  compact : deduplicate the missed ids and compact them into the planner's
            intent-sized buffer of M slots (per *unique* id — this is what
            makes `engine.intent_miss_bound` an exact bound);
  gather  : move the row data — the M unique missed rows come out of the
            owner-sharded table through the blocked `embed_gather` kernel,
            and the per-token select between cache row and miss-buffer row
            is the `pm_combine` kernel below.

The probe/compact stage is pure int32 index arithmetic over (T,) vectors —
it runs on the scalar path and its outputs feed the kernels' scalar-prefetch
operands (`PrefetchScalarGridSpec`), exactly the pattern `embed_gather`
uses: indices live in SMEM, index_maps route the right (1, block_d) row
tiles of HBM-resident sources into VMEM.  The row data-path — the part that
is bandwidth-bound — never touches a dense (T, D) table gather: hits read
the replicated cache, misses read the compact (M+1, D) buffer (on TPU the
buffer is what the masked partial-sum all-reduce moves; slot M is the
all-zeros overflow/trash row).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pick_block_d

# any real token id is a vocab row index < 2**31 - 1.  numpy scalar on
# purpose: a module-level jnp constant would create a device array at
# import time and freeze the backend's device count before test/launch
# entry points get to set XLA_FLAGS (e.g. the forced host-device counts
# of tests/test_dryrun.py and the mesh CI job).
_SENTINEL = np.int32(2 ** 31 - 1)


class ProbeCompact(NamedTuple):
    """Index-stage outputs of the managed lookup (all static shapes)."""

    hit: jnp.ndarray         # (T,) bool, token served by the replica cache
    cache_slot: jnp.ndarray  # (T,) int32 cache row (clipped; valid on hit)
    buf_ids: jnp.ndarray     # (M,) int32 UNIQUE missed ids (pad: 0)
    buf_slot: jnp.ndarray    # (T,) int32 buffer slot per token (M = trash)
    n_miss: jnp.ndarray      # () int32 count of unique missed ids
    overflow: jnp.ndarray    # (T,) bool, unique misses beyond capacity M


def probe_and_compact(cache_ids: jnp.ndarray, tok: jnp.ndarray,
                      miss_capacity: int) -> ProbeCompact:
    """Probe (T,) tokens against the sorted cache and compact the *unique*
    missed ids into ``miss_capacity`` buffer slots.

    Deduplication is load-bearing: the planner's `intent_miss_bound` counts
    unique ids per step, so duplicate missed tokens must share one slot for
    the static capacity to be exact (each duplicate consuming its own slot
    silently overflowed the bound; see ISSUE 2)."""
    M = miss_capacity
    T = tok.shape[0]
    slot = jnp.searchsorted(cache_ids, tok)
    slot = jnp.clip(slot, 0, cache_ids.shape[0] - 1).astype(jnp.int32)
    hit = cache_ids[slot] == tok

    # sort the missed ids to the front (sentinel sorts hits to the back);
    # first-of-group flags give each unique missed id one dense slot
    miss_tok = jnp.where(hit, _SENTINEL, tok)
    order = jnp.argsort(miss_tok)            # stable
    s = miss_tok[order]
    valid = s != _SENTINEL
    first = valid & jnp.concatenate(
        [jnp.ones((1,), bool), s[1:] != s[:-1]])
    grp = jnp.cumsum(first.astype(jnp.int32)) - 1   # unique index per token
    n_miss = jnp.sum(first.astype(jnp.int32))

    in_buf = first & (grp < M)
    buf_ids = jnp.zeros((M + 1,), jnp.int32).at[
        jnp.where(in_buf, grp, M)].set(jnp.where(in_buf, s, 0))[:M]
    slot_sorted = jnp.where(valid & (grp < M), grp, M).astype(jnp.int32)
    buf_slot = jnp.zeros((T,), jnp.int32).at[order].set(slot_sorted)
    over_sorted = valid & (grp >= M)
    overflow = jnp.zeros((T,), bool).at[order].set(over_sorted)
    return ProbeCompact(hit, slot, buf_ids, buf_slot, n_miss, overflow)


def _combine_kernel(hit_ref, slot_ref, pos_ref, cache_ref, buf_ref, out_ref):
    # index_maps already staged the token's cache row tile and miss-buffer
    # row tile into VMEM; the scalar hit flag picks the winner.
    i = pl.program_id(0)
    out_ref[...] = jnp.where(hit_ref[i] != 0, cache_ref[...], buf_ref[...])


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def pm_combine(hit: jnp.ndarray, cache_slot: jnp.ndarray,
               buf_slot: jnp.ndarray, cache_rows: jnp.ndarray,
               buf_rows: jnp.ndarray, *, block_d: int = 512,
               interpret: bool = True) -> jnp.ndarray:
    """Per-token select: out[i] = cache_rows[cache_slot[i]] on hit else
    buf_rows[buf_slot[i]].  cache_rows (C, D); buf_rows (M+1, D) with the
    trash row last; returns (T, D)."""
    T = hit.shape[0]
    D = cache_rows.shape[1]
    block_d = pick_block_d(D, block_d)
    grid = (T, D // block_d)

    return pl.pallas_call(
        _combine_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_d),
                             lambda i, j, h, s, p: (s[i], j)),   # cache
                pl.BlockSpec((1, block_d),
                             lambda i, j, h, s, p: (p[i], j)),   # buffer
            ],
            out_specs=pl.BlockSpec((1, block_d),
                                   lambda i, j, h, s, p: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, D), cache_rows.dtype),
        interpret=interpret,
    )(hit.astype(jnp.int32), cache_slot.astype(jnp.int32),
      buf_slot.astype(jnp.int32), cache_rows, buf_rows)
