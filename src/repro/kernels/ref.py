"""Pure-jnp oracles for every kernel in `repro.kernels` (allclose targets
for the interpret-mode Pallas runs and the CPU fallback path)."""

from __future__ import annotations

import jax.numpy as jnp


def embed_gather_ref(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, ids.astype(jnp.int32), axis=0)


def adagrad_row_update_ref(table, accum, ids, grads, *, lr=0.1, eps=1e-8):
    """Summed-gradient AdaGrad on unique rows ``ids``."""
    ids = ids.astype(jnp.int32)
    g = grads.astype(jnp.float32)
    acc_rows = accum[ids].astype(jnp.float32) + g * g
    p_rows = table[ids].astype(jnp.float32) \
        - lr * g / (jnp.sqrt(acc_rows) + eps)
    new_accum = accum.at[ids].set(acc_rows.astype(accum.dtype))
    new_table = table.at[ids].set(p_rows.astype(table.dtype))
    return new_table, new_accum


def adagrad_row_add_ref(table, accum, ids, grads, *, lr=0.1, eps=1e-8):
    """Scatter-ADD based AdaGrad row update: exact for unique ``ids``
    plus any number of duplicate slots carrying all-zero gradients (the
    routed mesh path's pad slots all alias local row 0).  The set-based
    oracle above is undefined under duplicates (XLA picks one writer); the
    add form is deterministic — a zero-grad duplicate contributes 0 to the
    accumulator and 0 to the row delta."""
    ids = ids.astype(jnp.int32)
    g = grads.astype(jnp.float32)
    new_accum = accum.at[ids].add((g * g).astype(accum.dtype))
    denom = jnp.sqrt(new_accum[ids].astype(jnp.float32)) + eps
    new_table = table.at[ids].add((-lr * g / denom).astype(table.dtype))
    return new_table, new_accum


def pm_combine_ref(hit, cache_slot, buf_slot, cache_rows, buf_rows):
    """Per-token select between cache row and compact miss-buffer row."""
    hit_rows = jnp.take(cache_rows, cache_slot.astype(jnp.int32), axis=0)
    miss_rows = jnp.take(buf_rows, buf_slot.astype(jnp.int32), axis=0)
    return jnp.where(hit[:, None], hit_rows, miss_rows)


def scatter_rows_ref(base, ids, rows):
    """Row scatter of unique ids (pad collisions must carry equal rows)."""
    return base.at[ids.astype(jnp.int32)].set(rows.astype(base.dtype))


def segment_rows_ref(ids, grads, n_unique: int):
    """Aggregate duplicate row gradients: returns (unique_ids padded with
    table-size sentinel handled by caller, summed grads) — reference for
    `ops.segment_rows`."""
    import numpy as np
    ids_np = np.asarray(ids)
    uniq, inv = np.unique(ids_np, return_inverse=True)
    out = np.zeros((n_unique, grads.shape[1]), dtype=np.float32)
    np.add.at(out, inv, np.asarray(grads, dtype=np.float32))
    pad = n_unique - len(uniq)
    uniq = np.concatenate([uniq, np.full((pad,), -1, dtype=ids_np.dtype)])
    return uniq[:n_unique], out
