"""Pallas TPU kernel: fused sparse AdaGrad row update (scatter-apply).

The paper trains all five tasks with AdaGrad (§C); the write hot spot of a
parameter manager is applying sparse row updates:

    accum[id] += g^2
    table[id] -= lr * g / (sqrt(accum[id]) + eps)

TPU adaptation: the update is a scalar-prefetched blocked scatter with
input/output aliasing — program (i, j) stages tile (ids[i], j) of both the
table and the accumulator into VMEM, applies the fused update against the
i-th gradient row tile, and writes back in place (no separate gather /
square / rsqrt / scatter round trips through HBM).

Row ids must be UNIQUE within one call (duplicates are pre-aggregated by
`repro.kernels.ops.segment_rows`); the TPU grid executes sequentially so
duplicates would not race, but their semantics (sequential apply) would
differ from the summed-gradient oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pick_block_d


def _make_kernel(lr: float, eps: float):
    def kernel(ids_ref, table_ref, accum_ref, grad_ref,
               table_out, accum_out):
        g = grad_ref[...].astype(jnp.float32)
        acc = accum_ref[...].astype(jnp.float32) + g * g
        p = table_ref[...].astype(jnp.float32)
        p = p - lr * g / (jnp.sqrt(acc) + eps)
        accum_out[...] = acc.astype(accum_out.dtype)
        table_out[...] = p.astype(table_out.dtype)
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("lr", "eps", "block_d", "interpret"))
def adagrad_row_update(table: jnp.ndarray, accum: jnp.ndarray,
                       ids: jnp.ndarray, grads: jnp.ndarray, *,
                       lr: float = 0.1, eps: float = 1e-8,
                       block_d: int = 512, interpret: bool = True):
    """Apply AdaGrad to rows ``ids`` of (table, accum) with ``grads``.

    table, accum: (V, D); ids: (n,) unique int32; grads: (n, D).
    Returns (new_table, new_accum); both alias their inputs (in-place on
    TPU: donated buffers, no fresh HBM allocation for the full tables).
    """
    n = ids.shape[0]
    V, D = table.shape
    block_d = pick_block_d(D, block_d)
    grid = (n, D // block_d)

    def row_tile(i, j, ids_ref):
        return (ids_ref[i], j)

    def grad_tile(i, j, ids_ref):
        return (i, j)

    kernel = _make_kernel(float(lr), float(eps))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_d), row_tile),   # table
                pl.BlockSpec((1, block_d), row_tile),   # accum
                pl.BlockSpec((1, block_d), grad_tile),  # grads
            ],
            out_specs=[
                pl.BlockSpec((1, block_d), row_tile),
                pl.BlockSpec((1, block_d), row_tile),
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct(table.shape, table.dtype),
                   jax.ShapeDtypeStruct(accum.shape, accum.dtype)],
        input_output_aliases={1: 0, 2: 1},  # table->out0, accum->out1
        interpret=interpret,
    )(ids.astype(jnp.int32), table, accum, grads)
