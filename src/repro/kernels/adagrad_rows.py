"""Pallas TPU kernel: fused sparse AdaGrad row update (scatter-apply).

The paper trains all five tasks with AdaGrad (§C); the write hot spot of a
parameter manager is applying sparse row updates:

    accum[id] += g^2
    table[id] -= lr * g / (sqrt(accum[id]) + eps)

TPU adaptation: table and accumulator stay HBM-resident (``memory_space=
ANY``) and are donated in place (input/output aliasing — no fresh (V, D)
allocation per step).  Each grid program owns a ``(block_r, block_d)``
gradient tile (multi-row tiling, ~block_r× fewer programs than the old
one-row grid) and, per row: DMAs the table/accum row tile into VMEM
scratch, applies the fused update against the gradient row, and DMAs the
result back.  The copies are issued and waited in row order inside the
program and the grid is sequential, so a read always observes the
preceding write (the property the pad-slot reversal in `train.steps`
relies on).

Row ids must be UNIQUE within one call (duplicates are pre-aggregated by
`repro.kernels.ops.segment_rows`, which itself reuses the step's sort
residual); duplicate ids would not race, but their sequential-apply
semantics would differ from the summed-gradient oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pad_d, pick_blocks


def _make_kernel(lr: float, eps: float):
    def kernel(ids_ref, table_ref, accum_ref, grad_ref,
               table_out, accum_out, tbuf, abuf, sem):
        i, j = pl.program_id(0), pl.program_id(1)
        block_r, block_d = grad_ref.shape
        n = ids_ref.shape[0]
        for r in range(block_r):
            row = i * block_r + r

            @pl.when(row < n)
            def _():
                idx = ids_ref[row]
                col = pl.ds(j * block_d, block_d)
                cin = pltpu.make_async_copy(table_out.at[idx, col],
                                            tbuf.at[0], sem)
                cin.start()
                cin.wait()
                ain = pltpu.make_async_copy(accum_out.at[idx, col],
                                            abuf.at[0], sem)
                ain.start()
                ain.wait()
                g = grad_ref[r].astype(jnp.float32)
                acc = abuf[0].astype(jnp.float32) + g * g
                p = tbuf[0].astype(jnp.float32) \
                    - lr * g / (jnp.sqrt(acc) + eps)
                abuf[0] = acc.astype(abuf.dtype)
                tbuf[0] = p.astype(tbuf.dtype)
                cout = pltpu.make_async_copy(tbuf.at[0],
                                             table_out.at[idx, col], sem)
                cout.start()
                cout.wait()
                aout = pltpu.make_async_copy(abuf.at[0],
                                             accum_out.at[idx, col], sem)
                aout.start()
                aout.wait()
    return kernel


@functools.partial(jax.jit, static_argnames=("lr", "eps", "block_r",
                                             "block_d", "interpret"))
def _adagrad_row_update(table, accum, ids, grads, lr: float, eps: float,
                        block_r: int, block_d: int, interpret: bool):
    n = ids.shape[0]
    V, D = table.shape
    dp = pad_d(D)
    if dp != D:
        table = jnp.pad(table, ((0, 0), (0, dp - D)))
        accum = jnp.pad(accum, ((0, 0), (0, dp - D)))
        grads = jnp.pad(grads, ((0, 0), (0, dp - D)))
    grid = (-(-n // block_r), dp // block_d)
    ANY = pltpu.TPUMemorySpace.ANY
    out = pl.pallas_call(
        _make_kernel(float(lr), float(eps)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=ANY),                   # table
                pl.BlockSpec(memory_space=ANY),                   # accum
                pl.BlockSpec((block_r, block_d),
                             lambda i, j, ids_ref: (i, j)),       # grads
            ],
            out_specs=[pl.BlockSpec(memory_space=ANY),
                       pl.BlockSpec(memory_space=ANY)],
            scratch_shapes=[pltpu.VMEM((1, block_d), table.dtype),
                            pltpu.VMEM((1, block_d), accum.dtype),
                            pltpu.SemaphoreType.DMA],
        ),
        out_shape=[jax.ShapeDtypeStruct((V, dp), table.dtype),
                   jax.ShapeDtypeStruct((V, dp), accum.dtype)],
        input_output_aliases={1: 0, 2: 1},  # table->out0, accum->out1
        interpret=interpret,
    )(ids.astype(jnp.int32), table, accum, grads)
    if dp != D:
        out = [o[:, :D] for o in out]
    return tuple(out)


def adagrad_row_update(table: jnp.ndarray, accum: jnp.ndarray,
                       ids: jnp.ndarray, grads: jnp.ndarray, *,
                       lr: float = 0.1, eps: float = 1e-8,
                       block_r: int | None = None,
                       block_d: int | None = None,
                       interpret: bool = True):
    """Apply AdaGrad to rows ``ids`` of (table, accum) with ``grads``.

    table, accum: (V, D); ids: (n,) unique int32; grads: (n, D).
    Returns (new_table, new_accum); both alias their inputs (in-place on
    TPU: donated buffers, no fresh HBM allocation for the full tables).
    """
    n = ids.shape[0]
    D = table.shape[1]

    def bench(br, bd):
        from .blocking import probe_ids, time_bench
        t = jnp.zeros(table.shape, table.dtype)
        a = jnp.zeros(accum.shape, accum.dtype)
        z = probe_ids(n, table.shape[0])
        g = jnp.zeros(grads.shape, grads.dtype)
        return time_bench(
            lambda: _adagrad_row_update(t, a, z, g, lr, eps, br, bd,
                                        interpret))

    br, bd = pick_blocks("adagrad", n, D, table.dtype,
                         table_rows=table.shape[0], block_r=block_r,
                         block_d=block_d, bench=bench)
    return _adagrad_row_update(table, accum, ids, grads, lr=lr, eps=eps,
                               block_r=br, block_d=bd, interpret=interpret)
