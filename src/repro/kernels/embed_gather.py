"""Pallas TPU kernel: blocked sparse row gather from an embedding table.

This is the read hot spot the paper's parameter manager serves (embedding /
KGE / CTR rows).  TPU adaptation: instead of per-key RPCs, the gather is a
scalar-prefetched blocked copy — the row ids live in SMEM (scalar prefetch),
and the grid's index_map uses them to select which (1, block_d) tile of the
HBM-resident table is staged into VMEM for each program instance.  The MXU
is not involved; the kernel is bandwidth-bound by design, and block_d is
sized so a tile is a multiple of the (8, 128) VREG lane layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pick_block_d


def _gather_kernel(ids_ref, table_ref, out_ref):
    # The index_map already routed the right table row-tile into VMEM.
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def embed_gather(table: jnp.ndarray, ids: jnp.ndarray, *,
                 block_d: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Gather ``table[ids]``: table (V, D), ids (n,) int32 -> (n, D).

    Grid: (n, D // block_d); program (i, j) copies tile
    ``table[ids[i], j*block_d : (j+1)*block_d]`` via VMEM.
    """
    n = ids.shape[0]
    V, D = table.shape
    block_d = pick_block_d(D, block_d)
    grid = (n, D // block_d)

    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_d),
                             lambda i, j, ids_ref: (ids_ref[i], j)),
            ],
            out_specs=pl.BlockSpec((1, block_d), lambda i, j, ids_ref: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, D), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
