"""Pallas TPU kernel: blocked sparse row gather from an embedding table.

This is the read hot spot the paper's parameter manager serves (embedding /
KGE / CTR rows).  TPU adaptation: instead of per-key RPCs, the gather is a
scalar-prefetched blocked copy — the row ids live in SMEM (scalar
prefetch), the table stays HBM-resident (``memory_space=ANY``), and each
grid program issues one guarded async DMA per row of its
``(block_r, block_d)`` output tile.  Multi-row tiling shrinks the grid
~block_r× versus the old one-row-per-program layout; the MXU is not
involved; the kernel is bandwidth-bound by design, and block_d is a
multiple of the (8, 128) VREG lane layout — non-aligned feature dims are
padded up, never tiled down (`kernels.blocking`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pad_d, pick_blocks


def _gather_kernel(ids_ref, table_ref, out_ref, sem):
    i, j = pl.program_id(0), pl.program_id(1)
    block_r, block_d = out_ref.shape
    n = ids_ref.shape[0]
    for r in range(block_r):
        row = i * block_r + r

        @pl.when(row < n)
        def _():
            dma = pltpu.make_async_copy(
                table_ref.at[ids_ref[row], pl.ds(j * block_d, block_d)],
                out_ref.at[r], sem)
            dma.start()
            dma.wait()


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_d", "interpret"))
def _embed_gather(table, ids, block_r: int, block_d: int, interpret: bool):
    n = ids.shape[0]
    V, D = table.shape
    dp = pad_d(D)
    if dp != D:
        table = jnp.pad(table, ((0, 0), (0, dp - D)))
    grid = (-(-n // block_r), dp // block_d)
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
            out_specs=pl.BlockSpec((block_r, block_d),
                                   lambda i, j, ids_ref: (i, j)),
            scratch_shapes=[pltpu.SemaphoreType.DMA],
        ),
        out_shape=jax.ShapeDtypeStruct((n, dp), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
    return out if dp == D else out[:, :D]


def embed_gather(table: jnp.ndarray, ids: jnp.ndarray, *,
                 block_r: int | None = None, block_d: int | None = None,
                 interpret: bool = True) -> jnp.ndarray:
    """Gather ``table[ids]``: table (V, D), ids (n,) int32 -> (n, D).

    Grid: (ceil(n / block_r), D' // block_d); program (i, j) DMA-copies
    the j-tile of ``block_r`` table rows into its output tile."""
    n = ids.shape[0]
    D = table.shape[1]

    def bench(br, bd):
        from .blocking import probe_ids, time_bench
        t = jnp.zeros(table.shape, table.dtype)
        z = probe_ids(n, table.shape[0])
        return time_bench(lambda: _embed_gather(t, z, br, bd, interpret))

    br, bd = pick_blocks("gather", n, D, table.dtype,
                         table_rows=table.shape[0], block_r=block_r,
                         block_d=block_d, bench=bench)
    return _embed_gather(table, ids, block_r=br, block_d=bd,
                         interpret=interpret)
