"""Pallas TPU kernel: blocked sparse row gather from an embedding table.

This is the read hot spot the paper's parameter manager serves (embedding /
KGE / CTR rows).  TPU adaptation: instead of per-key RPCs, the gather is a
scalar-prefetched blocked copy — the row ids live in SMEM (scalar
prefetch), the table stays HBM-resident (``memory_space=ANY``), and each
grid program issues one guarded async DMA per row of its
``(block_r, block_d)`` output tile, double-buffered over two DMA
semaphores so row r+1's fetch is in flight while row r completes (the
intra-tile half of the ISSUE-9 prefetch pipeline).  Multi-row tiling
shrinks the grid
~block_r× versus the old one-row-per-program layout; the MXU is not
involved; the kernel is bandwidth-bound by design, and block_d is a
multiple of the (8, 128) VREG lane layout — non-aligned feature dims are
padded up, never tiled down (`kernels.blocking`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pad_d, pick_blocks


def _gather_kernel(ids_ref, table_ref, out_ref, sem):
    # double-buffered row prefetch: the copy for row r+1 is started before
    # the wait on row r, so the next row's HBM fetch overlaps the current
    # row's completion instead of serializing start->wait per row.  The
    # two DMAs alternate over a 2-deep semaphore array; start and wait
    # pair up by reconstructing the same copy descriptor (equal
    # parameters -> same semaphore slot).
    i, j = pl.program_id(0), pl.program_id(1)
    block_r, block_d = out_ref.shape
    n = ids_ref.shape[0]

    def copy(r, slot):
        row = i * block_r + r
        return pltpu.make_async_copy(
            table_ref.at[ids_ref[row], pl.ds(j * block_d, block_d)],
            out_ref.at[r], sem.at[slot])

    @pl.when(i * block_r < n)
    def _():
        copy(0, 0).start()

    for r in range(block_r):
        row = i * block_r + r
        if r + 1 < block_r:
            @pl.when(row + 1 < n)
            def _():
                copy(r + 1, (r + 1) % 2).start()

        @pl.when(row < n)
        def _():
            copy(r, r % 2).wait()


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_d", "interpret"))
def _embed_gather(table, ids, block_r: int, block_d: int, interpret: bool):
    n = ids.shape[0]
    V, D = table.shape
    dp = pad_d(D)
    if dp != D:
        table = jnp.pad(table, ((0, 0), (0, dp - D)))
    grid = (-(-n // block_r), dp // block_d)
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
            out_specs=pl.BlockSpec((block_r, block_d),
                                   lambda i, j, ids_ref: (i, j)),
            scratch_shapes=[pltpu.SemaphoreType.DMA((2,))],
        ),
        out_shape=jax.ShapeDtypeStruct((n, dp), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
    return out if dp == D else out[:, :D]


def embed_gather(table: jnp.ndarray, ids: jnp.ndarray, *,
                 block_r: int | None = None, block_d: int | None = None,
                 interpret: bool = True) -> jnp.ndarray:
    """Gather ``table[ids]``: table (V, D), ids (n,) int32 -> (n, D).

    Grid: (ceil(n / block_r), D' // block_d); program (i, j) DMA-copies
    the j-tile of ``block_r`` table rows into its output tile."""
    n = ids.shape[0]
    D = table.shape[1]

    def bench(br, bd):
        from .blocking import probe_ids, time_bench
        t = jnp.zeros(table.shape, table.dtype)
        z = probe_ids(n, table.shape[0])
        return time_bench(lambda: _embed_gather(t, z, br, bd, interpret))

    br, bd = pick_blocks("gather", n, D, table.dtype,
                         table_rows=table.shape[0], block_r=block_r,
                         block_d=block_d, bench=bench)
    return _embed_gather(table, ids, block_r=br, block_d=bd,
                         interpret=interpret)
