"""Shared tile-size selection for the row-blocked kernels."""

from __future__ import annotations


def pick_block_d(d: int, block_d: int) -> int:
    """Largest divisor of ``d`` that is <= ``block_d``: the row kernels
    tile the feature dim in (1, block_d) blocks, so the tile must divide D
    exactly (e.g. D=576 with the default 512 cap -> 288).  Multiples of
    128 (the VREG lane width) are preferred automatically whenever D
    itself is lane-aligned; trace-time only, so the linear scan is free."""
    b = max(1, min(block_d, d))
    while d % b:
        b -= 1
    return b
