"""Tile-size selection for the row-blocked kernels: lane-aligned feature
padding plus a small measured autotuner.

Every row kernel in this package moves `(block_r, block_d)` tiles of row
data (multi-row tiling — the grid is ``(ceil(n / block_r), D' / block_d)``,
a ~block_r× smaller grid than the old one-row-per-program layout).  Two
decisions live here so the kernels stay mechanical:

  feature dim   ``pad_d`` rounds D up to the next multiple of the 128-lane
                VREG width.  Non-lane-aligned D (576, 570, ...) used to
                silently shrink the tile to the largest divisor (D=570 ->
                block 2 — a 285× grid blow-up); now the kernels pad the
                feature dim and keep full-lane tiles, slicing the pad off
                on the way out.  Lane-aligned D pays nothing; odd D pays
                full pad/slice copies of the row operands (and forfeits
                in-place donation for that call) — keep embedding dims
                lane-aligned on the hot path, padding is the correctness
                escape hatch.
  tile shape    `pick_blocks` answers (block_r, block_d) per
                (kind, n, d, dtype, backend).  The default is a cheap
                heuristic; when measurement is enabled the caller hands in
                a ``bench(block_r, block_d) -> seconds`` probe and the
                result is cached per key, so each shape is measured once
                per process (trace-time only — kernels re-trace per shape
                anyway).

Overrides, strongest first: `set_block_override()` (config hook used by
tests and launch scripts), then the ``REPRO_BLOCK_R`` / ``REPRO_BLOCK_D``
environment variables, then the autotuner cache.  ``REPRO_AUTOTUNE``
selects the tuning mode: ``auto`` (default — measure only on a real
accelerator backend, heuristic on CPU where interpret-mode timing is
meaningless), ``measure`` (always measure when a bench probe is given),
``off`` (heuristic only).
"""

from __future__ import annotations

import os
from typing import Callable, Dict, Optional, Tuple

LANE = 128            # VREG lane width: feature tiles are multiples of this
DEFAULT_BLOCK_D = 512  # cap on the feature-tile width
DEFAULT_BLOCK_R = 8    # rows per program (multi-row tiling)
_ROW_CANDIDATES = (1, 2, 4, 8, 16)

_TUNE_CACHE: Dict[tuple, Tuple[int, int]] = {}
_OVERRIDE: Dict[str, Optional[int]] = {"block_r": None, "block_d": None}


def pad_d(d: int) -> int:
    """Feature dim rounded up to the next multiple of the 128-lane width
    (the kernels pad their row data to this and slice the pad off)."""
    return -(-d // LANE) * LANE


def pick_block_d(d: int, block_d: int = DEFAULT_BLOCK_D) -> int:
    """Largest lane-multiple tile width that divides the *padded* feature
    dim and is <= the ``block_d`` cap (never below one 128-lane tile).

    The old rule returned the largest divisor of the raw D, so D=576
    shrank the tile to 288 and D=570 collapsed it to 2; padding keeps the
    tile full-width regardless of alignment."""
    lanes = pad_d(d) // LANE
    cap = max(1, block_d // LANE)
    best = 1
    for k in range(1, lanes + 1):
        if lanes % k == 0 and k <= cap:
            best = k
    return best * LANE


def set_block_override(block_r: Optional[int] = None,
                       block_d: Optional[int] = None) -> None:
    """Config hook: pin the tile shape globally (None clears a field).
    Takes effect for kernels traced after the call."""
    _OVERRIDE["block_r"] = block_r
    _OVERRIDE["block_d"] = block_d


def clear_autotune_cache() -> None:
    _TUNE_CACHE.clear()


def probe_ids(n: int, n_rows: int):
    """Row ids for an autotune measurement probe: spread over the table
    (unique whenever n <= n_rows) so the timed DMA pattern resembles a
    real scattered access, not n hits on row 0."""
    import jax.numpy as jnp
    return (jnp.arange(n, dtype=jnp.int32) % max(1, n_rows))


def time_bench(fn: Callable, iters: int = 3) -> float:
    """Seconds per call of ``fn()`` (one untimed warmup/compile call) —
    the measurement probe the kernel wrappers hand to `pick_blocks`."""
    import time

    import jax
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _env_int(name: str) -> Optional[int]:
    v = os.environ.get(name)
    return int(v) if v else None


def _measure_enabled(bench) -> bool:
    if bench is None:
        return False
    mode = os.environ.get("REPRO_AUTOTUNE", "auto")
    if mode == "off":
        return False
    if mode == "measure":
        return True
    # "auto": interpret-mode timings on CPU are meaningless; only measure
    # where the kernels compile natively
    import jax
    return jax.default_backend() == "tpu"


def pick_blocks(kind: str, n: int, d: int, dtype=None, *,
                table_rows: Optional[int] = None,
                block_r: Optional[int] = None,
                block_d: Optional[int] = None,
                bench: Optional[Callable[[int, int], float]] = None,
                ) -> Tuple[int, int]:
    """Tile shape for an (n, d) row kernel: explicit args win, then the
    `set_block_override` / env overrides, then the measured cache, then
    the heuristic.  ``bench(block_r, block_d) -> seconds`` enables the
    measured path (see module docstring for the mode switch); results are
    cached per (kind, n, d, dtype, table_rows, backend).

    ``table_rows``: the height of the table-side operand (the gather /
    scatter / update target).  It shapes the measured DMA pattern — the
    probe spreads ids over the table — so it MUST be part of the cache
    key: inside a `shard_map` the same (kind, n, d) call sees the
    shard-local ``V / n_shards`` block, and a tile measured against the
    full single-device V would otherwise be served stale to the mesh run
    (and vice versa)."""
    br = block_r if block_r is not None else \
        _OVERRIDE["block_r"] if _OVERRIDE["block_r"] is not None else \
        _env_int("REPRO_BLOCK_R")
    bd = block_d if block_d is not None else \
        _OVERRIDE["block_d"] if _OVERRIDE["block_d"] is not None else \
        _env_int("REPRO_BLOCK_D")
    bd = pick_block_d(d, bd if bd is not None else DEFAULT_BLOCK_D)
    if br is not None:
        return max(1, min(br, n)), bd

    import jax
    key = (kind, n, d, str(dtype), table_rows, jax.default_backend(), bd)
    if key in _TUNE_CACHE:
        return _TUNE_CACHE[key]
    if _measure_enabled(bench):
        timed = []
        for cand in _ROW_CANDIDATES:
            if cand > max(1, n):
                break
            timed.append((bench(cand, bd), cand))
        br = min(timed)[1] if timed else 1
        source = "measured"
    else:
        br = max(1, min(DEFAULT_BLOCK_R, n))
        source = "heuristic"
    _TUNE_CACHE[key] = (br, bd)
    # every fresh tile decision lands on the process-wide signal bus
    # (one event per cache key: re-hits return above), so runs can audit
    # which shapes were measured vs. defaulted (DESIGN.md §13)
    from repro.obs.telemetry import default_bus
    default_bus().event("autotune.blocks", kind=kind, n=n, d=d,
                        block_r=br, block_d=bd, source=source)
    return br, bd
