"""Pallas TPU kernel: row-wise scatter of compact gradient rows into the
owner-sharded table gradient.

Backward of the managed lookup: duplicate token gradients are pre-summed
(`ops.segment_rows` fed by the step's sort residual — no extra sort), then
this kernel writes each aggregated row into its table slot.  The dense
(V, D) gradient is the donated zero buffer (``memory_space=ANY`` +
input/output aliasing, in-place on TPU) and only the touched row tiles
ever move: each grid program issues one guarded VMEM->HBM DMA per row of
its ``(block_r, block_d)`` gradient tile (multi-row tiling, ~block_r×
fewer grid programs than the old one-row layout).

Row ids must be unique; pad slots point at a caller-provided trash row
(the managed path uses row V of a (V+1, D) buffer, sliced off afterwards),
so colliding pad writes are harmless last-wins zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pad_d, pick_blocks


def _scatter_kernel(ids_ref, base_ref, rows_ref, out_ref, sem):
    i, j = pl.program_id(0), pl.program_id(1)
    block_r, block_d = rows_ref.shape
    n = ids_ref.shape[0]
    for r in range(block_r):
        row = i * block_r + r

        @pl.when(row < n)
        def _():
            dma = pltpu.make_async_copy(
                rows_ref.at[r],
                out_ref.at[ids_ref[row], pl.ds(j * block_d, block_d)], sem)
            dma.start()
            dma.wait()


@functools.partial(jax.jit,
                   static_argnames=("block_r", "block_d", "interpret"))
def _scatter_rows(base, ids, rows, block_r: int, block_d: int,
                  interpret: bool):
    n = ids.shape[0]
    R, D = base.shape
    dp = pad_d(D)
    if dp != D:
        base = jnp.pad(base, ((0, 0), (0, dp - D)))
        rows = jnp.pad(rows, ((0, 0), (0, dp - D)))
    grid = (-(-n // block_r), dp // block_d)
    out = pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),  # base
                pl.BlockSpec((block_r, block_d),
                             lambda i, j, ids_ref: (i, j)),           # rows
            ],
            out_specs=pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            scratch_shapes=[pltpu.SemaphoreType.DMA],
        ),
        out_shape=jax.ShapeDtypeStruct((R, dp), base.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(ids.astype(jnp.int32), base, rows.astype(base.dtype))
    return out if dp == D else out[:, :D]


def scatter_rows(base: jnp.ndarray, ids: jnp.ndarray, rows: jnp.ndarray, *,
                 block_r: int | None = None, block_d: int | None = None,
                 interpret: bool = True) -> jnp.ndarray:
    """out = base with out[ids[i]] = rows[i]; base (R, D) is donated
    (in-place on TPU), ids (n,) int32 unique row indices, rows (n, D)."""
    n = ids.shape[0]
    D = base.shape[1]

    def bench(br, bd):
        from .blocking import probe_ids, time_bench
        b = jnp.zeros(base.shape, base.dtype)
        z = probe_ids(n, base.shape[0])
        g = jnp.zeros(rows.shape, rows.dtype)
        return time_bench(lambda: _scatter_rows(b, z, g, br, bd, interpret))

    br, bd = pick_blocks("scatter", n, D, base.dtype,
                         table_rows=base.shape[0], block_r=block_r,
                         block_d=block_d, bench=bench)
    return _scatter_rows(base, ids, rows, block_r=br, block_d=bd,
                         interpret=interpret)
