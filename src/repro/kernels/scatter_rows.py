"""Pallas TPU kernel: row-wise scatter of compact gradient rows into the
owner-sharded table gradient.

Backward of the managed lookup: duplicate token gradients are pre-summed
(`ops.segment_rows`, one compact (n, D) buffer), then this kernel writes
each aggregated row into its table slot — a scalar-prefetched blocked
scatter with input/output aliasing, so the dense (V, D) gradient is the
donated zero buffer and only the touched row tiles ever move through VMEM.

Rows ids must be unique; pad slots point at a caller-provided trash row
(the managed path uses row V of a (V+1, D) buffer, sliced off afterwards),
so colliding pad writes are harmless last-wins zeros.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .blocking import pick_block_d


def _scatter_kernel(ids_ref, base_ref, rows_ref, out_ref):
    # index_map routed out tile (ids[i], j); pure blocked row write.
    out_ref[...] = rows_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def scatter_rows(base: jnp.ndarray, ids: jnp.ndarray, rows: jnp.ndarray, *,
                 block_d: int = 512, interpret: bool = True) -> jnp.ndarray:
    """out = base with out[ids[i]] = rows[i]; base (R, D) is donated
    (in-place on TPU), ids (n,) int32 unique row indices, rows (n, D)."""
    n = ids.shape[0]
    R, D = base.shape
    block_d = pick_block_d(D, block_d)
    grid = (n, D // block_d)

    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_d),
                             lambda i, j, ids_ref: (ids_ref[i], j)),  # base
                pl.BlockSpec((1, block_d),
                             lambda i, j, ids_ref: (i, j)),           # rows
            ],
            out_specs=pl.BlockSpec((1, block_d),
                                   lambda i, j, ids_ref: (ids_ref[i], j)),
        ),
        out_shape=jax.ShapeDtypeStruct((R, D), base.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(ids.astype(jnp.int32), base, rows.astype(base.dtype))
