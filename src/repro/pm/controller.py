"""Zero-tuning online controller for runtime knobs (DESIGN.md §13).

The paper's contract is that the *task* signals (easy) while the
*manager* adapts (hard, automatic) — yet through PR 6 every runtime layer
still exposed hand-set constants: replica-cache capacity, replan/refresh
cadence, serve micro-batch size, double-buffered admission on/off.  This
module closes the loop, extending the PR-5 measured block autotuner's
pattern (probe, cache per bucket, never re-measure a shape) from kernel
tiles to runtime parameters.  Two mechanisms, by information source:

  signal rules   knobs the intent signals fully determine get *computed*,
                 not searched: replica-cache capacity follows the queued
                 horizon's cache-worthy demand (`steer_capacity` — grow
                 immediately on the hard signal, shrink only after the
                 demand stays low for ``shrink_patience`` consecutive
                 replans), and double-buffered admission turns on exactly
                 when the measured admission/execute overlap ratio pays
                 (`overlap_pays`).  This is "Towards Self-Tuning Parameter
                 Servers"'s observation specialized by exact intent: when
                 the workload is known in advance, the right capacity is
                 arithmetic, and measurement is only a refinement.
  hill-climb     knobs whose effect is a wall-clock property of THIS host
                 (replan cadence, micro-batch size, refresh cadence) are
                 searched online: epsilon-greedy coordinate hill-climb
                 over small bucketed ladders (MLtuner's trial-and-revert,
                 one knob in flight at a time so reward attribution stays
                 clean).  A trial epoch's reward is compared against the
                 epoch before it; improving moves stick, worsening moves
                 revert, and ties follow the knob's ``prefer_low`` bias
                 (e.g. shrink capacity on a plateau — same throughput for
                 less memory).

Every knob value lives on a bucketed ladder (powers of two for capacity),
so downstream jitted executables specialize per bucket and revisiting a
bucket never recompiles — the exact discipline of
`serve.runtime._managed_fn(route_cap)` and the train loop's
miss-capacity `step_fns`.

Decisions and their causes are published to the telemetry bus
(``ctl.*`` events), so benches and tests can assert on *why* a knob
moved, not just where it ended up.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.telemetry import Telemetry

AUTO = "auto"


def is_auto(v) -> bool:
    """True when a config field asks for controller management."""
    return isinstance(v, str) and v == AUTO


def resolve_knob(v, default):
    """Initial (untuned) value for a config field: explicit values pass
    through; ``"auto"`` starts at ``default`` and is adapted online."""
    return default if is_auto(v) else v


def pow2_ladder(lo: int, hi: int) -> Tuple[int, ...]:
    """Powers of two in [lo, hi] (ladder buckets == jit-cache buckets)."""
    vals = []
    v = 1
    while v < lo:
        v *= 2
    while v <= hi:
        vals.append(v)
        v *= 2
    return tuple(vals) or (lo,)


def capacity_ladder(vocab: int, floor: int = 64,
                    max_frac: int = 8) -> Tuple[int, ...]:
    """Replica-cache capacity buckets: powers of two from ``floor`` up to
    ``vocab / max_frac``.  The cap is scale-free on purpose (a fraction of
    the table, not a tuned row count): replicating more than 1/8 of the
    vocabulary stops being *selective* replication and the refresh gather
    starts to dominate the replan."""
    return pow2_ladder(floor, max(floor, vocab // max_frac))


def overlap_pays(ratio: Optional[float],
                 threshold: float = 1.15) -> bool:
    """Auto-enable rule for double-buffered admission: the one-slot
    pipeline is worth its extra in-flight state only when the measured
    admission/execute overlap ratio beats ``threshold`` (1.0 = one side
    completely dominates, 2.0 = perfectly balanced halves)."""
    return ratio is not None and ratio >= threshold


@dataclass
class Knob:
    """One controlled parameter on a bucketed ladder.

    ``adapt=False`` knobs are rule-steered only (`steer_capacity` /
    `force_at_least`) and skipped by the hill-climb; ``prefer_low`` breaks
    reward ties toward the smaller ladder index (cheaper resource)."""

    name: str
    ladder: Tuple
    index: int = 0
    adapt: bool = True
    prefer_low: bool = False

    def __post_init__(self) -> None:
        self.ladder = tuple(self.ladder)
        self.index = max(0, min(self.index, len(self.ladder) - 1))

    @property
    def value(self):
        return self.ladder[self.index]


@dataclass
class _Trial:
    name: str
    old_index: int
    new_index: int
    base_reward: float


class OnlineController:
    """Epsilon-greedy coordinate hill-climb plus signal rules over a set
    of `Knob`s.  The owner calls `observe(reward)` once per decision
    boundary (a replan round with a measured epoch behind it) and applies
    the returned ``{name: value}`` changes."""

    def __init__(self, knobs: Sequence[Knob], telemetry: Telemetry = None,
                 *, epsilon: float = 0.2, tol: float = 0.05,
                 shrink_patience: int = 2, settle_after: int = 2,
                 seed: int = 0):
        self.knobs: Dict[str, Knob] = {k.name: k for k in knobs}
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self.epsilon = epsilon
        self.tol = tol
        self.shrink_patience = shrink_patience
        # exploration budget: a knob whose last ``settle_after`` trials
        # all reverted is SETTLED (frozen out of the climb) — trial
        # epochs run at a deliberately wrong value, so unbounded
        # exploration taxes steady-state throughput for nothing once the
        # neighborhood is known flat.  A signal-rule move (`force_at_
        # least` / `steer_capacity`) un-settles every knob: the regime
        # changed, the old verdicts are stale.
        self.settle_after = settle_after
        self._rng = np.random.default_rng(seed)
        self._adjustable: List[str] = [
            k.name for k in knobs if k.adapt and len(k.ladder) > 1]
        self._cycle = itertools.cycle(self._adjustable) \
            if self._adjustable else None
        self._trial: Optional[_Trial] = None
        self._last_dir: Dict[str, int] = {}
        self._low_streak: Dict[str, int] = {}
        self._revert_streak: Dict[str, int] = {}
        self.decisions = 0

    def _settled(self, name: str) -> bool:
        return self._revert_streak.get(name, 0) >= self.settle_after

    def _unsettle(self) -> None:
        self._revert_streak.clear()

    # ------------------------------------------------------------- reads
    def value(self, name: str):
        return self.knobs[name].value

    def values(self) -> Dict[str, object]:
        return {n: k.value for n, k in self.knobs.items()}

    # ------------------------------------------------------ signal rules
    def force_at_least(self, name: str, target,
                       cause: str = "signal") -> Optional[object]:
        """Hard signal: jump ``name`` to the first ladder bucket >=
        ``target`` (clamped to the top).  Returns the new value when the
        knob moved, else None.  Cancels any in-flight trial on the knob —
        a forced move invalidates the trial's reward attribution."""
        knob = self.knobs[name]
        idx = next((i for i, v in enumerate(knob.ladder) if v >= target),
                   len(knob.ladder) - 1)
        if idx <= knob.index:
            return None
        self._cancel_trial(name)
        self._unsettle()
        knob.index = idx
        # `target` rides along: the triggering signal (e.g. the intent
        # demand count), so attribution records show WHY the knob moved
        self.telemetry.event("ctl.force", knob=name, value=knob.value,
                             cause=cause, target=int(target))
        return knob.value

    def steer_capacity(self, name: str, demand: int,
                       headroom: float = 1.0) -> Optional[object]:
        """Intent-signal capacity rule: the queued horizon says exactly
        how many rows are worth caching (``demand``), so the bucket is
        computed, not searched.  Growth applies immediately (misses are
        being paid NOW); shrink waits for ``shrink_patience`` consecutive
        low-demand replans and a >= 4x gap (hysteresis: a drift spike must
        not thrash the jit buckets).  Returns the new value or None."""
        knob = self.knobs[name]
        target = max(1, int(demand * headroom))
        grown = self.force_at_least(name, target, cause="demand")
        if grown is not None:
            self._low_streak[name] = 0
            return grown
        if target * 4 <= knob.value and knob.index > 0:
            self._low_streak[name] = self._low_streak.get(name, 0) + 1
            if self._low_streak[name] >= self.shrink_patience:
                self._low_streak[name] = 0
                self._cancel_trial(name)
                self._unsettle()
                idx = next((i for i, v in enumerate(knob.ladder)
                            if v >= target), len(knob.ladder) - 1)
                knob.index = idx
                self.telemetry.event("ctl.force", knob=name,
                                     value=knob.value, cause="demand_low",
                                     target=int(target))
                return knob.value
        else:
            self._low_streak[name] = 0
        return None

    # ---------------------------------------------------- measured climb
    def observe(self, reward: float) -> Dict[str, object]:
        """One decision boundary with the epoch's measured reward (higher
        is better, e.g. served requests/s or loss-drop/s).  Concludes the
        in-flight trial (accept or revert) or proposes the next move;
        returns the knob values the caller must apply ({} = no change)."""
        self.decisions += 1
        self.telemetry.set("ctl.decisions", self.decisions)
        changed: Dict[str, object] = {}
        if self._trial is not None:
            t, self._trial = self._trial, None
            knob = self.knobs[t.name]
            down = t.new_index < t.old_index
            gate = (1.0 - self.tol) if (down and knob.prefer_low) \
                else (1.0 + self.tol)
            accept = reward >= t.base_reward * gate
            if accept:
                self._last_dir[t.name] = 1 if t.new_index > t.old_index \
                    else -1
                self._revert_streak[t.name] = 0
            else:
                knob.index = t.old_index
                changed[t.name] = knob.value
                self._last_dir[t.name] = -self._last_dir.get(t.name, 1)
                self._revert_streak[t.name] = \
                    self._revert_streak.get(t.name, 0) + 1
                if self._settled(t.name):
                    self.telemetry.event("ctl.settle", knob=t.name,
                                         value=knob.value)
            self.telemetry.event(
                "ctl.trial", knob=t.name, accepted=accept,
                value=knob.value, reward=round(reward, 3),
                baseline=round(t.base_reward, 3))
            return changed
        if self._cycle is None:
            return changed
        active = [n for n in self._adjustable if not self._settled(n)]
        if not active:
            return changed
        if self._rng.random() < self.epsilon:
            name = active[int(self._rng.integers(len(active)))]
            direction = int(self._rng.choice((-1, 1)))
        else:
            name = next(self._cycle)
            for _ in range(len(self._adjustable)):
                if not self._settled(name):
                    break
                name = next(self._cycle)
            direction = self._last_dir.get(name, 1)
        knob = self.knobs[name]
        new_index = knob.index + direction
        if not 0 <= new_index < len(knob.ladder):
            direction = -direction
            new_index = knob.index + direction
        if not 0 <= new_index < len(knob.ladder):
            return changed
        self._trial = _Trial(name, knob.index, new_index, reward)
        knob.index = new_index
        changed[name] = knob.value
        self.telemetry.event("ctl.propose", knob=name, value=knob.value,
                             direction=direction)
        return changed

    def _cancel_trial(self, name: str) -> None:
        if self._trial is not None and self._trial.name == name:
            self._trial = None
