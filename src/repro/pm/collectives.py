"""Collective backends: the vocab-parallel communication layer of the
intent-managed embedding (DESIGN.md §10).

The managed lookup's perf claim is about what moves through the network:
only the compact ``(M+1, D)`` miss buffer instead of every token's row.
This module isolates *how* that movement happens behind a small backend
protocol so the lookup data path (`pm.embedding`) is written once and the
collective substrate is swappable:

  `EmulatedBackend`
      The single-device reference.  ``n_shards > 1`` materializes one
      owner-masked ``(n, D)`` partial per shard behind
      `lax.optimization_barrier` — the cost model that stands in for the
      all-reduce's wire bytes on a one-device host (the seed repo's
      ``shard_partial_sum``).  ``n_shards == 1`` degenerates to a plain
      (optionally Pallas-blocked) gather, which is the training default.

  `MeshBackend`
      The real thing: the table is sharded ``P(axis, None)`` over a JAX
      device mesh and every data movement is an explicit `shard_map`
      collective.  Since this PR the hot path is *destination-compacted
      routing* (DESIGN.md §12) — the ascending unique-id layout that falls
      out of the step's one sort already groups ids by owner shard, so
      per-owner blocks are carved with `searchsorted` + `dynamic_slice`
      (no extra sort) and each device touches only the rows it owns:

        gather_rows_routed  each owner gathers its contiguous run of the
                          compact miss ids from its local ``(V/n, D)``
                          block into a fixed ``(cap, D)`` send block; one
                          `lax.all_gather` of the per-owner blocks
                          reassembles the replicated ``(M, D)`` buffer —
                          per-device comm ~ ``n * cap * D = O(M·D)``,
                          independent of n_shards (vs the replicated
                          psum's ``O(M·D·n)``).  A skewed batch whose
                          per-owner count exceeds the static cap falls
                          back to the masked psum under one `lax.cond`;
        gather_rows       the legacy replicated path (masked partial
                          gather per shard + `lax.psum` of the full
                          buffer) — the routed path's fallback arm and the
                          benchmark baseline;
        scatter_row_grads segment slots are chunked over shards; each
                          shard destination-compacts its chunk (ascending
                          -> contiguous per-owner runs) and one
                          `lax.all_to_all` hands every owner exactly its
                          rows, which scatter-add into the local
                          ``(V/n, D)`` block — the dense ``(V, D)``
                          partial + tiled psum_scatter of the legacy path
                          (kept as `scatter_row_grads_psum`) never
                          materialize;
        update_rows       the fused sparse AdaGrad applied where the row
                          lives: the same all_to_all routing delivers
                          (id, grad-row) pairs to their owners and the
                          row kernel updates the owner's local block
                          in-place inside the same shard_map;
        refresh_rows      replica sync via the routed gather over the
                          sorted hot-id set (pad ids ``>= V`` belong to
                          no shard and come back zero).

      Runs on any multi-device backend; CI exercises it on CPU via
      ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Backends are frozen dataclasses (hashable) so they ride through
`jax.custom_vjp` nondiff args and `jax.jit` static closures without
recompilation churn.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax.shard_map import shard_map  # type: ignore

from repro.kernels import ops, ref


def route_block_cap(m: int, n: int) -> int:
    """Static per-owner block size of the routed miss path: the expected
    even split ``ceil(m / n)`` with 2x headroom for skew, rounded to a
    power of two (few distinct caps -> few compiled variants), never above
    ``m`` itself.  Batches whose worst per-owner count exceeds this fall
    back to the replicated psum under `lax.cond` — the same
    correctness-over-capacity contract as the miss buffer's overflow
    branch."""
    c = 2 * (-(-m // n))
    p = 1
    while p < c:
        p *= 2
    return min(m, p)


def _all_to_all_route(axis: str, n: int, block: int, vocab: int,
                      tokp, gp, cap: int):
    """INSIDE-shard_map half of the routed scatter/update: destination-
    compact this shard's ``cap``-slot chunk of the (padded, ascending)
    segment slots and exchange per-owner blocks with one `lax.all_to_all`.

    The chunk is a contiguous slice of a globally ascending unique-id
    list, so each destination's rows form one contiguous run —
    `searchsorted` finds the run starts and ``rank = j - start[owner]``
    places each row in its send block; a run can never exceed the chunk
    length ``cap``, so the send layout ``(n * cap,)`` needs no overflow
    arm.  Pad slots (id == vocab) are dropped on send and arrive as
    sentinel ids on the receive side.  Returns ``(recv_ids, recv_g)``:
    ``n * cap`` global ids (vocab = pad) with their gradient rows, all
    owned by this shard."""
    k = jax.lax.axis_index(axis)
    tc = jax.lax.dynamic_slice_in_dim(tokp, k * cap, cap)
    gc = jax.lax.dynamic_slice_in_dim(gp, k * cap, cap, axis=0)
    starts = jnp.searchsorted(
        tc, jnp.arange(n, dtype=jnp.int32) * block).astype(jnp.int32)
    j = jnp.arange(cap, dtype=jnp.int32)
    owner = tc // block
    valid = tc < vocab
    rank = j - starts[jnp.clip(owner, 0, n - 1)]
    dst = jnp.where(valid, owner * cap + rank, n * cap)
    send_ids = jnp.full((n * cap,), vocab, jnp.int32).at[dst].set(
        tc, mode="drop")
    send_g = jnp.zeros((n * cap, gp.shape[1]), gp.dtype).at[dst].set(
        gc, mode="drop")
    recv_ids = jax.lax.all_to_all(send_ids, axis, 0, 0, tiled=True)
    recv_g = jax.lax.all_to_all(send_g, axis, 0, 0, tiled=True)
    return recv_ids, recv_g


@dataclass(frozen=True)
class EmulatedBackend:
    """Single-host stand-in for the vocab-parallel collectives.

    With ``n_shards > 1`` each gather materializes one owner-masked
    ``(n, D)`` partial per shard behind `lax.optimization_barrier` so XLA
    cannot algebraically fuse the mask-and-sum back into a plain gather:
    every shard's message is a real ``(n, D)`` buffer, the cost model for
    its wire bytes (proportional to ``n_shards * len(ids) * D`` — exactly
    the lever the managed path pulls by routing only the compact miss
    buffer through it)."""

    n_shards: int = 1
    mesh_real: bool = field(default=False, init=False)

    def gather_rows(self, table, ids, *, kernel: bool = False):
        """Rows for ``ids`` through the emulated collective."""
        ids = ids.astype(jnp.int32)
        rows = ops.embed_gather(table, ids, use_pallas=kernel) if kernel \
            else jnp.take(table, ids, axis=0)
        if self.n_shards <= 1:
            return rows
        V = table.shape[0]
        block = -(-V // self.n_shards)
        owner = ids // block
        partial = jnp.zeros_like(rows)
        for s in range(self.n_shards):
            msg = jnp.where((owner == s)[:, None], rows, 0.0)
            partial = partial + jax.lax.optimization_barrier(msg)
        return partial

    def scatter_row_grads(self, tok, g, vocab_size: int, *,
                          kernel: bool = False, segmented: bool = False):
        """Route all row gradients to the (conceptually owner-sharded)
        table: dense scatter-add, or — ``kernel`` — compact unique slots
        followed by one blocked Pallas scatter (pad slots hit the sentinel
        trash row V).  ``segmented`` marks (tok, g) as ALREADY
        duplicate-pre-summed compact slots (the lookup backward feeds the
        forward's sort residual through `ops.segment_rows`), so no index
        work happens here."""
        V = vocab_size
        if not kernel:
            # pad/sentinel ids (== V, only present on segmented inputs)
            # fall outside the table and are dropped
            return jnp.zeros((V, g.shape[1]),
                             dtype=g.dtype).at[tok].add(g, mode="drop")
        if segmented:
            slot_ids, slot_g = tok, g
        else:
            slot_ids, slot_g = ops.segment_rows(tok, g,
                                                n_slots=tok.shape[0],
                                                pad_id=V)
        base = jnp.zeros((V + 1, g.shape[1]), dtype=g.dtype)
        return ops.scatter_rows(base, slot_ids, slot_g)[:V]

    def refresh_rows(self, table, cache_ids):
        """Replica sync: gather the hot rows (pad ids >= V read zeros).
        Eager-friendly op-by-op — the XLA CPU backend lowers a jitted
        clip+gather+mask into a far slower fused gather."""
        V = table.shape[0]
        ids = cache_ids.astype(jnp.int32)
        return ops.masked_embed_gather(table, jnp.clip(ids, 0, V - 1),
                                       ids < V, use_pallas=False)

    def refresh_rows_delta(self, table, cache_rows, ids, slots):
        """Incremental replica sync: re-gather only ``ids`` (ascending,
        V-padded) and write them into ``cache_rows`` at ``slots`` (pad
        slots == C fall off the end and are dropped).  Rows the optimizer
        did not touch since the last refresh are bitwise unchanged in the
        table, so skipping them is exact — the delta-refresh gate in
        `train/loop.py` only takes this path when that holds (sparse
        AdaGrad, untied embeddings)."""
        V = table.shape[0]
        ids = ids.astype(jnp.int32)
        rows = ops.masked_embed_gather(table, jnp.clip(ids, 0, V - 1),
                                       ids < V, use_pallas=False)
        return cache_rows.at[slots.astype(jnp.int32)].set(rows, mode="drop")

    def update_rows(self, table, accum, seg_ids, seg_g, *, lr: float,
                    eps: float = 1e-8, kernel: bool = False):
        """Fused sparse AdaGrad over segment slots: ``seg_ids`` are the
        ascending unique batch ids followed by sentinel (== V) pads with
        zero gradients (`ops.segment_rows` output).  Single-device
        reference of the mesh backend's on-shard routed update — the
        training step calls this through the backend so the optimizer
        applies where the row lives on every substrate.

        The slot order is REVERSED for the kernel path so every pad
        program (an identity write: zero grad, original row value) runs
        before row 0's real update — the grid executes in order, so the
        real update always lands last and a trailing pad can never
        overwrite it with the stale row.  The jnp path uses the
        scatter-ADD form, which is order-free under zero-grad
        duplicates."""
        V = table.shape[0]
        ids = seg_ids[::-1]
        valid = ids < V
        ids = jnp.where(valid, ids, 0)
        rows_g = seg_g[::-1] * valid[:, None].astype(seg_g.dtype)
        if kernel:
            return ops.adagrad_row_update(table, accum, ids, rows_g,
                                          lr=lr, eps=eps)
        return ref.adagrad_row_add_ref(table, accum, ids, rows_g,
                                       lr=lr, eps=eps)


@dataclass(frozen=True)
class MeshBackend:
    """Real SPMD collectives over a device mesh: the table lives sharded
    ``P(axis, None)`` (contiguous vocab blocks, shard k owns rows
    ``[k*V/n, (k+1)*V/n)``) and `shard_map` makes every transfer an
    explicit psum / psum_scatter.  Requires ``V % n_shards == 0`` (the
    same divisibility `models.losses.vocab_parallel_ce` asserts).

    ``check_rep=False`` on the shard_maps: the Pallas gather kernel has no
    replication rule, and the outputs' replication is structural (psum ->
    replicated, psum_scatter -> sharded by construction)."""

    mesh: jax.sharding.Mesh
    axis: str = "model"
    mesh_real: bool = field(default=True, init=False)

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    def place_table(self, table):
        """Owner-shard the table over the mesh (the §3b allocation) via
        `launch.sharding.managed_table_sharding`."""
        from repro.launch.sharding import managed_table_sharding
        return jax.device_put(table,
                              managed_table_sharding(self.mesh, self.axis))

    def _check(self, V: int) -> int:
        n = self.n_shards
        if V % n:
            raise ValueError(
                f"vocab {V} must divide the {self.axis!r} axis ({n})")
        return V // n

    def gather_rows(self, table, ids, *, kernel: bool = False):
        """Masked partial gather per shard + psum of the compact buffer:
        each shard gathers the rows it owns (zeros elsewhere) from its
        local ``(V/n, D)`` block — Pallas-blocked when ``kernel`` — and
        one `lax.psum` moves the summed ``(n, D)`` buffer to every shard.
        Ids outside every block (e.g. cache pad V) come back zero."""
        V = table.shape[0]
        block = self._check(V)

        def f(tblk, ids):
            lo = jax.lax.axis_index(self.axis) * block
            local = ids.astype(jnp.int32) - lo
            inb = (local >= 0) & (local < block)
            rows = ops.masked_embed_gather(
                tblk, jnp.clip(local, 0, block - 1), inb, use_pallas=kernel)
            return jax.lax.psum(rows, self.axis)

        return shard_map(
            f, mesh=self.mesh,
            in_specs=(P(self.axis, None), P(None)), out_specs=P(None),
            check_rep=False)(table, ids)

    def gather_rows_routed(self, table, ids, n_valid, *,
                           route_cap: int = 0, kernel: bool = False):
        """Destination-compacted miss gather (DESIGN.md §12): ``ids`` must
        be ascending unique real ids on ``ids[:n_valid]`` (the
        probe/compact contract — unique missed ids claim buffer slots in
        ascending-id order, so the step's one sort already grouped them by
        owner); pad entries after may hold anything and come back ZERO
        (unlike `gather_rows`, which returns row 0 for pad id 0 — callers
        never read pad slots either way).

        Each owner carves its contiguous run out of the id list
        (`ops.owner_segments`: searchsorted + dynamic_slice, no sort),
        gathers those rows from its local ``(V/n, D)`` block into a fixed
        ``(cap, D)`` send block tagged with the original buffer slots, and
        one `lax.all_gather` of the per-owner blocks reassembles the
        replicated ``(M, D)`` buffer — every consumer needs every row (the
        activations are replicated over the model axis), so the all-to-all
        degenerates into an all-gather of owner blocks, and each row
        crosses the wire once per consumer instead of riding all n psum
        partials: per-device comm ``n * cap * D ~ 2·M·D``, independent of
        n_shards.

        ``route_cap`` pins the static per-owner block (the serving plan's
        `route_capacity`); 0 derives `route_block_cap(M, n)`.  A batch
        whose worst per-owner count exceeds the cap falls back to the
        replicated psum under one `lax.cond` — correct, just slower."""
        V, D = table.shape
        block = self._check(V)
        n = self.n_shards
        M = ids.shape[0]
        cap = min(M, route_cap) if route_cap > 0 else route_block_cap(M, n)
        view, seg = ops.owner_segments(ids, n_valid, n, block)
        viewp = jnp.concatenate([view, jnp.full((cap,), V, jnp.int32)])

        def routed(_):
            def f(tblk, viewp, seg):
                k = jax.lax.axis_index(self.axis)
                start = seg[k]
                cnt = seg[k + 1] - start
                sl = jax.lax.dynamic_slice_in_dim(viewp, start, cap)
                j = jnp.arange(cap, dtype=jnp.int32)
                mine = j < cnt
                local = jnp.clip(sl - k * block, 0, block - 1)
                rows = ops.masked_embed_gather(tblk, local, mine,
                                               use_pallas=kernel)
                # original buffer slot of each sent row; padding lands on
                # the extra slot M and is sliced off after reassembly
                slots = jnp.where(mine, start + j, M)
                rows_all = jax.lax.all_gather(rows, self.axis)
                slots_all = jax.lax.all_gather(slots, self.axis)
                buf = jnp.zeros((M + 1, D), rows.dtype)
                buf = buf.at[slots_all.reshape(-1)].add(
                    rows_all.reshape(-1, D))
                return buf[:M]

            return shard_map(
                f, mesh=self.mesh,
                in_specs=(P(self.axis, None), P(None), P(None)),
                out_specs=P(None), check_rep=False)(table, viewp, seg)

        if cap >= M:        # the cap cannot be exceeded: no fallback arm
            return routed(None)
        counts = seg[1:] - seg[:-1]
        return jax.lax.cond(jnp.max(counts) <= cap, routed,
                            lambda _: self.gather_rows(table, view,
                                                       kernel=kernel),
                            None)

    def scatter_row_grads(self, tok, g, vocab_size: int, *,
                          kernel: bool = False, segmented: bool = False):
        """all_to_all-routed row gradients: segment slots are chunked over
        the mesh axis, each shard destination-compacts its chunk (the
        global slot list is ascending unique ids then V-pads, so a chunk's
        per-owner rows are contiguous runs — `_all_to_all_route`) and one
        `lax.all_to_all` hands every owner exactly its rows, which
        scatter-add into the local ``(V/n, D)`` block.  Neither the dense
        ``(V, D)`` partial nor the tiled psum_scatter of the legacy path
        (`scatter_row_grads_psum`) is materialized: per-device wire is the
        ``(n·cap, D)`` send/recv blocks, ~``T·D / n`` each way.

        Non-``segmented`` inputs are segmented here first (one sort, off
        the single-sort hot path — every in-repo mesh caller arrives
        segmented through the lookup backward's residual-fed pass)."""
        V = vocab_size
        n = self.n_shards
        block = self._check(V)
        if not segmented:
            seg_ids, seg_g = ops.segment_rows(tok, g, n_slots=tok.shape[0],
                                              pad_id=V)
            tok, g = seg_ids, seg_g.astype(g.dtype)
        D = g.shape[1]
        T = tok.shape[0]
        cap = -(-T // n)
        pad = n * cap - T
        tokp = jnp.concatenate(
            [tok.astype(jnp.int32), jnp.full((pad,), V, jnp.int32)])
        gp = jnp.concatenate([g, jnp.zeros((pad, D), g.dtype)])

        def f(tokp, gp):
            recv_ids, recv_g = _all_to_all_route(self.axis, n, block, V,
                                                 tokp, gp, cap)
            k = jax.lax.axis_index(self.axis)
            local = recv_ids - k * block
            ok = (local >= 0) & (local < block)
            return jnp.zeros((block, D), gp.dtype).at[
                jnp.where(ok, local, block)].add(recv_g, mode="drop")

        return shard_map(
            f, mesh=self.mesh, in_specs=(P(None), P(None)),
            out_specs=P(self.axis, None), check_rep=False)(tokp, gp)

    def scatter_row_grads_psum(self, tok, g, vocab_size: int, *,
                               kernel: bool = False,
                               segmented: bool = False):
        """Legacy replicated-partial path (the PR-4 data movement, kept as
        the routed path's benchmark/equivalence baseline): each shard
        scatter-adds its chunk into a local dense ``(V, D)`` partial and
        one tiled `lax.psum_scatter` both sums the partials and delivers
        each owner its ``(V/n, D)`` block."""
        V = vocab_size
        n = self.n_shards
        self._check(V)
        D = g.shape[1]
        T = tok.shape[0]
        cap = -(-T // n)
        pad = n * cap - T
        tokp = jnp.concatenate(
            [tok.astype(jnp.int32), jnp.full((pad,), V, jnp.int32)])
        gp = jnp.concatenate([g, jnp.zeros((pad, D), g.dtype)])

        def f(tokp, gp):
            i = jax.lax.axis_index(self.axis)
            tc = jax.lax.dynamic_slice_in_dim(tokp, i * cap, cap)
            gc = jax.lax.dynamic_slice_in_dim(gp, i * cap, cap, axis=0)
            if kernel and not segmented:
                tc, gc = ops.segment_rows(tc, gc, n_slots=cap, pad_id=V)
                gc = gc.astype(gp.dtype)
            partial = jnp.zeros((V, D), gp.dtype).at[tc].add(gc,
                                                             mode="drop")
            return jax.lax.psum_scatter(partial, self.axis,
                                        scatter_dimension=0, tiled=True)

        return shard_map(
            f, mesh=self.mesh, in_specs=(P(None), P(None)),
            out_specs=P(self.axis, None), check_rep=False)(tokp, gp)

    def update_rows(self, table, accum, seg_ids, seg_g, *, lr: float,
                    eps: float = 1e-8, kernel: bool = False):
        """The on-shard fused sparse optimizer: the same all_to_all
        routing as `scatter_row_grads` delivers each (id, grad-row) pair
        to its owner, and the fused AdaGrad row kernel updates the owner's
        local ``(V/n, D)`` table/accumulator blocks inside the same
        shard_map — no dense sweep, no dense gradient, no second
        collective.  ``seg_ids`` / ``seg_g`` follow the `segment_rows`
        contract (ascending unique ids, then V-pads with zero gradients).

        Received pad slots alias local row 0 with a zero gradient — safe
        on the kernel path because the sequential grid re-reads the row
        before each (identity) write, and on the jnp path because the
        scatter-ADD form is order-free under zero-grad duplicates; real
        received ids are unique per shard (chunks are disjoint slices of
        a globally unique list)."""
        V, D = table.shape
        block = self._check(V)
        n = self.n_shards
        T = seg_ids.shape[0]
        cap = -(-T // n)
        pad = n * cap - T
        tokp = jnp.concatenate(
            [seg_ids.astype(jnp.int32), jnp.full((pad,), V, jnp.int32)])
        gp = jnp.concatenate([seg_g, jnp.zeros((pad, D), seg_g.dtype)])

        def f(tblk, ablk, tokp, gp):
            recv_ids, recv_g = _all_to_all_route(self.axis, n, block, V,
                                                 tokp, gp, cap)
            k = jax.lax.axis_index(self.axis)
            local = recv_ids - k * block
            ok = (local >= 0) & (local < block)
            ids_l = jnp.where(ok, local, 0)
            g_l = recv_g * ok[:, None].astype(recv_g.dtype)
            if kernel:
                return ops.adagrad_row_update(tblk, ablk, ids_l[::-1],
                                              g_l[::-1], lr=lr, eps=eps)
            return ref.adagrad_row_add_ref(tblk, ablk, ids_l, g_l,
                                           lr=lr, eps=eps)

        return shard_map(
            f, mesh=self.mesh,
            in_specs=(P(self.axis, None), P(self.axis, None), P(None),
                      P(None)),
            out_specs=(P(self.axis, None), P(self.axis, None)),
            check_rep=False)(table, accum, tokp, gp)

    def refresh_rows(self, table, cache_ids):
        """Replica sync round: the grouped all-gather of the plan's hot
        rows through the routed gather — ``cache_ids`` are sorted
        ascending with V-pads (the cache contract), exactly the layout the
        router wants, and `searchsorted` recovers the real-id count
        without a sort.  Pad ids >= V belong to no shard and come back
        zero — the padded-cache contract."""
        ids = cache_ids.astype(jnp.int32)
        n_valid = jnp.searchsorted(ids, jnp.int32(table.shape[0]))
        return self.gather_rows_routed(table, ids, n_valid)

    def refresh_rows_delta(self, table, cache_rows, ids, slots):
        """Incremental replica sync through the routed owner-block
        gather: only ``ids`` (ascending, V-padded — the layout the router
        wants) cross the mesh; everything else in ``cache_rows`` is
        bitwise current already (delta-refresh gate, `train/loop.py`).
        Pad slots == C drop off the end of the cache buffer."""
        ids = ids.astype(jnp.int32)
        n_valid = jnp.searchsorted(ids, jnp.int32(table.shape[0]))
        rows = self.gather_rows_routed(table, ids, n_valid)
        return cache_rows.at[slots.astype(jnp.int32)].set(rows, mode="drop")


#: module-level default: the training path's single-device reference.
EMULATED = EmulatedBackend(1)


def resolve(backend, n_shards: int = 1):
    """``backend`` if given, else the emulated backend at ``n_shards`` —
    the rule every `pm.embedding` entry point applies to its arguments."""
    if backend is not None:
        return backend
    return EMULATED if n_shards <= 1 else EmulatedBackend(n_shards)


def make_backend(collective: str, model_shards: int = 0):
    """Config-string entry point shared by the training loop and the
    serving runtime: ``"emulated"`` -> None (the per-call `resolve`
    default), ``"mesh"`` -> a `MeshBackend` over the first
    ``model_shards`` local devices (0 = all, `launch.mesh.
    make_model_mesh`).  Callers owning a table should `place_table` it."""
    if collective == "emulated":
        return None
    if collective == "mesh":
        from repro.launch.mesh import make_model_mesh
        return MeshBackend(make_model_mesh(model_shards))
    raise ValueError(f"unknown collective {collective!r}")
