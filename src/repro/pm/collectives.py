"""Collective backends: the vocab-parallel communication layer of the
intent-managed embedding (DESIGN.md §10).

The managed lookup's perf claim is about what moves through the network:
only the compact ``(M+1, D)`` miss buffer instead of every token's row.
This module isolates *how* that movement happens behind a small backend
protocol so the lookup data path (`pm.embedding`) is written once and the
collective substrate is swappable:

  `EmulatedBackend`
      The single-device reference.  ``n_shards > 1`` materializes one
      owner-masked ``(n, D)`` partial per shard behind
      `lax.optimization_barrier` — the cost model that stands in for the
      all-reduce's wire bytes on a one-device host (the seed repo's
      ``shard_partial_sum``).  ``n_shards == 1`` degenerates to a plain
      (optionally Pallas-blocked) gather, which is the training default.

  `MeshBackend`
      The real thing: the table is sharded ``P(axis, None)`` over a JAX
      device mesh and every data movement is an explicit `shard_map`
      collective —

        gather_rows       masked partial gather per shard + `lax.psum`
                          of the ``(n, D)`` buffer (each shard contributes
                          the rows it owns, zeros elsewhere);
        scatter_row_grads tokens are chunked over shards, each shard
                          scatter-adds its chunk's row gradients into a
                          local ``(V, D)`` partial, and one tiled
                          `lax.psum_scatter` routes the summed rows to
                          their owner shard's ``(V/n, D)`` block;
        refresh_rows      the replica-sync grouped all-gather: one masked
                          psum over the ``(C, D)`` hot-row set (pad ids
                          ``>= V`` belong to no shard and come back zero).

      Runs on any multi-device backend; CI exercises it on CPU via
      ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Backends are frozen dataclasses (hashable) so they ride through
`jax.custom_vjp` nondiff args and `jax.jit` static closures without
recompilation churn.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax.shard_map import shard_map  # type: ignore

from repro.kernels import ops


@dataclass(frozen=True)
class EmulatedBackend:
    """Single-host stand-in for the vocab-parallel collectives.

    With ``n_shards > 1`` each gather materializes one owner-masked
    ``(n, D)`` partial per shard behind `lax.optimization_barrier` so XLA
    cannot algebraically fuse the mask-and-sum back into a plain gather:
    every shard's message is a real ``(n, D)`` buffer, the cost model for
    its wire bytes (proportional to ``n_shards * len(ids) * D`` — exactly
    the lever the managed path pulls by routing only the compact miss
    buffer through it)."""

    n_shards: int = 1
    mesh_real: bool = field(default=False, init=False)

    def gather_rows(self, table, ids, *, kernel: bool = False):
        """Rows for ``ids`` through the emulated collective."""
        ids = ids.astype(jnp.int32)
        rows = ops.embed_gather(table, ids, use_pallas=kernel) if kernel \
            else jnp.take(table, ids, axis=0)
        if self.n_shards <= 1:
            return rows
        V = table.shape[0]
        block = -(-V // self.n_shards)
        owner = ids // block
        partial = jnp.zeros_like(rows)
        for s in range(self.n_shards):
            msg = jnp.where((owner == s)[:, None], rows, 0.0)
            partial = partial + jax.lax.optimization_barrier(msg)
        return partial

    def scatter_row_grads(self, tok, g, vocab_size: int, *,
                          kernel: bool = False, segmented: bool = False):
        """Route all row gradients to the (conceptually owner-sharded)
        table: dense scatter-add, or — ``kernel`` — compact unique slots
        followed by one blocked Pallas scatter (pad slots hit the sentinel
        trash row V).  ``segmented`` marks (tok, g) as ALREADY
        duplicate-pre-summed compact slots (the lookup backward feeds the
        forward's sort residual through `ops.segment_rows`), so no index
        work happens here."""
        V = vocab_size
        if not kernel:
            # pad/sentinel ids (== V, only present on segmented inputs)
            # fall outside the table and are dropped
            return jnp.zeros((V, g.shape[1]),
                             dtype=g.dtype).at[tok].add(g, mode="drop")
        if segmented:
            slot_ids, slot_g = tok, g
        else:
            slot_ids, slot_g = ops.segment_rows(tok, g,
                                                n_slots=tok.shape[0],
                                                pad_id=V)
        base = jnp.zeros((V + 1, g.shape[1]), dtype=g.dtype)
        return ops.scatter_rows(base, slot_ids, slot_g)[:V]

    def refresh_rows(self, table, cache_ids):
        """Replica sync: gather the hot rows (pad ids >= V read zeros).
        Eager-friendly op-by-op — the XLA CPU backend lowers a jitted
        clip+gather+mask into a far slower fused gather."""
        V = table.shape[0]
        ids = cache_ids.astype(jnp.int32)
        return ops.masked_embed_gather(table, jnp.clip(ids, 0, V - 1),
                                       ids < V, use_pallas=False)


@dataclass(frozen=True)
class MeshBackend:
    """Real SPMD collectives over a device mesh: the table lives sharded
    ``P(axis, None)`` (contiguous vocab blocks, shard k owns rows
    ``[k*V/n, (k+1)*V/n)``) and `shard_map` makes every transfer an
    explicit psum / psum_scatter.  Requires ``V % n_shards == 0`` (the
    same divisibility `models.losses.vocab_parallel_ce` asserts).

    ``check_rep=False`` on the shard_maps: the Pallas gather kernel has no
    replication rule, and the outputs' replication is structural (psum ->
    replicated, psum_scatter -> sharded by construction)."""

    mesh: jax.sharding.Mesh
    axis: str = "model"
    mesh_real: bool = field(default=True, init=False)

    @property
    def n_shards(self) -> int:
        return self.mesh.shape[self.axis]

    def place_table(self, table):
        """Owner-shard the table over the mesh (the §3b allocation) via
        `launch.sharding.managed_table_sharding`."""
        from repro.launch.sharding import managed_table_sharding
        return jax.device_put(table,
                              managed_table_sharding(self.mesh, self.axis))

    def _check(self, V: int) -> int:
        n = self.n_shards
        if V % n:
            raise ValueError(
                f"vocab {V} must divide the {self.axis!r} axis ({n})")
        return V // n

    def gather_rows(self, table, ids, *, kernel: bool = False):
        """Masked partial gather per shard + psum of the compact buffer:
        each shard gathers the rows it owns (zeros elsewhere) from its
        local ``(V/n, D)`` block — Pallas-blocked when ``kernel`` — and
        one `lax.psum` moves the summed ``(n, D)`` buffer to every shard.
        Ids outside every block (e.g. cache pad V) come back zero."""
        V = table.shape[0]
        block = self._check(V)

        def f(tblk, ids):
            lo = jax.lax.axis_index(self.axis) * block
            local = ids.astype(jnp.int32) - lo
            inb = (local >= 0) & (local < block)
            rows = ops.masked_embed_gather(
                tblk, jnp.clip(local, 0, block - 1), inb, use_pallas=kernel)
            return jax.lax.psum(rows, self.axis)

        return shard_map(
            f, mesh=self.mesh,
            in_specs=(P(self.axis, None), P(None)), out_specs=P(None),
            check_rep=False)(table, ids)

    def scatter_row_grads(self, tok, g, vocab_size: int, *,
                          kernel: bool = False, segmented: bool = False):
        """psum_scatter-routed row gradients: tokens are chunked over the
        mesh axis, each shard scatter-adds its chunk into a local ``(V, D)``
        partial, and one tiled `lax.psum_scatter` both sums the partials
        and delivers each owner shard exactly its ``(V/n, D)`` block
        (n-fold less wire than a psum of the full gradient).

        ``segmented`` inputs are already duplicate-pre-summed compact
        slots — the lookup backward's single global `segment_rows` pass
        over the forward's sort residual — so the chunks (disjoint unique
        ids) go straight into the partial: the per-chunk pre-sum that used
        to run one sort per shard inside the shard_map is batched into
        that one residual-fed pass.  Pad/chunk-pad tokens carry id V and
        are dropped."""
        V = vocab_size
        n = self.n_shards
        self._check(V)
        D = g.shape[1]
        T = tok.shape[0]
        cap = -(-T // n)
        pad = n * cap - T
        tokp = jnp.concatenate(
            [tok.astype(jnp.int32), jnp.full((pad,), V, jnp.int32)])
        gp = jnp.concatenate([g, jnp.zeros((pad, D), g.dtype)])

        def f(tokp, gp):
            i = jax.lax.axis_index(self.axis)
            tc = jax.lax.dynamic_slice_in_dim(tokp, i * cap, cap)
            gc = jax.lax.dynamic_slice_in_dim(gp, i * cap, cap, axis=0)
            if kernel and not segmented:
                tc, gc = ops.segment_rows(tc, gc, n_slots=cap, pad_id=V)
                gc = gc.astype(gp.dtype)
            partial = jnp.zeros((V, D), gp.dtype).at[tc].add(gc,
                                                             mode="drop")
            return jax.lax.psum_scatter(partial, self.axis,
                                        scatter_dimension=0, tiled=True)

        return shard_map(
            f, mesh=self.mesh, in_specs=(P(None), P(None)),
            out_specs=P(self.axis, None), check_rep=False)(tokp, gp)

    def refresh_rows(self, table, cache_ids):
        """Replica sync round: the grouped all-gather of the plan's hot
        rows, lowered as one owner-masked psum over ``(C, D)`` (each shard
        contributes its owned hot rows; pad ids >= V belong to no shard
        and come back zero — exactly the padded-cache contract)."""
        return self.gather_rows(table, cache_ids)


#: module-level default: the training path's single-device reference.
EMULATED = EmulatedBackend(1)


def resolve(backend, n_shards: int = 1):
    """``backend`` if given, else the emulated backend at ``n_shards`` —
    the rule every `pm.embedding` entry point applies to its arguments."""
    if backend is not None:
        return backend
    return EMULATED if n_shards <= 1 else EmulatedBackend(n_shards)


def make_backend(collective: str, model_shards: int = 0):
    """Config-string entry point shared by the training loop and the
    serving runtime: ``"emulated"`` -> None (the per-call `resolve`
    default), ``"mesh"`` -> a `MeshBackend` over the first
    ``model_shards`` local devices (0 = all, `launch.mesh.
    make_model_mesh`).  Callers owning a table should `place_table` it."""
    if collective == "emulated":
        return None
    if collective == "mesh":
        from repro.launch.mesh import make_model_mesh
        return MeshBackend(make_model_mesh(model_shards))
    raise ValueError(f"unknown collective {collective!r}")
