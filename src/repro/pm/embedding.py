"""Intent-managed embedding: the TPU-native mapping of AdaPM (DESIGN.md §3b).

The embedding table is vocab-sharded over the ``model`` mesh axis (the
"allocation": every row has one owner shard).  A per-device *replica cache*
holds the rows the planner decided to replicate (rows with concurrent
multi-shard intent — AdaPM's selective replication).  Lookups take two
paths:

  hit  : the row is in the replica cache -> pure local read, no collective;
  miss : the row is only on its owner shard -> the miss tokens are
         compacted into a fixed-capacity buffer (capacity M is *known in
         advance from intent*, bucketed to keep shapes static) and served
         by one masked-partial-sum all-reduce over (M, D) instead of the
         dense (B*S, D) all-reduce of plain vocab-parallel embedding.

Replica synchronization: gradients NEVER flow into the cache (replicas are
not independent parameters).  A custom VJP routes all row gradients to the
owner-sharded table; the cache is re-gathered from the table once per
refresh round (`refresh_cache`), which in the synchronous SPMD mapping
bounds replica staleness to one round — refresh-after-update gives exact
equivalence with an unmanaged embedding (tested).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class EmbedPMState(NamedTuple):
    """Device-side state of the intent-managed embedding."""

    table: jnp.ndarray       # (V, D), vocab-sharded over "model"
    cache_ids: jnp.ndarray   # (C,) int32, SORTED; padded with V (no match)
    cache_rows: jnp.ndarray  # (C, D), replicated


def make_state(table: jnp.ndarray, cache_ids: jnp.ndarray) -> EmbedPMState:
    """Build state with a freshly synchronized cache.  ``cache_ids`` must be
    sorted ascending; pad slots use V (matches no token)."""
    cache_rows = jnp.take(table, jnp.clip(cache_ids, 0, table.shape[0] - 1),
                          axis=0)
    pad = (cache_ids >= table.shape[0])[:, None]
    cache_rows = jnp.where(pad, 0.0, cache_rows)
    return EmbedPMState(table, cache_ids.astype(jnp.int32), cache_rows)


def refresh_cache(state: EmbedPMState,
                  cache_ids: jnp.ndarray | None = None) -> EmbedPMState:
    """Replica sync round: re-gather the hot rows from their owners (one
    grouped all-gather on TPU).  Optionally installs a new plan's ids."""
    ids = state.cache_ids if cache_ids is None else cache_ids
    return make_state(state.table, ids)


def _cache_probe(cache_ids, tokens_flat):
    """(slot, hit) per token via binary search over the sorted cache ids."""
    slot = jnp.searchsorted(cache_ids, tokens_flat)
    slot = jnp.clip(slot, 0, cache_ids.shape[0] - 1)
    hit = cache_ids[slot] == tokens_flat
    return slot, hit


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def pm_lookup(table, cache_ids, cache_rows, tokens, miss_capacity: int,
              strict: bool = False):
    """Intent-managed embedding lookup.

    table (V, D); cache_ids (C,) sorted; cache_rows (C, D); tokens (B, S).
    ``miss_capacity``: static bound on cache-miss tokens per call — the
    planner derives it exactly from intent and picks a bucket; overflow
    misses are transparently correct (they fall back to a second pass
    guarded by a predicate) but cost an extra dense lookup, so the planner
    sizing them away is the perf story, not a correctness requirement.
    """
    out, _ = _pm_lookup_fwd(table, cache_ids, cache_rows, tokens,
                            miss_capacity, strict)
    return out


def _lookup_impl(table, cache_ids, cache_rows, tokens, miss_capacity,
                 strict=False):
    B, S = tokens.shape
    T = B * S
    M = min(miss_capacity, T)
    tok = tokens.reshape(T).astype(jnp.int32)
    slot, hit = _cache_probe(cache_ids, tok)
    hit_rows = jnp.take(cache_rows, slot, axis=0)

    # compact the misses into M slots (intent-planned capacity)
    miss = ~hit
    pos = jnp.cumsum(miss.astype(jnp.int32)) - 1          # position per miss
    in_buf = miss & (pos < M)
    buf_slot = jnp.where(in_buf, pos, M)                  # overflow -> trash
    buf_ids = jnp.zeros((M + 1,), jnp.int32).at[buf_slot].set(tok)[:M]
    # one compact lookup (on TPU: masked partial + all-reduce over (M, D))
    buf_rows = jnp.take(table, buf_ids, axis=0)           # (M, D)
    miss_rows = jnp.concatenate(
        [buf_rows, jnp.zeros((1,) + buf_rows.shape[1:], buf_rows.dtype)])[
        buf_slot]
    # rare overflow: correctness fallback via a direct (dense) gather
    n_miss = jnp.sum(miss.astype(jnp.int32))
    overflow = miss & (pos >= M)

    def with_overflow(mr):
        dense = jnp.take(table, tok, axis=0)
        return jnp.where(overflow[:, None], dense, mr)

    if not strict:
        # rare overflow: correctness fallback via a direct (dense) gather.
        # ``strict=True`` (dry-run / planner-guaranteed capacity) omits the
        # branch entirely so no conditional dense collective is lowered.
        miss_rows = jax.lax.cond(n_miss > M, with_overflow,
                                 lambda mr: mr, miss_rows)
    out = jnp.where(hit[:, None], hit_rows, miss_rows)
    return out.reshape(B, S, table.shape[1])


def _pm_lookup_fwd(table, cache_ids, cache_rows, tokens, miss_capacity,
                   strict=False):
    out = _lookup_impl(table, cache_ids, cache_rows, tokens, miss_capacity,
                       strict)
    return out, (tokens, table.shape)


def _pm_lookup_bwd(miss_capacity, strict, res, g):
    tokens, (V, D) = res
    B, S = tokens.shape
    tok = tokens.reshape(B * S).astype(jnp.int32)
    gt = g.reshape(B * S, D)
    # replica write-back: ALL row gradients go to the owner-sharded table
    grad_table = jnp.zeros((V, D), dtype=gt.dtype).at[tok].add(gt)
    return (grad_table, None, None, None)


pm_lookup.defvjp(_pm_lookup_fwd, _pm_lookup_bwd)


def plain_lookup(table, tokens):
    """Unmanaged vocab-parallel lookup (static-partitioning baseline)."""
    return jnp.take(table, tokens.astype(jnp.int32), axis=0)
