"""Intent-managed embedding: the TPU-native mapping of AdaPM (DESIGN.md §3b).

The embedding table is vocab-sharded over the ``model`` mesh axis (the
"allocation": every row has one owner shard).  A per-device *replica cache*
holds the rows the planner decided to replicate (rows with concurrent
multi-shard intent — AdaPM's selective replication).  Lookups take two
paths:

  hit  : the row is in the replica cache -> pure local read, no collective;
  miss : the row is only on its owner shard -> the *unique* missed ids are
         deduplicated and compacted into a fixed-capacity buffer (capacity
         M is *known in advance from intent* — the planner's per-unique-id
         `intent_miss_bound` — bucketed to keep shapes static) and served
         by one masked-partial-sum all-reduce over (M, D) instead of the
         dense (B*S, D) all-reduce of plain vocab-parallel embedding.

Every lookup variant here — the training VJP (`pm_lookup`), the serving
read-only probe-on-device (`serve_lookup`) and probe-at-admission
(`planned_serve_lookup`) modes, and the unmanaged baselines — is a thin
wrapper over ONE shared data path (`combine_miss_buffer`), parameterized
by a collective backend (`pm.collectives`): `EmulatedBackend` materializes
the owner-masked partials on a single device (the barrier cost model),
`MeshBackend` runs the real `shard_map` psum over a multi-device mesh
(DESIGN.md §10).

``kernel=True`` runs the row data-path through the Pallas kernels
(DESIGN.md §3c): blocked miss-buffer gather + scalar-prefetched per-token
combine forward, compact row scatter backward.

Replica synchronization: gradients NEVER flow into the cache (replicas are
not independent parameters).  A custom VJP routes all row gradients to the
owner-sharded table (`backend.scatter_row_grads` — a psum_scatter on the
mesh); the cache is re-gathered from the table once per refresh round
(`refresh_cache`, the backend's grouped all-gather), which in the
synchronous SPMD mapping bounds replica staleness to one round —
refresh-after-update gives exact equivalence with an unmanaged embedding
(tested).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.pm_forward import (StepResidual, host_compact,
                                      probe_and_compact, step_residual)
from repro.pm.collectives import resolve


class EmbedPMState(NamedTuple):
    """Device-side state of the intent-managed embedding."""

    table: jnp.ndarray       # (V, D), vocab-sharded over "model"
    cache_ids: jnp.ndarray   # (C,) int32, SORTED; padded with V (no match)
    cache_rows: jnp.ndarray  # (C, D), replicated


def make_state(table: jnp.ndarray, cache_ids: jnp.ndarray,
               backend=None) -> EmbedPMState:
    """Build state with a freshly synchronized cache.  ``cache_ids`` must be
    sorted ascending; pad slots use V (matches no token).  ``backend``
    picks the collective that gathers the hot rows (the mesh backend's
    grouped all-gather; emulated/None reads locally)."""
    cache_ids = cache_ids.astype(jnp.int32)
    cache_rows = resolve(backend).refresh_rows(table, cache_ids)
    return EmbedPMState(table, cache_ids, cache_rows)


def refresh_cache(state: EmbedPMState, cache_ids: jnp.ndarray | None = None,
                  backend=None) -> EmbedPMState:
    """Replica sync round: re-gather the hot rows from their owners (one
    grouped all-gather on the mesh backend).  Optionally installs a new
    plan's ids."""
    ids = state.cache_ids if cache_ids is None else cache_ids
    return make_state(state.table, ids, backend)


def combine_miss_buffer(backend, table, cache_rows, hit, cache_slot,
                        buf_ids, buf_slot, *, kernel: bool = False,
                        n_miss=None, route_cap: int = 0):
    """THE shared managed-lookup data path (all variants funnel here):
    move the compact unique-miss buffer through the backend's
    vocab-parallel collective, append the all-zero trash row (slot M —
    overflow tokens land there), and per-token combine: hits read the
    local replica cache, misses read the buffer.  Returns (T, D) rows.

    ``n_miss`` (the probe's unique-miss count) switches the mesh backend
    onto the destination-compacted routed gather (DESIGN.md §12): only
    each owner's run of the compact ids moves, instead of the full
    replicated buffer riding a psum.  ``route_cap`` optionally pins the
    routed per-owner block (the serving plan's `route_capacity`)."""
    be = resolve(backend)
    if getattr(be, "mesh_real", False) and n_miss is not None:
        buf_rows = be.gather_rows_routed(
            table, buf_ids, jnp.minimum(n_miss, buf_ids.shape[0]),
            route_cap=route_cap, kernel=kernel)
    else:
        buf_rows = be.gather_rows(table, buf_ids, kernel=kernel)
    buffer = jnp.concatenate(
        [buf_rows, jnp.zeros((1, table.shape[1]), buf_rows.dtype)])
    return ops.pm_combine(hit, cache_slot, buf_slot, cache_rows, buffer,
                          use_pallas=kernel)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def pm_lookup(table, cache_ids, cache_rows, tokens, miss_capacity: int,
              strict: bool = False, kernel: bool = False, backend=None,
              residual: StepResidual | None = None):
    """Intent-managed embedding lookup (training mode, differentiable).

    table (V, D); cache_ids (C,) sorted; cache_rows (C, D); tokens (B, S).
    ``miss_capacity``: static bound on cache-miss tokens per call — the
    planner derives it exactly from intent (per *unique* id; misses are
    deduplicated before compaction to keep that bound exact) and picks a
    bucket; overflow misses are transparently correct (they fall back to a
    second pass guarded by a predicate) but cost an extra dense lookup, so
    the planner sizing them away is the perf story, not a correctness
    requirement.  ``kernel=True`` routes the row data-path through the
    Pallas kernels (`repro.kernels`: blocked miss-buffer gather + per-token
    combine forward, blocked row scatter backward); the default jnp path is
    the bitwise reference.  ``backend`` selects the collective substrate
    (`pm.collectives`; None = single-device emulated reference).

    ``residual``: a precomputed `pm_forward.step_residual` for these
    (cache_ids, tokens) — the single-sort step contract (DESIGN.md §11):
    the train step computes the residual once and the forward compaction,
    the backward pre-sum AND the fused optimizer all consume it.  Left
    None, the lookup derives it here (still one sort: the forward's
    residual is saved for the backward, which never re-sorts).
    """
    out, _ = _pm_lookup_fwd(table, cache_ids, cache_rows, tokens,
                            miss_capacity, strict, kernel, backend,
                            residual)
    return out


def _lookup_impl(table, cache_ids, cache_rows, tokens, miss_capacity,
                 strict=False, kernel=False, backend=None, residual=None):
    B, S = tokens.shape
    T = B * S
    M = min(miss_capacity, T)
    tok = tokens.reshape(T).astype(jnp.int32)
    # probe + dedup/compact: UNIQUE missed ids fill the M intent-planned
    # slots (duplicates share a slot, matching `intent_miss_bound`);
    # computed from the step's one sort, or reused from the caller's
    if residual is None:
        residual = step_residual(cache_ids, tok, M)
    pc = residual.probe
    out = combine_miss_buffer(backend, table, cache_rows, pc.hit,
                              pc.cache_slot, pc.buf_ids, pc.buf_slot,
                              kernel=kernel, n_miss=pc.n_miss)

    def with_overflow(o):
        dense = resolve(backend).gather_rows(table, tok)
        return jnp.where(pc.overflow[:, None], dense, o)

    if not strict:
        # rare overflow: correctness fallback via a direct (dense) gather
        # through the same collective backend.  ``strict=True`` (dry-run /
        # planner-guaranteed capacity) omits the branch entirely so no
        # conditional dense collective is lowered.
        out = jax.lax.cond(pc.n_miss > M, with_overflow, lambda o: o, out)
    return out.reshape(B, S, table.shape[1]), residual


def _pm_lookup_fwd(table, cache_ids, cache_rows, tokens, miss_capacity,
                   strict=False, kernel=False, backend=None, residual=None):
    out, residual = _lookup_impl(table, cache_ids, cache_rows, tokens,
                                 miss_capacity, strict, kernel, backend,
                                 residual)
    # the sort residual rides to the backward so the duplicate pre-sum
    # never re-sorts the tokens it already sorted in the forward
    return out, (tokens, table.shape, residual.sort)


def _pm_lookup_bwd(miss_capacity, strict, kernel, backend, res, g):
    tokens, (V, D), srt = res
    B, S = tokens.shape
    T = B * S
    tok = tokens.reshape(T).astype(jnp.int32)
    gt = g.reshape(T, D)
    # replica write-back: ALL row gradients go to the owner-sharded table
    # (on the mesh backend a psum_scatter routes each summed row to its
    # owner's block; emulated = the dense/kernel scatter reference).  The
    # kernel/mesh paths pre-sum duplicates into compact slots using the
    # forward's sort residual — zero additional sorts.
    be = resolve(backend)
    if kernel or be.mesh_real:
        seg_ids, seg_g = ops.segment_rows(tok, gt, n_slots=T, pad_id=V,
                                          residual=srt)
        grad_table = be.scatter_row_grads(seg_ids, seg_g.astype(gt.dtype),
                                          V, kernel=kernel, segmented=True)
    else:
        grad_table = be.scatter_row_grads(tok, gt, V, kernel=False)
    return (grad_table, None, None, None, None)


pm_lookup.defvjp(_pm_lookup_fwd, _pm_lookup_bwd)


def plain_lookup(table, tokens):
    """Unmanaged vocab-parallel lookup (static-partitioning baseline)."""
    return jnp.take(table, tokens.astype(jnp.int32), axis=0)


# ---------------------------------------------------------------- serving

class ServeLookupResult(NamedTuple):
    """Outputs of the serving-mode lookup (all static shapes)."""

    out: jnp.ndarray       # (B, K, D) rows; overflow slots are zeros and
    #                        MUST NOT be served (re-queue their requests)
    hit: jnp.ndarray       # (B, K) bool, served from the replica cache
    overflow: jnp.ndarray  # (B, K) bool, unique misses beyond capacity
    n_miss: jnp.ndarray    # () int32, unique missed ids this batch


def shard_partial_sum(table, ids, n_shards: int, *, kernel: bool = False):
    """Back-compat alias: the emulated vocab-parallel gather — see
    `pm.collectives.EmulatedBackend.gather_rows` for the cost-model
    semantics (one barrier-materialized owner-masked partial per shard)."""
    return resolve(None, n_shards).gather_rows(table, ids, kernel=kernel)


def plain_serve_lookup(table, tokens, *, n_shards: int = 1, backend=None):
    """Unmanaged serving baseline: every token's row moves through the
    vocab-parallel collective (the dense (T, D) partial-sum)."""
    B, K = tokens.shape
    tok = tokens.reshape(B * K)
    out = resolve(backend, n_shards).gather_rows(table, tok)
    return out.reshape(B, K, -1)


def serve_lookup(table, cache_ids, cache_rows, tokens, miss_capacity: int,
                 *, n_shards: int = 1, kernel: bool = False,
                 backend=None) -> ServeLookupResult:
    """Serving-mode managed lookup: read-only (no VJP, no optimizer), and
    it NEVER falls back to a dense gather silently — misses beyond the
    planned capacity come back as zeros with their ``overflow`` flag set,
    and the runtime re-queues those requests (the request is served late,
    never wrong).  Hits read the local replica cache (no collective);
    unique misses are compacted into the intent-sized buffer and only that
    (M+1, D) buffer moves through the backend's vocab-parallel collective.
    """
    B, K = tokens.shape
    T = B * K
    M = min(miss_capacity, T)
    D = table.shape[1]
    tok = tokens.reshape(T).astype(jnp.int32)
    pc = probe_and_compact(cache_ids, tok, M)
    out = combine_miss_buffer(resolve(backend, n_shards), table, cache_rows,
                              pc.hit, pc.cache_slot, pc.buf_ids,
                              pc.buf_slot, kernel=kernel, n_miss=pc.n_miss)
    # overflow tokens route to the trash row -> zeros; make that explicit
    # (a planned buf id of 0 must not leak row 0 into an overflow slot)
    out = jnp.where(pc.overflow[:, None], 0.0, out)
    return ServeLookupResult(out.reshape(B, K, D),
                             pc.hit.reshape(B, K),
                             pc.overflow.reshape(B, K),
                             pc.n_miss)


class HostProbe(NamedTuple):
    """Host-side index stage of the serving lookup (all numpy)."""

    hit: np.ndarray         # (T,) bool, token served by the replica cache
    cache_slot: np.ndarray  # (T,) int32 cache row (clipped; valid on hit)
    buf_ids: np.ndarray     # (M,) int32 unique missed ids asc (pad: 0)
    buf_slot: np.ndarray    # (T,) int32 buffer slot per token (M = trash)
    overflow: np.ndarray    # (T,) bool, unique misses beyond capacity
    n_miss: int             # unique missed ids (may exceed M)


def probe_host(cache_ids, tok, miss_capacity: int, *,
               owner_shards: int = 0, route_capacity: int = 0,
               vocab: int = 0) -> HostProbe:
    """Numpy mirror of `kernels.pm_forward.probe_and_compact` for the
    serving runtime's admission path.

    ``owner_shards`` / ``route_capacity`` / ``vocab`` (all three required
    to engage) additionally flag *per-owner* overflow for the mesh
    backend's routed miss path (DESIGN.md §12): a unique missed id whose
    rank within its owner shard (owner = id // (V / owner_shards); the
    compact ids are ascending, so ranks are positional) reaches
    ``route_capacity`` would not fit the routed per-destination block, and
    every token reading its slot gets its ``overflow`` flag set — the
    runtime re-queues those requests exactly like global-capacity
    overflow, so admission capacity matches the per-owner buffers the
    routed collective actually has.

    On the serving hot path the scheduler holds the batch's token ids on
    the host the moment the batch is formed (they came out of the request
    queue) — so the whole index stage (probe, dedup, compact, overflow
    flags) runs here in numpy at admission time, and the device executes
    pure data movement (`planned_serve_lookup`).  This is the same
    scalar-path/data-path split the Pallas kernels use (indices in SMEM
    via scalar prefetch, rows in VMEM), applied host-side; it also means
    miss-rate/overflow drift feedback needs no device readback at all.

    There are no parallel implementations to pin against each other
    anymore: this IS `pm_forward._compact_math` — the same arithmetic the
    device `step_residual`/`probe_and_compact` runs, executed on numpy
    (`pm_forward.host_compact`) — so host and device probes cannot drift
    (the pin test now checks one implementation against itself on two
    array backends)."""
    r = host_compact(cache_ids, tok, miss_capacity)
    overflow = r["overflow"]
    if owner_shards > 0 and route_capacity > 0 and vocab > 0:
        overflow = _route_overflow(r["hit"], r["buf_ids"], r["buf_slot"],
                                   overflow, int(r["n_miss"]),
                                   owner_shards, route_capacity, vocab)
    return HostProbe(r["hit"], r["cache_slot"], r["buf_ids"],
                     r["buf_slot"], overflow, int(r["n_miss"]))


def _route_overflow(hit, buf_ids, buf_slot, overflow, n_miss: int,
                    owner_shards: int, route_capacity: int,
                    vocab: int) -> np.ndarray:
    """Per-owner overflow flags for the routed miss path (DESIGN.md §12),
    shared by `probe_host` and `CacheProbeView`: a unique missed id whose
    rank within its owner shard reaches ``route_capacity`` would not fit
    the routed per-destination block.  The compact ids are ascending, so
    each owner's ids are one contiguous run and rank-within-owner is
    positional (the device router's layout)."""
    M = buf_ids.shape[0]
    nm = min(int(n_miss), M)
    ids = np.asarray(buf_ids[:nm], dtype=np.int64)
    block = -(-vocab // owner_shards)
    starts = np.searchsorted(ids, np.arange(owner_shards,
                                            dtype=np.int64) * block)
    rank = np.arange(nm) - starts[np.minimum(ids // block,
                                             owner_shards - 1)]
    slot_over = np.zeros(M + 1, dtype=bool)
    slot_over[:nm] = rank >= min(route_capacity, M)
    return overflow | (slot_over[buf_slot] & ~hit)


class CacheProbeView:
    """Memoized host probe for ONE cache generation (ISSUE 9 satellite).

    `probe_host` re-derives the probe from scratch on every batch — one
    argsort of the batch tokens PLUS a binary search of every token
    against the sorted cache ids — even though the cache ids only change
    once per refresh/replan round.  This view pays one O(V) lookup-table
    build when the cache generation changes and then probes each batch
    with two vectorized table reads; the only per-batch sort left is the
    `np.unique` over the batch's missed tokens, which any compaction
    needs.  Every `HostProbe` field is byte-identical to `probe_host`
    (pinned in tests/test_prefetch.py) — `np.unique` returns the missed
    ids ascending with duplicates sharing one inverse slot, exactly
    `_compact_math`'s miss-group ranks."""

    def __init__(self, cache_ids: np.ndarray, vocab: int):
        cache_ids = np.asarray(cache_ids)
        self.cache_ids = cache_ids
        self.vocab = int(vocab)
        C = cache_ids.shape[0]
        vals = np.arange(self.vocab, dtype=cache_ids.dtype)
        if C:
            slot = np.clip(np.searchsorted(cache_ids, vals),
                           0, C - 1).astype(np.int32)
            self._slot_lut = slot
            self._hit_lut = cache_ids[slot] == vals
        else:
            self._slot_lut = np.zeros(self.vocab, np.int32)
            self._hit_lut = np.zeros(self.vocab, bool)

    def probe(self, tok, miss_capacity: int, *, owner_shards: int = 0,
              route_capacity: int = 0) -> HostProbe:
        """`probe_host(self.cache_ids, tok, ...)`, via the LUTs."""
        tok = np.asarray(tok, dtype=np.int32)
        T = tok.shape[0]
        M = miss_capacity
        cache_slot = self._slot_lut[tok]
        hit = self._hit_lut[tok]
        miss = ~hit
        uniq, inverse = np.unique(tok[miss], return_inverse=True)
        n_miss = int(uniq.shape[0])
        k = min(n_miss, M)
        buf_ids = np.zeros(M, np.int32)
        buf_ids[:k] = uniq[:k]
        buf_slot = np.full(T, M, np.int32)
        buf_slot[miss] = np.where(inverse < M, inverse, M).astype(np.int32)
        overflow = np.zeros(T, bool)
        overflow[miss] = inverse >= M
        if owner_shards > 0 and route_capacity > 0 and self.vocab > 0:
            overflow = _route_overflow(hit, buf_ids, buf_slot, overflow,
                                       n_miss, owner_shards,
                                       route_capacity, self.vocab)
        return HostProbe(hit, cache_slot, buf_ids, buf_slot, overflow,
                         n_miss)


def planned_serve_lookup(table, cache_rows, buf_ids, hit, cache_slot,
                         buf_slot, *, n_shards: int = 1,
                         kernel: bool = False, backend=None,
                         n_miss=None, route_cap: int = 0):
    """Device data path of the serving lookup, with the index stage
    already done (`probe_host` at admission — intent means the host knows
    the batch's miss set before the batch runs).  Only the (M+1, D)
    compact buffer moves through the backend's vocab-parallel collective;
    hits read the local replica cache; overflow slots read the all-zero
    trash row (``buf_slot == M``) and their requests are re-queued by the
    runtime, never served.  Returns (T, D) rows.

    ``n_miss`` (host probe's unique-miss count, passed as a device
    scalar) routes the mesh backend onto the destination-compacted gather
    with per-owner blocks of ``route_cap`` (the plan's `route_capacity`;
    the runtime's per-owner admission guarantees the cap fits, and the
    psum fallback arm keeps even an unplanned batch correct)."""
    return combine_miss_buffer(resolve(backend, n_shards), table,
                               cache_rows, hit, cache_slot, buf_ids,
                               buf_slot, kernel=kernel, n_miss=n_miss,
                               route_cap=route_cap)


# The staged serving dispatch needs no dedicated device fn: the runtime
# folds the tenure's staging buffer into the cache side (``cache_rows ++
# staging_rows``, one concat per tenure) and converts staged miss tokens
# into extended-cache hits at admission, so the device path is
# `planned_serve_lookup` over the residual bucket alone — no extra
# gathers or masks per round (DESIGN.md §15).
