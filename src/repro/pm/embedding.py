"""Intent-managed embedding: the TPU-native mapping of AdaPM (DESIGN.md §3b).

The embedding table is vocab-sharded over the ``model`` mesh axis (the
"allocation": every row has one owner shard).  A per-device *replica cache*
holds the rows the planner decided to replicate (rows with concurrent
multi-shard intent — AdaPM's selective replication).  Lookups take two
paths:

  hit  : the row is in the replica cache -> pure local read, no collective;
  miss : the row is only on its owner shard -> the *unique* missed ids are
         deduplicated and compacted into a fixed-capacity buffer (capacity
         M is *known in advance from intent* — the planner's per-unique-id
         `intent_miss_bound` — bucketed to keep shapes static) and served
         by one masked-partial-sum all-reduce over (M, D) instead of the
         dense (B*S, D) all-reduce of plain vocab-parallel embedding.

``kernel=True`` runs the row data-path through the Pallas kernels
(DESIGN.md §3c): blocked miss-buffer gather + scalar-prefetched per-token
combine forward, compact row scatter backward.

Replica synchronization: gradients NEVER flow into the cache (replicas are
not independent parameters).  A custom VJP routes all row gradients to the
owner-sharded table; the cache is re-gathered from the table once per
refresh round (`refresh_cache`), which in the synchronous SPMD mapping
bounds replica staleness to one round — refresh-after-update gives exact
equivalence with an unmanaged embedding (tested).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.pm_forward import probe_and_compact


class EmbedPMState(NamedTuple):
    """Device-side state of the intent-managed embedding."""

    table: jnp.ndarray       # (V, D), vocab-sharded over "model"
    cache_ids: jnp.ndarray   # (C,) int32, SORTED; padded with V (no match)
    cache_rows: jnp.ndarray  # (C, D), replicated


def make_state(table: jnp.ndarray, cache_ids: jnp.ndarray) -> EmbedPMState:
    """Build state with a freshly synchronized cache.  ``cache_ids`` must be
    sorted ascending; pad slots use V (matches no token)."""
    cache_rows = jnp.take(table, jnp.clip(cache_ids, 0, table.shape[0] - 1),
                          axis=0)
    pad = (cache_ids >= table.shape[0])[:, None]
    cache_rows = jnp.where(pad, 0.0, cache_rows)
    return EmbedPMState(table, cache_ids.astype(jnp.int32), cache_rows)


def refresh_cache(state: EmbedPMState,
                  cache_ids: jnp.ndarray | None = None) -> EmbedPMState:
    """Replica sync round: re-gather the hot rows from their owners (one
    grouped all-gather on TPU).  Optionally installs a new plan's ids."""
    ids = state.cache_ids if cache_ids is None else cache_ids
    return make_state(state.table, ids)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def pm_lookup(table, cache_ids, cache_rows, tokens, miss_capacity: int,
              strict: bool = False, kernel: bool = False):
    """Intent-managed embedding lookup.

    table (V, D); cache_ids (C,) sorted; cache_rows (C, D); tokens (B, S).
    ``miss_capacity``: static bound on cache-miss tokens per call — the
    planner derives it exactly from intent (per *unique* id; misses are
    deduplicated before compaction to keep that bound exact) and picks a
    bucket; overflow misses are transparently correct (they fall back to a
    second pass guarded by a predicate) but cost an extra dense lookup, so
    the planner sizing them away is the perf story, not a correctness
    requirement.  ``kernel=True`` routes the row data-path through the
    Pallas kernels (`repro.kernels`: blocked miss-buffer gather + per-token
    combine forward, blocked row scatter backward); the default jnp path is
    the bitwise reference.
    """
    out, _ = _pm_lookup_fwd(table, cache_ids, cache_rows, tokens,
                            miss_capacity, strict, kernel)
    return out


def _lookup_impl(table, cache_ids, cache_rows, tokens, miss_capacity,
                 strict=False, kernel=False):
    B, S = tokens.shape
    T = B * S
    M = min(miss_capacity, T)
    D = table.shape[1]
    tok = tokens.reshape(T).astype(jnp.int32)
    # probe + dedup/compact: UNIQUE missed ids fill the M intent-planned
    # slots (duplicates share a slot, matching `intent_miss_bound`)
    pc = probe_and_compact(cache_ids, tok, M)

    # blocked gather of the compact miss buffer (on TPU: the (M+1, D)
    # buffer is what the masked partial-sum all-reduce moves) + per-token
    # combine — Pallas kernels when ``kernel``, their jnp oracles otherwise
    buf_rows = ops.embed_gather(table, pc.buf_ids, use_pallas=kernel)
    buffer = jnp.concatenate(
        [buf_rows, jnp.zeros((1, D), buf_rows.dtype)])        # trash row M
    out = ops.pm_combine(pc.hit, pc.cache_slot, pc.buf_slot,
                         cache_rows, buffer, use_pallas=kernel)

    def with_overflow(o):
        dense = jnp.take(table, tok, axis=0)
        return jnp.where(pc.overflow[:, None], dense, o)

    if not strict:
        # rare overflow: correctness fallback via a direct (dense) gather.
        # ``strict=True`` (dry-run / planner-guaranteed capacity) omits the
        # branch entirely so no conditional dense collective is lowered.
        out = jax.lax.cond(pc.n_miss > M, with_overflow, lambda o: o, out)
    return out.reshape(B, S, D)


def _pm_lookup_fwd(table, cache_ids, cache_rows, tokens, miss_capacity,
                   strict=False, kernel=False):
    out = _lookup_impl(table, cache_ids, cache_rows, tokens, miss_capacity,
                       strict, kernel)
    return out, (tokens, table.shape)


def _pm_lookup_bwd(miss_capacity, strict, kernel, res, g):
    tokens, (V, D) = res
    B, S = tokens.shape
    tok = tokens.reshape(B * S).astype(jnp.int32)
    gt = g.reshape(B * S, D)
    # replica write-back: ALL row gradients go to the owner-sharded table
    if kernel:
        # pre-sum duplicates into compact slots (pad -> trash row V), then
        # one blocked scatter into the donated zero gradient buffer
        slot_ids, slot_g = ops.segment_rows(tok, gt, n_slots=B * S,
                                            pad_id=V)
        base = jnp.zeros((V + 1, D), dtype=gt.dtype)
        grad_table = ops.scatter_rows(base, slot_ids, slot_g)[:V]
    else:
        grad_table = jnp.zeros((V, D), dtype=gt.dtype).at[tok].add(gt)
    return (grad_table, None, None, None)


pm_lookup.defvjp(_pm_lookup_fwd, _pm_lookup_bwd)


def plain_lookup(table, tokens):
    """Unmanaged vocab-parallel lookup (static-partitioning baseline)."""
    return jnp.take(table, tokens.astype(jnp.int32), axis=0)
