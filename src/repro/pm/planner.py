"""Host-side placement planner: turns intent signals from the data loader
into placement plans for the intent-managed embedding (DESIGN.md §3b).

This is where the faithful AdaPM logic (repro.core) plugs into the SPMD
runtime.  The planner treats each *data shard* as a node and routes its
placement decisions through the shared intent engine
(`repro.core.engine`) — the same §4.1 decision procedure the simulator
policies use:

  * rows with active intent on >= 2 shards in the planning window are
    *replicated* -> placed in the device replica cache (AdaPM §4.1:
    concurrent intent -> selective replication), weighted by the summed
    shard count (`engine.concurrent_intent`);
  * rows with single-shard intent stay owner-sharded (the relocation arm
    degenerates under SPMD: ownership is affine in the row id, so
    "relocate" means "serve via the compact miss path", which moves the
    value exactly once to exactly the shard that needs it — the same bytes
    a relocation would move);
  * Algorithm 1 (ActionTimer) decides how many steps of lookahead the plan
    must cover, i.e. when to act on the loader's intent signals.

Because intent is exact, the planner also knows the exact per-step
cache-miss count (`engine.intent_miss_bound`) and sizes the compact miss
buffer (bucketed powers of two) — static shapes for XLA out of dynamic
workload knowledge.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional

import numpy as np

from repro.core.engine import concurrent_intent, intent_miss_bound
from repro.core.timing import ActionTimer
from repro.obs.telemetry import Telemetry


@dataclass(frozen=True)
class PlacementPlan:
    version: int
    cache_ids: np.ndarray        # (C,) sorted int32, padded with V
    miss_capacity: int           # bucketed exact bound from intent
    window: tuple                # (start_step, end_step) the plan covers
    predicted_miss_rate: float = 0.0   # expected per-access miss fraction
    #   over the signaled window — the serving runtime's drift baseline
    #   (observed miss rate far above it = the workload left the plan)
    route_capacity: int = 0      # bucketed exact per-OWNER-shard unique-
    #   miss bound (planners built with ``owner_shards > 0``): the static
    #   per-destination block of the mesh backend's routed gather
    #   (DESIGN.md §12) — admission capacity for the all_to_all path,
    #   where `miss_capacity` sizes the shared compact buffer.  0 = no
    #   owner accounting (non-mesh backends).
    demand: int = 0              # cache-worthy ids in the window (score >
    #   0 under this plan's ranking): the intent-derived signal the
    #   zero-tuning controller steers replica-cache capacity by
    #   (`pm.controller.OnlineController.steer_capacity`, DESIGN.md §13)


def _bucket(n: int, floor: int = 64) -> int:
    b = floor
    while b < n:
        b *= 2
    return b


class IntentPlanner:
    """Consumes per-step, per-shard intent (the upcoming batches' row ids)
    and emits `PlacementPlan`s."""

    def __init__(self, vocab_size: int, cache_capacity: int,
                 n_nodes: Optional[int] = None, plan_every: int = 8,
                 per_node_bound: bool = False, owner_shards: int = 0,
                 alpha: float = 0.1, p: float = 0.9999, lam0: float = 10.0,
                 n_shards: Optional[int] = None,
                 telemetry: Optional[Telemetry] = None):
        # ``n_nodes`` is the number of §4.1 *nodes* intent signals arrive
        # from — what counts as a node depends on the caller: the training
        # loop's data shards, or the serving runtime's requester slots
        # within a micro-batch.  (``n_shards`` is the pre-PR-7 name, kept
        # as an alias; it misread as vocab sharding at serving call sites,
        # where a "shard" is really a request slot.)
        if n_nodes is None:
            n_nodes = n_shards
        if n_nodes is None:
            raise TypeError("IntentPlanner requires n_nodes (the number "
                            "of intent-signaling nodes)")
        self.V = vocab_size
        self.C = cache_capacity
        self.n_nodes = n_nodes
        self.plan_every = plan_every
        # owner_shards > 0: additionally bound unique misses per OWNER
        # shard (owner = id // (V / owner_shards), the engine's affine
        # ownership rule) and publish it as `PlacementPlan.route_capacity`
        # — the per-destination admission capacity of the mesh backend's
        # routed miss path.  Note this is a bound over owner shards (where
        # the row lives), not over signaling nodes (who wants it): the
        # compact buffer is shared, so `miss_capacity` stays the global
        # bound either way.
        self.owner_shards = owner_shards
        # miss-capacity scope, threaded from the collective backend
        # (DESIGN.md §10): False sizes the buffer by the worst per-step
        # GLOBAL unique-miss count (the emulated single-buffer lookup);
        # True sizes it per signaling shard (`intent_miss_bound(
        # per_node=True)`) — the mesh backend's per-shard capacity, where
        # each data shard compacts its own misses.  With one data shard
        # the two bounds coincide; multi-shard mesh configs stay correct
        # through the lookup's non-strict dense fallback.
        self.per_node_bound = per_node_bound
        self.timer = ActionTimer(alpha=alpha, p=p, lam0=lam0)
        # step -> list over shards of id arrays (the intent signal buffer;
        # decisions over it are made by the engine classifiers)
        self._intents: Dict[int, List[np.ndarray]] = {}
        self._version = 0
        self._last_planned_step = -1
        # optional shared bus (DESIGN.md §13): the planner publishes what
        # each plan promised (``plan.*`` gauges) on the SAME bus the
        # runtime/controller use — callers pass their runtime's bus, so
        # there is never a second, divergent bus
        self.telemetry = telemetry

    @property
    def n_shards(self) -> int:
        """Pre-PR-7 alias for `n_nodes` (see __init__)."""
        return self.n_nodes

    def set_capacity(self, cache_capacity: int) -> None:
        """Retarget the replica-cache capacity (the zero-tuning
        controller's resize hook); takes effect at the next plan."""
        self.C = int(cache_capacity)

    # ------------------------------------------------------------ signals
    def signal(self, step: int, shard: int, ids: np.ndarray) -> None:
        """Loader signals: ``shard`` will access ``ids`` at ``step``
        (Intent(P, step, step+1) in the paper's API)."""
        per_shard = self._intents.setdefault(
            step, [None] * self.n_nodes)  # type: ignore[list-item]
        per_shard[shard] = np.asarray(ids, dtype=np.int64)

    def signaled_ids(self, step: int) -> Optional[np.ndarray]:
        """Union of ids signaled for ``step`` (host-side; None if the
        signals were never received or already collected)."""
        per_shard = self._intents.get(step)
        if per_shard is None:
            return None
        ids = [i for i in per_shard if i is not None and len(i)]
        return np.unique(np.concatenate(ids)) if ids else None

    def observe_round(self, step: int) -> None:
        """One planning round passed; the training step counter is the
        worker clock (Algorithm 1 rate estimation)."""
        self.timer.observe_round(0, step)

    # ------------------------------------------------------------- plans
    def lookahead(self) -> int:
        """How far ahead a plan must cover: one planning period *plus* the
        Alg. 1 soft upper bound on clock advance.  Covering only the
        horizon would make `should_replan` true one step after every plan
        (window_end = step + horizon moves in lockstep with the replan
        threshold), degenerating into a replan-every-round loop."""
        return self.plan_every + self.timer.horizon(0)

    def _window_signals(self, lo: int, hi: int):
        """Flatten the signal buffer over ``[lo, hi)`` into parallel
        (keys, shards, steps) arrays for the engine classifiers."""
        keys, shards, steps = [], [], []
        for s in range(lo, hi):
            per_shard = self._intents.get(s)
            if per_shard is None:
                continue
            for sh, ids in enumerate(per_shard):
                if ids is None or len(ids) == 0:
                    continue
                keys.append(ids)
                shards.append(np.full(len(ids), sh, np.int64))
                steps.append(np.full(len(ids), s, np.int64))
        if not keys:
            z = np.zeros(0, np.int64)
            return z, z, z
        return (np.concatenate(keys), np.concatenate(shards),
                np.concatenate(steps))

    def _build_plan(self, keys: np.ndarray, nodes: np.ndarray,
                    steps: np.ndarray, window: tuple, *,
                    cache_singles: bool = False,
                    commit: bool = True) -> PlacementPlan:
        """Shared §4.1 plan construction over flattened (keys, nodes,
        steps) signals — used by the training-window `plan` and the online
        `replan_from_queue` entry points.

        ``cache_singles=False`` (training): only concurrent-intent keys
        are replicated; single-shard keys stay on the owner/miss path.
        ``cache_singles=True`` (serving): single-requester keys compete
        for leftover cache capacity ranked by total demand — on a serving
        node §4.1's *relocation* arm (single active node -> move the value
        to it) degenerates to cache residency, because the requester IS
        this node; concurrent keys still rank first.

        ``commit=False`` builds a *candidate*: pure arithmetic, no
        version bump, no telemetry — safe to run off-thread while the
        training step is in flight (`plan_candidate`).  A candidate
        becomes the active plan only through `adopt`, which stamps the
        next version and publishes, ON the caller's thread."""
        # §4.1 via the engine: concurrent intent -> replicate (weighted),
        # single-node intent -> owner path
        uniq, weight, single = concurrent_intent(keys, nodes, steps)
        if cache_singles:
            score = weight * (np.int64(np.max(single) + 1)
                              if len(single) else 1) + single
        else:
            score = weight
        multi = uniq[score > 0]
        order = np.argsort(-score[score > 0], kind="stable")
        hot = multi[order][: self.C].astype(np.int64)
        cache_ids = np.full((self.C,), self.V, dtype=np.int32)
        if len(hot):
            cache_ids[: len(hot)] = hot.astype(np.int32)
        cache_ids = np.sort(cache_ids)

        # exact per-step miss counts over the window -> capacity
        # (per_node=False: the managed lookup dedups misses over the whole
        # step's batch, so unique ids per step is the exact bound;
        # per_node=True: per-shard capacity for the mesh backend — the
        # loader signals unique ids per shard, so per-(step, shard)
        # counts are per-shard unique counts)
        worst_miss = max(1, intent_miss_bound(
            keys, nodes, steps, hot, per_node=self.per_node_bound))
        miss_rate = (float(np.mean(~np.isin(keys, hot)))
                     if len(keys) else 0.0)
        plan = PlacementPlan(
            version=self._version + 1,
            cache_ids=cache_ids,
            miss_capacity=_bucket(worst_miss),
            window=window,
            predicted_miss_rate=miss_rate,
            route_capacity=self._route_capacity(keys, steps, hot),
            demand=int(np.count_nonzero(score > 0)),
        )
        return self._commit(plan) if commit else plan

    def _commit(self, plan: PlacementPlan) -> PlacementPlan:
        """Make ``plan`` the planner's next version and publish it —
        always on the owner's thread (the uncommitted `plan_candidate`
        path must never touch `_version` or the bus from a worker)."""
        self._version += 1
        plan = replace(plan, version=self._version)
        if self.telemetry is not None:
            self.telemetry.set("plan.version", plan.version)
            self.telemetry.set("plan.predicted_miss_rate",
                               plan.predicted_miss_rate)
            self.telemetry.set("plan.miss_capacity", plan.miss_capacity)
            self.telemetry.set("plan.demand", plan.demand)
            self.telemetry.event("plan.built", version=plan.version,
                                 window=list(plan.window),
                                 predicted=plan.predicted_miss_rate,
                                 miss_capacity=plan.miss_capacity,
                                 demand=plan.demand)
        return plan

    def _route_capacity(self, keys: np.ndarray, steps: np.ndarray,
                        hot: np.ndarray) -> int:
        """Exact per-owner-shard unique-miss bound over the window: the
        worst, over (step, owner) pairs, count of distinct missed ids the
        owner must serve in one step — the routed gather's per-destination
        block size.  Bucketed with a smaller floor than the global bound
        (per-owner counts are ~n_shards-fold smaller) and clamped to the
        global capacity at the use site."""
        if self.owner_shards <= 0:
            return 0
        if len(keys) == 0:
            return _bucket(1, floor=16)
        miss = ~np.isin(keys, hot)
        if not np.any(miss):
            return _bucket(1, floor=16)
        block = -(-self.V // self.owner_shards)
        # distinct (step, key) pairs, then count per (step, owner)
        pair = np.unique(steps[miss].astype(np.int64) * np.int64(self.V)
                         + keys[miss].astype(np.int64))
        grp = (pair // np.int64(self.V)) * np.int64(self.owner_shards) \
            + (pair % np.int64(self.V)) // block
        _, cnt = np.unique(grp, return_counts=True)
        return _bucket(int(cnt.max()), floor=16)

    def plan_window(self, current_step: int) -> tuple:
        """The window `plan(current_step)` would cover right now: one
        lookahead, clipped to the steps with signals in hand — a window
        running past the loader's prefetch horizon would under-count
        misses for the signal-less tail (the bound must stay exact).
        Exposed so the prefetch pipeline can pin a background candidate's
        window on the main thread (`max` iterates the intent dict, which
        only the main thread may do while signals keep arriving)."""
        end = current_step + self.lookahead()
        if self._intents:
            end = max(current_step + 1,
                      min(end, max(self._intents) + 1))
        return (current_step, end)

    def plan(self, current_step: int) -> PlacementPlan:
        """Build the plan for [current_step, current_step + lookahead)."""
        window = self.plan_window(current_step)
        keys, shards, steps = self._window_signals(*window)
        plan = self._build_plan(keys, shards, steps, window)
        self._last_planned_step = current_step
        return plan

    # ------------------------------------------------- prefetch pipeline
    def plan_candidate(self, window: tuple) -> PlacementPlan:
        """Uncommitted plan over ``window`` — the background half of the
        plan-ahead pipeline (DESIGN.md §15).  ``window`` must come from a
        main-thread `plan_window` call at submission time; the build then
        only issues GIL-atomic ``dict.get`` reads against the signal
        buffer, and is safe to run concurrently with new signals because
        a step's signals are inserted in one shot for steps AT OR BEYOND
        the submission-time window end (the loader's prefetch horizon
        already covered every step inside it).  No planner state is
        mutated; the result is inert until `adopt`."""
        keys, shards, steps = self._window_signals(*window)
        return self._build_plan(keys, shards, steps, tuple(window),
                                commit=False)

    def adopt(self, candidate: Optional[PlacementPlan],
              current_step: int) -> Optional[PlacementPlan]:
        """Promote a background candidate to the active plan IFF it is
        exactly the plan a synchronous `plan(current_step)` call would
        build now: the windows must match (the Alg.-1 horizon — and with
        it `lookahead` — can shift between submission and the replan
        boundary via `observe_round`).  On a match, stamp the next
        version and publish; on a mismatch return None and let the
        caller fall back to the synchronous build — the pipeline is an
        optimization, never a semantics change."""
        if candidate is None:
            return None
        if tuple(candidate.window) != self.plan_window(current_step):
            return None
        plan = self._commit(candidate)
        self._last_planned_step = current_step
        return plan

    def replan_from_queue(self, keys: np.ndarray, slots: np.ndarray,
                          ticks: np.ndarray) -> PlacementPlan:
        """Online serving entry point (DESIGN.md §9): plan from the
        *queued* — already-signaled — horizon instead of a fixed training
        window.  The inputs are a `StreamingIntentBuffer.snapshot` of the
        request queue: ``ticks`` are the micro-batches the scheduler will
        form (the serving logical clock), ``slots`` are request positions
        within a batch (the "nodes" of §4.1 — a key wanted by >= 2 queued
        requests in the same batch is concurrent intent -> replicated;
        leftover capacity goes to single-requester keys by demand — the
        relocation arm lands on this node, see `_build_plan` — and
        everything else rides the compact miss buffer, whose capacity is
        the exact `intent_miss_bound` over the queued horizon)."""
        keys = np.asarray(keys, np.int64)
        end = int(ticks.max()) + 1 if len(keys) else 1
        return self._build_plan(keys, np.asarray(slots, np.int64),
                                np.asarray(ticks, np.int64), (0, end),
                                cache_singles=True)

    def should_replan(self, current_step: int,
                      active: Optional[PlacementPlan]) -> bool:
        """Act-on-intent decision: replan when the Alg.-1 horizon says the
        worker may run past the active plan's window before the *next*
        planning round completes.  Planning rounds come at most every
        ``plan_every`` steps (the plan's window cannot outrun the loader's
        signal horizon, so without this floor the horizon test degenerates
        into replanning — and re-gathering the replica cache — every
        step); an exhausted window forces a replan regardless."""
        if active is None:
            return True
        if current_step >= active.window[1]:
            return True
        if current_step - self._last_planned_step < self.plan_every:
            return False
        horizon = self.timer.horizon(0)
        return active.window[1] < current_step + horizon

    def gc(self, before_step: int) -> None:
        for s in [s for s in self._intents if s < before_step]:
            del self._intents[s]
