"""Synthetic sparse-access workloads mirroring the paper's five ML tasks
(§5.1, Appendix C).  Each generator produces a `Workload` — per-(node,
worker) streams of batches, each batch being the distinct parameter keys the
batch's training step reads and writes:

  KGE: positive entities/relations follow a skewed (degree-like) Zipf
       distribution; negatives are sampled uniformly over all entities.
  WV:  word frequencies are heavily Zipfian (natural language).
  MF:  row parameters are partitioned per node (pure locality); each worker
       sweeps columns sequentially, giving long single-node access stretches
       per column parameter — the workload where relocation shines (§5.5).
  CTR: Zipf embedding keys plus a handful of dense "wide" keys accessed by
       every batch on every node — extreme hot spots.
  GNN: graph-partitioned keys; batches access large groups, mostly from the
       node's own partition with a boundary fraction from other partitions.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.simulator import Workload


def _zipf_probs(n: int, a: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


def _streams_from_sampler(rng, n_nodes, wpn, n_batches, sample_batch):
    streams = []
    for node in range(n_nodes):
        node_streams = []
        for w in range(wpn):
            node_streams.append(
                [sample_batch(rng, node, w, b) for b in range(n_batches)])
        streams.append(node_streams)
    return streams


def kge_workload(n_nodes=8, wpn=4, n_batches=200, n_keys=100_000,
                 batch_pos=32, batch_neg=32, zipf_a=1.05,
                 seed=0) -> Workload:
    rng = np.random.default_rng(seed)
    p = _zipf_probs(n_keys, zipf_a)
    perm = rng.permutation(n_keys)  # hot keys spread over the id space

    def sample(rng, node, w, b):
        pos = perm[rng.choice(n_keys, size=batch_pos, p=p)]
        neg = rng.integers(0, n_keys, size=batch_neg)
        return np.unique(np.concatenate([pos, neg]))

    return Workload("KGE", n_keys,
                    _streams_from_sampler(rng, n_nodes, wpn, n_batches, sample))


def wv_workload(n_nodes=8, wpn=4, n_batches=200, n_keys=60_000,
                batch_size=48, zipf_a=1.25, seed=1) -> Workload:
    rng = np.random.default_rng(seed)
    p = _zipf_probs(n_keys, zipf_a)
    perm = rng.permutation(n_keys)

    def sample(rng, node, w, b):
        return np.unique(perm[rng.choice(n_keys, size=batch_size, p=p)])

    return Workload("WV", n_keys,
                    _streams_from_sampler(rng, n_nodes, wpn, n_batches, sample))


def mf_workload(n_nodes=8, wpn=4, n_batches=200, n_rows=8_000,
                n_cols=2_000, batch_points=48, batches_per_col=20,
                seed=2) -> Workload:
    """Rows partitioned to nodes; workers sweep columns sequentially."""
    rng = np.random.default_rng(seed)
    n_keys = n_rows + n_cols
    rows_per_node = n_rows // n_nodes

    streams = []
    for node in range(n_nodes):
        row_lo = node * rows_per_node
        node_streams = []
        for w in range(wpn):
            col_order = rng.permutation(n_cols)
            batches = []
            for b in range(n_batches):
                col = col_order[(b // batches_per_col) % n_cols]
                rows = row_lo + rng.integers(0, rows_per_node,
                                             size=batch_points)
                keys = np.unique(np.concatenate(
                    [rows, np.array([n_rows + col])]))
                batches.append(keys)
            node_streams.append(batches)
        streams.append(node_streams)
    return Workload("MF", n_keys, streams)


def ctr_workload(n_nodes=8, wpn=4, n_batches=200, n_keys=120_000,
                 batch_size=40, zipf_a=1.2, n_dense=8, seed=3) -> Workload:
    """Zipf embedding keys + dense 'wide' keys hit by every batch."""
    rng = np.random.default_rng(seed)
    p = _zipf_probs(n_keys - n_dense, zipf_a)
    perm = rng.permutation(n_keys - n_dense) + n_dense
    dense = np.arange(n_dense)

    def sample(rng, node, w, b):
        emb = perm[rng.choice(n_keys - n_dense, size=batch_size, p=p)]
        return np.unique(np.concatenate([dense, emb]))

    return Workload("CTR", n_keys,
                    _streams_from_sampler(rng, n_nodes, wpn, n_batches, sample))


def gnn_workload(n_nodes=8, wpn=4, n_batches=150, n_keys=160_000,
                 batch_size=128, boundary_frac=0.15, seed=4) -> Workload:
    """Graph-partitioned node embeddings, group access with boundary keys."""
    rng = np.random.default_rng(seed)
    per_node = n_keys // n_nodes

    def sample(rng, node, w, b):
        n_own = int(batch_size * (1.0 - boundary_frac))
        own = node * per_node + rng.integers(0, per_node, size=n_own)
        other = rng.integers(0, n_keys, size=batch_size - n_own)
        return np.unique(np.concatenate([own, other]))

    return Workload("GNN", n_keys,
                    _streams_from_sampler(rng, n_nodes, wpn, n_batches, sample))


def zipf_workload(n_nodes=4, wpn=2, n_batches=100, n_keys=1_000_000,
                  batch_size=64, zipf_a=1.1, seed=5) -> Workload:
    """Pure skewed Zipf stream at arbitrary key counts (scale sweeps).

    Sampling goes through the inverse CDF (``searchsorted``) instead of
    ``rng.choice(p=...)``, which is O(n_keys) per draw — at 10^6+ keys the
    naive sampler dominates the whole simulation."""
    rng = np.random.default_rng(seed)
    cdf = np.cumsum(_zipf_probs(n_keys, zipf_a))
    perm = rng.permutation(n_keys)  # hot keys spread over the id space

    def sample(rng, node, w, b):
        r = np.minimum(np.searchsorted(cdf, rng.random(batch_size),
                                       side="right"), n_keys - 1)
        return np.unique(perm[r])

    return Workload(f"ZIPF(n={n_keys})", n_keys,
                    _streams_from_sampler(rng, n_nodes, wpn, n_batches,
                                          sample))


TASKS = {
    "KGE": kge_workload,
    "WV": wv_workload,
    "MF": mf_workload,
    "CTR": ctr_workload,
    "GNN": gnn_workload,
    "ZIPF": zipf_workload,
}


def make_workload(task: str, n_nodes: int = 8, wpn: int = 4,
                  scale: float = 1.0, seed: Optional[int] = None,
                  n_keys: Optional[int] = None) -> Workload:
    """Build one of the paper tasks (plus the synthetic ZIPF scale task),
    optionally scaling batch counts and overriding the key-space size."""
    fn = TASKS[task]
    kwargs = {"n_nodes": n_nodes, "wpn": wpn}
    if seed is not None:
        kwargs["seed"] = seed
    if n_keys is not None:
        if task == "MF":
            n_rows = int(n_keys * 0.8)
            kwargs["n_rows"] = n_rows
            kwargs["n_cols"] = n_keys - n_rows
        else:
            kwargs["n_keys"] = n_keys
    wl = fn(**kwargs)
    if scale != 1.0:
        for node_streams in wl.streams:
            for i, stream in enumerate(node_streams):
                node_streams[i] = stream[: max(1, int(len(stream) * scale))]
    return wl
