"""Batch construction shared by the data pipeline, smoke tests, and the
dry-run `input_specs` (which mirrors these shapes as ShapeDtypeStructs)."""

from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Shape/dtype description of one training/prefill batch (as numpy
    metadata; `launch.dryrun` converts to ShapeDtypeStruct)."""
    d: Dict[str, Any] = {
        "tokens": ((batch, seq), np.int32),
        "labels": ((batch, seq), np.int32),
    }
    if cfg.mrope:
        d["positions"] = ((batch, seq, 3), np.int32)
    else:
        d["positions"] = ((batch, seq), np.int32)
    if cfg.family == "vlm":
        n = min(cfg.n_img_tokens, max(1, seq // 4))
        d["img_embeds"] = ((batch, n, cfg.d_model), np.float32)
        d["img_pos"] = ((batch, n), np.int32)
    if cfg.family == "encdec":
        d["frames"] = ((batch, cfg.encoder.n_frames, cfg.d_model),
                       np.float32)
    return d


def make_batch(cfg: ModelConfig, batch: int, seq: int,
               rng: np.random.Generator) -> Dict[str, jnp.ndarray]:
    """A concrete random batch matching `batch_struct` (smoke/e2e use)."""
    out: Dict[str, jnp.ndarray] = {}
    for name, (shape, dtype) in batch_struct(cfg, batch, seq).items():
        if name == "tokens" or name == "labels":
            arr = rng.integers(0, cfg.vocab_size, size=shape)
        elif name == "positions":
            if cfg.mrope:
                base = np.broadcast_to(
                    np.arange(seq)[None, :, None], shape)
                arr = base.copy()
            else:
                arr = np.broadcast_to(np.arange(seq)[None, :], shape).copy()
        elif name == "img_pos":
            n = shape[1]
            arr = np.broadcast_to(np.arange(n)[None, :], shape).copy()
        else:
            arr = rng.normal(size=shape).astype(np.float32) * 0.02
        out[name] = jnp.asarray(arr, dtype=dtype)
    return out
