"""Token data pipeline with intent signaling (paper §3, Figure 2).

The loader prepares batches ``prefetch`` steps ahead of training.  The
moment a batch is constructed its token-id set is known, so the loader
signals intent to the `IntentPlanner` right then — exactly the paper's
data-loader integration.  The training loop later asks the planner for
placement plans; the loader itself never makes PM decisions (information
and action stay decoupled).
"""

from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.batches import make_batch
from repro.pm.planner import IntentPlanner


class SyntheticCorpus:
    """Zipf-distributed token stream (natural-language-like marginals)."""

    def __init__(self, vocab_size: int, zipf_a: float = 1.1, seed: int = 0):
        self.V = vocab_size
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.p = p / p.sum()
        self.perm = np.random.default_rng(seed).permutation(vocab_size)
        self.rng = np.random.default_rng(seed + 1)

    def tokens(self, shape) -> np.ndarray:
        flat = self.rng.choice(self.V, size=int(np.prod(shape)), p=self.p)
        return self.perm[flat].reshape(shape).astype(np.int32)


class DriftingZipfCorpus(SyntheticCorpus):
    """Zipf stream whose hot set drifts: `rotate()` re-draws the rank ->
    token-id permutation, so yesterday's head becomes tail mass overnight.
    This is the serving-side access pattern (hot entities change by the
    minute) the online runtime adapts to; the training loader can use it
    too for drift-robustness runs."""

    def __init__(self, vocab_size: int, zipf_a: float = 1.1, seed: int = 0):
        super().__init__(vocab_size, zipf_a=zipf_a, seed=seed)
        self._perm_rng = np.random.default_rng(seed + 2)
        self.rotations = 0

    def rotate(self) -> None:
        self.perm = self._perm_rng.permutation(self.V)
        self.rotations += 1


class IntentSignalingLoader:
    """Iterator of (step, batch) that runs ``prefetch`` steps ahead and
    signals intent per data shard as each batch is constructed."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *,
                 n_shards: int = 1, prefetch: int = 16,
                 planner: Optional[IntentPlanner] = None,
                 corpus: Optional[SyntheticCorpus] = None, seed: int = 0):
        self.cfg = cfg
        self.B, self.S = batch, seq
        self.n_shards = n_shards
        self.prefetch = prefetch
        self.planner = planner
        self.corpus = corpus or SyntheticCorpus(cfg.vocab_size, seed=seed)
        self.rng = np.random.default_rng(seed + 7)
        self._queue: Deque[Tuple[int, Dict]] = deque()
        self._next_prepare = 0

    def _prepare(self, step: int) -> Dict:
        batch = make_batch(self.cfg, self.B, self.S, self.rng)
        toks = self.corpus.tokens((self.B, self.S))
        labels = np.roll(toks, -1, axis=1)
        batch = dict(batch)
        import jax.numpy as jnp
        batch["tokens"] = jnp.asarray(toks)
        batch["labels"] = jnp.asarray(labels)
        if self.planner is not None:
            # every row must be signaled: the last shard takes the
            # B % n_shards remainder (dropping it broke the planner's
            # exact miss bound for the trailing rows — ISSUE 2)
            shard_size = max(1, self.B // self.n_shards)
            for shard in range(self.n_shards):
                lo = shard * shard_size
                hi = (shard + 1) * shard_size \
                    if shard < self.n_shards - 1 else self.B
                if lo >= self.B:
                    break
                ids = np.unique(toks[lo:hi])
                self.planner.signal(step, shard, ids)
        return batch

    def fill(self) -> None:
        while len(self._queue) < self.prefetch:
            self._queue.append(
                (self._next_prepare, self._prepare(self._next_prepare)))
            self._next_prepare += 1

    def __iter__(self) -> Iterator[Tuple[int, Dict]]:
        while True:
            self.fill()
            yield self._queue.popleft()
