"""Sharding rules: logical parameter/activation axes -> mesh axes.

Scheme (DESIGN.md §6):
  * "model" axis: tensor parallelism — vocab, attention heads, FFN hidden,
    MoE experts (expert-parallel when E divides), Mamba d_inner;
  * "data" (x "pod") axis: batch; parameters/optimizer state additionally
    ZeRO-shard their d_model-sized dimension over "data" (FSDP-style; XLA
    inserts the per-layer all-gather inside the layer scan);
  * any rule whose dimension does not divide the mesh axis falls back to
    replication for that dimension (e.g. smollm's 9 heads on a 16-way
    model axis -> FFN/vocab-only tensor parallelism).

Everything here is pure shape reasoning — usable on ShapeDtypeStructs.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.configs.base import ModelConfig
from .mesh import axis_size, batch_axes


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def managed_table_sharding(mesh, axis: str = "model") -> jax.NamedSharding:
    """Placement of the intent-managed embedding table for a collective
    backend mesh: vocab-sharded over ``axis`` (every row has one owner
    shard — the allocation of DESIGN.md §3b), feature dim replicated.
    `device_put` target for `pm.collectives.MeshBackend` callers."""
    return jax.NamedSharding(mesh, P(axis, None))


def _roles_for(name: str, shape, in_moe: bool, cfg: ModelConfig):
    """Role per dimension of the (unstacked) leaf."""
    nd = len(shape)
    if name == "embed":
        return ("vocab", "zero")
    if name == "head":
        return ("zero", "vocab")
    if in_moe:
        if name == "router":
            return ("zero", None)
        if name in ("w_gate", "w_up"):
            return ("expert", "zero", "tp_sub")
        if name == "w_down":
            return ("expert", "tp_sub", "zero")
    if name in ("wq",):
        return ("zero", "tp")
    if name in ("wk", "wv"):
        return ("zero", "tp")
    if name == "wo":
        return ("tp", "zero")
    if name in ("w_gate", "w_up", "w_in"):
        return ("zero", "tp")
    if name in ("w_down", "w_out"):
        return ("tp", "zero")
    if name == "in_proj":
        return ("zero", "tp")
    if name == "out_proj":
        return ("tp", "zero")
    if name == "conv_w":
        return ("tp", None)
    if name in ("conv_b", "dt_bias", "D_skip"):
        return ("tp",)
    if name == "x_proj":
        return ("tp", None)
    if name == "dt_proj":
        # mamba1: (dt_rank, d_inner); mamba2: (d_model, n_heads)
        return (None, "tp") if nd == 2 else ("tp",)
    if name == "A_log":
        return ("tp", None) if nd == 2 else ("tp",)
    if name in ("B_proj", "C_proj"):
        return ("zero", None)
    return tuple(None for _ in range(nd))


def needs_zero(cfg: ModelConfig, mesh, budget_bytes: float = 10e9) -> bool:
    """Auto-ZeRO heuristic: shard layer weights over "data" (FSDP) only
    when TP-only weights + AdaGrad state would not fit the per-device
    budget (bf16 params + f32 accumulator = 6 bytes/param)."""
    msize = axis_size(mesh, "model")
    per_dev = cfg.param_count() / msize * 6.0
    return per_dev > budget_bytes


def param_pspecs(shapes: Any, cfg: ModelConfig, mesh, *,
                 zero_embed_head: bool = True,
                 zero_layers: Optional[bool] = None) -> Any:
    """PartitionSpec tree matching ``shapes`` (arrays or SDStructs).

    ``zero_embed_head``: also ZeRO-shard the d_model dimension of the
    embedding table and LM head over "data".  This is the naive-FSDP
    baseline; it shards the head *contraction* dimension, which forces XLA
    to partial-sum all-reduce the full (B, S, V) logits across the data
    axis — the dominant collective for every large-vocab config (see
    EXPERIMENTS.md §Perf iteration 1).  ``False`` keeps embed/head sharded
    over "model" (vocab) only: logits come out vocab-sharded with NO
    collective.

    ``zero_layers``: ZeRO-shard layer weights over "data".  ``None`` =
    auto (`needs_zero`): enabled only when TP-only weights would not fit
    per-device memory (llama3-405b, mixtral-8x22b, qwen3-moe).  When
    enabled, pair it with the FSDP weight-gather constraints in the layer
    scan (`layer_constraint_specs` + forward(fsdp_spec=…)), otherwise
    GSPMD partial-sums full-batch activations over "data" instead of
    gathering the (small) weights (EXPERIMENTS.md §Perf iteration 6)."""
    dsize = axis_size(mesh, "data")
    msize = axis_size(mesh, "model")
    if zero_layers is None:
        zero_layers = needs_zero(cfg, mesh)
    expert_parallel = cfg.n_experts > 0 and _fits(cfg.n_experts, msize)

    def resolve(role: Optional[str], dim: int,
                expert_used: bool) -> Optional[str]:
        if role == "vocab" or role == "tp":
            return "model" if _fits(dim, msize) else None
        if role == "expert":
            return "model" if expert_parallel else None
        if role == "tp_sub":
            # shard expert-FFN hidden over model only when experts are NOT
            # expert-parallel (a dim can't use "model" twice)
            if expert_used:
                return None
            return "model" if _fits(dim, msize) else None
        if role == "zero":
            if not zero_layers:
                return None
            return "data" if _fits(dim, dsize) else None
        return None

    def leaf_spec(path, leaf):
        names = [e.key for e in path if isinstance(e, DictKey)]
        shape = tuple(leaf.shape)
        name = names[-1] if names else ""
        stacked = any(n in ("layers", "enc_layers") for n in names)
        core = shape[1:] if stacked else shape
        in_moe = "moe" in names
        roles = _roles_for(name, core, in_moe, cfg)
        if not zero_embed_head:
            if name == "embed":
                roles = ("vocab", None)
            elif name == "head":
                roles = (None, "vocab")
        expert_used = expert_parallel and "expert" in roles
        spec = [resolve(r, d, expert_used and r == "tp_sub")
                for r, d in zip(roles, core)]
        if stacked:
            spec = [None] + spec
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, shapes)


def batch_pspecs(cfg: ModelConfig, mesh, batch_shapes: Any) -> Any:
    """Sharding for a training/prefill batch dict (dim 0 = global batch)."""
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= axis_size(mesh, a)

    def leaf_spec(path, leaf):
        names = [e.key for e in path if isinstance(e, DictKey)]
        name = names[-1] if names else ""
        shape = tuple(leaf.shape)
        if name.startswith("pm_cache"):
            return P(*([None] * len(shape)))  # replica cache: replicated
        first = baxes if _fits(shape[0], bsize) or shape[0] == bsize else None
        rest = [None] * (len(shape) - 1)
        return P(first, *rest)

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_shapes)


def cache_pspecs(cfg: ModelConfig, mesh, cache: Any) -> Any:
    """Sharding for decode caches."""
    baxes = batch_axes(mesh)
    bsize = 1
    for a in baxes:
        bsize *= axis_size(mesh, a)
    dsize = axis_size(mesh, "data")
    msize = axis_size(mesh, "model")

    def leaf_spec(path, leaf):
        names = [e.key for e in path if isinstance(e, DictKey)]
        name = names[-1] if names else ""
        shape = tuple(leaf.shape)
        if name == "len":
            return P()
        if name in ("k", "v", "attn_k", "attn_v"):
            L, B, S, KvH, hd = shape
            b_ax = baxes if _fits(B, bsize) else None
            kv_ax = "model" if _fits(KvH, msize) else None
            hd_ax = None
            s_ax = None
            if kv_ax is None and _fits(hd, msize):
                hd_ax = "model"
            if b_ax is None and _fits(S, dsize):
                s_ax = "data"
            return P(None, b_ax, s_ax, kv_ax, hd_ax)
        if name == "conv":
            L, B, K1, di = shape
            return P(None, baxes if _fits(B, bsize) else None, None,
                     "model" if _fits(di, msize) else None)
        if name == "h":
            if len(shape) == 4:      # mamba1 (L, B, di, N)
                L, B, di, N = shape
                return P(None, baxes if _fits(B, bsize) else None,
                         "model" if _fits(di, msize) else None, None)
            L, B, nh, hd, N = shape  # mamba2
            return P(None, baxes if _fits(B, bsize) else None,
                     "model" if _fits(nh, msize) else None, None, None)
        if name == "enc_out":
            B, F, D = shape
            return P(baxes if _fits(B, bsize) else None, None, None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)
