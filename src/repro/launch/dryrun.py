import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
combination on the production meshes, with NO real allocation (all inputs
are ShapeDtypeStructs).

Per combination this produces:
  * proof the sharding config is coherent (compile succeeds),
  * ``compiled.memory_analysis()``  (fits-per-device evidence),
  * ``compiled.cost_analysis()``    (HLO FLOPs / bytes for the roofline),
  * collective bytes parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute),
written as JSON for `benchmarks.roofline`.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k [--multi-pod] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, InputShape
from repro.data.batches import batch_struct
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import batch_pspecs, cache_pspecs, param_pspecs
from repro.models.model import init_cache, init_model
from repro.optim.optimizers import adagrad_init
from repro.train.steps import (make_prefill_step, make_serve_step,
                               make_train_step)

PARAM_DTYPE = jnp.bfloat16

# Documented skips (DESIGN.md §5): long_500k needs sub-quadratic context.
LONG_OK = {"falcon-mamba-7b", "zamba2-1.2b", "mixtral-8x22b"}


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and cfg.arch_id not in LONG_OK:
        return ("full-attention family: 500k decode requires sub-quadratic "
                "attention (DESIGN.md §5)")
    return None


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    if shape.kind in ("train", "prefill"):
        structs = batch_struct(cfg, shape.global_batch, shape.seq_len)
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in
                structs.items()}
    # decode: one new token against a cache of seq_len context
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), np.int32)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           dtype=PARAM_DTYPE))
    return {"tokens": tokens, "cache": cache}


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: init_model(cfg, jax.random.PRNGKey(0),
                           param_dtype=PARAM_DTYPE))


_COLL_RE = re.compile(
    r"(\w+\[[^\]]*\])[^=]*=\s*(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)\(", re.I)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
                "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}


def _tuple_shapes(text: str):
    """All 'dtype[dims]' occurrences inside one result-type string."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


_HDR_RE = re.compile(r"^(ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COLL_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)\(")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> its text block (optimized-HLO printing:
    headers at column 0, closing '}' at column 0)."""
    blocks: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo_text.splitlines():
        m = _HDR_RE.match(line)
        if m:
            cur_name, cur_lines = m.group(2), []
            continue
        if line.startswith("}") and cur_name is not None:
            blocks[cur_name] = "\n".join(cur_lines)
            cur_name = None
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return blocks


def collective_bytes(hlo_text: str, default_trip: float = 1.0
                     ) -> Dict[str, float]:
    """Per-device collective bytes from the optimized HLO, *execution-count
    aware*: XLA prints a while body once, so collectives inside scanned
    layer stacks are scaled by the loop's trip count (parsed from the
    comparison constant in the condition computation; falls back to
    ``default_trip`` = n_layers when unparseable).  Nested loops multiply.

    Accounting per device: all-reduce = 2x result bytes (ring);
    all-gather / reduce-scatter / all-to-all / collective-permute =
    1x result bytes (result shapes are post-SPMD per-device shapes).
    """
    blocks = _split_computations(hlo_text)
    # while-call graph: body -> (parent_block, trip_count)
    parent: Dict[str, str] = {}
    trip: Dict[str, float] = {}
    for name, text in blocks.items():
        for m in _WHILE_RE.finditer(text):
            cond, body = m.group(1), m.group(2)
            consts = [int(c) for c in _CONST_RE.findall(
                blocks.get(cond, ""))]
            trips = [c for c in consts if c > 1]
            trip[body] = float(max(trips)) if trips else default_trip
            parent[body] = name

    def multiplier(name: str, depth=0) -> float:
        if depth > 16 or name not in parent:
            return 1.0
        return trip.get(name, 1.0) * multiplier(parent[name], depth + 1)

    per_op: Dict[str, float] = {}
    for name, text in blocks.items():
        mult = multiplier(name) if name in parent else 1.0
        for m in _COLL_LINE_RE.finditer(text):
            result_ty, op = m.group(1), m.group(2)
            nbytes = sum(_tuple_shapes(result_ty))
            w = (2.0 if op == "all-reduce" else 1.0) * mult
            per_op[op] = per_op.get(op, 0.0) + w * nbytes
    return per_op


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               pm_miss_capacity: int = 0, zero_embed_head: bool = True,
               prefill_last_only: bool = False, vp_loss: bool = False,
               remat_policy: str = "full", pad_vocab: bool = False,
               zero_layers=True, fsdp_gather: bool = False,
               verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    if pad_vocab:
        import dataclasses
        pad_to = 16 * 128
        v = -(-cfg.vocab_size // pad_to) * pad_to
        cfg = dataclasses.replace(cfg, vocab_size=v)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "pm_miss_capacity": pm_miss_capacity,
        "zero_embed_head": zero_embed_head,
        "prefill_last_only": prefill_last_only,
        "vp_loss": vp_loss,
        "remat_policy": remat_policy,
        "pad_vocab": pad_vocab,
        "zero_layers": "auto" if zero_layers is None else zero_layers,
        "fsdp_gather": fsdp_gather,
    }
    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    p_sds = params_specs(cfg)
    p_spec = param_pspecs(p_sds, cfg, mesh, zero_embed_head=zero_embed_head,
                          zero_layers=zero_layers)
    from repro.launch.sharding import needs_zero
    zl_effective = needs_zero(cfg, mesh) if zero_layers is None \
        else zero_layers
    rec["zero_layers_effective"] = zl_effective
    fsdp_spec = None
    if fsdp_gather and zl_effective and "layers" in p_sds:
        layer_sds = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape[1:], s.dtype),
            p_sds["layers"])
        fsdp_spec = param_pspecs(layer_sds, cfg, mesh, zero_layers=False)

    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        if shape.kind == "train":
            opt_sds = jax.eval_shape(adagrad_init, p_sds)
            opt_spec = type(opt_sds)(accum=param_pspecs(
                opt_sds.accum, cfg, mesh, zero_embed_head=zero_embed_head,
                zero_layers=zero_layers))
            b_sds = input_specs(cfg, shape)
            if pm_miss_capacity:
                C = 4096
                b_sds = dict(
                    b_sds,
                    pm_cache_ids=jax.ShapeDtypeStruct((C,), np.int32),
                    pm_cache_rows=jax.ShapeDtypeStruct(
                        (C, cfg.d_model), PARAM_DTYPE))
            b_spec = batch_pspecs(cfg, mesh, b_sds)
            from jax.sharding import PartitionSpec as P
            # the shard_map vocab-parallel CE needs V % model-axis == 0
            vp_ok = vp_loss and cfg.vocab_size % mesh.shape["model"] == 0
            fn = make_train_step(cfg, pm_miss_capacity=pm_miss_capacity,
                                 pm_strict=bool(pm_miss_capacity),
                                 remat_policy=remat_policy,
                                 vp_loss_mesh=mesh if vp_ok else None,
                                 fsdp_spec=fsdp_spec)
            jitted = jax.jit(
                fn,
                in_shardings=(jax.tree_util.tree_map(
                    lambda s: jax.NamedSharding(mesh, s), p_spec),
                    jax.tree_util.tree_map(
                        lambda s: jax.NamedSharding(mesh, s), opt_spec),
                    jax.tree_util.tree_map(
                        lambda s: jax.NamedSharding(mesh, s), b_spec)),
            )
            lowered = jitted.lower(p_sds, opt_sds, b_sds)
        elif shape.kind == "prefill":
            b_sds = input_specs(cfg, shape)
            b_spec = batch_pspecs(cfg, mesh, b_sds)
            fn = make_prefill_step(cfg, last_only=prefill_last_only,
                                   fsdp_spec=fsdp_spec)
            jitted = jax.jit(
                fn,
                in_shardings=(jax.tree_util.tree_map(
                    lambda s: jax.NamedSharding(mesh, s), p_spec),
                    jax.tree_util.tree_map(
                        lambda s: jax.NamedSharding(mesh, s), b_spec)),
            )
            lowered = jitted.lower(p_sds, b_sds)
        else:  # decode
            spec_in = input_specs(cfg, shape)
            cache_sds = spec_in["cache"]
            c_spec = cache_pspecs(cfg, mesh, cache_sds)
            tok_sds = spec_in["tokens"]
            from jax.sharding import PartitionSpec as P
            from repro.launch.mesh import batch_axes
            baxes = batch_axes(mesh)
            bsize = int(np.prod([mesh.shape[a] for a in baxes]))
            tok_spec = P(baxes if shape.global_batch % bsize == 0 else None,
                         None)
            fn = make_serve_step(cfg, fsdp_spec=fsdp_spec)
            jitted = jax.jit(
                fn,
                in_shardings=(jax.tree_util.tree_map(
                    lambda s: jax.NamedSharding(mesh, s), p_spec),
                    jax.tree_util.tree_map(
                        lambda s: jax.NamedSharding(mesh, s), c_spec),
                    jax.NamedSharding(mesh, tok_spec)),
            )
            lowered = jitted.lower(p_sds, cache_sds, tok_sds)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                "output_bytes": getattr(ma, "output_size_in_bytes", None),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            }
    except Exception:
        pass
    hlo = compiled.as_text()
    coll = collective_bytes(hlo, default_trip=float(cfg.n_layers))

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw cost_analysis values count while bodies ONCE (calibrated);
        # benchmarks.roofline combines them with analytic layer-scaled
        # estimates — see EXPERIMENTS.md §Dry-run methodology.
        "flops_raw": cost.get("flops", 0.0),
        "bytes_accessed_raw": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_op": coll,
        "collective_bytes": sum(coll.values()),
        "memory": mem,
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "hlo_bytes": len(hlo),
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: OK "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s, "
              f"raw GFLOPs {rec['flops_raw']/1e9:.1f}, "
              f"coll {rec['collective_bytes']/1e6:.1f}MB)")
        if mem:
            print(f"         memory_analysis: {mem}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--pm-miss-capacity", type=int, default=0)
    ap.add_argument("--no-zero-embed-head", dest="zero_embed_head",
                    action="store_false",
                    help="perf: keep embed/head vocab-sharded only "
                         "(kills the logits partial-sum all-reduce)")
    ap.add_argument("--prefill-last-only", action="store_true",
                    help="perf: head matmul on the final position only")
    ap.add_argument("--vp-loss", action="store_true",
                    help="perf: explicit vocab-parallel CE (shard_map)")
    ap.add_argument("--remat-policy", choices=("full", "dots"),
                    default="full",
                    help="perf: 'dots' saves matmul outputs (less "
                         "recompute, more activation memory)")
    ap.add_argument("--auto-zero-layers", action="store_true",
                    help="perf: ZeRO layer weights only when TP-only "
                         "weights+optimizer would not fit per-device")
    ap.add_argument("--fsdp-gather", action="store_true",
                    help="perf: constrain layer weights to their TP "
                         "layout inside the scan (gather weights, not "
                         "activations) when ZeRO is active")
    ap.add_argument("--pad-vocab", action="store_true",
                    help="perf: pad vocab to a multiple of 16*128 so the "
                         "embedding/head shard over the model axis")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    combos = []
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or not args.shape) else (args.shape,)
    meshes = (False, True) if args.both_meshes else (args.multi_pod,)
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    results = []
    for (a, s, mp) in combos:
        try:
            rec = dryrun_one(a, s, multi_pod=mp,
                             pm_miss_capacity=args.pm_miss_capacity,
                             zero_embed_head=args.zero_embed_head,
                             prefill_last_only=args.prefill_last_only,
                             vp_loss=args.vp_loss,
                             remat_policy=args.remat_policy,
                             pad_vocab=args.pad_vocab,
                             zero_layers=(None if args.auto_zero_layers
                                          else True),
                             fsdp_gather=args.fsdp_gather)
        except Exception as e:
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": repr(e),
                   "trace": traceback.format_exc()[-2000:]}
            print(f"[dryrun] {a} x {s}: FAILED {e!r}", file=sys.stderr)
        results.append(rec)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    err = sum(1 for r in results if r["status"] == "error")
    print(f"[dryrun] done: {ok} ok, {sk} skipped (documented), {err} failed")
    return 1 if err else 0


if __name__ == "__main__":
    sys.exit(main())
