"""Production mesh construction (TPU v5e pods).

Single pod:  (data=16, model=16)        = 256 chips
Multi-pod:   (pod=2, data=16, model=16) = 512 chips

Functions only — importing this module never touches jax device state.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_model_mesh(n_shards: int = 0):
    """1-D ``("model",)`` mesh over the first ``n_shards`` local devices
    (0 = all) — the vocab-parallel mesh of the collective backend
    (`repro.pm.collectives.MeshBackend`).  On CPU hosts,
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` provides the
    multi-device substrate CI exercises the real psum path on."""
    devs = jax.devices()
    n = n_shards or len(devs)
    if len(devs) < n:
        raise ValueError(
            f"mesh needs {n} devices, host has {len(devs)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("model",))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
