"""Production mesh construction (TPU v5e pods).

Single pod:  (data=16, model=16)        = 256 chips
Multi-pod:   (pod=2, data=16, model=16) = 512 chips

Functions only — importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh, name: str) -> int:
    if name not in mesh.axis_names:
        return 1
    return mesh.shape[name]
