"""Training driver: ``python -m repro.launch.train --arch smollm-135m ...``

CPU runs use the reduced (``--smoke``) configs; full configs are exercised
through the dry-run (`repro.launch.dryrun`).
"""

from __future__ import annotations

import argparse

from repro.configs.registry import ARCH_IDS, get_config
from repro.pm.controller import AUTO
from repro.train.loop import LoopConfig, train_loop


def _auto_or_int(v: str):
    """Knob flag value: ``auto`` (controller-managed, the default) or an
    explicit integer pin."""
    return AUTO if v == AUTO else int(v)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-135m")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU-runnable); default on")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="full config (requires real accelerators)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--optimizer", choices=("adagrad", "adam"),
                    default="adagrad")
    ap.add_argument("--no-pm", dest="pm", action="store_false",
                    help="disable intent-managed embeddings")
    ap.add_argument("--kernel", action="store_true",
                    help="Pallas-backed managed hot path (native on TPU)")
    ap.add_argument("--cache-capacity", type=_auto_or_int, default=AUTO,
                    help="replica-cache rows, or 'auto' (default): steered "
                         "by intent demand over power-of-two buckets")
    ap.add_argument("--shards", type=int, default=4,
                    help="logical data shards for intent aggregation")
    ap.add_argument("--refresh-every", type=_auto_or_int, default=AUTO,
                    help="replica sync cadence in steps (0: replans only), "
                         "or 'auto' (default): hill-climbed on measured "
                         "loss-drop/s")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--init-from", default=None,
                    help="checkpoint to restore from: a step_* directory "
                         "or a --ckpt-dir root (newest step is used)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write per-phase spans (signal/plan/refresh/step) "
                         "as Chrome trace-event JSON to PATH")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    lc = LoopConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                    lr=args.lr, optimizer=args.optimizer, pm=args.pm,
                    kernel=args.kernel,
                    cache_capacity=args.cache_capacity,
                    n_shards=args.shards,
                    refresh_every=args.refresh_every,
                    ckpt_dir=args.ckpt_dir,
                    ckpt_every=args.ckpt_every, init_from=args.init_from)
    tracer = None
    if args.trace:
        from repro.obs.trace import SpanTracer
        tracer = SpanTracer()
    res = train_loop(cfg, lc, tracer=tracer)
    if tracer is not None:
        tracer.dump(args.trace)
        from repro.obs.report import render_report
        print(render_report(tracer.to_chrome()["traceEvents"],
                            title="train shutdown report"))
        print(f"trace: {args.trace} ({tracer.count} spans, "
              f"{tracer.dropped} dropped)")
    print(f"done: {len(res.losses)} steps, final loss "
          f"{res.losses[-1]:.4f}, {res.plans} placement plans, "
          f"{res.refreshes} replica refreshes, {res.overflows} overflow "
          f"fallbacks, {res.recompiles} compiled buckets, "
          f"{res.capacity_resizes} capacity resizes, "
          f"knobs {res.knobs}, {res.wall_s:.1f}s wall")


if __name__ == "__main__":
    main()
