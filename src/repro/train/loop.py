"""Training loop with first-class intent-managed parameter management.

Per step:
  1. the loader (already ``prefetch`` steps ahead) has signaled intent for
     upcoming batches;
  2. the planner (Algorithm 1 timing) decides whether to act: emit a new
     placement plan (replica-cache contents + miss-buffer capacity);
  3. the replica cache is synchronized from the owner-sharded table (one
     grouped gather per *refresh round* — AdaPM's batched replica sync:
     on replan rounds, plus every ``refresh_every`` steps; in between,
     replicas serve reads at most one refresh round stale);
  4. the train step runs with the managed embedding path (optionally the
     Pallas-kernel-backed one, ``LoopConfig.kernel``; with
     ``LoopConfig.collective="mesh"`` the table is vocab-sharded over a
     real device mesh and the lookup/backward/refresh run through the
     shard_map collectives of `pm.collectives.MeshBackend`).

Miss-capacity buckets map to distinct compiled executables; the bucket
ladder is small (powers of two) so recompiles amortize away.

``LoopResult.overflows`` counts steps whose actual unique-miss count
exceeded the plan's capacity (forcing the lookup's dense fallback); with
exact intent this stays 0 — the planner's bound is exact.

Zero-tuning (DESIGN.md §13): ``cache_capacity`` and ``refresh_every``
accept ``"auto"`` (the default) and are then owned by the online
controller — capacity follows the planning window's cache-worthy demand
(`PlacementPlan.demand`, the intent signal) over power-of-two buckets,
resized exactly at replan boundaries (the managed lookup is exact
regardless of cache contents, so resizes can never change the loss
trajectory — they only move misses); refresh cadence is hill-climbed on
measured loss-drop per second (the convergence-rate reward).  Progress
signals (step latency, loss, plans, refreshes, overflows, resizes) are
published to the `repro.obs.telemetry` bus (``train.*`` records).
"""

from __future__ import annotations

import os
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint
from repro.configs.base import ModelConfig
from repro.data.pipeline import IntentSignalingLoader
from repro.models.model import init_model
from repro.obs.telemetry import Telemetry
from repro.obs.trace import SpanTracer, make_tracer
from repro.pm.controller import (AUTO, Knob, OnlineController,
                                 capacity_ladder, is_auto, resolve_knob)
from repro.pm.embedding import make_state
from repro.pm.planner import IntentPlanner, PlacementPlan
from repro.train.steps import make_opt_init, make_train_step


@dataclass
class LoopConfig:
    steps: int = 50
    batch: int = 8
    seq: int = 64
    lr: float = 0.01
    optimizer: str = "adagrad"
    pm: bool = True                  # intent-managed embedding on/off
    kernel: bool = False             # Pallas-backed managed hot path
    collective: str = "emulated"     # "emulated" | "mesh": the managed
    #                                  lookup's collective backend
    #                                  (pm/collectives.py); "mesh" shards
    #                                  the table over a real device mesh
    #                                  and runs the shard_map psum path
    model_shards: int = 0            # mesh size for collective="mesh"
    #                                  (0 = every local device)
    cache_capacity: Union[int, str] = AUTO  # replica-cache rows; "auto"
    #                                  (the default): steered by the
    #                                  planning window's intent demand
    #                                  over power-of-two buckets
    n_shards: int = 1
    prefetch: int = 16
    plan_every: int = 8
    refresh_every: Union[int, str] = AUTO  # replica sync cadence (steps);
    #                                  replan rounds always refresh.
    #                                  "auto": hill-climbed on measured
    #                                  loss-drop/s (starts at 1, the old
    #                                  hand-set default)
    pipeline_depth: Union[int, str] = AUTO  # prefetch pipeline (DESIGN.md
    #                                  §15): 0 = fully synchronous (the
    #                                  pre-ISSUE-9 loop, bitwise); >= 1
    #                                  defers loss blocking up to that
    #                                  many steps, runs the planner one
    #                                  replan round ahead in a background
    #                                  thread, and switches eligible
    #                                  refresh rounds to the delta
    #                                  re-gather of only the rows touched
    #                                  since the last sync.  "auto":
    #                                  starts at 1, hill-climbed
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    init_from: Optional[str] = None  # checkpoint dir to restore from
    log_every: int = 10
    seed: int = 0


@dataclass
class LoopResult:
    losses: List[float] = field(default_factory=list)
    plans: int = 0
    refreshes: int = 0               # replica-cache sync rounds
    overflows: int = 0               # steps with unique misses > capacity
    recompiles: int = 0
    capacity_resizes: int = 0        # mid-run replica-cache bucket changes
    start_step: int = 0              # first step index (restored runs)
    wall_s: float = 0.0
    knobs: Dict[str, object] = field(default_factory=dict)
    #   the loop's knob values at the end of the run (auto knobs land
    #   wherever the controller drove them)


def train_loop(cfg: ModelConfig, lc: LoopConfig,
               telemetry: Optional[Telemetry] = None,
               tracer: Optional[SpanTracer] = None) -> LoopResult:
    t0 = time.time()
    bus = telemetry if telemetry is not None else Telemetry()
    # per-phase span tracing (DESIGN.md §14): default-off no-op unless
    # the caller injects an enabled tracer (launch/train.py --trace)
    tr = make_tracer(False, tracer=tracer)
    key = jax.random.PRNGKey(lc.seed)
    params = init_model(cfg, key)
    opt_state = make_opt_init(lc.optimizer)(params)

    res = LoopResult()
    if lc.init_from:
        # accept either a step_XXXXXXX directory or a checkpoint root
        # (resolved to its newest step)
        path = lc.init_from
        if not os.path.exists(os.path.join(path, "manifest.json")):
            latest = checkpoint.latest_step(path)
            if latest is None:
                raise FileNotFoundError(
                    f"no checkpoint under {path!r} (expected a manifest or "
                    f"step_* subdirectories)")
            path = latest
        restored, res.start_step = checkpoint.load(
            path, {"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]

    # collective backend for the managed lookup: the emulated single-
    # device reference, or the real shard_map psum path over a vocab-
    # sharded table (DESIGN.md §10) — in which case the table (and its
    # optimizer accumulator) is placed owner-sharded up front and every
    # gather/scatter/refresh below runs through explicit mesh collectives
    backend = None
    if lc.pm:
        from repro.pm.collectives import make_backend
        backend = make_backend(lc.collective, lc.model_shards)
    if backend is not None:
        params["embed"] = backend.place_table(params["embed"])
        opt_state = jax.tree_util.tree_map(
            lambda a: backend.place_table(a)
            if a.shape == params["embed"].shape else a, opt_state)

    # ---- knob resolution: "auto" fields belong to the controller
    auto = {name for name, v in (("cache_capacity", lc.cache_capacity),
                                 ("refresh_every", lc.refresh_every),
                                 ("pipeline_depth", lc.pipeline_depth))
            if is_auto(v)}
    cap_ladder = capacity_ladder(cfg.vocab_size)
    cache_capacity = int(resolve_knob(lc.cache_capacity, cap_ladder[0]))
    refresh_every = int(resolve_knob(lc.refresh_every, 1))
    pipeline_depth = int(resolve_knob(lc.pipeline_depth, 1))
    ctl: Optional[OnlineController] = None
    if lc.pm and auto:
        knobs = []
        if "cache_capacity" in auto:
            # intent-steered, not hill-climbed: the window's demand
            # computes the bucket directly (controller.steer_capacity)
            knobs.append(Knob("cache_capacity", cap_ladder,
                              index=cap_ladder.index(cache_capacity),
                              adapt=False, prefer_low=True))
        if "refresh_every" in auto:
            # 0 = replan rounds only; >0 adds a between-replan cadence
            ladder = (0, 1, 2, 4, 8)
            knobs.append(Knob("refresh_every", ladder,
                              index=ladder.index(refresh_every),
                              prefer_low=True))
        if "pipeline_depth" in auto:
            # the lookup is exact at every depth (the pipeline only moves
            # blocking and refresh traffic), so the hill-climb can probe
            # freely on the loss-drop/s reward
            ladder = (0, 1, 2, 4)
            knobs.append(Knob("pipeline_depth", ladder,
                              index=ladder.index(pipeline_depth),
                              prefer_low=True))
        ctl = OnlineController(knobs, bus, seed=lc.seed)

    # n_nodes = the training data shards signaling intent (§4.1 nodes):
    # a key wanted by >= 2 shards in the window is concurrent intent
    # the planner, controller and loop publish on ONE shared bus — the
    # caller's `telemetry` (or this run's fresh one), never a second,
    # divergent bus (mirrors ServingRuntime's explicit telemetry= arg)
    planner = IntentPlanner(cfg.vocab_size, cache_capacity,
                            n_nodes=max(1, lc.n_shards),
                            plan_every=lc.plan_every,
                            per_node_bound=backend is not None,
                            telemetry=bus) if lc.pm else None
    loader = IntentSignalingLoader(
        cfg, lc.batch, lc.seq, n_shards=max(1, lc.n_shards),
        prefetch=lc.prefetch, planner=planner, seed=lc.seed)

    step_fns: Dict[int, callable] = {}

    def step_fn(miss_capacity: int):
        if miss_capacity not in step_fns:
            # params + optimizer state are donated: the (V, D) table and
            # its AdaGrad accumulator — the step's hot buffers — are
            # updated in place instead of being copied every step (the
            # loop rebinds both from the step's outputs, so the old
            # buffers are dead the moment the call returns).  This holds
            # on the mesh path too: the NamedSharding'd table/accumulator
            # enter and leave the fused step with the same P("model",
            # None) layout, so XLA aliases the sharded buffers (pinned by
            # the re-feed guard test in tests/test_collectives.py)
            step_fns[miss_capacity] = jax.jit(
                make_train_step(
                    cfg, optimizer=lc.optimizer, lr=lc.lr,
                    pm_miss_capacity=miss_capacity, pm_kernel=lc.kernel,
                    pm_backend=backend),
                donate_argnums=(0, 1))
        return step_fns[miss_capacity]

    plan: Optional[PlacementPlan] = None
    cache_ids = None
    cache_rows = None
    # controller reward epochs: measured between replan boundaries
    epoch_t0: Optional[float] = None
    epoch_loss: Optional[float] = None

    # ---- prefetch pipeline state (DESIGN.md §15)
    # deferred loss blocking: the device queue holds up to pipeline_depth
    # dispatched-but-unread steps; draining preserves the synchronous
    # loop's exact per-step ordering of losses/telemetry/logs
    pending: deque = deque()   # (step, loss_device, step_t0)

    def drain(limit: int) -> None:
        while len(pending) > limit:
            s, loss_d, t0s = pending.popleft()
            _t = tr.now_ns() if tr.enabled else 0
            loss_f = float(loss_d)          # blocks on the device queue
            if tr.enabled:
                tr.record("prefetch.drain", _t, tr.now_ns(), a=s)
            res.losses.append(loss_f)
            bus.set("train.loss", loss_f)
            bus.observe("train.step_ms",
                        (time.perf_counter() - t0s) * 1e3)
            if lc.log_every and s % lc.log_every == 0:
                print(f"step {s:5d}  loss {loss_f:.4f}")

    # background plan-ahead: ONE worker builds the next boundary's plan
    # candidate off the already-signaled window while steps run; windows
    # are computed on the main thread (`plan_window`) and candidates only
    # become plans through `adopt`'s window-equality check
    executor = ThreadPoolExecutor(max_workers=1) \
        if planner is not None else None
    pending_plan = None        # (future, target_step, window)
    last_plan_step = -1
    # delta refresh: union of table rows the steps since the last sync
    # actually updated (the loader's signaled ids — exact for the sparse
    # and dense AdaGrad paths; see the refresh gate below)
    touched = np.zeros(0, dtype=np.int64)
    touched_known = True
    delta_refresh = None
    if lc.pm:
        from repro.pm.collectives import resolve
        delta_refresh = jax.jit(resolve(backend).refresh_rows_delta,
                                donate_argnums=(1,))
    # delta refresh is exact only when untouched rows are bitwise frozen
    # between syncs: sparse/dense AdaGrad leaves zero-grad rows unchanged
    # (acc + 0^2 == acc, p - lr*0 == p), but tied embeddings take dense
    # head gradients on every row and momentum-style optimizers decay
    # untouched rows' state — those always take the full re-gather
    delta_exact = (lc.optimizer == "adagrad"
                   and not getattr(cfg, "tie_embeddings", False))

    it = iter(loader)
    while True:
        # the loader's __next__ IS the intent-signaling phase: pulling a
        # batch signals its (and the prefetch horizon's) ids
        _t_sig = tr.now_ns() if tr.enabled else 0
        try:
            step, batch = next(it)
        except StopIteration:
            break
        if tr.enabled:
            tr.record("train.signal", _t_sig, tr.now_ns(), a=step)
        if step >= lc.steps:
            break
        step_t0 = time.perf_counter()
        if planner is not None:
            planner.observe_round(step)
            replanned = False
            if planner.should_replan(step, plan):
                _t_plan = tr.now_ns() if tr.enabled else 0
                # the controller's reward reads the epoch's losses — the
                # deferred tail must land in res.losses first, exactly as
                # the synchronous loop would have blocked step by step
                drain(0)
                # measured hill-climb decision at the boundary: reward is
                # the epoch's loss-drop per second (convergence rate)
                now = time.perf_counter()
                if ctl is not None and epoch_t0 is not None \
                        and res.losses:
                    cur = float(np.mean(res.losses[-lc.plan_every:]))
                    if epoch_loss is not None and now > epoch_t0:
                        reward = (epoch_loss - cur) / (now - epoch_t0)
                        bus.set("ctl.reward", reward)
                        for name, v in ctl.observe(reward).items():
                            if name == "refresh_every":
                                refresh_every = int(v)
                            elif name == "pipeline_depth":
                                pipeline_depth = int(v)
                    epoch_loss = cur
                elif ctl is not None and res.losses:
                    epoch_loss = float(np.mean(res.losses[-lc.plan_every:]))
                epoch_t0 = now
                # plan-ahead adoption: the background candidate becomes
                # the plan iff it covers exactly the window a synchronous
                # build would — otherwise (horizon moved under it) fall
                # back to building here, bitwise the pre-pipeline path
                cand = None
                if pending_plan is not None:
                    cand = pending_plan[0].result()
                    pending_plan = None
                plan = planner.adopt(cand, step)
                if plan is not None:
                    bus.inc("train.prefetch_plan_hits")
                else:
                    if cand is not None:
                        bus.inc("train.prefetch_plan_misses")
                    plan = planner.plan(step)
                if ctl is not None and "cache_capacity" in auto:
                    # intent-signal capacity steering: the window's demand
                    # count IS the bucket; a changed bucket re-plans over
                    # the same signals so plan and cache stay consistent
                    new_cap = ctl.steer_capacity("cache_capacity",
                                                 plan.demand)
                    if new_cap is not None:
                        cache_capacity = int(new_cap)
                        planner.set_capacity(cache_capacity)
                        res.capacity_resizes += 1
                        bus.inc("train.capacity_resizes")
                        bus.event("train.capacity_resize", step=step,
                                  capacity=cache_capacity)
                        plan = planner.plan(step)
                cache_ids = jnp.asarray(plan.cache_ids)
                res.plans += 1
                bus.inc("train.plans")
                replanned = True
                last_plan_step = step
                planner.gc(step)
                if tr.enabled:
                    tr.record("train.plan", _t_plan, tr.now_ns(), a=step)
            # replica sync round: re-gather hot rows from the live table —
            # once per refresh round (replan rounds + the refresh_every
            # cadence), NOT every step; replicas in between are at most one
            # refresh round stale (pm/embedding.py docstring bound)
            if replanned or cache_rows is None or (
                    refresh_every > 0
                    and step % refresh_every == 0):
                # delta refresh (pipeline on, same plan, exact-update
                # optimizer, touched set known): re-gather only the
                # cache rows the steps since the last sync updated and
                # scatter them into the DONATED previous cache buffer.
                # Bitwise the full re-gather — untouched rows are frozen
                # in the table between syncs (see delta_exact above)
                ids = None
                if (pipeline_depth >= 1 and not replanned
                        and cache_rows is not None and touched_known
                        and delta_exact):
                    ids = np.intersect1d(
                        touched, np.asarray(plan.cache_ids, np.int64))
                    n = max(64, 1 << (int(ids.size) - 1).bit_length()) \
                        if ids.size else 64
                    if n >= plan.cache_ids.shape[0]:
                        ids = None       # near-full delta: one gather wins
                if ids is not None:
                    C = plan.cache_ids.shape[0]
                    slots = np.searchsorted(
                        np.asarray(plan.cache_ids, np.int64), ids)
                    ids_p = np.full(n, cfg.vocab_size, np.int32)
                    ids_p[:ids.size] = ids
                    slots_p = np.full(n, C, np.int32)
                    slots_p[:ids.size] = slots
                    with tr.span("prefetch.refresh", a=step):
                        cache_rows = delta_refresh(
                            params["embed"], cache_rows,
                            jnp.asarray(ids_p), jnp.asarray(slots_p))
                    bus.inc("train.delta_refreshes")
                else:
                    with tr.span("train.refresh", a=step):
                        state = make_state(params["embed"], cache_ids,
                                           backend)
                        cache_rows = state.cache_rows
                touched = np.zeros(0, dtype=np.int64)
                touched_known = True
                res.refreshes += 1
                bus.inc("train.refreshes")
            batch = dict(batch,
                         pm_cache_ids=cache_ids.astype(jnp.int32),
                         pm_cache_rows=cache_rows)
            # exact-bound accounting: with deduped misses, unique misses
            # must fit the plan's capacity (zero dense-fallback rounds).
            # The loader's host-side signals ARE the step's unique ids —
            # no device-to-host readback on the hot path.
            uniq = planner.signaled_ids(step)
            if uniq is not None:
                n_miss = np.setdiff1d(uniq, plan.cache_ids).size
                if n_miss > plan.miss_capacity:
                    res.overflows += 1
                    bus.inc("train.overflows")
                # the step's unique ids are exactly the table rows its
                # optimizer update touches — the delta-refresh work set
                touched = np.union1d(touched, uniq.astype(np.int64))
            else:
                touched_known = False
            fn = step_fn(plan.miss_capacity)
            # plan-ahead submission: the earliest possible next boundary
            # is min(last boundary + plan_every, window end); one step
            # before it, hand the worker the window to build against.
            # A candidate whose predicted boundary slipped (the horizon
            # test deferred the replan) is discarded and resubmitted.
            if (executor is not None and pipeline_depth >= 1
                    and plan is not None):
                if pending_plan is not None and pending_plan[1] <= step:
                    pending_plan[0].result()
                    pending_plan = None
                t_pred = min(last_plan_step + lc.plan_every,
                             plan.window[1])
                if pending_plan is None and step == t_pred - 1:
                    window = planner.plan_window(t_pred)
                    fut = executor.submit(planner.plan_candidate, window)
                    pending_plan = (fut, t_pred, window)
                    if tr.enabled:
                        _t = tr.now_ns()
                        tr.record("prefetch.plan", _t, _t, a=t_pred)
        else:
            fn = step_fn(0)
        with tr.span("train.step", a=step):
            loss, params, opt_state = fn(params, opt_state, batch)
            if pipeline_depth == 0:
                # blocks: the span covers real step time (the synchronous
                # contract); at depth >= 1 the block moves to drain()
                loss = float(loss)
        pending.append((step, loss, step_t0))
        drain(pipeline_depth)
        if lc.ckpt_dir and lc.ckpt_every and step and \
                step % lc.ckpt_every == 0:
            checkpoint.save(f"{lc.ckpt_dir}/step_{step:07d}",
                            {"params": params, "opt": opt_state}, step)

    drain(0)
    if pending_plan is not None:
        pending_plan[0].result()
        pending_plan = None
    if executor is not None:
        executor.shutdown(wait=True)

    res.recompiles = len(step_fns)
    res.wall_s = time.time() - t0
    res.knobs = {"cache_capacity": cache_capacity,
                 "refresh_every": refresh_every,
                 "pipeline_depth": pipeline_depth,
                 "plan_every": lc.plan_every}
    return res
