"""Training loop with first-class intent-managed parameter management.

Per step:
  1. the loader (already ``prefetch`` steps ahead) has signaled intent for
     upcoming batches;
  2. the planner (Algorithm 1 timing) decides whether to act: emit a new
     placement plan (replica-cache contents + miss-buffer capacity);
  3. the replica cache is synchronized from the owner-sharded table (one
     grouped gather per round — AdaPM's batched replica sync);
  4. the train step runs with the managed embedding path.

Miss-capacity buckets map to distinct compiled executables; the bucket
ladder is small (powers of two) so recompiles amortize away.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint
from repro.configs.base import ModelConfig
from repro.data.pipeline import IntentSignalingLoader
from repro.models.model import init_model
from repro.pm.embedding import make_state
from repro.pm.planner import IntentPlanner, PlacementPlan
from repro.train.steps import make_opt_init, make_train_step


@dataclass
class LoopConfig:
    steps: int = 50
    batch: int = 8
    seq: int = 64
    lr: float = 0.01
    optimizer: str = "adagrad"
    pm: bool = True                  # intent-managed embedding on/off
    cache_capacity: int = 256
    n_shards: int = 1
    prefetch: int = 16
    plan_every: int = 8
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    log_every: int = 10
    seed: int = 0


@dataclass
class LoopResult:
    losses: List[float] = field(default_factory=list)
    plans: int = 0
    recompiles: int = 0
    wall_s: float = 0.0


def train_loop(cfg: ModelConfig, lc: LoopConfig) -> LoopResult:
    t0 = time.time()
    key = jax.random.PRNGKey(lc.seed)
    params = init_model(cfg, key)
    opt_state = make_opt_init(lc.optimizer)(params)

    planner = IntentPlanner(cfg.vocab_size, lc.cache_capacity,
                            n_shards=max(1, lc.n_shards),
                            plan_every=lc.plan_every) if lc.pm else None
    loader = IntentSignalingLoader(
        cfg, lc.batch, lc.seq, n_shards=max(1, lc.n_shards),
        prefetch=lc.prefetch, planner=planner, seed=lc.seed)

    step_fns: Dict[int, callable] = {}

    def step_fn(miss_capacity: int):
        if miss_capacity not in step_fns:
            step_fns[miss_capacity] = jax.jit(make_train_step(
                cfg, optimizer=lc.optimizer, lr=lc.lr,
                pm_miss_capacity=miss_capacity))
        return step_fns[miss_capacity]

    res = LoopResult()
    plan: Optional[PlacementPlan] = None
    cache_ids = None

    for step, batch in loader:
        if step >= lc.steps:
            break
        if planner is not None:
            planner.observe_round(step)
            if planner.should_replan(step, plan):
                plan = planner.plan(step)
                cache_ids = jnp.asarray(plan.cache_ids)
                res.plans += 1
                planner.gc(step)
            # replica sync round: re-gather hot rows from the live table
            state = make_state(params["embed"], cache_ids)
            batch = dict(batch,
                         pm_cache_ids=state.cache_ids,
                         pm_cache_rows=state.cache_rows)
            fn = step_fn(plan.miss_capacity)
        else:
            fn = step_fn(0)
        loss, params, opt_state = fn(params, opt_state, batch)
        res.losses.append(float(loss))
        if lc.log_every and step % lc.log_every == 0:
            print(f"step {step:5d}  loss {float(loss):.4f}")
        if lc.ckpt_dir and lc.ckpt_every and step and \
                step % lc.ckpt_every == 0:
            checkpoint.save(f"{lc.ckpt_dir}/step_{step:07d}",
                            {"params": params, "opt": opt_state}, step)

    res.recompiles = len(step_fns)
    res.wall_s = time.time() - t0
    return res
