"""Training and serving step builders (pjit-ready pure functions)."""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import forward, loss_fn
from repro.optim.optimizers import (adagrad_init, adagrad_update, adam_init,
                                    adam_update)


def make_train_step(cfg: ModelConfig, *, optimizer: str = "adagrad",
                    lr: float = 0.01, pm_miss_capacity: int = 0,
                    pm_strict: bool = False, remat: bool = True,
                    remat_policy: str = "full",
                    vp_loss_mesh=None, fsdp_spec=None,
                    act_spec=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (loss, params, state).

    ``pm_miss_capacity > 0`` activates the intent-managed embedding path
    (batch must then carry pm_cache_ids / pm_cache_rows).

    ``vp_loss_mesh``: a Mesh enables the explicit vocab-parallel CE
    (shard_map collective schedule, `repro.models.losses`) instead of the
    GSPMD-derived loss — §Perf iteration 3.
    """
    update = adagrad_update if optimizer == "adagrad" else adam_update

    def train_step(params, opt_state, batch):
        def loss(p):
            if vp_loss_mesh is not None:
                from repro.launch.mesh import batch_axes
                from repro.models.losses import vocab_parallel_ce
                h, aux, _ = forward(p, cfg, batch, remat=remat,
                                    remat_policy=remat_policy,
                                    pm_miss_capacity=pm_miss_capacity,
                                    pm_strict=pm_strict, skip_head=True,
                                    fsdp_spec=fsdp_spec, act_spec=act_spec)
                head = p["embed"].T if cfg.tie_embeddings else p["head"]
                return vocab_parallel_ce(
                    h, head, batch["labels"], vp_loss_mesh,
                    batch_axes=batch_axes(vp_loss_mesh), aux=aux)
            logits, aux, _ = forward(p, cfg, batch, remat=remat,
                                     remat_policy=remat_policy,
                                     pm_miss_capacity=pm_miss_capacity,
                                     pm_strict=pm_strict,
                                     fsdp_spec=fsdp_spec,
                                     act_spec=act_spec)
            return loss_fn(logits, batch["labels"], aux)

        loss_val, grads = jax.value_and_grad(loss)(params)
        new_params, new_state = update(grads, opt_state, params, lr=lr)
        return loss_val, new_params, new_state

    return train_step


def make_opt_init(optimizer: str = "adagrad") -> Callable:
    return adagrad_init if optimizer == "adagrad" else adam_init


def make_prefill_step(cfg: ModelConfig, *, last_only: bool = False,
                      fsdp_spec=None) -> Callable:
    """Forward-only prefill: returns last-position logits.

    ``last_only=True`` slices the hidden state to the final position
    *before* the (D, V) head matmul, so only (B, 1, V) logits are ever
    computed/communicated instead of (B, S, V) — §Perf iteration for
    prefill shapes (XLA does not push the slice through the collective
    itself)."""

    def prefill_step(params, batch):
        logits, _, _ = forward(params, cfg, batch, remat=False,
                               head_last_only=last_only,
                               fsdp_spec=fsdp_spec)
        return logits[:, -1]

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, fsdp_spec=None) -> Callable:
    """One decode step: consume one token per sequence against the cache.

    serve_step(params, cache, tokens(B,1)) -> (logits (B, V), new_cache).
    Advances cache["len"] itself (the new token occupies position len).
    """

    def serve_step(params, cache, tokens):
        cache = {**cache, "len": cache["len"] + 1}
        logits, _, new_cache = forward(params, cfg, {"tokens": tokens},
                                       cache=cache, remat=False,
                                       fsdp_spec=fsdp_spec)
        return logits[:, -1], new_cache

    return serve_step
