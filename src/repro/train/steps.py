"""Training and serving step builders (pjit-ready pure functions)."""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.kernels.pm_forward import step_residual
from repro.models.model import forward, loss_fn
from repro.optim.optimizers import (AdaGradState, adagrad_init,
                                    adagrad_update, adam_init, adam_update)
from repro.pm.collectives import resolve


def make_train_step(cfg: ModelConfig, *, optimizer: str = "adagrad",
                    lr: float = 0.01, pm_miss_capacity: int = 0,
                    pm_strict: bool = False, pm_kernel: bool = False,
                    pm_backend=None, remat: bool = True,
                    remat_policy: str = "full",
                    vp_loss_mesh=None, fsdp_spec=None,
                    act_spec=None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (loss, params, state).

    ``pm_miss_capacity > 0`` activates the intent-managed embedding path
    (batch must then carry pm_cache_ids / pm_cache_rows); ``pm_kernel``
    additionally routes the lookup through the Pallas kernels.  For untied
    AdaGrad runs, ``pm_kernel`` or a mesh backend applies the embedding
    update via the fused sparse row path on exactly the touched rows
    instead of a dense (V, D) sweep — on the mesh the update is *routed*:
    each row's gradient travels to its owner shard over `lax.all_to_all`
    and the row update runs on the owner's (V/n, D) block (DESIGN.md §12).

    Single-sort step (DESIGN.md §11): the step computes ONE
    `pm_forward.step_residual` from the batch tokens and every index
    consumer — forward probe/compact, backward duplicate pre-sum, fused
    sparse optimizer — reads it; no other sort is traced into the step.
    On the fused path the loss is differentiated with respect to the
    gathered token *rows* rather than the table, so the dense (V, D)
    embedding gradient (zeros + scatter-add + gather) never materializes:
    the compact (T, D) row grads go residual-fed segment -> AdaGrad row
    kernel, and the table/accumulator buffers are donated end to end
    (`train.loop` jits the step with ``donate_argnums=(0, 1)``).

    ``pm_backend``: the collective backend for the managed lookup
    (`repro.pm.collectives`; None = single-device emulated reference, a
    `MeshBackend` runs the real shard_map psum data path).

    ``vp_loss_mesh``: a Mesh enables the explicit vocab-parallel CE
    (shard_map collective schedule, `repro.models.losses`) instead of the
    GSPMD-derived loss — §Perf iteration 3.
    """
    update = adagrad_update if optimizer == "adagrad" else adam_update
    # sparse row updates need the gradient support to be exactly the batch
    # tokens: tied embeddings receive dense head gradients, so they keep
    # the dense optimizer sweep.  The mesh backend takes the fused path
    # regardless of ``pm_kernel``: its `update_rows` routes each segment
    # slot to its owner shard (all_to_all) and updates the owner's
    # (V/n, D) block inside shard_map — kernel or jnp row update alike —
    # so the dense (V, D) sweep never runs on the mesh.
    mesh_real = getattr(pm_backend, "mesh_real", False)
    sparse_embed = (pm_miss_capacity > 0 and optimizer == "adagrad"
                    and not cfg.tie_embeddings
                    and (pm_kernel or mesh_real))

    def run_loss(p, batch, residual, embed_rows=None):
        if vp_loss_mesh is not None:
            from repro.launch.mesh import batch_axes
            from repro.models.losses import vocab_parallel_ce
            h, aux, _ = forward(p, cfg, batch, remat=remat,
                                remat_policy=remat_policy,
                                pm_miss_capacity=pm_miss_capacity,
                                pm_strict=pm_strict, pm_kernel=pm_kernel,
                                pm_backend=pm_backend, pm_residual=residual,
                                embed_rows=embed_rows, skip_head=True,
                                fsdp_spec=fsdp_spec, act_spec=act_spec)
            head = p["embed"].T if cfg.tie_embeddings else p["head"]
            return vocab_parallel_ce(
                h, head, batch["labels"], vp_loss_mesh,
                batch_axes=batch_axes(vp_loss_mesh), aux=aux)
        logits, aux, _ = forward(p, cfg, batch, remat=remat,
                                 remat_policy=remat_policy,
                                 pm_miss_capacity=pm_miss_capacity,
                                 pm_strict=pm_strict, pm_kernel=pm_kernel,
                                 pm_backend=pm_backend, pm_residual=residual,
                                 embed_rows=embed_rows,
                                 fsdp_spec=fsdp_spec, act_spec=act_spec)
        return loss_fn(logits, batch["labels"], aux)

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        T = B * S
        tok = tokens.reshape(T).astype(jnp.int32)
        pm_on = pm_miss_capacity > 0 and "pm_cache_ids" in batch
        # THE step's one sort: probe/compact + full-token segmentation
        residual = step_residual(batch["pm_cache_ids"], tok,
                                 min(pm_miss_capacity, T)) if pm_on else None

        if not sparse_embed:
            loss_val, grads = jax.value_and_grad(
                lambda p: run_loss(p, batch, residual))(params)
            new_params, new_state = update(grads, opt_state, params, lr=lr)
            return loss_val, new_params, new_state

        # fused sparse path: gather the token rows ONCE up front, then
        # differentiate the loss with respect to those rows — the lookup's
        # VJP (and with it any dense (V, D) gradient buffer) is never
        # invoked, and the compact (T, D) row grads flow residual-fed
        # segment -> fused AdaGrad rows
        emb = params["embed"]
        rest = {k: v for k, v in params.items() if k != "embed"}
        if pm_on:
            h0 = pm_lookup_rows(emb, batch, tokens, pm_miss_capacity,
                                pm_strict, pm_kernel, pm_backend, residual)
        else:
            h0 = jnp.take(emb, tokens, axis=0)

        loss_val, (g_rest, g_rows) = jax.value_and_grad(
            lambda rp, h_in: run_loss(dict(rp, embed=emb), batch, residual,
                                      embed_rows=h_in),
            argnums=(0, 1))(rest, h0)

        rest_acc = {k: v for k, v in opt_state.accum.items() if k != "embed"}
        new_rest, rest_state = adagrad_update(g_rest, AdaGradState(rest_acc),
                                              rest, lr=lr)
        # fused sparse AdaGrad on exactly the touched (unique) rows,
        # applied where the row lives: the emulated backend updates the
        # local table (`EmulatedBackend.update_rows` — the reversed-slot
        # row kernel that used to live here), the mesh backend routes each
        # segment slot's gradient to its owner shard (all_to_all) and runs
        # the row update on the owner's (V/n, D) block inside shard_map
        # (`MeshBackend.update_rows`, DESIGN.md §12)
        V = cfg.vocab_size
        gt = g_rows.reshape(T, emb.shape[1])
        seg_ids, seg_g = ops.segment_rows(
            tok, gt, n_slots=T, pad_id=V,
            residual=residual.sort if residual is not None else None)
        new_emb, new_acc = resolve(pm_backend).update_rows(
            emb, opt_state.accum["embed"], seg_ids, seg_g, lr=lr,
            kernel=pm_kernel)
        new_params = dict(new_rest, embed=new_emb)
        new_state = AdaGradState(dict(rest_state.accum, embed=new_acc))
        return loss_val, new_params, new_state

    return train_step


def pm_lookup_rows(emb, batch, tokens, pm_miss_capacity, pm_strict,
                   pm_kernel, pm_backend, residual):
    """The fused step's forward-only managed gather (differentiation
    happens with respect to its output, not the table)."""
    from repro.pm.embedding import pm_lookup
    T = tokens.shape[0] * tokens.shape[1]
    return pm_lookup(emb, batch["pm_cache_ids"], batch["pm_cache_rows"],
                     tokens, min(pm_miss_capacity, T), pm_strict,
                     pm_kernel, pm_backend, residual)


def make_opt_init(optimizer: str = "adagrad") -> Callable:
    return adagrad_init if optimizer == "adagrad" else adam_init


def make_prefill_step(cfg: ModelConfig, *, last_only: bool = False,
                      fsdp_spec=None) -> Callable:
    """Forward-only prefill: returns last-position logits.

    ``last_only=True`` slices the hidden state to the final position
    *before* the (D, V) head matmul, so only (B, 1, V) logits are ever
    computed/communicated instead of (B, S, V) — §Perf iteration for
    prefill shapes (XLA does not push the slice through the collective
    itself)."""

    def prefill_step(params, batch):
        logits, _, _ = forward(params, cfg, batch, remat=False,
                               head_last_only=last_only,
                               fsdp_spec=fsdp_spec)
        return logits[:, -1]

    return prefill_step


def make_prefill_decode_step(cfg: ModelConfig, *, fsdp_spec=None
                             ) -> Callable:
    """Fused prefill into a decode cache: one jit entry for the whole
    prompt instead of a Python loop of P single-token serve steps (the
    loop re-enters jit P times and dominates wall-clock at prompt lengths
    of 64+ — examples/serve_decode.py, the serving runtime's decode side).

    prefill_step(params, cache, tokens(B, P)) -> (last logits (B, V),
    cache advanced by P).

    Attention families run the prompt as ONE chunked forward (k/v for all
    P positions written in one dynamic slice; `decode_attention` is
    causal within the chunk).  Recurrent families (ssm/hybrid) keep the
    per-token recurrence but move the loop *inside* jit as a `lax.scan`
    over positions — same single compilation, state rides the carry.

    Exact match with the token-by-token loop for every family except MoE
    capacity dropping: the chunk routes the whole prompt through expert
    capacity at once (the training-time semantics), where the loop routed
    one token at a time.  The prompt must fit the KV cache (P <= cache
    sequence length) — the same bound the loop already had.
    """
    chunked = cfg.family in ("dense", "moe", "vlm", "encdec")

    def prefill_chunk(params, cache, tokens):
        P = tokens.shape[1]
        cache = {**cache, "len": cache["len"] + P}
        logits, _, new_cache = forward(params, cfg, {"tokens": tokens},
                                       cache=cache, remat=False,
                                       fsdp_spec=fsdp_spec)
        return logits[:, -1], new_cache

    def prefill_scan(params, cache, tokens):
        def body(cache, tok):
            cache = {**cache, "len": cache["len"] + 1}
            logits, _, cache = forward(params, cfg, {"tokens": tok},
                                       cache=cache, remat=False,
                                       fsdp_spec=fsdp_spec)
            return cache, logits[:, -1]

        # scan over positions: tokens (B, P) -> (P, B, 1) chunks
        cache, logits = jax.lax.scan(
            body, cache, jnp.swapaxes(tokens, 0, 1)[:, :, None])
        return logits[-1], cache

    return prefill_chunk if chunked else prefill_scan


def make_serve_step(cfg: ModelConfig, *, fsdp_spec=None) -> Callable:
    """One decode step: consume one token per sequence against the cache.

    serve_step(params, cache, tokens(B,1)) -> (logits (B, V), new_cache).
    Advances cache["len"] itself (the new token occupies position len).
    """

    def serve_step(params, cache, tokens):
        cache = {**cache, "len": cache["len"] + 1}
        logits, _, new_cache = forward(params, cfg, {"tokens": tokens},
                                       cache=cache, remat=False,
                                       fsdp_spec=fsdp_spec)
        return logits[:, -1], new_cache

    return serve_step
