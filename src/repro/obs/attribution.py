"""Plan-vs-actual attribution at replan boundaries (DESIGN.md §14).

The controller moves knobs and the planner promises miss rates; this
module is the audit trail that says whether reality agreed.  The serving
runtime feeds every executed batch's token-level hit mask into a
`PlanAttribution` tracker (host-side numpy, admission-time — no device
readbacks), and at each replan boundary `flush()` closes the outgoing
plan's tenure into one `AttributionRecord`:

  * predicted vs realized miss rate — the outgoing plan's
    ``predicted_miss_rate`` against what the executed batches measured;
  * per-owner-shard miss counts — which shard's rows the misses landed
    on (``owner = id // ceil(V / owner_shards)``, the engine's affine
    ownership rule), the signal the mesh route capacity is sized by;
  * top-K hot keys behind the uncovered misses — the specific ids a
    better plan would have cached, ranked by missed-access count;
  * the knob/capacity decisions taken during the window with their
    triggering signal — read back from the telemetry bus's ``ctl.*`` /
    capacity-resize events, so "why did the knob move" and "what did it
    cost" live in one record.

Records are emitted onto the telemetry bus (``attr.replan`` events),
kept on the tracker (``records``), and serialize to schema-versioned
JSON for the `obs.export.JsonlSink` — `python -m repro.obs.report`
renders them as the miss-attribution table.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.telemetry import Telemetry, json_safe

ATTRIBUTION_SCHEMA = "repro.obs.attribution/v1"


@dataclass
class AttributionRecord:
    """One closed plan tenure: what the plan promised, what happened."""

    round: int                   # replan boundary (runtime round / step)
    plan_version: int            # outgoing plan (0 = no plan yet)
    cause: str                   # what triggered the replan that closed it
    batches: int                 # executed batches in the tenure
    tokens: int                  # token-level accesses observed
    misses: int                  # token-level cache misses observed
    predicted_miss_rate: float   # the outgoing plan's promise
    realized_miss_rate: Optional[float]  # None: no batch executed
    per_owner_misses: Dict[int, int]     # owner shard -> missed accesses
    top_keys: List[Tuple[int, int]]      # (key, miss count), hottest first
    capacity: int                # replica-cache capacity at the boundary
    miss_capacity: int           # the new plan's compact-buffer bucket
    knobs: Dict[str, object]     # live knob values at the boundary
    prefetch_hits: int = 0       # miss slots served from the tenure's
    #   staged prefetch buffer (DESIGN.md §15)
    prefetch_stale: int = 0      # miss slots the stage did not cover —
    #   they paid the residual collective gather
    decisions: List[dict] = field(default_factory=list)
    #   ctl.* / capacity-resize bus events during the tenure (each carries
    #   its own ``cause`` — the triggering signal)

    @property
    def miss_rate_error(self) -> Optional[float]:
        """Realized minus predicted (positive: plan was optimistic)."""
        if self.realized_miss_rate is None:
            return None
        return self.realized_miss_rate - self.predicted_miss_rate

    def to_json(self) -> dict:
        return json_safe({
            "schema": ATTRIBUTION_SCHEMA,
            "round": self.round,
            "plan_version": self.plan_version,
            "cause": self.cause,
            "batches": self.batches,
            "tokens": self.tokens,
            "misses": self.misses,
            "predicted_miss_rate": round(self.predicted_miss_rate, 6),
            "realized_miss_rate": (
                None if self.realized_miss_rate is None
                else round(self.realized_miss_rate, 6)),
            "per_owner_misses": {str(k): v for k, v in
                                 sorted(self.per_owner_misses.items())},
            "top_keys": [[k, c] for k, c in self.top_keys],
            "capacity": self.capacity,
            "miss_capacity": self.miss_capacity,
            "knobs": dict(self.knobs),
            "prefetch_hits": self.prefetch_hits,
            "prefetch_stale": self.prefetch_stale,
            "decisions": self.decisions,
        })


class PlanAttribution:
    """Accumulates per-batch observations, flushes one record per replan.

    ``owner_shards``/``vocab`` enable the per-owner split (0 = no owner
    accounting, matching non-mesh backends); ``telemetry`` is the bus the
    decision events are read back from (and the records are published
    to) — the same bus the runtime and controller share."""

    def __init__(self, *, owner_shards: int = 0, vocab: int = 0,
                 top_k: int = 8, telemetry: Optional[Telemetry] = None):
        self.owner_shards = int(owner_shards)
        self.vocab = int(vocab)
        self.top_k = int(top_k)
        self.telemetry = telemetry
        self.records: List[AttributionRecord] = []
        self._pending: List[np.ndarray] = []   # missed ids, per batch
        self._tokens = 0
        self._misses = 0
        self._batches = 0
        self._prefetch_hits = 0
        self._prefetch_stale = 0
        self._last_seq = -1      # high-water mark into the bus event log

    # ----------------------------------------------------- accumulation
    def note_batch(self, tokens: np.ndarray, hit: np.ndarray) -> None:
        """One executed batch: flat token ids and the aligned boolean
        cache-hit mask (both come straight from the admission probe).
        Hot-path cheap on purpose — the missed ids are stashed raw and
        only aggregated (`np.unique`) once per tenure, at `flush`."""
        tokens = np.asarray(tokens).reshape(-1)
        hit = np.asarray(hit, bool).reshape(-1)
        self._batches += 1
        self._tokens += tokens.size
        missed = tokens[~hit]                  # boolean index: a copy
        self._misses += missed.size
        if missed.size:
            self._pending.append(missed)

    def note_prefetch(self, hits: int, stale: int) -> None:
        """One executed batch's staged-prefetch outcome: how many of its
        unique miss slots the tenure's staging buffer covered (``hits``)
        vs fell through to the residual collective gather (``stale``)."""
        self._prefetch_hits += int(hits)
        self._prefetch_stale += int(stale)

    # ----------------------------------------------------------- flush
    def _window_decisions(self) -> List[dict]:
        if self.telemetry is None:
            return []
        out = []
        for ev in self.telemetry.events():
            if ev["_seq"] <= self._last_seq:
                continue
            name = ev["_name"]
            if name.startswith("ctl.") or name.endswith("capacity_resize"):
                out.append(json_safe(ev))
        if out:
            self._last_seq = max(ev["_seq"] for ev in out)
        return out

    def flush(self, *, rnd: int, plan, cause: str,
              knobs: Dict[str, object], capacity: int,
              miss_capacity: int = 0) -> AttributionRecord:
        """Close the outgoing plan's tenure (``plan`` — None before the
        first replan) into a record and reset the accumulators."""
        realized = (self._misses / self._tokens
                    if self._tokens else None)
        miss_counts: Dict[int, int] = {}
        if self._pending:
            keys, counts = np.unique(np.concatenate(self._pending),
                                     return_counts=True)
            miss_counts = dict(zip(keys.tolist(), counts.tolist()))
        per_owner: Dict[int, int] = {}
        if self.owner_shards > 0 and self.vocab > 0 and miss_counts:
            block = -(-self.vocab // self.owner_shards)
            for k, c in miss_counts.items():
                o = int(k) // block
                per_owner[o] = per_owner.get(o, 0) + c
        top = sorted(miss_counts.items(),
                     key=lambda kc: (-kc[1], kc[0]))[: self.top_k]
        rec = AttributionRecord(
            round=int(rnd),
            plan_version=int(plan.version) if plan is not None else 0,
            cause=cause,
            batches=self._batches,
            tokens=self._tokens,
            misses=self._misses,
            predicted_miss_rate=(float(plan.predicted_miss_rate)
                                 if plan is not None else 0.0),
            realized_miss_rate=realized,
            per_owner_misses=per_owner,
            top_keys=[(int(k), int(c)) for k, c in top],
            capacity=int(capacity),
            miss_capacity=int(miss_capacity),
            knobs=json_safe(dict(knobs)),
            prefetch_hits=self._prefetch_hits,
            prefetch_stale=self._prefetch_stale,
            decisions=self._window_decisions(),
        )
        self.records.append(rec)
        if self.telemetry is not None:
            self.telemetry.event(
                "attr.replan", round=rec.round,
                plan_version=rec.plan_version, cause=cause,
                predicted=rec.predicted_miss_rate,
                realized=rec.realized_miss_rate, misses=rec.misses,
                tokens=rec.tokens)
        self._pending = []
        self._tokens = 0
        self._misses = 0
        self._batches = 0
        self._prefetch_hits = 0
        self._prefetch_stale = 0
        return rec
