"""Structured telemetry bus: counters, gauges, latency reservoirs, events
(DESIGN.md §13).

One `Telemetry` instance is the signal plane of a runtime (the serving
runtime and the training loop each own one; the kernel autotuner publishes
into a process-wide default bus).  Producers publish with one call —

    bus.inc("serve.overflow_batches")
    bus.set("serve.miss_rate", 0.03)
    bus.observe("serve.round_ms", dt * 1e3)
    bus.event("serve.replan", cause="overflow", round=12)

— and consumers (the online controller, benches, tests) read the same
records back by name: `counter_value` / `gauge_value` / `latency(...)
.percentile(99)` / `events("serve.replan")`.  Everything is host-side
numpy; nothing here ever touches JAX or the device, so publishing from
admission-time code costs nanoseconds, not readbacks.

Records are keyed by ``name`` plus optional keyword labels (e.g.
``bus.counter("serve.replans", cause="drift")``); the label-free parent
is NOT implicitly aggregated — publishers that want both a total and a
per-cause split publish both (cheap, explicit, greppable).

`snapshot()` renders the whole bus as one JSON-ready dict (the benches
embed it), and `summary_line()` is the single human-readable line a
runtime prints at shutdown — the replacement for the ad-hoc calibration
prints this bus retired.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

# reservoirs keep at most this many samples (uniform reservoir sampling
# past it): percentile queries stay O(maxlen log maxlen) and a long-lived
# runtime cannot grow memory with its uptime
_RESERVOIR_MAXLEN = 4096


def json_safe(obj):
    """Recursively convert ``obj`` into plain JSON types: numpy scalars
    and arrays become Python numbers/lists, non-finite floats become
    None (JSON has no NaN/Inf), dict keys become strings.  The bus
    accepts whatever producers publish (counters bumped with np.int64,
    events carrying array fields), so every export surface —
    `Telemetry.snapshot`, the JSONL sink, attribution records — funnels
    through this to stay strictly `json.dumps`-able."""
    if isinstance(obj, dict):
        return {str(k): json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [json_safe(v) for v in obj.tolist()]
    if isinstance(obj, (np.integer, np.bool_)):
        return obj.item()
    if isinstance(obj, np.floating):
        obj = float(obj)
    if isinstance(obj, float):
        return obj if np.isfinite(obj) else None
    return obj


class Counter:
    """Monotonically increasing count (overflows, replans, requeues)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def add(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins measurement (miss rate, overlap ratio, capacity)."""

    __slots__ = ("value", "updates")

    def __init__(self) -> None:
        self.value: Optional[float] = None
        self.updates = 0

    def set(self, v: float) -> None:
        self.value = float(v)
        self.updates += 1


class Reservoir:
    """Latency/size distribution with p50/p99 queries.

    Keeps every sample up to ``maxlen``, then switches to uniform
    reservoir sampling (Vitter's algorithm R) so the percentile estimate
    stays unbiased over the whole stream without unbounded memory."""

    __slots__ = ("_vals", "_n", "_maxlen", "_rng")

    def __init__(self, maxlen: int = _RESERVOIR_MAXLEN, seed: int = 0):
        self._vals: List[float] = []
        self._n = 0
        self._maxlen = maxlen
        self._rng = np.random.default_rng(seed)

    def record(self, v: float) -> None:
        self._n += 1
        if len(self._vals) < self._maxlen:
            self._vals.append(float(v))
        else:
            j = int(self._rng.integers(0, self._n))
            if j < self._maxlen:
                self._vals[j] = float(v)

    def extend(self, vs) -> None:
        for v in vs:
            self.record(v)

    @property
    def count(self) -> int:
        return self._n

    def values(self) -> List[float]:
        """Copy of the held samples (the serve bench pools these across
        paired runs for its trace-overhead estimator)."""
        return list(self._vals)

    def percentile(self, p: float) -> float:
        # empty-safe by contract: 0.0, never a raise or NaN (callers ask
        # for p50/p99 at shutdown whether or not anything was observed)
        if not self._vals:
            return 0.0
        return float(np.percentile(np.asarray(self._vals), p))

    def mean(self) -> float:
        return float(np.mean(self._vals)) if self._vals else 0.0

    def reset(self) -> None:
        self._vals.clear()
        self._n = 0

    _EMPTY_STATS = {"count": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0}

    def stats(self) -> Dict[str, float]:
        """p50/p99 summary; an untouched reservoir returns the
        well-defined all-zero record (count distinguishes it)."""
        if not self._vals:
            return dict(self._EMPTY_STATS, count=self.count)
        return {"count": self.count, "mean": round(self.mean(), 6),
                "p50": round(self.percentile(50), 6),
                "p99": round(self.percentile(99), 6)}


def _key(name: str, labels: dict) -> str:
    if not labels:
        return name
    lab = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{lab}}}"


class Telemetry:
    """The signal bus: named counters / gauges / reservoirs + an event
    log, lazily created on first touch."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._reservoirs: Dict[str, Reservoir] = {}
        self._events: List[Tuple[int, str, dict]] = []
        self._seq = 0
        # flat key -> (name, labels): exact label structure for exporters
        # (the flat key is lossy — a label value may itself contain "="
        # or "," — so Prometheus rendering reads this, not the key)
        self._meta: Dict[str, Tuple[str, dict]] = {}

    # ------------------------------------------------------------ handles
    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        c = self._counters.get(k)
        if c is None:
            c = self._counters[k] = Counter()
            self._meta[k] = (name, labels)
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            g = self._gauges[k] = Gauge()
            self._meta[k] = (name, labels)
        return g

    def latency(self, name: str, **labels) -> Reservoir:
        k = _key(name, labels)
        r = self._reservoirs.get(k)
        if r is None:
            r = self._reservoirs[k] = Reservoir()
            self._meta[k] = (name, labels)
        return r

    def key_meta(self, flat_key: str) -> Tuple[str, dict]:
        """(name, labels) for a flat snapshot key (exporter surface)."""
        return self._meta.get(flat_key, (flat_key, {}))

    # --------------------------------------------------------- one-liners
    def inc(self, name: str, n: float = 1, **labels) -> None:
        self.counter(name, **labels).add(n)

    def set(self, name: str, v: float, **labels) -> None:
        self.gauge(name, **labels).set(v)

    def observe(self, name: str, v: float, **labels) -> None:
        self.latency(name, **labels).record(v)

    def event(self, name: str, **fields) -> None:
        self._events.append((self._seq, name, fields))
        self._seq += 1

    # -------------------------------------------------------------- reads
    def counter_value(self, name: str, **labels) -> float:
        c = self._counters.get(_key(name, labels))
        return c.value if c is not None else 0.0

    def gauge_value(self, name: str, default: Optional[float] = None,
                    **labels) -> Optional[float]:
        g = self._gauges.get(_key(name, labels))
        return g.value if g is not None and g.value is not None else default

    def events(self, name: Optional[str] = None) -> List[dict]:
        return [dict(fields, _seq=seq, _name=nm)
                for seq, nm, fields in self._events
                if name is None or nm == name]

    # ----------------------------------------------------------- exports
    def snapshot(self) -> dict:
        """JSON-ready dump of the whole bus (bench/test surface).
        Strictly `json.dumps`-able: event fields and values pass through
        `json_safe` (producers publish numpy scalars freely)."""
        return json_safe({
            "counters": {k: c.value for k, c in sorted(
                self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "latencies": {k: r.stats() for k, r in sorted(
                self._reservoirs.items())},
            "events": self.events(),
        })

    def summary_line(self, prefix: str = "telemetry") -> str:
        """The single human-readable shutdown line: headline counters,
        gauges, and latency p50/p99s, in name order."""
        parts: List[str] = []
        for k, c in sorted(self._counters.items()):
            parts.append(f"{k}={int(c.value)}")
        for k, g in sorted(self._gauges.items()):
            if g.value is not None:
                parts.append(f"{k}={g.value:.4g}")
        for k, r in sorted(self._reservoirs.items()):
            if r.count:
                parts.append(f"{k}[p50={r.percentile(50):.3g},"
                             f"p99={r.percentile(99):.3g}]")
        return f"[{prefix}] " + " ".join(parts)


_DEFAULT: Optional[Telemetry] = None


def default_bus() -> Telemetry:
    """Process-wide bus for publishers without a runtime of their own
    (e.g. the kernel block autotuner, whose cache is process-global)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Telemetry()
    return _DEFAULT
