"""Low-overhead ring-buffered span tracer (DESIGN.md §14).

Answers "where did this request's latency go?" without perturbing the
thing it measures: a closed span is one tuple appended to a bounded
deque (no per-span dict, no string formatting, no numpy boxing, no I/O
on the hot path — names are interned to small ints first), the clock
is `time.perf_counter_ns` (monotonic, the same clock the runtime's
latency accounting already uses), and the buffer is a ring — the
deque's ``maxlen`` makes a long serve run overwrite its oldest spans
instead of growing without bound (`dropped` counts the evictions, so
an export can never silently claim full coverage).

Tracing is **default-off**.  A disabled tracer's `span()` returns one
shared no-op context manager and `record()`/`point()` return before
touching the buffer — the instrumented call sites stay in the code with
no measurable cost (the serve bench's paired overhead guard pins the
*enabled* cost under 2%; disabled is a branch).

Per-entity sampling (`sampled(rid)`) is deterministic — a multiplicative
hash of the id against `sample` — so the same request is either fully
traced or fully absent, across requeues and across runs; phase spans
(few per round) are always recorded when the tracer is enabled.

Span vocabulary (names are interned; two int64 arg slots ``a``/``b``
ride in the arrays):

  serving   serve.round > serve.enqueue / serve.plan / serve.probe /
            serve.dispatch / serve.served, per-request
            ``serve.request`` (enqueue -> served, a=rid b=attempts) and
            ``serve.requeue`` instant points (a=rid)
  training  train.signal / train.plan / train.refresh / train.step
            (a=step)
  prefetch  the ISSUE-9 pipeline stages (DESIGN.md §15):
            ``prefetch.plan`` — background plan-ahead (an instant at
            submission, a span when the boundary joins the candidate;
            a=target step); ``prefetch.refresh`` — the delta replica
            re-gather that replaced a full train.refresh (a=step);
            ``prefetch.drain`` — a deferred step's loss block (a=step);
            ``prefetch.stage`` — the serving tenure's staging-buffer
            gather (a=round)

`to_chrome()` renders the buffer as Chrome trace-event JSON ("X"
complete events + "i" instants, ts/dur in microseconds) — loadable in
Perfetto / chrome://tracing; `repro.obs.report` turns the same events
into the shutdown latency report.
"""

from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

_DEFAULT_CAPACITY = 1 << 15


class _NullSpan:
    """Shared no-op context manager: the disabled tracer's span()."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """An open span: records itself into the ring on exit."""

    __slots__ = ("_tr", "_name", "_tid", "_a", "_b", "_t0")

    def __init__(self, tr: "SpanTracer", name: str, tid: int,
                 a: int, b: int):
        self._tr = tr
        self._name = name
        self._tid = tid
        self._a = a
        self._b = b
        self._t0 = 0

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        self._tr.record(self._name, self._t0, time.perf_counter_ns(),
                        tid=self._tid, a=self._a, b=self._b)
        return False


class SpanTracer:
    """Bounded ring of (name_id, t0, t1, tid, a, b) span tuples."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY,
                 sample: float = 1.0, enabled: bool = True):
        self.enabled = bool(enabled)
        self.sample = float(sample)
        self.capacity = int(capacity)
        assert self.capacity > 0
        self._buf: deque = deque(maxlen=self.capacity)
        self._n = 0                       # total spans ever recorded
        self._names: List[str] = []       # interning table: id -> name
        self._name_ids: Dict[str, int] = {}
        # trace origin: exports are relative to construction time, so ts
        # stays small and positive (perf_counter_ns shares this origin
        # with perf_counter, so seconds-clock timestamps convert exactly)
        self.epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------ writes
    def now_ns(self) -> int:
        return time.perf_counter_ns()

    def sampled(self, i: int) -> bool:
        """Deterministic per-entity coin: the same id is always in or
        always out at a given sampling rate (requeued requests keep
        their verdict)."""
        if self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        return ((int(i) * 2654435761) & 0xFFFFFFFF) < \
            self.sample * 4294967296.0

    def _name_id(self, name: str) -> int:
        nid = self._name_ids.get(name)
        if nid is None:
            nid = len(self._names)
            self._names.append(name)
            self._name_ids[name] = nid
        return nid

    def record(self, name: str, t0_ns: int, t1_ns: int, *, tid: int = 0,
               a: int = 0, b: int = 0) -> None:
        """Append one closed span (the fast path: one tuple append —
        measurably cheaper than per-field numpy scalar stores)."""
        if not self.enabled:
            return
        nid = self._name_ids.get(name)
        if nid is None:
            nid = self._name_id(name)
        self._buf.append((nid, t0_ns, t1_ns, tid, a, b))
        self._n += 1

    def record_many(self, name: str, t0s_ns, t1_ns: int, *,
                    tids=None, a=None, b=None) -> None:
        """Batched append of spans sharing one name and end time — the
        per-request lifecycle spans of a served batch land as one
        `deque.extend` instead of a Python loop of `record` calls (the
        serve bench's 2% overhead budget is won here).  ``t0s_ns`` /
        ``tids`` / ``a`` / ``b`` accept lists or numpy arrays."""
        if not self.enabled:
            return
        t0s = (t0s_ns.tolist() if isinstance(t0s_ns, np.ndarray)
               else list(t0s_ns))
        n = len(t0s)
        if n == 0:
            return
        nid = self._name_id(name)
        t1 = int(t1_ns)

        def _col(v):
            if v is None:
                return (0,) * n
            return v.tolist() if isinstance(v, np.ndarray) else list(v)

        self._buf.extend(zip((nid,) * n, t0s, (t1,) * n,
                             _col(tids), _col(a), _col(b)))
        self._n += n

    def point(self, name: str, *, tid: int = 0, a: int = 0,
              b: int = 0) -> None:
        """Instant event (t1 == t0): requeues, knob flips, markers."""
        if not self.enabled:
            return
        t = time.perf_counter_ns()
        self.record(name, t, t, tid=tid, a=a, b=b)

    def span(self, name: str, *, tid: int = 0, a: int = 0, b: int = 0):
        """Context manager measuring the enclosed block.  Disabled
        tracers return one shared no-op — no allocation, no clock."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, tid, a, b)

    # ------------------------------------------------------------- reads
    @property
    def count(self) -> int:
        """Total spans ever recorded (evicted ones included)."""
        return self._n

    @property
    def dropped(self) -> int:
        """Spans evicted by the ring (recorded but no longer held)."""
        return max(0, self._n - self.capacity)

    def events(self) -> List[dict]:
        """Held spans, oldest-first, decoded to dicts (export surface).
        The bounded deque evicts oldest-first, so iteration order is
        already chronological — no ring-index arithmetic needed."""
        names = self._names
        return [{
            "name": names[nid],
            "t0_ns": int(t0),
            "t1_ns": int(t1),
            "tid": int(tid),
            "a": int(a),
            "b": int(b),
        } for nid, t0, t1, tid, a, b in self._buf]

    # ----------------------------------------------------------- exports
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON (the object form Perfetto loads).

        Spans become "X" complete events (required fields: name, ph, ts,
        pid, tid, plus dur), zero-duration records become "i" instants;
        ts/dur are microseconds relative to the tracer's epoch."""
        trace_events = []
        for e in self.events():
            ts = (e["t0_ns"] - self.epoch_ns) / 1e3
            dur = (e["t1_ns"] - e["t0_ns"]) / 1e3
            ev = {
                "name": e["name"],
                "cat": e["name"].split(".", 1)[0],
                "ph": "X" if dur > 0 else "i",
                "ts": ts,
                "pid": 0,
                "tid": e["tid"],
                "args": {"a": e["a"], "b": e["b"]},
            }
            if ev["ph"] == "X":
                ev["dur"] = dur
            else:
                ev["s"] = "t"       # instant scope: thread
            trace_events.append(ev)
        trace_events.sort(key=lambda ev: ev["ts"])
        return {
            "traceEvents": trace_events,
            "displayTimeUnit": "ms",
            "otherData": {
                "spans_recorded": self._n,
                "spans_dropped": self.dropped,
                "sample": self.sample,
            },
        }

    def dump(self, path: str) -> None:
        """Write `to_chrome()` to ``path`` as JSON."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


def make_tracer(enabled: bool, sample: float = 1.0,
                capacity: int = _DEFAULT_CAPACITY,
                tracer: Optional[SpanTracer] = None) -> SpanTracer:
    """Resolve a runtime's tracer: an injected instance wins; otherwise
    build one in the requested state (disabled tracers keep every call
    site branch-free and cost one early return per record)."""
    if tracer is not None:
        return tracer
    return SpanTracer(capacity=capacity, sample=sample, enabled=enabled)
