"""Observability report: trace/metrics files -> the shutdown report
(DESIGN.md §14).

``python -m repro.obs.report trace.json metrics.jsonl`` renders, from a
Chrome trace-event file (`obs.trace.SpanTracer.dump`) and/or a JSONL
metrics sink (`obs.export.JsonlSink`), the same report a traced serve or
train run prints at shutdown:

  * per-request latency — p50/p99/mean over ``serve.request`` spans,
    plus a per-phase breakdown (where a round's time went);
  * miss attribution — per replan tenure: predicted vs realized miss
    rate, the top hot keys behind the uncovered misses, per-owner-shard
    miss counts;
  * knob timeline — every controller/capacity decision in order, with
    the triggering signal.

Loading *validates*: a trace event missing a Chrome trace-event required
field (name/ph/ts/pid/tid, dur for "X") or an unparseable JSONL line
raises — CI runs this CLI on the serve bench's artifacts so a schema
break fails the build instead of a future reader.
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.obs.export import read_jsonl

_REQUIRED = ("name", "ph", "ts", "pid", "tid")


def validate_chrome(doc: dict) -> List[dict]:
    """Check trace-event JSON against the format's required fields;
    returns the event list."""
    if "traceEvents" not in doc:
        raise ValueError("not a Chrome trace: no 'traceEvents' key")
    events = doc["traceEvents"]
    for i, ev in enumerate(events):
        for field in _REQUIRED:
            if field not in ev:
                raise ValueError(
                    f"traceEvents[{i}] missing required field "
                    f"{field!r}: {ev}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(
                f"traceEvents[{i}] is a complete event without 'dur'")
    return events


def load_trace(path: str) -> List[dict]:
    with open(path) as f:
        return validate_chrome(json.load(f))


def _pct(vals, p):
    return float(np.percentile(np.asarray(vals), p))


def _request_section(events: List[dict]) -> List[str]:
    spans: Dict[str, List[float]] = {}
    for ev in events:
        if ev["ph"] != "X":
            continue
        spans.setdefault(ev["name"], []).append(ev["dur"] / 1e3)  # -> ms
    out = []
    req = spans.pop("serve.request", None)
    if req:
        out.append(f"  requests traced: {len(req)}  "
                   f"p50 {_pct(req, 50):.3f} ms  "
                   f"p99 {_pct(req, 99):.3f} ms  "
                   f"mean {float(np.mean(req)):.3f} ms")
    requeues = sum(1 for ev in events if ev["name"] == "serve.requeue")
    if requeues:
        out.append(f"  requeues traced: {requeues}")
    if spans:
        out.append("  phase breakdown (ms, p50/p99 over spans):")
        for name in sorted(spans):
            vs = spans[name]
            out.append(f"    {name:<18} n={len(vs):<6} "
                       f"p50 {_pct(vs, 50):8.3f}  p99 {_pct(vs, 99):8.3f}")
    return out


def _attribution_section(records: List[dict]) -> List[str]:
    attrs = [r for r in records if r.get("kind") == "attribution"]
    if not attrs:
        return []
    out = ["  round  plan  cause     predicted  realized   misses  "
           "top keys (key:count)"]
    errors = []
    for r in attrs:
        realized = r.get("realized_miss_rate")
        if realized is not None and r.get("batches"):
            errors.append(abs(realized - r["predicted_miss_rate"]))
        top = " ".join(f"{k}:{c}" for k, c in r.get("top_keys", [])[:4])
        out.append(
            f"  {r['round']:>5}  {r['plan_version']:>4}  "
            f"{r['cause']:<8}  {r['predicted_miss_rate']:>9.4f}  "
            f"{('%8.4f' % realized) if realized is not None else '     n/a'}"
            f"  {r['misses']:>7}  {top}")
        owners = r.get("per_owner_misses") or {}
        if owners:
            owned = " ".join(f"shard{k}:{v}" for k, v in
                             sorted(owners.items(), key=lambda kv:
                                    int(kv[0])))
            out.append(f"         per-owner misses: {owned}")
        ph, ps = r.get("prefetch_hits", 0), r.get("prefetch_stale", 0)
        if ph or ps:
            total = ph + ps
            out.append(f"         prefetch: {ph}/{total} miss slots "
                       f"staged ({ps} residual)")
    if errors:
        out.append(f"  plan-vs-actual |error|: mean "
                   f"{float(np.mean(errors)):.4f}  max "
                   f"{float(np.max(errors)):.4f} over {len(errors)} "
                   f"measured tenures")
    return out


def _knob_section(records: List[dict]) -> List[str]:
    out = []
    for r in records:
        if r.get("kind") == "event":
            name = r.get("name", "")
            if not (name.startswith("ctl.")
                    or name.endswith("capacity_resize")):
                continue
            f = r.get("fields", {})
            detail = " ".join(f"{k}={v}" for k, v in sorted(f.items()))
            out.append(f"  [{r.get('event_seq', '?'):>4}] {name:<22} "
                       f"{detail}")
        elif r.get("kind") == "attribution":
            for d in r.get("decisions", []):
                detail = " ".join(f"{k}={v}" for k, v in sorted(d.items())
                                  if not k.startswith("_"))
                out.append(f"  [{d.get('_seq', '?'):>4}] "
                           f"{d.get('_name', '?'):<22} {detail}")
    # attribution decisions duplicate bus events when both files are
    # given; dedup on the event sequence tag, keeping order
    seen = set()
    uniq = []
    for line in out:
        tag = line.split("]")[0]
        if tag in seen:
            continue
        seen.add(tag)
        uniq.append(line)
    return uniq


def _counter_section(records: List[dict]) -> List[str]:
    snaps = [r for r in records if r.get("kind") == "snapshot"]
    if not snaps:
        return []
    snap = snaps[-1]
    out = []
    counters = snap.get("counters", {})
    if counters:
        out.append("  " + "  ".join(f"{k}={int(v)}" for k, v in
                                    sorted(counters.items())))
    for key, st in sorted(snap.get("latencies", {}).items()):
        if st.get("count"):
            out.append(f"  {key}: n={st['count']} p50={st['p50']:.3f} "
                       f"p99={st['p99']:.3f}")
    return out


def render_report(trace_events: Optional[List[dict]] = None,
                  records: Optional[List[dict]] = None,
                  title: str = "observability report") -> str:
    """The shutdown report: whatever sections the inputs support."""
    lines = [f"=== {title} ==="]
    sections = []
    if trace_events:
        sections.append(("request latency (trace)",
                         _request_section(trace_events)))
    if records:
        sections.append(("miss attribution (plan vs actual)",
                         _attribution_section(records)))
        sections.append(("knob timeline", _knob_section(records)))
        sections.append(("final counters", _counter_section(records)))
    wrote = False
    for header, body in sections:
        if not body:
            continue
        lines.append(f"-- {header}")
        lines.extend(body)
        wrote = True
    if not wrote:
        lines.append("(no spans or records to report)")
    return "\n".join(lines)


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv or any(a in ("-h", "--help") for a in argv):
        print(__doc__)
        return 0 if argv else 2
    trace_events: List[dict] = []
    records: List[dict] = []
    for path in argv:
        if path.endswith(".jsonl"):
            records.extend(read_jsonl(path))
        else:
            trace_events.extend(load_trace(path))
    print(render_report(trace_events or None, records or None,
                        title="observability report: " + " ".join(argv)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
