"""Metrics export: Prometheus text format + schema-versioned JSONL sink
(DESIGN.md §14).

Two ways out of the process for the telemetry bus:

* `prometheus_text(bus)` renders counters/gauges as Prometheus
  exposition text and reservoirs as summaries (``{quantile="0.5"}`` /
  ``{quantile="0.99"}`` + ``_count``/``_sum``) — scrape-ready without a
  client library.  Pass the `Telemetry` itself when you can (exact label
  structure via `key_meta`); a bare `snapshot()` dict is accepted with
  best-effort label parsing of the flat keys.

* `JsonlSink` appends schema-versioned JSON lines (``{"schema":
  "repro.obs/v1", "kind": ..., ...}``) with periodic flush — every
  ``flush_every`` records or ``flush_s`` seconds, whichever first — so a
  killed run loses at most one flush window.  `write_bus` dumps a bus as
  one ``snapshot`` record plus one ``event`` record per bus event;
  attribution records go in as ``attribution``.  `repro.obs.report`
  reads these lines back into the shutdown report, and CI fails if a
  schema change breaks that round trip.
"""

from __future__ import annotations

import json
import re
import time
from typing import IO, Optional, Union

from repro.obs.telemetry import Telemetry, json_safe

SCHEMA_VERSION = "repro.obs/v1"

_METRIC_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _metric_name(name: str) -> str:
    """Sanitize to the Prometheus metric-name charset (dots -> _)."""
    name = _METRIC_OK.sub("_", name)
    if not name or name[0].isdigit():
        name = "_" + name
    return name


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    parts = []
    for k in sorted(labels):
        v = str(labels[k])
        v = v.replace("\\", "\\\\").replace('"', '\\"').replace("\n",
                                                                "\\n")
        parts.append(f'{_metric_name(str(k))}="{v}"')
    return "{" + ",".join(parts) + "}"


def _split_flat_key(flat: str):
    """Best-effort (name, labels) from a ``name{k=v,...}`` snapshot key —
    the fallback when only a snapshot dict is available (values
    containing ``,``/``=`` need the live bus's `key_meta`)."""
    if "{" not in flat or not flat.endswith("}"):
        return flat, {}
    name, _, rest = flat.partition("{")
    labels = {}
    for part in rest[:-1].split(","):
        k, eq, v = part.partition("=")
        if eq:
            labels[k] = v
    return name, labels


def prometheus_text(source: Union[Telemetry, dict]) -> str:
    """Render a bus (or its `snapshot()`) as Prometheus text format."""
    if isinstance(source, Telemetry):
        snap = source.snapshot()
        meta = source.key_meta
    else:
        snap = source
        meta = _split_flat_key
    lines = []
    typed = set()

    def emit(kind: str, flat: str, value, suffix: str = "",
             extra_labels: Optional[dict] = None) -> None:
        name, labels = meta(flat)
        family = _metric_name(name)
        metric = family + suffix
        if (family, kind) not in typed:
            # one TYPE line per metric FAMILY, before its first sample —
            # a summary's _count/_sum samples belong to the base family
            # and must not get their own TYPE line
            typed.add((family, kind))
            lines.append(f"# TYPE {family} {kind}")
        if extra_labels:
            labels = dict(labels, **extra_labels)
        if value is None:
            value = float("nan")
        lines.append(f"{metric}{_label_str(labels)} {value}")

    for flat, v in snap.get("counters", {}).items():
        emit("counter", flat, v)
    for flat, v in snap.get("gauges", {}).items():
        emit("gauge", flat, v)
    for flat, st in snap.get("latencies", {}).items():
        emit("summary", flat, st["p50"], extra_labels={"quantile": "0.5"})
        emit("summary", flat, st["p99"], extra_labels={"quantile": "0.99"})
        emit("summary", flat, st["count"], suffix="_count")
        # approximate: the reservoir subsamples, so sum = mean * count
        emit("summary", flat, round(st["mean"] * st["count"], 6),
             suffix="_sum")
    return "\n".join(lines) + "\n"


class JsonlSink:
    """Append-only JSONL with a schema version stamped on every line."""

    def __init__(self, path_or_file: Union[str, IO], *,
                 flush_every: int = 64, flush_s: float = 5.0):
        if isinstance(path_or_file, str):
            self._f = open(path_or_file, "w")
            self._owns = True
        else:
            self._f = path_or_file
            self._owns = False
        self.flush_every = int(flush_every)
        self.flush_s = float(flush_s)
        self.written = 0
        self._since_flush = 0
        self._last_flush = time.perf_counter()

    def write(self, kind: str, record: dict) -> None:
        line = {"schema": SCHEMA_VERSION, "kind": kind, "seq": self.written}
        line.update(json_safe(record))
        self._f.write(json.dumps(line) + "\n")
        self.written += 1
        self._since_flush += 1
        now = time.perf_counter()
        if self._since_flush >= self.flush_every \
                or now - self._last_flush >= self.flush_s:
            self.flush()

    def write_bus(self, bus: Telemetry, *, label: str = "") -> None:
        """One ``snapshot`` record (counters/gauges/latencies) plus one
        ``event`` record per bus event — the report CLI's input shape."""
        snap = bus.snapshot()
        events = snap.pop("events")
        self.write("snapshot", {"label": label, **snap})
        for ev in events:
            self.write("event", {"name": ev.pop("_name"),
                                 "event_seq": ev.pop("_seq"),
                                 "fields": ev})

    def write_attribution(self, records) -> None:
        for rec in records:
            self.write("attribution", rec.to_json())

    def flush(self) -> None:
        self._f.flush()
        self._since_flush = 0
        self._last_flush = time.perf_counter()

    def close(self) -> None:
        self.flush()
        if self._owns:
            self._f.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: str):
    """Parse a sink file back into records (the report CLI's loader);
    raises ValueError on a line that is not valid JSON."""
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: bad JSONL line: {e}")
    return out
