"""Observability: telemetry bus, span tracer, plan attribution, export
(DESIGN.md §13-§14).

The serve runtime, train loop, planner, collectives and the kernel block
autotuner record counters, gauges, latency reservoirs and events on the
`Telemetry` bus; the online controller (`repro.pm.controller`) consumes
the same records to adapt runtime knobs — one signal path instead of
ad-hoc prints and scattered result fields.

Above the bus: `SpanTracer` (ring-buffered per-request/per-phase spans,
Chrome-trace export), `PlanAttribution` (plan-vs-actual accounting at
replan boundaries), `prometheus_text`/`JsonlSink` (scrape/file export),
and ``python -m repro.obs.report`` (the shutdown report renderer).
"""

from repro.obs.attribution import (ATTRIBUTION_SCHEMA, AttributionRecord,
                                   PlanAttribution)
from repro.obs.export import (SCHEMA_VERSION, JsonlSink, prometheus_text,
                              read_jsonl)
from repro.obs.telemetry import (Counter, Gauge, Reservoir, Telemetry,
                                 default_bus, json_safe)
from repro.obs.trace import SpanTracer, make_tracer

__all__ = [
    "ATTRIBUTION_SCHEMA", "AttributionRecord", "Counter", "Gauge",
    "JsonlSink", "PlanAttribution", "Reservoir", "SCHEMA_VERSION",
    "SpanTracer", "Telemetry", "default_bus", "json_safe", "make_tracer",
    "prometheus_text", "read_jsonl",
]
