"""Observability: the structured telemetry bus every runtime layer
publishes into (DESIGN.md §13).

The serve runtime, train loop, planner, collectives and the kernel block
autotuner record counters, gauges, latency reservoirs and events here;
the online controller (`repro.pm.controller`) consumes the same records
to adapt runtime knobs — one signal path instead of ad-hoc prints and
scattered result fields.
"""

from repro.obs.telemetry import (Counter, Gauge, Reservoir, Telemetry,
                                 default_bus)

__all__ = ["Counter", "Gauge", "Reservoir", "Telemetry", "default_bus"]
