"""Intent signaling primitives (paper §3).

An *intent* is a declaration by one worker that it will access a set of
parameter keys in a logical-clock window ``[c_start, c_end)``.  Each worker
owns an independent logical clock that it advances with ``advance()`` (cheap,
only raises the counter).  Intents are signaled *before* the access so the
parameter manager can act proactively.

States of an intent w.r.t. its worker's clock ``C`` (paper §3):
  inactive: C <  c_start
  active:   c_start <= C < c_end
  expired:  c_end <= C
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Dict, Iterable, Sequence, Set, Tuple

import numpy as np


class IntentType(enum.Enum):
    """Optional intent type. AdaPM treats all types identically (§4.1)."""

    READ = "read"
    WRITE = "write"
    READ_WRITE = "read+write"


@dataclass(frozen=True)
class Intent:
    """One signaled intent: worker ``worker_id`` will access ``keys`` in
    the clock window ``[c_start, c_end)`` of *its own* logical clock.
    ``keys`` may be any integer sequence (tuple or ndarray)."""

    keys: Sequence[int]
    c_start: int
    c_end: int
    worker_id: int
    type: IntentType = IntentType.READ_WRITE

    def __post_init__(self):
        if self.c_end <= self.c_start:
            raise ValueError(
                f"empty intent window [{self.c_start}, {self.c_end})")

    def state(self, clock: int) -> str:
        if clock < self.c_start:
            return "inactive"
        if clock < self.c_end:
            return "active"
        return "expired"


class LogicalClock:
    """Per-worker logical clock.  ``advance()`` is cheap by design (§3)."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0):
        self.value = value

    def advance(self, by: int = 1) -> int:
        if by < 0:
            raise ValueError("clocks are monotone")
        self.value += by
        return self.value


class IntentTable:
    """Node-local store of signaled intents, indexed by key.

    Tracks, per key, the union of intent windows of this node's workers.
    Supports the queries the manager needs:
      * is there *active* intent for key k (given current worker clocks)?
      * is there *inactive* (future) intent, and what is its earliest start?
      * garbage-collect expired windows.

    Workers can signal overlapping/extending intents freely (§3); the table
    simply stores all windows and reasons over the union.  Storage and the
    activation queries are the vectorized `engine.IntentStore`; this class
    is the per-`Intent` adapter.
    """

    def __init__(self):
        from .engine import IntentStore
        self._store = IntentStore()

    def signal(self, intent: Intent) -> None:
        self._store.signal(np.asarray(intent.keys, np.int64),
                           intent.c_start, intent.c_end, intent.worker_id)

    def keys_with_any_intent(self) -> Iterable[int]:
        return [int(k) for k in self._store.keys()]

    def has_active(self, key: int, clocks: Dict[int, int]) -> bool:
        return self._store.has_active(key, clocks)

    def active_workers(self, key: int, clocks: Dict[int, int]) -> Set[int]:
        return self._store.active_workers(key, clocks)

    def earliest_future_start(self, key: int, clocks: Dict[int, int]):
        """Earliest c_start among *inactive* windows for ``key`` together
        with its worker, or ``None`` if no inactive intent exists."""
        return self._store.earliest_future_start(key, clocks)

    def last_end(self, key: int) -> int:
        """Max c_end over all windows (used for expiry bookkeeping)."""
        return self._store.last_end(key)

    def gc(self, clocks: Dict[int, int]) -> None:
        """Drop expired windows; drop keys with no windows left."""
        self._store.gc(clocks)

    def __len__(self) -> int:
        return len(self._store)
