"""Intent signaling primitives (paper §3).

An *intent* is a declaration by one worker that it will access a set of
parameter keys in a logical-clock window ``[c_start, c_end)``.  Each worker
owns an independent logical clock that it advances with ``advance()`` (cheap,
only raises the counter).  Intents are signaled *before* the access so the
parameter manager can act proactively.

States of an intent w.r.t. its worker's clock ``C`` (paper §3):
  inactive: C <  c_start
  active:   c_start <= C < c_end
  expired:  c_end <= C
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Set, Tuple


class IntentType(enum.Enum):
    """Optional intent type. AdaPM treats all types identically (§4.1)."""

    READ = "read"
    WRITE = "write"
    READ_WRITE = "read+write"


@dataclass(frozen=True)
class Intent:
    """One signaled intent: worker ``worker_id`` will access ``keys`` in
    the clock window ``[c_start, c_end)`` of *its own* logical clock."""

    keys: Tuple[int, ...]
    c_start: int
    c_end: int
    worker_id: int
    type: IntentType = IntentType.READ_WRITE

    def __post_init__(self):
        if self.c_end <= self.c_start:
            raise ValueError(
                f"empty intent window [{self.c_start}, {self.c_end})")

    def state(self, clock: int) -> str:
        if clock < self.c_start:
            return "inactive"
        if clock < self.c_end:
            return "active"
        return "expired"


class LogicalClock:
    """Per-worker logical clock.  ``advance()`` is cheap by design (§3)."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0):
        self.value = value

    def advance(self, by: int = 1) -> int:
        if by < 0:
            raise ValueError("clocks are monotone")
        self.value += by
        return self.value


@dataclass
class _KeyIntents:
    """Per-key bag of (c_start, c_end, worker_id) windows on one node."""

    windows: List[Tuple[int, int, int]] = field(default_factory=list)


class IntentTable:
    """Node-local store of signaled intents, indexed by key.

    Tracks, per key, the union of intent windows of this node's workers.
    Supports the queries the manager needs:
      * is there *active* intent for key k (given current worker clocks)?
      * is there *inactive* (future) intent, and what is its earliest start?
      * garbage-collect expired windows.

    Workers can signal overlapping/extending intents freely (§3); the table
    simply stores all windows and reasons over the union.
    """

    def __init__(self):
        self._by_key: Dict[int, _KeyIntents] = {}

    def signal(self, intent: Intent) -> None:
        for k in intent.keys:
            self._by_key.setdefault(k, _KeyIntents()).windows.append(
                (intent.c_start, intent.c_end, intent.worker_id))

    def keys_with_any_intent(self) -> Iterable[int]:
        return self._by_key.keys()

    def has_active(self, key: int, clocks: Dict[int, int]) -> bool:
        ki = self._by_key.get(key)
        if ki is None:
            return False
        for (s, e, w) in ki.windows:
            c = clocks.get(w, 0)
            if s <= c < e:
                return True
        return False

    def active_workers(self, key: int, clocks: Dict[int, int]) -> Set[int]:
        ki = self._by_key.get(key)
        if ki is None:
            return set()
        out = set()
        for (s, e, w) in ki.windows:
            c = clocks.get(w, 0)
            if s <= c < e:
                out.add(w)
        return out

    def earliest_future_start(self, key: int, clocks: Dict[int, int]):
        """Earliest c_start among *inactive* windows for ``key`` together
        with its worker, or ``None`` if no inactive intent exists."""
        ki = self._by_key.get(key)
        if ki is None:
            return None
        best = None
        for (s, e, w) in ki.windows:
            c = clocks.get(w, 0)
            if c < s:  # inactive
                if best is None or s < best[0]:
                    best = (s, w)
        return best

    def last_end(self, key: int) -> int:
        """Max c_end over all windows (used for expiry bookkeeping)."""
        ki = self._by_key.get(key)
        if ki is None:
            return 0
        return max(e for (_, e, _) in ki.windows)

    def gc(self, clocks: Dict[int, int]) -> None:
        """Drop expired windows; drop keys with no windows left."""
        dead = []
        for k, ki in self._by_key.items():
            ki.windows = [
                (s, e, w) for (s, e, w) in ki.windows
                if clocks.get(w, 0) < e
            ]
            if not ki.windows:
                dead.append(k)
        for k in dead:
            del self._by_key[k]

    def __len__(self) -> int:
        return len(self._by_key)
