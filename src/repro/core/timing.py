"""Adaptive action timing (paper §4.2, Algorithm 1).

AdaPM acts on intent signals in point-to-point communication rounds.  It must
decide, per intent, whether to act in the *current* round or whether a later
round still suffices.  A later round suffices if the *next* round will finish
before the worker reaches the intent's start clock.

AdaPM models the number of clock advances of worker ``i`` during one round as
Poisson(lambda_t^i), estimates the rate by exponential smoothing over observed
per-round clock deltas, and acts on an intent in round ``t`` iff

    C_start < C_t^i + Q_Poiss(2 * max(lambda_hat_t^i, Delta), p)

i.e. iff the worker might plausibly reach C_start within the next two rounds
(the current one plus the next).  Robustness details from the paper:
  * the estimate is NOT updated when the worker did not advance its clock
    during the previous round (evaluation pauses, end of epoch, ...);
  * ``max(lambda_hat, Delta)`` lets the estimate escape "slow regimes" where
    a too-low estimate caused remote-access stalls that kept clocks slow.

Defaults are the paper's zero-tuning constants: alpha=0.1, p=0.9999,
lambda_0=10 — used unchanged for every task in the paper's evaluation.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict

# z-scores for the normal approximation of high Poisson quantiles.
_Z = {0.5: 0.0, 0.9: 1.2816, 0.99: 2.3263, 0.999: 3.0902,
      0.9999: 3.7190, 0.99999: 4.2649}


def _z_for(p: float) -> float:
    if p in _Z:
        return _Z[p]
    # Acklam-style rational approximation of the normal quantile.
    if not 0.0 < p < 1.0:
        raise ValueError(f"quantile p must be in (0,1), got {p}")
    # Beasley-Springer-Moro.
    a = [2.50662823884, -18.61500062529, 41.39119773534, -25.44106049637]
    b = [-8.47351093090, 23.08336743743, -21.06224101826, 3.13082909833]
    c = [0.3374754822726147, 0.9761690190917186, 0.1607979714918209,
         0.0276438810333863, 0.0038405729373609, 0.0003951896511919,
         0.0000321767881768, 0.0000002888167364, 0.0000003960315187]
    y = p - 0.5
    if abs(y) < 0.42:
        r = y * y
        num = y * (((a[3] * r + a[2]) * r + a[1]) * r + a[0])
        den = (((b[3] * r + b[2]) * r + b[1]) * r + b[0]) * r + 1.0
        return num / den
    r = p if y <= 0 else 1.0 - p
    s = math.log(-math.log(r))
    t = c[0]
    for i in range(1, 9):
        t += c[i] * s ** i
    return t if y > 0 else -t


def poisson_quantile(lam: float, p: float) -> int:
    """Smallest k with CDF_Poisson(lam)(k) >= p.

    Exact summation for small rates; normal approximation with continuity
    correction for large rates (error negligible at the quantiles AdaPM uses).
    """
    if lam < 0:
        raise ValueError("rate must be non-negative")
    if lam == 0.0:
        return 0
    if lam <= 64.0:
        # exact: iterate pmf/cdf
        k = 0
        pmf = math.exp(-lam)
        cdf = pmf
        # upper iteration guard: mean + 12*std + slack
        guard = int(lam + 12.0 * math.sqrt(lam) + 32)
        while cdf < p and k < guard:
            k += 1
            pmf *= lam / k
            cdf += pmf
        return k
    z = _z_for(p)
    return int(math.ceil(lam + z * math.sqrt(lam) + 0.5))


@dataclass
class WorkerRateEstimate:
    lam_hat: float
    last_clock: int = 0
    last_delta: int = 0


@dataclass
class ActionTimer:
    """Algorithm 1 state for one node, tracking each worker's clock rate."""

    alpha: float = 0.1
    p: float = 0.9999
    lam0: float = 10.0
    _workers: Dict[int, WorkerRateEstimate] = field(default_factory=dict)

    def _est(self, worker: int) -> WorkerRateEstimate:
        est = self._workers.get(worker)
        if est is None:
            est = WorkerRateEstimate(lam_hat=self.lam0)
            self._workers[worker] = est
        return est

    def observe_round(self, worker: int, clock_now: int) -> None:
        """Called once per communication round with the worker's current
        clock; performs the exponential-smoothing update (Alg. 1, l.1-6)."""
        est = self._est(worker)
        delta = clock_now - est.last_clock
        if delta < 0:
            raise ValueError("clocks are monotone")
        if delta > 0:
            est.lam_hat = (1.0 - self.alpha) * est.lam_hat + self.alpha * delta
        # delta == 0: keep estimate (training pause, §4.2.2)
        est.last_delta = delta
        est.last_clock = clock_now

    def horizon(self, worker: int) -> int:
        """Soft upper bound on clock advance over the next two rounds."""
        est = self._est(worker)
        lam = 2.0 * max(est.lam_hat, float(est.last_delta))
        return poisson_quantile(lam, self.p)

    def should_act(self, worker: int, clock_now: int, c_start: int) -> bool:
        """Algorithm 1 return: act on the intent in this round iff the worker
        might reach ``c_start`` before the *next* round completes."""
        return c_start < clock_now + self.horizon(worker)

    def rate(self, worker: int) -> float:
        return self._est(worker).lam_hat
