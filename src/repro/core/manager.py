"""The AdaPM parameter manager (paper §4, §B), as a simulator-drivable policy.

All mechanism lives in the vectorized `core.engine.IntentEngine` — intent
tables, Algorithm 1 action timing, the §4.1 owner-side decision rule,
ownership/location caches, and versioned replica delta sync.  This module is
the thin policy shell that adapts the engine to the `PMPolicy` interface and
keeps the seed's public surface (``dir``, ``_repl``, ``trace``) so tests and
benchmarks keep working.  Behavior is pinned to the seed dict-and-heap
implementation by `tests/test_engine.py`.

Faithful mechanisms (see `engine.py` for the implementation):
  * per-worker logical clocks and intent tables (§3);
  * Algorithm 1 adaptive action timing on the signaling node (§4.2, §B.2.1);
  * owner-side decision rule (§4.1): exactly-one active node and no replicas
    -> relocate; concurrent active intent -> selective replicas exactly while
    intent is active; relocation never happens while replicas exist (§B.2.4);
  * responsibility follows allocation (§B.1); versioned delta replica sync,
    batched per round (§B.1.2, §B.2.2); home-node fallback routing with
    location caches (§B.2.3); intent is optional — un-signaled accesses fall
    back to synchronous remote access (§4).

Ablation variants (paper §5.5, §5.8): ``relocation=False`` (replication
only), ``replication=False`` (relocation only), ``immediate_action=True``
(skip Algorithm 1, act on signals as soon as they arrive).
"""

from __future__ import annotations

from typing import Optional, Sequence, Set, Tuple

import numpy as np

from .api import AccessResult, CostModel, PMPolicy, budget_prefix
from .engine import IntentEngine
from .intent import Intent


class _ReplicaView:
    """Read-only stand-in for the seed's per-key ``_ReplicaState``."""

    __slots__ = ("_engine", "_key")

    def __init__(self, engine: IntentEngine, key: int):
        self._engine = engine
        self._key = key

    @property
    def holders(self) -> Set[int]:
        return self._engine.holders(self._key)

    @property
    def version(self) -> int:
        if self._key >= self._engine.capacity:
            return 0
        return int(self._engine.version[self._key])


class _ReplMap:
    """Dict-like view of the engine's replica bitmasks (seed ``_repl``)."""

    def __init__(self, engine: IntentEngine):
        self._engine = engine

    def __contains__(self, key: int) -> bool:
        return bool(self._engine.holders(key))

    def __getitem__(self, key: int) -> _ReplicaView:
        return _ReplicaView(self._engine, key)

    def get(self, key: int, default=None):
        return self[key] if key in self else default


class AdaPM(PMPolicy):
    name = "AdaPM"

    def __init__(self, n_nodes: int, cost: CostModel, *,
                 relocation: bool = True, replication: bool = True,
                 immediate_action: bool = False,
                 alpha: float = 0.1, p: float = 0.9999, lam0: float = 10.0,
                 trace_keys: Optional[Set[int]] = None):
        super().__init__(n_nodes, cost)
        self.relocation = relocation
        self.replication = replication
        self.immediate = immediate_action
        if not relocation:
            self.name = "AdaPM w/o relocation"
        if not replication:
            self.name = "AdaPM w/o replication"
        if immediate_action:
            self.name = "AdaPM immediate action"
        self.engine = IntentEngine(
            n_nodes, cost, self.ledger, self.metrics,
            relocation=relocation, replication=replication,
            immediate=immediate_action, alpha=alpha, p=p, lam0=lam0,
            trace_keys=trace_keys)
        self.dir = self.engine.owners

    # ------------------------------------------------------- compat views
    @property
    def trace(self):
        return self.engine.trace

    @property
    def _repl(self) -> _ReplMap:
        return _ReplMap(self.engine)

    @property
    def _n_keys_hint(self) -> int:
        return self.engine.n_keys_hint

    @_n_keys_hint.setter
    def _n_keys_hint(self, n: int) -> None:
        self.engine.n_keys_hint = n
        self.engine.ensure_capacity(n)

    # ------------------------------------------------------------ sim hooks
    def signal_intent(self, node: int, intent: Intent, now: float) -> None:
        self.engine.signal(node, np.asarray(intent.keys, np.int64),
                           intent.c_start, intent.c_end, intent.worker_id)

    def advance_clock(self, node: int, worker: int, clock: int) -> None:
        self.engine.advance_clock(node, worker, clock)

    def access(self, node: int, worker: int, key: int,
               now: float, write: bool = True) -> AccessResult:
        self.metrics.n_accesses += 1
        e = self.engine
        e.ensure_capacity(key + 1)
        if e.owners.owner[key] == node:
            return AccessResult(local=True, staleness=0.0)
        if int(e.holder_mask[key]) >> node & 1:
            stale = max(0.0, now - float(e.sync_time[node, key]))
            e.replica_reads(node, np.array([key], np.int64),
                            np.array([now]), write)
            return AccessResult(local=True, staleness=stale)
        e.remote_accesses(node, np.array([key], np.int64))
        return AccessResult(local=False)

    def access_batch(self, node: int, worker: int, keys: Sequence[int],
                     now: float, dur: float, budget: float
                     ) -> Tuple[int, float]:
        keys = np.asarray(keys, np.int64)
        own, held = self.engine.classify(node, keys)
        local = own | held
        costs = np.where(local, self.cost.t_local, self.cost.t_remote)
        n, spent, excl = budget_prefix(costs, budget)
        keys, own, held = keys[:n], own[:n], held[:n]
        self.metrics.n_accesses += n
        rr = ~own & held
        if np.any(rr):
            times = now + (dur - budget) + excl[:n]
            self.engine.replica_reads(node, keys[rr], times[rr], True)
        rem = ~own & ~held
        if np.any(rem):
            self.engine.remote_accesses(node, keys[rem])
        return n, budget - spent

    def run_round(self, now: float, round_duration_hint: float) -> None:
        self.engine.step(now)

    def mem_bytes(self, node: int) -> float:
        return self.engine.mem_bytes(node)
