"""Baseline parameter-management policies the paper compares against (§2, §5,
Appendix A): static full replication, static parameter partitioning, selective
replication (Petuum-style SSP / ESSP), and a NuPS-style static multi-technique
manager (hot keys fully replicated, cold keys relocation-managed with
application-triggered ``localize`` calls at a fixed relocation offset).
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Set, Tuple

from .api import AccessResult, CostModel, PMPolicy
from .intent import Intent
from .ownership import OwnershipDirectory, home_node


class StaticFullReplication(PMPolicy):
    """Every node holds a replica of the full model (§A.1).

    All accesses are local.  Replicas are synchronized every ``sync_every``
    rounds with a dense AllReduce over the *entire* model (mirrored/DDP
    semantics: the synchronization is oblivious to which values were
    actually written — the over-communication the paper criticizes, §A.1;
    ~2 bytes move per value per node in a ring).  Infeasible when the model
    exceeds node memory.
    """

    name = "Full replication"

    def __init__(self, n_nodes: int, cost: CostModel, n_keys: int,
                 sync_every: int = 1):
        super().__init__(n_nodes, cost)
        self.n_keys = n_keys
        self.sync_every = sync_every
        self._last_sync_time = 0.0
        self._round = 0
        model_bytes = n_keys * cost.value_bytes
        if model_bytes > cost.node_mem_bytes:
            self.metrics.oom = True
        self.metrics.peak_mem_bytes = model_bytes

    def access(self, node, worker, key, now, write=True):
        if self.metrics.oom:
            return AccessResult(local=False)
        self.metrics.n_accesses += 1
        stale = max(0.0, now - self._last_sync_time)
        self.metrics.staleness_sum += stale
        self.metrics.n_replica_reads += 1
        return AccessResult(local=True, staleness=stale)

    def run_round(self, now, round_duration_hint):
        self.metrics.rounds += 1
        self._round += 1
        if self._round % self.sync_every != 0:
            return
        nbytes = 2.0 * self.n_keys * self.cost.value_bytes
        for node in range(self.n_nodes):
            self.ledger.charge(node, nbytes, nmsgs=2 * (self.n_nodes - 1))
        self._last_sync_time = now

    def mem_bytes(self, node):
        return self.n_keys * self.cost.value_bytes


class StaticPartitioning(PMPolicy):
    """Classic parameter server: keys hash-partitioned, every non-local
    access is a synchronous network round trip (§A.2)."""

    name = "Static partitioning"

    def __init__(self, n_nodes: int, cost: CostModel):
        super().__init__(n_nodes, cost)

    def access(self, node, worker, key, now, write=True):
        self.metrics.n_accesses += 1
        if home_node(key, self.n_nodes) == node:
            return AccessResult(local=True, staleness=0.0)
        nbytes = 2 * self.cost.value_bytes
        self.metrics.n_remote += 1
        self.ledger.charge(node, nbytes, nmsgs=2)
        return AccessResult(local=False)

    def run_round(self, now, round_duration_hint):
        self.metrics.rounds += 1


class SelectiveReplicationSSP(PMPolicy):
    """Petuum-style selective replication (§A.3).

    Replicas are created *reactively*: the first access of a key at a node
    blocks on a synchronous fetch.  A replica may serve reads while it is at
    most ``staleness_bound`` clocks old (SSP); once it exceeds the bound the
    next access blocks on a synchronous refresh.  Writes are pushed to the
    key's home node once per round.  ``staleness_bound=None`` gives ESSP:
    replicas are kept (and synchronized every round) forever, converging to
    full replication traffic.
    """

    def __init__(self, n_nodes: int, cost: CostModel,
                 staleness_bound: Optional[int] = None):
        super().__init__(n_nodes, cost)
        self.bound = staleness_bound
        self.name = ("ESSP" if staleness_bound is None
                     else f"SSP(bound={staleness_bound})")
        # per node: key -> (clock at last refresh, sim time of last refresh)
        self._repl: List[Dict[int, Tuple[int, float]]] = [
            dict() for _ in range(n_nodes)]
        self._dirty: List[Set[int]] = [set() for _ in range(n_nodes)]
        self._clock: List[int] = [0] * n_nodes  # max worker clock per node

    def advance_clock(self, node, worker, clock):
        if clock > self._clock[node]:
            self._clock[node] = clock

    def access(self, node, worker, key, now, write=True):
        self.metrics.n_accesses += 1
        if home_node(key, self.n_nodes) == node:
            return AccessResult(local=True, staleness=0.0)
        ent = self._repl[node].get(key)
        clk = self._clock[node]
        fresh = ent is not None and (
            self.bound is None or clk - ent[0] <= self.bound)
        stalled = False
        if not fresh:
            # synchronous fetch/refresh (blocks the worker)
            nbytes = self.cost.value_bytes + 64
            self.metrics.n_remote += 1
            self.ledger.charge(node, nbytes, nmsgs=2)
            self._repl[node][key] = (clk, now)
            ent = self._repl[node][key]
            stalled = True
        if write:
            self._dirty[node].add(key)
        stale = max(0.0, now - ent[1])
        self.metrics.staleness_sum += stale
        self.metrics.n_replica_reads += 1
        return AccessResult(local=True, staleness=stale, stalled=stalled)

    def run_round(self, now, round_duration_hint):
        self.metrics.rounds += 1
        for node in range(self.n_nodes):
            n_dirty = len(self._dirty[node])
            if n_dirty:
                # push accumulated writes to the keys' home nodes
                nbytes = n_dirty * self.cost.value_bytes
                self.ledger.charge(node, nbytes, nmsgs=self.n_nodes - 1)
                self._dirty[node].clear()
            if self.bound is None:
                # ESSP: every held replica is refreshed every round
                # (downstream traffic, charged to this node as receiver-side
                # share of the home nodes' fan-out)
                held = self._repl[node]
                nbytes = len(held) * self.cost.value_bytes
                if nbytes:
                    self.ledger.charge(node, nbytes, nmsgs=self.n_nodes - 1)
                for k in held:
                    held[k] = (self._clock[node], now)

    def mem_bytes(self, node):
        return len(self._repl[node]) * self.cost.value_bytes


class NuPSStatic(PMPolicy):
    """NuPS-style static multi-technique PM (§A.5).

    The application declares, *before training*, a hot set (here: the true
    ``hot_frac`` most frequent keys, i.e. the best-case oracle statistics)
    that is fully replicated on all nodes and synchronized every round.  All
    other keys are relocation-managed: the application calls ``localize``
    (modeled through ``signal_intent``) ``reloc_offset`` clocks before the
    access; the relocation is executed at the next round boundary.  Accesses
    to cold keys that are not (yet, or anymore) on the node are synchronous
    remote accesses — including *relocation conflicts*, where another node
    localized the key away in the meantime (§5.7).
    """

    def __init__(self, n_nodes: int, cost: CostModel, n_keys: int,
                 hot_keys: Set[int], reloc_offset: int = 64):
        super().__init__(n_nodes, cost)
        self.name = f"NuPS(hot={len(hot_keys)},off={reloc_offset})"
        self.hot = hot_keys
        self.reloc_offset = reloc_offset
        self.dir = OwnershipDirectory(n_nodes)
        self._dirty_hot: List[Set[int]] = [set() for _ in range(n_nodes)]
        self._last_hot_sync = 0.0
        # localize requests queued until the next round: (node, key, c_start)
        self._pending_reloc: List[Tuple[int, int, int]] = []
        self._clock: List[int] = [0] * n_nodes
        self.metrics.peak_mem_bytes = (
            len(hot_keys) + n_keys / n_nodes) * cost.value_bytes

    def advance_clock(self, node, worker, clock):
        if clock > self._clock[node]:
            self._clock[node] = clock

    def signal_intent(self, node: int, intent: Intent, now: float) -> None:
        # The application issues localize() reloc_offset ahead; intents that
        # arrive earlier are still queued at the fixed offset semantics —
        # NuPS has no action timing, it acts on whatever was localized at
        # the next round (the offset is the app's tuning knob).
        for k in intent.keys:
            if k not in self.hot:
                self._pending_reloc.append((node, k, intent.c_start))

    def access(self, node, worker, key, now, write=True):
        self.metrics.n_accesses += 1
        if key in self.hot:
            if write:
                self._dirty_hot[node].add(key)
            stale = max(0.0, now - self._last_hot_sync)
            self.metrics.staleness_sum += stale
            self.metrics.n_replica_reads += 1
            return AccessResult(local=True, staleness=stale)
        if self.dir.owner_of(key) == node:
            return AccessResult(local=True, staleness=0.0)
        # relocation conflict or missed localize -> synchronous remote access
        hops = self.dir.route(node, key)
        nbytes = 2 * self.cost.value_bytes + hops * 64
        self.metrics.n_remote += 1
        self.ledger.charge(node, nbytes, nmsgs=1 + hops)
        return AccessResult(local=False)

    def run_round(self, now, round_duration_hint):
        self.metrics.rounds += 1
        c = self.cost
        # hot-set AllReduce-ish sync every round
        for node in range(self.n_nodes):
            nbytes = 2.0 * len(self._dirty_hot[node]) * c.value_bytes
            if nbytes:
                self.ledger.charge(node, nbytes, nmsgs=2 * (self.n_nodes - 1))
                self._dirty_hot[node].clear()
        self._last_hot_sync = now
        # execute queued relocations whose access is within the offset window
        remaining: List[Tuple[int, int, int]] = []
        for (node, k, c_start) in self._pending_reloc:
            if c_start - self._clock[node] > self.reloc_offset:
                remaining.append((node, k, c_start))
                continue
            src = self.dir.owner_of(k)
            if src != node:
                hops = self.dir.route(node, k)
                nbytes = c.value_bytes + 64 * hops
                self.ledger.charge(src, nbytes)  # grouped per round
                self.dir.relocate(k, node)
                self.metrics.n_relocations += 1
        self._pending_reloc = remaining
