"""Baseline parameter-management policies the paper compares against (§2, §5,
Appendix A): static full replication, static parameter partitioning, selective
replication (Petuum-style SSP / ESSP), and a NuPS-style static multi-technique
manager (hot keys fully replicated, cold keys relocation-managed with
application-triggered ``localize`` calls at a fixed relocation offset).

All baselines are thin policies over the vectorized engine primitives
(`engine.home_nodes`, `engine.OwnerTable`): per-key state is
structure-of-arrays and accesses are accounted batch-at-a-time through
``access_batch`` so the same workloads run at 10x+ more keys.
"""

from __future__ import annotations

from typing import List, Optional, Set

import numpy as np

from .api import AccessResult, CostModel, PMPolicy, budget_prefix
from .engine import OwnerTable, home_nodes
from .intent import Intent
from .ownership import home_node


class _NodeArrays:
    """Per-(node, key) growable SoA used by the replication baselines."""

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.capacity = 0
        self.rep_clock = np.empty((n_nodes, 0), np.int64)   # -1 = no replica
        self.rep_time = np.empty((n_nodes, 0), np.float64)

    def ensure_capacity(self, n: int) -> None:
        if n <= self.capacity:
            return
        cap = max(64, self.capacity)
        while cap < n:
            cap *= 2
        clock = np.full((self.n_nodes, cap), -1, np.int64)
        clock[:, : self.capacity] = self.rep_clock[:, : self.capacity]
        time = np.zeros((self.n_nodes, cap), np.float64)
        time[:, : self.capacity] = self.rep_time[:, : self.capacity]
        self.rep_clock, self.rep_time, self.capacity = clock, time, cap


class StaticFullReplication(PMPolicy):
    """Every node holds a replica of the full model (§A.1).

    All accesses are local.  Replicas are synchronized every ``sync_every``
    rounds with a dense AllReduce over the *entire* model (mirrored/DDP
    semantics: the synchronization is oblivious to which values were
    actually written — the over-communication the paper criticizes, §A.1;
    ~2 bytes move per value per node in a ring).  Infeasible when the model
    exceeds node memory.
    """

    name = "Full replication"

    def __init__(self, n_nodes: int, cost: CostModel, n_keys: int,
                 sync_every: int = 1):
        super().__init__(n_nodes, cost)
        self.n_keys = n_keys
        self.sync_every = sync_every
        self._last_sync_time = 0.0
        self._round = 0
        model_bytes = n_keys * cost.value_bytes
        if model_bytes > cost.node_mem_bytes:
            self.metrics.oom = True
        self.metrics.peak_mem_bytes = model_bytes

    def access(self, node, worker, key, now, write=True):
        if self.metrics.oom:
            return AccessResult(local=False)
        self.metrics.n_accesses += 1
        stale = max(0.0, now - self._last_sync_time)
        self.metrics.staleness_sum += stale
        self.metrics.n_replica_reads += 1
        return AccessResult(local=True, staleness=stale)

    def access_batch(self, node, worker, keys, now, dur, budget):
        m = len(keys)
        if self.metrics.oom:
            costs = np.full(m, self.cost.t_remote)
            n, spent, _ = budget_prefix(costs, budget)
            return n, budget - spent
        costs = np.full(m, self.cost.t_local)
        n, spent, excl = budget_prefix(costs, budget)
        times = now + (dur - budget) + excl[:n]
        self.metrics.n_accesses += n
        self.metrics.n_replica_reads += n
        self.metrics.staleness_sum += float(
            np.maximum(0.0, times - self._last_sync_time).sum())
        return n, budget - spent

    def run_round(self, now, round_duration_hint):
        self.metrics.rounds += 1
        self._round += 1
        if self._round % self.sync_every != 0:
            return
        nbytes = 2.0 * self.n_keys * self.cost.value_bytes
        for node in range(self.n_nodes):
            self.ledger.charge(node, nbytes, nmsgs=2 * (self.n_nodes - 1))
        self._last_sync_time = now

    def mem_bytes(self, node):
        return self.n_keys * self.cost.value_bytes


class StaticPartitioning(PMPolicy):
    """Classic parameter server: keys hash-partitioned, every non-local
    access is a synchronous network round trip (§A.2)."""

    name = "Static partitioning"

    def __init__(self, n_nodes: int, cost: CostModel):
        super().__init__(n_nodes, cost)

    def access(self, node, worker, key, now, write=True):
        self.metrics.n_accesses += 1
        if home_node(key, self.n_nodes) == node:
            return AccessResult(local=True, staleness=0.0)
        nbytes = 2 * self.cost.value_bytes
        self.metrics.n_remote += 1
        self.ledger.charge(node, nbytes, nmsgs=2)
        return AccessResult(local=False)

    def access_batch(self, node, worker, keys, now, dur, budget):
        keys = np.asarray(keys, np.int64)
        local = home_nodes(keys, self.n_nodes) == node
        costs = np.where(local, self.cost.t_local, self.cost.t_remote)
        n, spent, _ = budget_prefix(costs, budget)
        n_rem = int(np.count_nonzero(~local[:n]))
        self.metrics.n_accesses += n
        self.metrics.n_remote += n_rem
        self.ledger.charge(node, 2 * self.cost.value_bytes * n_rem,
                           nmsgs=2 * n_rem)
        return n, budget - spent

    def run_round(self, now, round_duration_hint):
        self.metrics.rounds += 1


class SelectiveReplicationSSP(PMPolicy):
    """Petuum-style selective replication (§A.3).

    Replicas are created *reactively*: the first access of a key at a node
    blocks on a synchronous fetch.  A replica may serve reads while it is at
    most ``staleness_bound`` clocks old (SSP); once it exceeds the bound the
    next access blocks on a synchronous refresh.  Writes are pushed to the
    key's home node once per round.  ``staleness_bound=None`` gives ESSP:
    replicas are kept (and synchronized every round) forever, converging to
    full replication traffic.
    """

    def __init__(self, n_nodes: int, cost: CostModel,
                 staleness_bound: Optional[int] = None):
        super().__init__(n_nodes, cost)
        self.bound = staleness_bound
        self.name = ("ESSP" if staleness_bound is None
                     else f"SSP(bound={staleness_bound})")
        self._arr = _NodeArrays(n_nodes)
        # per node: all keys ever replicated there (replicas are never
        # dropped) and the keys written since the last round
        self._held: List[List[np.ndarray]] = [[] for _ in range(n_nodes)]
        self._held_count = np.zeros(n_nodes, np.int64)
        self._dirty: List[List[np.ndarray]] = [[] for _ in range(n_nodes)]
        self._clock: List[int] = [0] * n_nodes  # max worker clock per node

    def advance_clock(self, node, worker, clock):
        if clock > self._clock[node]:
            self._clock[node] = clock

    def access(self, node, worker, key, now, write=True):
        self.metrics.n_accesses += 1
        if home_node(key, self.n_nodes) == node:
            return AccessResult(local=True, staleness=0.0)
        self._arr.ensure_capacity(key + 1)
        rep_clock = self._arr.rep_clock[node]
        rep_time = self._arr.rep_time[node]
        clk = self._clock[node]
        fresh = rep_clock[key] >= 0 and (
            self.bound is None or clk - rep_clock[key] <= self.bound)
        stalled = False
        if not fresh:
            # synchronous fetch/refresh (blocks the worker)
            self.metrics.n_remote += 1
            self.ledger.charge(node, self.cost.value_bytes + 64, nmsgs=2)
            if rep_clock[key] < 0:
                self._held[node].append(np.array([key], np.int64))
                self._held_count[node] += 1
            rep_clock[key] = clk
            rep_time[key] = now
            stalled = True
        if write:
            self._dirty[node].append(np.array([key], np.int64))
        stale = max(0.0, now - float(rep_time[key]))
        self.metrics.staleness_sum += stale
        self.metrics.n_replica_reads += 1
        return AccessResult(local=True, staleness=stale, stalled=stalled)

    def access_batch(self, node, worker, keys, now, dur, budget):
        keys = np.asarray(keys, np.int64)
        self._arr.ensure_capacity(int(keys.max()) + 1 if len(keys) else 0)
        home = home_nodes(keys, self.n_nodes) == node
        rep_clock = self._arr.rep_clock[node]
        rep_time = self._arr.rep_time[node]
        clk = self._clock[node]
        exists = rep_clock[keys] >= 0
        if self.bound is None:
            fresh = exists
        else:
            fresh = exists & (clk - rep_clock[keys] <= self.bound)
        stall = ~home & ~fresh
        costs = np.where(home | fresh, self.cost.t_local, self.cost.t_remote)
        n, spent, excl = budget_prefix(costs, budget)
        keys, home, fresh, stall, exists = (
            a[:n] for a in (keys, home, fresh, stall, exists))
        times = now + (dur - budget) + excl[:n]
        self.metrics.n_accesses += n
        # synchronous fetch/refresh for stale/missing replicas
        n_miss = int(np.count_nonzero(stall))
        if n_miss:
            self.metrics.n_remote += n_miss
            self.ledger.charge(node, (self.cost.value_bytes + 64) * n_miss,
                               nmsgs=2 * n_miss)
            mk = keys[stall]
            new = mk[~exists[stall]]
            if len(new):
                self._held[node].append(new)
                self._held_count[node] += len(new)
            rep_clock[mk] = clk
            rep_time[mk] = times[stall]
        repl = ~home
        n_repl = int(np.count_nonzero(repl))
        if n_repl:
            self._dirty[node].append(keys[repl].copy())
            stale = np.maximum(0.0, times[repl] - rep_time[keys[repl]])
            self.metrics.staleness_sum += float(stale.sum())
            self.metrics.n_replica_reads += n_repl
        return n, budget - spent

    def run_round(self, now, round_duration_hint):
        self.metrics.rounds += 1
        for node in range(self.n_nodes):
            if self._dirty[node]:
                # push accumulated writes to the keys' home nodes
                n_dirty = len(np.unique(np.concatenate(self._dirty[node])))
                self.ledger.charge(node, n_dirty * self.cost.value_bytes,
                                   nmsgs=self.n_nodes - 1)
                self._dirty[node] = []
            if self.bound is None and self._held_count[node]:
                # ESSP: every held replica is refreshed every round
                # (downstream traffic, charged to this node as
                # receiver-side share of the home nodes' fan-out)
                held = np.concatenate(self._held[node])
                self._held[node] = [held]
                self.ledger.charge(node, len(held) * self.cost.value_bytes,
                                   nmsgs=self.n_nodes - 1)
                self._arr.rep_clock[node, held] = self._clock[node]
                self._arr.rep_time[node, held] = now

    def mem_bytes(self, node):
        return int(self._held_count[node]) * self.cost.value_bytes


class NuPSStatic(PMPolicy):
    """NuPS-style static multi-technique PM (§A.5).

    The application declares, *before training*, a hot set (here: the true
    ``hot_frac`` most frequent keys, i.e. the best-case oracle statistics)
    that is fully replicated on all nodes and synchronized every round.  All
    other keys are relocation-managed: the application calls ``localize``
    (modeled through ``signal_intent``) ``reloc_offset`` clocks before the
    access; the relocation is executed at the next round boundary.  Accesses
    to cold keys that are not (yet, or anymore) on the node are synchronous
    remote accesses — including *relocation conflicts*, where another node
    localized the key away in the meantime (§5.7).

    Relocations are applied vectorized: queued localizes are grouped by key
    and replayed as an ownership chain (same final owner and relocation
    count as the seed's FIFO loop; forwarding for the intra-round chain tail
    is charged at one hop).
    """

    def __init__(self, n_nodes: int, cost: CostModel, n_keys: int,
                 hot_keys: Set[int], reloc_offset: int = 64):
        super().__init__(n_nodes, cost)
        self.name = f"NuPS(hot={len(hot_keys)},off={reloc_offset})"
        self.hot = hot_keys
        self._hot_arr = np.fromiter(sorted(hot_keys), np.int64,
                                    len(hot_keys))
        self.reloc_offset = reloc_offset
        self.owners = OwnerTable(n_nodes, capacity=n_keys)
        self._dirty_hot: List[List[np.ndarray]] = [
            [] for _ in range(n_nodes)]
        self._last_hot_sync = 0.0
        # localize requests queued until the next round
        self._pend_node: List[np.ndarray] = []
        self._pend_key: List[np.ndarray] = []
        self._pend_start: List[np.ndarray] = []
        self._clock: List[int] = [0] * n_nodes
        self.metrics.peak_mem_bytes = (
            len(hot_keys) + n_keys / n_nodes) * cost.value_bytes

    def advance_clock(self, node, worker, clock):
        if clock > self._clock[node]:
            self._clock[node] = clock

    def signal_intent(self, node: int, intent: Intent, now: float) -> None:
        # The application issues localize() reloc_offset ahead; intents that
        # arrive earlier are still queued at the fixed offset semantics —
        # NuPS has no action timing, it acts on whatever was localized at
        # the next round (the offset is the app's tuning knob).
        keys = np.asarray(intent.keys, np.int64)
        cold = keys[~np.isin(keys, self._hot_arr)]
        if len(cold):
            self._pend_node.append(np.full(len(cold), node, np.int64))
            self._pend_key.append(cold)
            self._pend_start.append(
                np.full(len(cold), intent.c_start, np.int64))

    def access(self, node, worker, key, now, write=True):
        self.metrics.n_accesses += 1
        if key in self.hot:
            if write:
                self._dirty_hot[node].append(np.array([key], np.int64))
            stale = max(0.0, now - self._last_hot_sync)
            self.metrics.staleness_sum += stale
            self.metrics.n_replica_reads += 1
            return AccessResult(local=True, staleness=stale)
        if self.owners.owner_of(key) == node:
            return AccessResult(local=True, staleness=0.0)
        # relocation conflict or missed localize -> synchronous remote access
        hops = int(self.owners.route_batch(
            node, np.array([key], np.int64))[0])
        nbytes = 2 * self.cost.value_bytes + hops * 64
        self.metrics.n_remote += 1
        self.ledger.charge(node, nbytes, nmsgs=1 + hops)
        return AccessResult(local=False)

    def access_batch(self, node, worker, keys, now, dur, budget):
        keys = np.asarray(keys, np.int64)
        self.owners.ensure_capacity(int(keys.max()) + 1 if len(keys) else 0)
        hot = np.isin(keys, self._hot_arr)
        own = self.owners.owners(keys) == node
        local = hot | (own & ~hot)
        costs = np.where(local, self.cost.t_local, self.cost.t_remote)
        n, spent, excl = budget_prefix(costs, budget)
        keys, hot, own = keys[:n], hot[:n], own[:n]
        times = now + (dur - budget) + excl[:n]
        self.metrics.n_accesses += n
        n_hot = int(np.count_nonzero(hot))
        if n_hot:
            self._dirty_hot[node].append(keys[hot].copy())
            self.metrics.staleness_sum += float(np.maximum(
                0.0, times[hot] - self._last_hot_sync).sum())
            self.metrics.n_replica_reads += n_hot
        rem = ~hot & ~own
        n_rem = int(np.count_nonzero(rem))
        if n_rem:
            hops = int(self.owners.route_batch(node, keys[rem]).sum())
            self.metrics.n_remote += n_rem
            self.ledger.charge(
                node, 2 * self.cost.value_bytes * n_rem + 64 * hops,
                nmsgs=n_rem + hops)
        return n, budget - spent

    def run_round(self, now, round_duration_hint):
        self.metrics.rounds += 1
        c = self.cost
        # hot-set AllReduce-ish sync every round
        for node in range(self.n_nodes):
            if self._dirty_hot[node]:
                n_dirty = len(np.unique(
                    np.concatenate(self._dirty_hot[node])))
                self.ledger.charge(node, 2.0 * n_dirty * c.value_bytes,
                                   nmsgs=2 * (self.n_nodes - 1))
                self._dirty_hot[node] = []
        self._last_hot_sync = now
        # execute queued relocations whose access is within the offset window
        if not self._pend_key:
            return
        nodes = np.concatenate(self._pend_node)
        keys = np.concatenate(self._pend_key)
        starts = np.concatenate(self._pend_start)
        self._pend_node, self._pend_key, self._pend_start = [], [], []
        clock = np.asarray(self._clock, np.int64)
        due = starts - clock[nodes] <= self.reloc_offset
        if not np.all(due):
            self._pend_node = [nodes[~due]]
            self._pend_key = [keys[~due]]
            self._pend_start = [starts[~due]]
        nodes, keys = nodes[due], keys[due]
        if len(keys) == 0:
            return
        # replay the localize queue as per-key ownership chains
        order = np.argsort(keys, kind="stable")
        ks, ns = keys[order], nodes[order]
        first = np.empty(len(ks), bool)
        first[0] = True
        first[1:] = ks[1:] != ks[:-1]
        prev = np.empty(len(ks), np.int64)
        prev[first] = self.owners.owners(ks[first])
        prev[~first] = ns[np.nonzero(~first)[0] - 1]
        moves = prev != ns
        self.metrics.n_relocations += int(np.count_nonzero(moves))
        # head-of-chain moves pay routed hops; chain tails forward directly
        head = first & moves
        for node in range(self.n_nodes):
            hm = head & (ns == node)
            if np.any(hm):
                hops = self.owners.route_batch(node, ks[hm])
                np.add.at(self.ledger.bytes_out, prev[hm],
                          c.value_bytes + 64.0 * hops)
        tail = ~first & moves
        if np.any(tail):
            np.add.at(self.ledger.bytes_out, prev[tail],
                      float(c.value_bytes + 64))
        last = np.empty(len(ks), bool)
        last[-1] = True
        last[:-1] = ks[1:] != ks[:-1]
        self.owners.relocate_batch(ks[last], ns[last])
