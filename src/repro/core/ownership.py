"""Ownership, home nodes, and location caches (paper §B.1.1, §B.2.3).

Every key has a statically assigned *home node* (hash partitioning) that is
the routing fallback: it always knows the current *owner* (the node holding
the primary copy).  Nodes route messages with *location caches* (last known
owner); a message routed to a stale owner is forwarded to the current owner
via the home node (extra hop), exactly as in Lapse.

The state itself lives in vectorized arrays in `core.engine.OwnerTable`;
this module keeps the seed's scalar `OwnershipDirectory` API as a thin
adapter over it for tests and per-key callers."""

from __future__ import annotations

import numpy as np

from .engine import OwnerTable, home_nodes  # noqa: F401  (re-exported)

_FIB = 11400714819323198485


def home_node(key: int, n_nodes: int) -> int:
    """Static hash partitioning of keys to home nodes (Fibonacci hashing —
    cheap, well-spread for dense integer key ranges).  The vectorized
    `engine.home_nodes` matches this exactly."""
    return ((key * _FIB) >> 32) % n_nodes


class OwnershipDirectory:
    """Global ownership state, as distributedly known — scalar adapter over
    `engine.OwnerTable`.

    The owner array is ground truth (the home node always tracks it —
    location updates are piggybacked on sync messages); per-node caches hold
    each node's last known owner.  ``route(n, k)`` returns the number of
    hops a message from node n to key k's owner takes (1 = direct, 2/3 = via
    stale cache or home forward), charging the realistic cost of the
    Lapse-style protocol.
    """

    def __init__(self, n_nodes: int):
        self.n_nodes = n_nodes
        self.table = OwnerTable(n_nodes)

    def owner_of(self, key: int) -> int:
        return self.table.owner_of(key)

    def route(self, src: int, key: int, update_cache: bool = True) -> int:
        """Hops for a message src -> current owner of ``key``: direct if the
        location cache (or home-node identity) is correct; otherwise the
        stale target forwards via the home node.  Responses carry the
        owner's identity, refreshing the cache."""
        self.table.ensure_capacity(key + 1)
        return int(self.table.route_batch(
            src, np.array([key], np.int64), update_cache)[0])

    def relocate(self, key: int, new_owner: int) -> None:
        """Transfer ownership.  The old owner informs the home node
        (piggybacked); caches of other nodes go stale silently."""
        self.table.ensure_capacity(key + 1)
        self.table.relocate_batch(np.array([key], np.int64),
                                  np.array([new_owner], np.int64))
