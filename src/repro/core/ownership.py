"""Ownership, home nodes, and location caches (paper §B.1.1, §B.2.3).

Every key has a statically assigned *home node* (hash partitioning) that is
the routing fallback: it always knows the current *owner* (the node holding
the primary copy).  Management responsibility follows allocation: the owner
decides relocate-vs-replicate and is the replica-sync hub; responsibility
moves with the parameter on relocation.

Nodes route messages with *location caches* (last known owner).  Caches are
never invalidated explicitly; a message routed to a stale owner is forwarded
to the current owner via the home node (extra hop), exactly as in Lapse.
The simulator charges those forwarding hops.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


def home_node(key: int, n_nodes: int) -> int:
    """Static hash partitioning of keys to home nodes."""
    # Fibonacci hashing — cheap, well-spread for dense integer key ranges.
    return ((key * 11400714819323198485) >> 32) % n_nodes


@dataclass
class OwnershipDirectory:
    """Global ownership state, as distributedly known.

    ``owner[k]`` is ground truth (the home node always tracks it — location
    updates are piggybacked on sync messages).  ``caches[n][k]`` is node n's
    last known owner.  ``route(n, k)`` returns the number of hops a message
    from node n to key k's owner takes (1 = direct, 2 = via stale cache or
    home forward), charging the realistic cost of the Lapse-style protocol.
    """

    n_nodes: int
    owner: Dict[int, int] = field(default_factory=dict)
    caches: List[Dict[int, int]] = None  # type: ignore[assignment]

    def __post_init__(self):
        if self.caches is None:
            self.caches = [dict() for _ in range(self.n_nodes)]

    def owner_of(self, key: int) -> int:
        o = self.owner.get(key)
        if o is None:
            o = home_node(key, self.n_nodes)
            self.owner[key] = o
        return o

    def route(self, src: int, key: int, update_cache: bool = True) -> int:
        """Hops for a message src -> current owner of ``key``.

        Direct if the location cache (or home-node identity) is correct;
        otherwise the stale target forwards via the home node (2 hops total
        beyond the first send -> 2 or 3 messages).  Returns message count.
        """
        true_owner = self.owner_of(key)
        if src == true_owner:
            return 0
        believed = self.caches[src].get(key, home_node(key, self.n_nodes))
        hops = 1
        if believed != true_owner:
            # stale: believed node (or home) forwards to the current owner
            hops += 1 if believed == home_node(key, self.n_nodes) else 2
        if update_cache:
            # responses carry the owner's identity -> cache refresh
            self.caches[src][key] = true_owner
        return hops

    def relocate(self, key: int, new_owner: int) -> None:
        """Transfer ownership.  The old owner informs the home node
        (piggybacked); caches of other nodes go stale silently."""
        self.owner[key] = new_owner
        self.caches[new_owner][key] = new_owner
