"""Faithful AdaPM core: intent signaling, the unified vectorized intent
engine, simulator-drivable policies, and the discrete-event cluster
simulator.  The engine (`repro.core.engine`) is the single decision
procedure — both the simulator policies and the SPMD planner
(`repro.pm.planner`) route placement decisions through it (DESIGN.md §2).
"""

from .api import AccessResult, CostModel, Metrics, PMPolicy, RoundLedger
from .engine import (IntentEngine, IntentStore, OwnerTable,
                     concurrent_intent, decide_on_activate, home_nodes,
                     intent_miss_bound)
from .intent import Intent, IntentTable, IntentType, LogicalClock
from .manager import AdaPM
from .simulator import SimConfig, Workload, simulate

__all__ = [
    "AccessResult", "AdaPM", "CostModel", "Intent", "IntentEngine",
    "IntentStore", "IntentTable", "IntentType", "LogicalClock", "Metrics",
    "OwnerTable", "PMPolicy", "RoundLedger", "SimConfig", "Workload",
    "concurrent_intent", "decide_on_activate", "home_nodes",
    "intent_miss_bound", "simulate",
]
