"""Common interface between parameter-management policies and the cluster
simulator, plus the metric containers every policy reports.

A *policy* owns all PM state (ownership, replicas, intent tables) and is
driven by the simulator through the hooks below.  The simulator owns time,
workers, clocks, and the access streams.  Traffic is charged to per-node,
per-round byte/message counters held by the policy's ``RoundLedger``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .intent import Intent


@dataclass
class CostModel:
    """Network / compute cost model for the simulated cluster.

    Defaults loosely model the paper's testbed: 100 Gbit/s links
    (~12.5 GB/s; we use an effective per-node bandwidth), sub-ms round
    latencies, microsecond local accesses, ~100 microsecond synchronous
    remote accesses (request + response + queueing).
    """

    value_bytes: int = 4 * 500          # one parameter value (dim 500 fp32)
    bandwidth: float = 6e9              # effective B/s per node
    per_msg: float = 20e-6              # s per (grouped) message
    base_round: float = 2e-3            # s floor per communication round
    t_local: float = 0.8e-6             # s per local key access
    t_remote: float = 120e-6            # s stall per synchronous remote access
    t_batch: float = 200e-6             # s compute per batch (besides access)
    signal_bytes: int = 16              # per aggregated intent transition
    node_mem_bytes: float = 512e9       # per-node memory capacity


def budget_prefix(costs: np.ndarray, budget: float
                  ) -> Tuple[int, float, np.ndarray]:
    """Batched compute-budget rule shared by every ``access_batch``: access
    i runs iff the budget *before* it is positive (the final access may push
    the budget negative; the simulator carries the deficit).  Returns
    ``(n_processed, spent, exclusive_cumsum)`` — ``(0, 0.0, ...)`` when no
    access fits or ``costs`` is empty."""
    cum = np.cumsum(costs)
    excl = cum - costs
    n = int(np.count_nonzero(budget - excl > 0.0))
    spent = float(cum[n - 1]) if n else 0.0
    return n, spent, excl


@dataclass
class RoundLedger:
    """Per-round traffic accumulator (reset by the simulator each round).

    Holds numpy arrays so vectorized policies (the intent engine) can charge
    whole batches at once with ``np.add.at``."""

    n_nodes: int
    bytes_out: np.ndarray = field(default_factory=lambda: np.zeros(0))
    msgs: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))

    def __post_init__(self):
        self.reset()

    def reset(self):
        self.bytes_out = np.zeros(self.n_nodes, np.float64)
        self.msgs = np.zeros(self.n_nodes, np.int64)

    def charge(self, node: int, nbytes: float, nmsgs: int = 0):
        self.bytes_out[node] += nbytes
        self.msgs[node] += nmsgs


@dataclass
class Metrics:
    """Per-run metrics (one epoch unless stated otherwise)."""

    epoch_time: float = 0.0
    bytes_per_node: float = 0.0         # mean over nodes, total for run
    total_bytes: float = 0.0
    n_accesses: int = 0
    n_remote: int = 0
    staleness_sum: float = 0.0          # seconds, summed over replica reads
    n_replica_reads: int = 0
    n_relocations: int = 0
    n_replica_creates: int = 0
    peak_mem_bytes: float = 0.0
    oom: bool = False
    rounds: int = 0

    @property
    def remote_fraction(self) -> float:
        return self.n_remote / max(1, self.n_accesses)

    @property
    def mean_staleness(self) -> float:
        return self.staleness_sum / max(1, self.n_replica_reads)

    def as_dict(self) -> Dict[str, float]:
        return {
            "epoch_time_s": round(self.epoch_time, 4),
            "gb_per_node": round(self.bytes_per_node / 1e9, 4),
            "remote_frac": round(self.remote_fraction, 6),
            "mean_staleness_ms": round(self.mean_staleness * 1e3, 3),
            "relocations": self.n_relocations,
            "replica_creates": self.n_replica_creates,
            "rounds": self.rounds,
            "oom": self.oom,
        }


@dataclass
class AccessResult:
    local: bool
    staleness: Optional[float] = None   # set for replica reads
    stalled: bool = False               # worker blocked on the network
    # (remote accesses always stall; a *local* access can still stall when
    #  the policy had to fetch/refresh synchronously first, e.g. SSP)

    @property
    def worker_stalled(self) -> bool:
        return self.stalled or not self.local


class PMPolicy:
    """Interface the simulator drives.  All hooks are node-local in the
    information they may use; the simulator is the only omniscient party."""

    name: str = "abstract"

    def __init__(self, n_nodes: int, cost: CostModel):
        self.n_nodes = n_nodes
        self.cost = cost
        self.ledger = RoundLedger(n_nodes)
        self.metrics = Metrics()

    # --- intent & clocks -------------------------------------------------
    def signal_intent(self, node: int, intent: Intent, now: float) -> None:
        """Loader on ``node`` signals an intent.  Optional for policies that
        ignore intent (static baselines)."""

    def advance_clock(self, node: int, worker: int, clock: int) -> None:
        """Worker finished a batch; its logical clock is now ``clock``."""

    # --- access path ------------------------------------------------------
    def access(self, node: int, worker: int, key: int,
               now: float, write: bool = True) -> AccessResult:
        """One parameter access during batch processing.  Returns whether the
        access was local; charges remote traffic to the ledger otherwise."""
        raise NotImplementedError

    def access_batch(self, node: int, worker: int, keys: Sequence[int],
                     now: float, dur: float, budget: float
                     ) -> Tuple[int, float]:
        """Process ``keys`` (distinct, in order) during the compute phase of
        the round ``[now, now + dur)`` until ``budget`` is exhausted; each
        access costs ``t_local`` or ``t_remote`` depending on whether the
        worker stalls.  Returns ``(n_processed, remaining_budget)`` — the
        budget may go negative on the final access (carried by the
        simulator).  The default implementation loops over ``access()``;
        vectorized policies override it with batched accounting."""
        n_done = 0
        for k in keys:
            if budget <= 0.0:
                break
            t_access = now + (dur - max(budget, 0.0))
            res = self.access(node, worker, int(k), t_access)
            budget -= (self.cost.t_remote if res.worker_stalled
                       else self.cost.t_local)
            n_done += 1
        return n_done, budget

    # --- communication rounds ----------------------------------------------
    def run_round(self, now: float, round_duration_hint: float) -> None:
        """Executed at a round boundary: exchange grouped sync messages,
        make decisions, apply relocations/replications, charge traffic."""
        raise NotImplementedError

    def mem_bytes(self, node: int) -> float:
        """Current PM memory footprint on ``node`` (for OOM checks)."""
        return 0.0

    def finalize(self) -> Metrics:
        return self.metrics


class LatencyRecorder:
    """Streaming latency accounting: record seconds, read percentiles.

    Numpy-only on purpose — it lives next to `Metrics` so both the
    serving scheduler (`repro.serve.scheduler`) and `benchmarks.common`
    can share the one percentile implementation without pulling JAX into
    the simulator benchmarks."""

    def __init__(self):
        self._vals: List[float] = []

    def record(self, seconds: float) -> None:
        self._vals.append(float(seconds))

    def extend(self, seconds: Sequence[float]) -> None:
        self._vals.extend(float(s) for s in seconds)

    def __len__(self) -> int:
        return len(self._vals)

    def reset(self) -> None:
        self._vals.clear()

    def percentile(self, q: float) -> float:
        if not self._vals:
            return 0.0
        return float(np.percentile(np.asarray(self._vals), q))

    def mean(self) -> float:
        return float(np.mean(self._vals)) if self._vals else 0.0

    def summary_ms(self, qs: Tuple[float, ...] = (50.0, 99.0)
                   ) -> Dict[str, float]:
        out = {f"p{q:g}_ms": round(self.percentile(q) * 1e3, 4)
               for q in qs}
        out["mean_ms"] = round(self.mean() * 1e3, 4)
        out["count"] = len(self._vals)
        return out
