"""Unified vectorized intent engine (paper §3-§4, §B) — structure-of-arrays.

This module is the single place where intent is *exploited*.  Both consumers
route their placement decisions through it:

  * the discrete-event simulator policies (`core.manager.AdaPM`, the
    baselines in `core.baselines`) drive the full `IntentEngine` state
    machine below — intent tables, per-key management state (owned /
    replicated / relocating), the owner-side decision rule (§4.1) and
    Algorithm 1 action timing;
  * the SPMD planner (`pm.planner.IntentPlanner`) calls the vectorized
    window classifiers (`concurrent_intent`, `intent_miss_bound`) that
    implement the same §4.1 rule over a planning window: concurrent intent
    on >= 2 nodes -> replicate, single-node intent -> owner path.

Everything is numpy structure-of-arrays instead of per-key dicts and heaps:
an int32 owner array, uint64 replica/active/dirty holder bitmasks (node
count <= 64), growable window arrays for pending/announced intents, and
per-round vectorized activation/expiry/decision/sync passes.  The observable
behavior (decisions, traffic charges, metrics) is pinned to the seed
dict-based AdaPM by `tests/test_engine.py`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .api import CostModel, Metrics, RoundLedger
from .timing import ActionTimer

# Fibonacci multiplier of the seed's `home_node`, split into 32-bit halves so
# the vectorized hash reproduces Python's arbitrary-precision
# ``(key * FIB) >> 32`` exactly (uint64 arithmetic alone would wrap).
_FIB = 11400714819323198485
_FIB_HI = np.uint64(_FIB >> 32)
_FIB_LO = np.uint64(_FIB & 0xFFFFFFFF)

_NO_CACHE = np.int32(-1)
_INF_CLOCK = np.int64(2 ** 62)


def home_nodes(keys: np.ndarray, n_nodes: int) -> np.ndarray:
    """Vectorized static hash partitioning; exact match of
    ``ownership.home_node`` for all keys < 2**32."""
    k = np.asarray(keys).astype(np.uint64)
    h = k * _FIB_HI + ((k * _FIB_LO) >> np.uint64(32))
    return (h % np.uint64(n_nodes)).astype(np.int64)


def single_bit_index(x: np.ndarray) -> np.ndarray:
    """Bit index for masks known to hold exactly one set bit (exact: all
    uint64 powers of two are representable in float64)."""
    return np.log2(x.astype(np.float64)).astype(np.int64)


class Windows:
    """Growable SoA of intent windows (key, c_start, c_end, worker-slot)."""

    __slots__ = ("key", "c_start", "c_end", "worker", "n")

    def __init__(self, cap: int = 64):
        self.key = np.empty(cap, np.int64)
        self.c_start = np.empty(cap, np.int64)
        self.c_end = np.empty(cap, np.int64)
        self.worker = np.empty(cap, np.int32)
        self.n = 0

    def __len__(self) -> int:
        return self.n

    def _grow(self, need: int) -> None:
        cap = len(self.key)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        for name in ("key", "c_start", "c_end", "worker"):
            old = getattr(self, name)
            new = np.empty(cap, old.dtype)
            new[: self.n] = old[: self.n]
            setattr(self, name, new)

    def append(self, keys, c_start, c_end, worker) -> None:
        keys = np.atleast_1d(np.asarray(keys, np.int64))
        m = len(keys)
        if m == 0:
            return
        self._grow(self.n + m)
        sl = slice(self.n, self.n + m)
        self.key[sl] = keys
        self.c_start[sl] = c_start
        self.c_end[sl] = c_end
        self.worker[sl] = worker
        self.n += m

    def keep(self, mask: np.ndarray) -> None:
        idx = np.nonzero(mask)[0]
        m = len(idx)
        for name in ("key", "c_start", "c_end", "worker"):
            arr = getattr(self, name)
            arr[:m] = arr[: self.n][idx]
        self.n = m

    def view(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        n = self.n
        return (self.key[:n], self.c_start[:n], self.c_end[:n],
                self.worker[:n])


class WorkerRegistry:
    """Dense worker-id -> slot mapping with a per-slot clock array."""

    __slots__ = ("ids", "index", "clock", "clocked")

    def __init__(self):
        self.ids: List[int] = []
        self.index: Dict[int, int] = {}
        self.clock = np.zeros(8, np.int64)
        self.clocked = np.zeros(8, bool)

    def slot(self, worker: int) -> int:
        s = self.index.get(worker)
        if s is None:
            s = len(self.ids)
            self.index[worker] = s
            self.ids.append(worker)
            if s >= len(self.clock):
                self.clock = np.concatenate(
                    [self.clock, np.zeros(len(self.clock), np.int64)])
                self.clocked = np.concatenate(
                    [self.clocked, np.zeros(len(self.clocked), bool)])
        return s

    def set_clock(self, worker: int, clock: int) -> None:
        s = self.slot(worker)
        self.clock[s] = clock
        self.clocked[s] = True


class IntentStore:
    """Vectorized node-local intent table (§3): stores signaled windows and
    answers the activation queries the manager needs.  Backs the per-key
    `intent.IntentTable` API and the satellite activation-semantics tests."""

    def __init__(self):
        self.windows = Windows()
        self.workers = WorkerRegistry()

    def signal(self, keys, c_start: int, c_end: int, worker: int) -> None:
        self.windows.append(keys, c_start, c_end, self.workers.slot(worker))

    def _clocks_by_slot(self, clocks: Dict[int, int]) -> np.ndarray:
        out = np.zeros(max(1, len(self.workers.ids)), np.int64)
        for w, c in clocks.items():
            s = self.workers.index.get(w)
            if s is not None:
                out[s] = c
        return out

    def states(self, clocks: Dict[int, int]) -> np.ndarray:
        """Per-window state vs ``Intent.state``: 0 inactive, 1 active,
        2 expired — the vectorized activation semantics."""
        key, c_start, c_end, worker = self.windows.view()
        clk = self._clocks_by_slot(clocks)[worker]
        return np.where(clk < c_start, 0, np.where(clk < c_end, 1, 2))

    def active_workers(self, key: int, clocks: Dict[int, int]) -> Set[int]:
        keys, c_start, c_end, worker = self.windows.view()
        clk = self._clocks_by_slot(clocks)[worker]
        m = (keys == key) & (c_start <= clk) & (clk < c_end)
        return {self.workers.ids[s] for s in np.unique(worker[m])}

    def has_active(self, key: int, clocks: Dict[int, int]) -> bool:
        keys, c_start, c_end, worker = self.windows.view()
        clk = self._clocks_by_slot(clocks)[worker]
        return bool(np.any((keys == key) & (c_start <= clk) & (clk < c_end)))

    def earliest_future_start(self, key: int, clocks: Dict[int, int]):
        keys, c_start, _c_end, worker = self.windows.view()
        clk = self._clocks_by_slot(clocks)[worker]
        m = (keys == key) & (clk < c_start)
        if not np.any(m):
            return None
        i = np.nonzero(m)[0][np.argmin(c_start[m])]
        return int(c_start[i]), self.workers.ids[int(worker[i])]

    def last_end(self, key: int) -> int:
        keys, _s, c_end, _w = self.windows.view()
        m = keys == key
        return int(c_end[m].max()) if np.any(m) else 0

    def gc(self, clocks: Dict[int, int]) -> None:
        _keys, _s, c_end, worker = self.windows.view()
        clk = self._clocks_by_slot(clocks)[worker]
        self.windows.keep(clk < c_end)

    def keys(self) -> np.ndarray:
        return np.unique(self.windows.view()[0])

    def __len__(self) -> int:
        """Number of distinct keys with any stored window."""
        return len(self.keys())


class StreamingIntentBuffer:
    """Streaming intent for the online serving runtime (DESIGN.md §9).

    Training intent arrives in fixed windows (the loader signals step
    ``s`` for clock ``[s, s+1)``); serving intent *streams*: a request's
    key set is known the moment it is enqueued, and the intent stays live
    until the request is served.  This buffer is the SoA store for those
    open-ended windows — ``ingest`` on enqueue, ``expire`` on serve — and
    ``snapshot`` projects the live intent onto the scheduler's logical
    clock so the window classifiers above (`concurrent_intent`,
    `intent_miss_bound`) apply unchanged: a queued request at position
    ``p`` runs in micro-batch ``p // batch_size`` (the clock tick) at slot
    ``p % batch_size`` (the "node" — concurrent intent from >= 2 requests
    in one batch -> replicate, §4.1).
    """

    __slots__ = ("key", "req", "n")

    def __init__(self, cap: int = 256):
        self.key = np.empty(cap, np.int64)
        self.req = np.empty(cap, np.int64)
        self.n = 0

    def __len__(self) -> int:
        return self.n

    def _grow(self, need: int) -> None:
        cap = len(self.key)
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        for name in ("key", "req"):
            old = getattr(self, name)
            new = np.empty(cap, old.dtype)
            new[: self.n] = old[: self.n]
            setattr(self, name, new)

    def ingest(self, req_id: int, keys) -> None:
        """Signal: request ``req_id`` will touch ``keys`` when scheduled."""
        keys = np.atleast_1d(np.asarray(keys, np.int64))
        self.ingest_batch(np.full(len(keys), req_id, np.int64), keys)

    def ingest_batch(self, req_ids: np.ndarray, keys: np.ndarray) -> None:
        """Vectorized ingest: ``req_ids[i]`` will touch ``keys[i]`` —
        one append for a whole admission wave instead of a Python loop
        per request (the enqueue path is on the serving hot path)."""
        keys = np.asarray(keys, np.int64)
        m = len(keys)
        if m == 0:
            return
        self._grow(self.n + m)
        self.key[self.n: self.n + m] = keys
        self.req[self.n: self.n + m] = np.asarray(req_ids, np.int64)
        self.n += m

    def expire(self, req_ids) -> None:
        """Serving a request expires its intent (the §4.1 expiry arm:
        replicas for keys nobody still wants fall out at the next plan)."""
        req_ids = np.atleast_1d(np.asarray(req_ids, np.int64))
        if len(req_ids) == 0 or self.n == 0:
            return
        keep = ~np.isin(self.req[: self.n], req_ids)
        m = int(keep.sum())
        self.key[:m] = self.key[: self.n][keep]
        self.req[:m] = self.req[: self.n][keep]
        self.n = m

    def snapshot(self, order: np.ndarray, batch_size: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Project live intent onto the queue order: ``order`` is the
        queued request ids front-to-back.  Returns (keys, slots, ticks)
        for the window classifiers.  Intent of in-flight requests (popped
        but not yet served/expired) is not in ``order`` and is dropped
        from the snapshot — their future is the executing batch."""
        z = np.zeros(0, np.int64)
        order = np.asarray(order, np.int64)
        if self.n == 0 or len(order) == 0:
            return z, z, z
        key, req = self.key[: self.n], self.req[: self.n]
        sidx = np.argsort(order, kind="stable")
        j = np.searchsorted(order[sidx], req)
        j = np.clip(j, 0, len(order) - 1)
        pos = sidx[j]
        queued = order[pos] == req
        pos = pos[queued]
        return (key[queued],
                pos % batch_size,
                pos // batch_size)


class OwnerTable:
    """Vectorized ownership + location caches (§B.1.1, §B.2.3).

    ``owner`` is ground truth (home node always knows it); ``cache[n, k]``
    is node n's last known owner (-1 = believe the home node).  Routing
    semantics match the seed's Lapse-style `OwnershipDirectory`."""

    def __init__(self, n_nodes: int, capacity: int = 0):
        self.n_nodes = n_nodes
        self.capacity = 0
        self.owner = np.empty(0, np.int32)
        self.cache = np.empty((n_nodes, 0), np.int32)
        if capacity:
            self.ensure_capacity(capacity)

    def ensure_capacity(self, n: int) -> None:
        if n <= self.capacity:
            return
        cap = max(64, self.capacity)
        while cap < n:
            cap *= 2
        owner = np.empty(cap, np.int32)
        owner[: self.capacity] = self.owner[: self.capacity]
        owner[self.capacity:] = home_nodes(
            np.arange(self.capacity, cap), self.n_nodes)
        cache = np.full((self.n_nodes, cap), _NO_CACHE, np.int32)
        cache[:, : self.capacity] = self.cache[:, : self.capacity]
        self.owner, self.cache, self.capacity = owner, cache, cap

    def owners(self, keys: np.ndarray) -> np.ndarray:
        return self.owner[keys]

    def owner_of(self, key: int) -> int:
        self.ensure_capacity(key + 1)
        return int(self.owner[key])

    def homes(self, keys: np.ndarray) -> np.ndarray:
        return home_nodes(keys, self.n_nodes)

    def route_batch(self, src: int, keys: np.ndarray,
                    update_cache: bool = True) -> np.ndarray:
        """Hops per message src -> owner (0 when src owns; +1 via stale
        home, +2 via stale non-home cache), with response cache refresh."""
        keys = np.asarray(keys, np.int64)
        if len(keys) == 0:
            return np.zeros(0, np.int64)
        self.ensure_capacity(int(keys.max()) + 1)
        true_owner = self.owner[keys].astype(np.int64)
        home = self.homes(keys)
        believed = self.cache[src, keys].astype(np.int64)
        believed = np.where(believed == _NO_CACHE, home, believed)
        hops = np.ones(len(keys), np.int64)
        stale = believed != true_owner
        hops += stale * np.where(believed == home, 1, 2)
        hops[true_owner == src] = 0
        if update_cache:
            self.cache[src, keys] = true_owner
        return hops

    def relocate_batch(self, keys: np.ndarray, dsts: np.ndarray) -> None:
        self.owner[keys] = dsts
        self.cache[dsts, keys] = dsts

    def owned_counts(self) -> np.ndarray:
        return np.bincount(self.owner[: self.capacity],
                           minlength=self.n_nodes)


# --------------------------------------------------------------------------
# §4.1 decision rule, vectorized — the single shared decision procedure.
# --------------------------------------------------------------------------

def decide_on_activate(active_after: np.ndarray, holder_mask: np.ndarray,
                       owners: np.ndarray, node: int, *,
                       relocation: bool, replication: bool
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Owner-side rule when ``node`` announces active intent for a batch of
    keys: exactly-one active node and no replicas -> relocate; concurrent
    active intent -> selective replica; relocation never happens while
    replicas exist (§B.2.4).  Returns (relocate_mask, replicate_mask) over
    the batch (owner's own keys must be excluded by the caller)."""
    bit = np.uint64(1 << node)
    others = (active_after & ~bit) != 0
    has_repl = holder_mask != 0
    reloc = relocation & ~has_repl & ~others
    repl = ~reloc & replication & (owners != node)
    return reloc, repl


def concurrent_intent(keys: np.ndarray, nodes: np.ndarray,
                      clocks: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Window classification for the planner: intent i says ``nodes[i]``
    accesses ``keys[i]`` at clock ``clocks[i]``.  Per clock tick, a key with
    intent from >= 2 nodes is *concurrent* (-> replicate, weighted by the
    node count, summed over ticks); single-node keys stay on the owner path
    (§4.1).  Returns (uniq_keys, replicate_weight, single_count)."""
    keys = np.asarray(keys, np.int64)
    nodes = np.asarray(nodes, np.int64)
    clocks = np.asarray(clocks, np.int64)
    uniq = np.unique(keys)
    if len(keys) == 0:
        z = np.zeros(0, np.int64)
        return uniq, z, z
    kidx = np.searchsorted(uniq, keys)
    # dedupe (clock, key, node), then count nodes per (clock, key)
    trip = (clocks * len(uniq) + kidx) * np.int64(nodes.max() + 1) + nodes
    _, first = np.unique(trip, return_index=True)
    pair = clocks[first] * len(uniq) + kidx[first]
    pairs, counts = np.unique(pair, return_counts=True)
    pair_key = (pairs % len(uniq)).astype(np.int64)
    multi = counts >= 2
    weight = np.bincount(pair_key[multi], weights=counts[multi],
                         minlength=len(uniq)).astype(np.int64)
    single = np.bincount(pair_key[~multi], minlength=len(uniq))
    return uniq, weight, single.astype(np.int64)


def intent_miss_bound(keys: np.ndarray, nodes: np.ndarray,
                      clocks: np.ndarray, cached: np.ndarray, *,
                      per_node: bool = True) -> int:
    """Exact worst cache-miss count over a window — the planner's static
    miss-buffer bound out of dynamic intent knowledge.

    ``per_node=True`` (simulator semantics) counts per (clock, node): each
    node serves its own misses.  ``per_node=False`` counts *unique* missed
    keys per clock across all nodes — the bound for a lookup that
    deduplicates misses over the whole step's batch (the SPMD managed
    embedding compacts one buffer per step, so a key missed by several
    shards occupies one slot)."""
    keys = np.asarray(keys, np.int64)
    if len(keys) == 0:
        return 0
    miss = ~np.isin(keys, cached)
    if not np.any(miss):
        return 0
    clocks = np.asarray(clocks, np.int64)
    if per_node:
        group = clocks * (np.int64(np.max(nodes)) + 1) \
            + np.asarray(nodes, np.int64)
        _, cnt = np.unique(group[miss], return_counts=True)
        return int(cnt.max())
    # unique (clock, key) pairs, then the worst per-clock unique count
    pair = clocks[miss] * (np.int64(np.max(keys)) + 1) + keys[miss]
    uniq_pair = np.unique(pair)
    _, cnt = np.unique(uniq_pair // (np.int64(np.max(keys)) + 1),
                       return_counts=True)
    return int(cnt.max())


class IntentEngine:
    """Full AdaPM state machine over structure-of-arrays state.

    Owns: per-node pending/announced intent windows, Algorithm-1 action
    timers, the ownership/location-cache table, replica holder bitmasks with
    versioned delta-sync bookkeeping, and the §4.1 owner decision rule.
    Charges traffic to the policy's `RoundLedger` and counts into its
    `Metrics` — the policy (`core.manager.AdaPM`) is a thin shell."""

    def __init__(self, n_nodes: int, cost: CostModel, ledger: RoundLedger,
                 metrics: Metrics, *, relocation: bool = True,
                 replication: bool = True, immediate: bool = False,
                 alpha: float = 0.1, p: float = 0.9999, lam0: float = 10.0,
                 trace_keys: Optional[Set[int]] = None):
        if n_nodes > 64:
            raise ValueError("bitmask engine supports at most 64 nodes")
        self.n_nodes = n_nodes
        self.cost = cost
        self.ledger = ledger
        self.metrics = metrics
        self.relocation = relocation
        self.replication = replication
        self.immediate = immediate
        self.owners = OwnerTable(n_nodes)
        self.timers = [ActionTimer(alpha=alpha, p=p, lam0=lam0)
                       for _ in range(n_nodes)]
        self.workers = [WorkerRegistry() for _ in range(n_nodes)]
        self.pending = [Windows() for _ in range(n_nodes)]
        self.announced = [Windows() for _ in range(n_nodes)]
        # per-key SoA management state
        self.capacity = 0
        self.active_mask = np.empty(0, np.uint64)   # nodes w/ active intent
        self.holder_mask = np.empty(0, np.uint64)   # replica holders
        self.dirty_mask = np.empty(0, np.uint64)    # wrote since last round
        self.version = np.empty(0, np.int64)        # replica delta version
        self.ann_count = np.empty((n_nodes, 0), np.int32)
        self.sync_version = np.empty((n_nodes, 0), np.int64)
        self.sync_time = np.empty((n_nodes, 0), np.float64)
        self._repl_keys: Set[int] = set()           # keys w/ replica state
        self.holder_count = np.zeros(n_nodes, np.int64)
        self.owned_extra = np.zeros(n_nodes, np.int64)
        self.n_keys_hint = 0
        self.trace_keys = trace_keys or set()
        self.trace: List[Tuple[float, int, int, str]] = []

    # ------------------------------------------------------------ capacity
    def ensure_capacity(self, n: int) -> None:
        if n <= self.capacity:
            return
        self.owners.ensure_capacity(n)
        cap = self.owners.capacity
        old = self.capacity

        def grow1(arr, fill, dtype):
            new = np.full(cap, fill, dtype)
            new[:old] = arr[:old]
            return new

        def grow2(arr, fill, dtype):
            new = np.full((self.n_nodes, cap), fill, dtype)
            new[:, :old] = arr[:, :old]
            return new

        self.active_mask = grow1(self.active_mask, 0, np.uint64)
        self.holder_mask = grow1(self.holder_mask, 0, np.uint64)
        self.dirty_mask = grow1(self.dirty_mask, 0, np.uint64)
        self.version = grow1(self.version, 0, np.int64)
        self.ann_count = grow2(self.ann_count, 0, np.int32)
        self.sync_version = grow2(self.sync_version, 0, np.int64)
        self.sync_time = grow2(self.sync_time, 0.0, np.float64)
        self.capacity = cap

    def _ensure_keys(self, keys: np.ndarray) -> None:
        if len(keys):
            self.ensure_capacity(int(keys.max()) + 1)

    # ------------------------------------------------------------ tracing
    def _trace_batch(self, now: float, keys: np.ndarray, nodes,
                     ev: str) -> None:
        if not self.trace_keys or len(keys) == 0:
            return
        nodes = np.broadcast_to(np.asarray(nodes), keys.shape)
        for k, n in zip(keys, nodes):
            if int(k) in self.trace_keys:
                self.trace.append((now, int(k), int(n), ev))

    # ---------------------------------------------------------- sim hooks
    def signal(self, node: int, keys, c_start: int, c_end: int,
               worker: int) -> None:
        keys = np.atleast_1d(np.asarray(keys, np.int64))
        self._ensure_keys(keys)
        self.pending[node].append(
            keys, c_start, c_end, self.workers[node].slot(worker))

    def advance_clock(self, node: int, worker: int, clock: int) -> None:
        self.workers[node].set_clock(worker, clock)

    # -------------------------------------------------------------- round
    def step(self, now: float) -> None:
        c = self.cost
        for node in range(self.n_nodes):
            reg = self.workers[node]
            timer = self.timers[node]
            nw = len(reg.ids)
            # Algorithm 1 lines 1-6: per-worker rate estimates (clocked
            # workers only, matching the seed's clocks-dict iteration).
            for s in range(nw):
                if reg.clocked[s]:
                    timer.observe_round(reg.ids[s], int(reg.clock[s]))
            # per-worker action thresholds (Alg. 1 soft upper bound)
            thr = np.full(max(1, nw), _INF_CLOCK, np.int64)
            if not self.immediate:
                for s in range(nw):
                    thr[s] = reg.clock[s] + timer.horizon(reg.ids[s])
                clocked = reg.clocked[:nw]
                if np.any(clocked):
                    scan_bound = int(thr[:nw][clocked].max())
                else:
                    scan_bound = timer.horizon(0)
                thr = np.minimum(thr, scan_bound)

            # pending scan: act / expire / keep (vectorized Alg. 1)
            pend = self.pending[node]
            pk, ps, pe, pw = pend.view()
            clk = reg.clock[pw]
            dead = pe <= clk
            act = ~dead & (ps < thr[pw])
            newly_k, newly_e = pk[act].copy(), pe[act].copy()
            newly_w = pw[act].copy()
            pend.keep(~(dead | act))

            # expirations of announced windows (§B.2.1 aggregated intent),
            # evaluated before this round's announcements merge — keys
            # re-announced in their expiry round lose that announcement
            # (seed behavior, pinned by the equivalence tests).
            ann = self.announced[node]
            ak, _as_, ae, aw = ann.view()
            exp = reg.clock[aw] >= ae
            counts = self.ann_count[node]
            if np.any(exp):
                np.subtract.at(counts, ak[exp], 1)
                exp_keys = np.unique(ak[exp])
                exp_keys = exp_keys[counts[exp_keys] == 0]
            else:
                exp_keys = np.empty(0, np.int64)
            ann.keep(~exp)

            # merge the newly announced windows; first announcements are
            # keys with no live window before this round
            first_keys = np.empty(0, np.int64)
            if len(newly_k):
                drop = np.isin(newly_k, exp_keys)
                keep_k, keep_e = newly_k[~drop], newly_e[~drop]
                u = np.unique(keep_k)
                first_keys = u[counts[u] == 0]
                ann.append(keep_k, 0, keep_e, newly_w[~drop])
                np.add.at(counts, keep_k, 1)

            # grouped signaling messages to owners + owner decisions
            dests: Set[int] = set()
            if len(first_keys):
                owners = self.owners.owners(first_keys)
                rem = first_keys[owners != node]
                if len(rem):
                    hops = self.owners.route_batch(node, rem)
                    self.ledger.charge(node, c.signal_bytes * int(hops.sum()))
                    dests.update(int(o) for o in np.unique(owners)
                                 if o != node)
                self._on_activate(first_keys, node, now)
            if len(exp_keys):
                owners_e = self.owners.owners(exp_keys)
                rem_e = exp_keys[owners_e != node]
                if len(rem_e):
                    hops = self.owners.route_batch(node, rem_e)
                    self.ledger.charge(node, c.signal_bytes * int(hops.sum()))
                    dests.update(int(o) for o in np.unique(owners_e)
                                 if o != node)
                self._on_expire(exp_keys, node, now)
            # one grouped request + response per peer (§B.2.2)
            self.ledger.charge(node, 0.0, nmsgs=2 * len(dests))

        self._sync_replicas(now)

    # ------------------------------------------------------ owner decisions
    def _on_activate(self, keys: np.ndarray, node: int, now: float) -> None:
        """§4.1 decision at the owner for a batch of first announcements."""
        bit = np.uint64(1 << node)
        self.active_mask[keys] |= bit
        own = self.owners.owners(keys) == node
        self._trace_batch(now, keys[own], node, "own-local")
        rest = keys[~own]
        if len(rest) == 0:
            return
        reloc, repl = decide_on_activate(
            self.active_mask[rest], self.holder_mask[rest],
            self.owners.owners(rest), node,
            relocation=self.relocation, replication=self.replication)
        if np.any(reloc):
            rk = rest[reloc]
            self._relocate(rk, np.full(len(rk), node, np.int64), now)
        if np.any(repl):
            self._create_replicas(rest[repl], node, now)

    def _on_expire(self, keys: np.ndarray, node: int, now: float) -> None:
        bit = np.uint64(1 << node)
        self.active_mask[keys] &= ~bit
        held = (self.holder_mask[keys] & bit) != 0
        if np.any(held):
            hk = keys[held]
            # destroy replicas exactly when intent expires (§4.1)
            self.holder_mask[hk] &= ~bit
            self.dirty_mask[hk] &= ~bit
            self.holder_count[node] -= len(hk)
            self._trace_batch(now, hk, node, "replica-destroy")
        if not self.relocation:
            return
        act = self.active_mask[keys]
        single = (act != 0) & ((act & (act - np.uint64(1))) == 0)
        if not np.any(single):
            return
        cand = keys[single]
        m = single_bit_index(act[single])
        owners = self.owners.owners(cand)
        hm = self.holder_mask[cand]
        only_m = hm == (np.uint64(1) << m.astype(np.uint64))
        go = (m != owners) & ((hm == 0) | only_m)
        if np.any(go):
            # single remaining active node -> relocate to it (Fig. 4d/11)
            self._relocate(cand[go], m[go], now)

    def _relocate(self, keys: np.ndarray, dsts: np.ndarray,
                  now: float) -> None:
        c = self.cost
        srcs = self.owners.owners(keys).astype(np.int64)
        dst_bit = np.uint64(1) << dsts.astype(np.uint64)
        dst_holds = (self.holder_mask[keys] & dst_bit) != 0
        if np.any(dst_holds):
            # dst already holds the value: ownership transfer + fresh delta
            self.holder_mask[keys[dst_holds]] &= ~dst_bit[dst_holds]
            np.subtract.at(self.holder_count, dsts[dst_holds], 1)
        nbytes = np.where(dst_holds, c.value_bytes, c.value_bytes + 64)
        np.add.at(self.ledger.bytes_out, srcs, nbytes.astype(np.float64))
        self.owners.relocate_batch(keys, dsts)
        np.subtract.at(self.owned_extra, srcs, 1)
        np.add.at(self.owned_extra, dsts, 1)
        self.metrics.n_relocations += len(keys)
        self._trace_batch(now, keys, dsts, "relocate-in")

    def _create_replicas(self, keys: np.ndarray, node: int,
                         now: float) -> None:
        c = self.cost
        bit = np.uint64(1 << node)
        fresh = (self.holder_mask[keys] & bit) == 0
        keys = keys[fresh]
        if len(keys) == 0:
            return
        self.holder_mask[keys] |= bit
        self.sync_version[node, keys] = self.version[keys]
        self.sync_time[node, keys] = now
        owners = self.owners.owners(keys).astype(np.int64)
        np.add.at(self.ledger.bytes_out, owners, float(c.value_bytes))
        self.holder_count[node] += len(keys)
        self.metrics.n_replica_creates += len(keys)
        self._repl_keys.update(int(k) for k in keys)
        self._trace_batch(now, keys, node, "replica-create")

    # --------------------------------------------------------- replica sync
    def _sync_replicas(self, now: float) -> None:
        """Versioned delta sync via the owner hub, batched (§B.1.2)."""
        c = self.cost
        if not self._repl_keys:
            self.metrics.rounds += 1
            return
        keys = np.fromiter(self._repl_keys, np.int64, len(self._repl_keys))
        hm = self.holder_mask[keys]
        gone = keys[hm == 0]
        if len(gone):
            # replica state dies with the last holder (seed: entry deleted)
            self.dirty_mask[gone] = 0
            self._repl_keys.difference_update(int(k) for k in gone)
        keys = keys[hm != 0]
        if len(keys) == 0:
            self.metrics.rounds += 1
            return
        hm = self.holder_mask[keys]
        dm = self.dirty_mask[keys]
        owners = self.owners.owners(keys).astype(np.int64)
        ver = self.version[keys]
        for n in range(self.n_nodes):
            bit = np.uint64(1 << n)
            # upstream: dirty non-owner holders push deltas to the owner
            n_dirty = int(np.count_nonzero(((dm & bit) != 0) & (owners != n)))
            if n_dirty:
                self.ledger.charge(n, n_dirty * c.value_bytes, nmsgs=0)
            # downstream: stale holders get the owner's fresh delta
            stale = ((hm & bit) != 0) & (self.sync_version[n, keys] < ver)
            if np.any(stale):
                sk = keys[stale]
                np.add.at(self.ledger.bytes_out, owners[stale],
                          float(c.value_bytes))
                self.sync_version[n, sk] = ver[stale]
                self.sync_time[n, sk] = now
        self.dirty_mask[keys] = 0
        self.metrics.rounds += 1

    # ----------------------------------------------------------- accesses
    def classify(self, node: int, keys: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """(owned, replicated-here) masks for a batch of keys."""
        self._ensure_keys(keys)
        own = self.owners.owners(keys) == node
        held = (self.holder_mask[keys] & np.uint64(1 << node)) != 0
        return own, held

    def replica_reads(self, node: int, keys: np.ndarray, times: np.ndarray,
                      write: bool) -> None:
        """Accounting for a batch of replica accesses at ``node``."""
        if len(keys) == 0:
            return
        if write:
            self.dirty_mask[keys] |= np.uint64(1 << node)
            self.version[keys] += 1
        stale = np.maximum(0.0, times - self.sync_time[node, keys])
        self.metrics.staleness_sum += float(stale.sum())
        self.metrics.n_replica_reads += len(keys)

    def remote_accesses(self, node: int, keys: np.ndarray) -> None:
        """Synchronous remote round trips (un-signaled accesses, §4)."""
        if len(keys) == 0:
            return
        hops = int(self.owners.route_batch(node, keys).sum())
        self.metrics.n_remote += len(keys)
        self.ledger.charge(node, 2 * self.cost.value_bytes * len(keys)
                           + 64 * hops, nmsgs=len(keys) + hops)

    # -------------------------------------------------------------- views
    def holders(self, key: int) -> Set[int]:
        if key >= self.capacity:
            return set()
        m = int(self.holder_mask[key])
        return {n for n in range(self.n_nodes) if m >> n & 1}

    def mem_bytes(self, node: int) -> float:
        base = self.n_keys_hint / self.n_nodes
        return (base + int(self.owned_extra[node])
                + int(self.holder_count[node])) * self.cost.value_bytes
