"""Discrete-event cluster simulator for parameter-management policies.

Models the paper's execution environment (§5.1): N nodes, W worker threads
per node, a data loader per worker that prepares batches ``signal_offset``
batches ahead (and signals intent when a batch is prepared), and background
communication rounds.  Time advances in rounds; a round's duration is the
max over nodes of its grouped sync traffic (bytes / bandwidth + per-message
overhead), floored at ``base_round``.  During a round every worker computes:
each key access costs ``t_local`` when the key is locally available (owned
or replicated at the node) and ``t_remote`` (a synchronous network stall)
otherwise; finishing a batch costs ``t_batch`` and advances the worker's
logical clock.

The simulator is the only omniscient party; policies only use node-local
information through the `PMPolicy` hooks.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence

import numpy as np

from .api import CostModel, Metrics, PMPolicy
from .intent import Intent


@dataclass
class Workload:
    """Pre-generated access streams.  ``streams[node][worker]`` is a list of
    batches; each batch is a 1-D int array of distinct keys accessed while
    training on that batch."""

    name: str
    n_keys: int
    streams: List[List[List[np.ndarray]]]

    @property
    def n_nodes(self) -> int:
        return len(self.streams)

    @property
    def workers_per_node(self) -> int:
        return len(self.streams[0])

    def key_frequencies(self) -> np.ndarray:
        freq = np.zeros(self.n_keys, dtype=np.int64)
        for node_streams in self.streams:
            for stream in node_streams:
                for batch in stream:
                    np.add.at(freq, batch, 1)
        return freq

    def hot_keys(self, frac: float) -> set:
        freq = self.key_frequencies()
        k = max(1, int(frac * self.n_keys))
        top = np.argpartition(freq, -k)[-k:]
        return set(int(x) for x in top if freq[x] > 0)


@dataclass
class SimConfig:
    signal_offset: int = 100       # batches the loader runs ahead
    intent_window: int = 1         # clocks an intent spans (one batch)
    max_rounds: int = 500_000
    track_mem_every: int = 64


@dataclass
class _WorkerState:
    batch_idx: int = 0
    key_idx: int = 0
    clock: int = 0
    carry: float = 0.0             # budget carried across round boundaries
    loader_next: int = 0           # next batch the loader will prepare


def _worker_gid(node: int, worker: int, wpn: int) -> int:
    return node * wpn + worker


def simulate(policy: PMPolicy, workload: Workload, cfg: SimConfig) -> Metrics:
    """Run one epoch of ``workload`` under ``policy``; returns its metrics."""
    cost = policy.cost
    n_nodes = workload.n_nodes
    wpn = workload.workers_per_node
    if hasattr(policy, "_n_keys_hint"):
        policy._n_keys_hint = workload.n_keys

    states: Dict[int, _WorkerState] = {}
    for node in range(n_nodes):
        for w in range(wpn):
            gid = _worker_gid(node, w, wpn)
            st = _WorkerState()
            states[gid] = st
            policy.advance_clock(node, gid, 0)

    def signal_up_to(node: int, w: int, now: float) -> None:
        """Loader keeps ``signal_offset`` batches prepared ahead."""
        gid = _worker_gid(node, w, wpn)
        st = states[gid]
        stream = workload.streams[node][w]
        limit = min(len(stream), st.batch_idx + cfg.signal_offset)
        while st.loader_next < limit:
            b = st.loader_next
            policy.signal_intent(
                node,
                Intent(keys=stream[b], c_start=b,
                       c_end=b + cfg.intent_window, worker_id=gid),
                now)
            st.loader_next += 1

    now = 0.0
    for node in range(n_nodes):
        for w in range(wpn):
            signal_up_to(node, w, now)

    metrics = policy.metrics
    unfinished = sum(len(workload.streams[n][w]) > 0
                     for n in range(n_nodes) for w in range(wpn))
    prev_dur = cost.base_round
    rounds = 0
    while unfinished > 0 and rounds < cfg.max_rounds:
        # collect last round's traffic (sync + ad-hoc remote accesses)
        metrics.total_bytes += float(np.sum(policy.ledger.bytes_out))
        policy.ledger.reset()
        policy.run_round(now, prev_dur)
        comm = max(
            float(policy.ledger.bytes_out[n]) / cost.bandwidth
            + int(policy.ledger.msgs[n]) * cost.per_msg
            for n in range(n_nodes))
        dur = max(cost.base_round, comm)
        # compute phase: every worker gets `dur` seconds; accesses are
        # accounted batch-at-a-time through `PMPolicy.access_batch`
        for node in range(n_nodes):
            for w in range(wpn):
                gid = _worker_gid(node, w, wpn)
                st = states[gid]
                stream = workload.streams[node][w]
                if st.batch_idx >= len(stream):
                    continue
                budget = dur + st.carry
                while budget > 0.0 and st.batch_idx < len(stream):
                    batch = stream[st.batch_idx]
                    if st.key_idx < len(batch):
                        n_done, budget = policy.access_batch(
                            node, gid, batch[st.key_idx:], now, dur, budget)
                        st.key_idx += n_done
                    if st.key_idx >= len(batch) and budget > 0.0:
                        budget -= cost.t_batch
                        st.key_idx = 0
                        st.batch_idx += 1
                        st.clock = st.batch_idx
                        policy.advance_clock(node, gid, st.clock)
                        signal_up_to(node, w, now + (dur - max(budget, 0.0)))
                        if st.batch_idx >= len(stream):
                            unfinished -= 1
                st.carry = min(budget, 0.0)
        now += dur
        prev_dur = dur
        rounds += 1
        if rounds % cfg.track_mem_every == 0:
            peak = max(policy.mem_bytes(n) for n in range(n_nodes))
            metrics.peak_mem_bytes = max(metrics.peak_mem_bytes, peak)
    metrics.total_bytes += float(np.sum(policy.ledger.bytes_out))
    metrics.epoch_time = now
    metrics.bytes_per_node = metrics.total_bytes / n_nodes
    return metrics


def single_node_epoch_time(workload: Workload, cost: CostModel) -> float:
    """Efficient shared-memory single-node baseline (§5.2): all accesses are
    local; the (same) global work is executed by the same number of worker
    threads on one node."""
    per_worker_times = []
    for node_streams in workload.streams:
        for stream in node_streams:
            t = sum(len(b) * cost.t_local + cost.t_batch for b in stream)
            per_worker_times.append(t)
    # workers run in parallel threads; epoch ends when the slowest finishes,
    # but on ONE node all streams run concurrently on that node's cores:
    # with the same total thread count as the cluster, time is the max of
    # per-thread times scaled by the node/cluster thread ratio.
    n_total = len(per_worker_times)
    wpn = len(workload.streams[0])
    scale = n_total / wpn  # one node has wpn threads, cluster has n_total
    return max(per_worker_times) * scale
