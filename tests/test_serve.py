"""Tests for the online serving runtime (DESIGN.md §9): streaming intent,
queue/scheduler, serving-mode lookups, drift adaptation, overflow
re-queueing, and the fused decode prefill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import StreamingIntentBuffer
from repro.kernels.pm_forward import probe_and_compact
from repro.pm.embedding import (make_state, plain_lookup,
                                plain_serve_lookup, planned_serve_lookup,
                                probe_host, serve_lookup)
from repro.pm.planner import IntentPlanner
from repro.serve import (DriftingZipfStream, ReplayStream, RequestQueue,
                         ServeConfig, ServeRequest, ServingRuntime)
from repro.serve.scheduler import LatencyRecorder, MicroBatchScheduler

V, D, C = 512, 16, 32


def setup_state(seed=0, cache_ids=None):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=jnp.float32)
    if cache_ids is None:
        cache_ids = np.sort(rng.choice(V, size=C, replace=False))
    cache_ids = jnp.asarray(cache_ids, dtype=jnp.int32)
    return make_state(table, cache_ids), rng


class TestStreamingIntent:
    def test_ingest_expire_snapshot(self):
        buf = StreamingIntentBuffer()
        buf.ingest(10, [1, 2, 3])
        buf.ingest(11, [2, 4])
        buf.ingest(12, [5])
        assert len(buf) == 6
        buf.expire([11])
        assert len(buf) == 4
        keys, slots, ticks = buf.snapshot(np.array([10, 12]), batch_size=2)
        # req 10 at position 0 (tick 0, slot 0); req 12 at position 1
        assert sorted(keys[ticks == 0].tolist()) == [1, 2, 3, 5]
        np.testing.assert_array_equal(slots[keys == 5], [1])

    def test_snapshot_ticks_follow_queue_position(self):
        buf = StreamingIntentBuffer()
        for rid in range(6):
            buf.ingest(rid, [100 + rid])
        keys, slots, ticks = buf.snapshot(np.arange(6), batch_size=2)
        order = np.argsort(keys)
        np.testing.assert_array_equal(ticks[order], [0, 0, 1, 1, 2, 2])
        np.testing.assert_array_equal(slots[order], [0, 1, 0, 1, 0, 1])

    def test_in_flight_requests_dropped_from_snapshot(self):
        buf = StreamingIntentBuffer()
        buf.ingest(0, [7])
        buf.ingest(1, [8])
        keys, _, _ = buf.snapshot(np.array([1]), batch_size=4)
        # req 0 was popped (in flight): only req 1's intent is planned
        np.testing.assert_array_equal(np.sort(keys), [8])

    def test_requeued_request_intent_still_live(self):
        q = RequestQueue(StreamingIntentBuffer())
        r = ServeRequest(0, np.array([3, 4]))
        q.enqueue(r, 0.0)
        popped = q.pop_batch(1)
        assert len(q.intent) == 2          # popped but not served
        q.requeue(popped)
        assert popped[0].attempts == 1
        q.served(popped)
        assert len(q.intent) == 0


class TestQueueScheduler:
    def test_fifo_and_requeue_front(self):
        q = RequestQueue()
        reqs = [ServeRequest(i, np.array([i])) for i in range(4)]
        q.enqueue_many(reqs, now=1.0)
        first = q.pop_batch(2)
        assert [r.rid for r in first] == [0, 1]
        q.requeue(first)
        assert q.order_ids().tolist() == [0, 1, 2, 3]

    def test_fixed_shape_batches_pad_with_known_keys(self):
        sched = MicroBatchScheduler(batch_requests=4, keys_per_request=3)
        q = RequestQueue()
        q.enqueue(ServeRequest(0, np.array([9, 8])), 0.0)
        q.enqueue(ServeRequest(1, np.array([7, 6, 5])), 0.0)
        batch = sched.admit(q)
        assert batch.tokens.shape == (4, 3)
        assert len(batch.reqs) == 2
        # short request rows pad with their own first key; empty request
        # slots clone row 0 — no key outside the signaled set appears
        np.testing.assert_array_equal(batch.tokens[0], [9, 8, 9])
        np.testing.assert_array_equal(batch.tokens[2], batch.tokens[0])

    def test_overlong_request_rejected_loudly(self):
        """Truncation would silently serve a partial request — the
        scheduler must refuse instead."""
        sched = MicroBatchScheduler(batch_requests=2, keys_per_request=3)
        q = RequestQueue()
        q.enqueue(ServeRequest(0, np.array([1, 2, 3, 4])), 0.0)
        with pytest.raises(ValueError, match="keys_per_request"):
            sched.admit(q)

    def test_latency_recorder_percentiles(self):
        rec = LatencyRecorder()
        rec.extend([0.001 * i for i in range(1, 101)])
        assert rec.percentile(50) == pytest.approx(0.0505, rel=1e-3)
        s = rec.summary_ms()
        assert s["count"] == 100
        assert s["p99_ms"] > s["p50_ms"]


class TestServeLookup:
    def test_matches_plain_when_capacity_fits(self):
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(4, 6)), jnp.int32)
        res = serve_lookup(state.table, state.cache_ids, state.cache_rows,
                           tokens, 32)
        exp = plain_lookup(state.table, tokens)
        assert not bool(res.overflow.any())
        np.testing.assert_allclose(np.asarray(res.out), np.asarray(exp),
                                   rtol=1e-6)

    def test_overflow_flagged_and_zeroed_never_silent(self):
        """The serving analogue of strict mode: misses beyond capacity come
        back as zeros WITH the overflow flag — the caller re-queues, the
        lookup never silently falls back to a dense gather."""
        state, rng = setup_state(cache_ids=np.arange(100, 100 + C))
        tokens = jnp.asarray([[3, 5, 7, 9]], jnp.int32)   # 4 unique misses
        res = serve_lookup(state.table, state.cache_ids, state.cache_rows,
                           tokens, 2)
        out = np.asarray(res.out)
        over = np.asarray(res.overflow)
        assert over.sum() == 2 and int(res.n_miss) == 4
        np.testing.assert_allclose(out[over], 0.0)
        exp = np.asarray(plain_lookup(state.table, tokens))
        np.testing.assert_allclose(out[~over], exp[~over], rtol=1e-6)

    def test_duplicates_share_one_slot(self):
        """Serving analogue of TestMissDedup: duplicate missed keys share
        one buffer slot, so capacity counts unique ids."""
        state, rng = setup_state(cache_ids=np.arange(100, 100 + C))
        tokens = jnp.asarray([[5, 5, 5, 7]], jnp.int32)
        res = serve_lookup(state.table, state.cache_ids, state.cache_rows,
                           tokens, 2)
        assert not bool(res.overflow.any())
        np.testing.assert_allclose(
            np.asarray(res.out), np.asarray(plain_lookup(state.table,
                                                         tokens)),
            rtol=1e-6)

    def test_shard_emulation_bitwise_neutral(self):
        """The emulated vocab-parallel collective is a cost model, not a
        semantics change: n_shards > 1 returns the exact same rows."""
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(3, 5)), jnp.int32)
        r1 = serve_lookup(state.table, state.cache_ids, state.cache_rows,
                          tokens, 16, n_shards=1)
        r4 = serve_lookup(state.table, state.cache_ids, state.cache_rows,
                          tokens, 16, n_shards=4)
        np.testing.assert_array_equal(np.asarray(r1.out),
                                      np.asarray(r4.out))
        p1 = plain_serve_lookup(state.table, tokens, n_shards=1)
        p4 = plain_serve_lookup(state.table, tokens, n_shards=4)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p4))

    def test_probe_host_matches_device_probe(self):
        """probe_host (admission-time numpy) is pinned to the device
        probe_and_compact on every output."""
        rng = np.random.default_rng(7)
        for _ in range(10):
            cache = np.sort(rng.choice(V, size=C, replace=False)) \
                .astype(np.int32)
            tok = rng.integers(0, V, size=37).astype(np.int32)
            M = int(rng.choice([1, 2, 8, 64]))
            hp = probe_host(cache, tok, M)
            pc = probe_and_compact(jnp.asarray(cache), jnp.asarray(tok), M)
            np.testing.assert_array_equal(hp.hit, np.asarray(pc.hit))
            np.testing.assert_array_equal(hp.cache_slot,
                                          np.asarray(pc.cache_slot))
            np.testing.assert_array_equal(hp.buf_ids,
                                          np.asarray(pc.buf_ids))
            np.testing.assert_array_equal(hp.buf_slot,
                                          np.asarray(pc.buf_slot))
            np.testing.assert_array_equal(hp.overflow,
                                          np.asarray(pc.overflow))
            assert hp.n_miss == int(pc.n_miss)

    def test_planned_lookup_matches_self_contained(self):
        state, rng = setup_state()
        tokens = rng.integers(0, V, size=(4, 6)).astype(np.int32)
        hp = probe_host(np.asarray(state.cache_ids), tokens.reshape(-1), 16)
        out = planned_serve_lookup(
            state.table, state.cache_rows, jnp.asarray(hp.buf_ids),
            jnp.asarray(hp.hit.astype(np.int32)),
            jnp.asarray(hp.cache_slot), jnp.asarray(hp.buf_slot))
        ref = serve_lookup(state.table, state.cache_ids, state.cache_rows,
                           jnp.asarray(tokens), 16)
        np.testing.assert_array_equal(
            np.asarray(out).reshape(4, 6, D), np.asarray(ref.out))


class TestReplanFromQueue:
    def test_concurrent_keys_cached_and_bound_exact(self):
        pl = IntentPlanner(vocab_size=1000, cache_capacity=4, n_shards=8)
        buf = StreamingIntentBuffer()
        # 8 queued requests, batch_size 4 -> 2 ticks; keys 1,2 wanted by
        # every request (concurrent), 50+i unique per request
        for i in range(8):
            buf.ingest(i, [1, 2, 50 + i])
        keys, slots, ticks = buf.snapshot(np.arange(8), batch_size=4)
        plan = pl.replan_from_queue(keys, slots, ticks)
        cached = set(int(i) for i in plan.cache_ids if i < 1000)
        assert {1, 2} <= cached
        # worst tick: 4 unique single-request keys miss (the 2 leftover
        # cache slots hold two of the 8 singles)
        assert plan.miss_capacity >= 2
        assert 0.0 < plan.predicted_miss_rate < 1.0

    def test_single_request_keys_fill_leftover_capacity(self):
        """Serving ranks leftover capacity by demand (the relocation arm
        lands on the serving node) — unlike the training plan."""
        pl = IntentPlanner(vocab_size=1000, cache_capacity=8, n_shards=4)
        buf = StreamingIntentBuffer()
        buf.ingest(0, [1, 1, 1])          # hot but single-request
        buf.ingest(1, [2])
        keys, slots, ticks = buf.snapshot(np.arange(2), batch_size=4)
        plan = pl.replan_from_queue(keys, slots, ticks)
        cached = set(int(i) for i in plan.cache_ids if i < 1000)
        assert {1, 2} <= cached


def _run_runtime(scenario="rotate", rounds=60, zipf_a=1.2, seed=5,
                 rotate_every=20, collect=False, **cfg_kw):
    rng = np.random.default_rng(0)
    table = rng.normal(size=(2048, 8)).astype(np.float32)
    kw = dict(vocab=2048, batch_requests=16, keys_per_request=8,
              cache_capacity=256, replan_every=6)
    kw.update(cfg_kw)
    cfg = ServeConfig(**kw)
    stream = DriftingZipfStream(2048, kw["keys_per_request"],
                                zipf_a=zipf_a,
                                arrival_rate=kw["batch_requests"],
                                scenario=scenario,
                                rotate_every=rotate_every, seed=seed)
    rt = ServingRuntime(table, cfg)
    res = rt.run(stream, rounds, collect_outputs=collect)
    return rt, stream, res, table


class TestDriftAdaptation:
    def test_miss_rate_recovers_within_one_replan_round(self):
        """Seeded rotating hot set: after each rotation reaches the
        scheduler, the first replan brings the miss rate back within 2x
        of the pre-rotation steady state."""
        rt, stream, res, _ = _run_runtime(rounds=64, rotate_every=20)
        assert res.zero_served == 0
        assert len(stream.rotation_rounds) >= 2
        trace = dict(res.miss_trace)
        checked = 0
        for rot in stream.rotation_rounds:
            if rot >= res.rounds - 4:
                continue
            pre = res.steady_miss_rate(rot - 6, rot)
            assert pre is not None, f"no batches before rotation at {rot}"
            replans = [r for r in res.replan_rounds if r >= rot]
            assert replans, f"no replan after rotation at {rot}"
            rr = replans[0]
            # within one replan round of the rotation hitting the
            # scheduler, served batches are back within 2x of steady
            post = [trace[r] for r in range(rr + 1, min(rr + 5,
                                                        res.rounds))
                    if r in trace]
            assert post, f"no served batches after replan {rr}"
            assert float(np.mean(post)) <= 2.0 * max(pre, 0.02), \
                f"rotation@{rot}: pre={pre:.3f} post={np.mean(post):.3f}"
            checked += 1
        assert checked >= 2

    def test_steady_state_no_requeues(self):
        _, _, res, _ = _run_runtime(scenario="steady", rounds=40)
        assert res.requeues == 0
        assert res.zero_served == 0
        assert res.served == 40 * 16

    def test_burst_and_flash_scenarios_serve_everything(self):
        for scenario in ("burst", "flash"):
            rt, stream, res, _ = _run_runtime(scenario=scenario, rounds=40)
            assert res.zero_served == 0
            # every admitted request is eventually served or still queued
            assert res.served + len(rt.queue) == stream._next_rid


class TestDoubleBufferedAdmission:
    """The one-slot admission pipeline (probe batch t+1 while the device
    executes batch t) is a pure wall-clock transform: identical serves,
    requeues, replans and rows as the serial loop."""

    def test_pipeline_semantics_identical_to_serial(self):
        rng = np.random.default_rng(0)
        table = rng.normal(size=(2048, 8)).astype(np.float32)
        live = DriftingZipfStream(2048, 8, zipf_a=1.2, arrival_rate=16,
                                  scenario="rotate", rotate_every=10,
                                  seed=5)
        replay = ReplayStream.record(live, 50)
        results = {}
        for db in (False, True):
            cfg = ServeConfig(vocab=2048, batch_requests=16,
                              keys_per_request=8, cache_capacity=256,
                              replan_every=6, double_buffer=db)
            rt = ServingRuntime(table, cfg)
            results[db] = rt.run(replay, rounds=30, collect_outputs=True)
        a, b = results[False], results[True]
        assert a.served == b.served
        assert a.requeues == b.requeues
        assert a.replans == b.replans
        assert a.replan_rounds == b.replan_rounds
        assert a.miss_trace == b.miss_trace
        assert b.zero_served == 0
        assert set(a.outputs) == set(b.outputs)
        for rid in a.outputs:
            np.testing.assert_array_equal(a.outputs[rid], b.outputs[rid])

    def test_pipeline_drains_on_idle_and_exit(self):
        """Batches in flight at an idle round or at loop exit are always
        finished — nothing admitted is ever dropped."""
        rng = np.random.default_rng(1)
        table = rng.normal(size=(512, 8)).astype(np.float32)
        cfg = ServeConfig(vocab=512, batch_requests=4, keys_per_request=4,
                          cache_capacity=64, replan_every=4,
                          double_buffer=True)

        class TrickleStream:
            """Arrivals only every third round: forces idle rounds with a
            batch still in flight."""

            def __init__(self):
                self.n = 0

            def arrivals(self, rnd):
                if rnd % 3:
                    return []
                out = [ServeRequest(self.n + i,
                                    np.arange(1 + i, 5 + i))
                       for i in range(4)]
                self.n += 4
                return out

        stream = TrickleStream()
        rt = ServingRuntime(table, cfg)
        res = rt.run(stream, rounds=18, warmup_backlog=1,
                     collect_outputs=True)
        assert res.zero_served == 0
        assert res.served + len(rt.queue) == stream.n
        for rid, rows in res.outputs.items():
            np.testing.assert_allclose(
                rows, table[np.arange(1 + rid % 4, 5 + rid % 4)],
                rtol=1e-6)


class TestOverflowRequeue:
    """Serving analogue of TestMissDedup: a request whose keys overflow
    the planned miss buffer is re-queued and served exactly later —
    never silently served zeros."""

    def test_surprise_cold_keys_requeue_then_serve_exact(self):
        rng = np.random.default_rng(0)
        table = rng.normal(size=(2048, 8)).astype(np.float32)
        # feedback-only replanning (replan_every=0) with the soft signal
        # off: ONLY an overflow can trigger a replan, so the surprise
        # wave must ride the requeue path
        cfg = ServeConfig(vocab=2048, batch_requests=8,
                          keys_per_request=16, cache_capacity=64,
                          replan_every=0, drift_factor=1e9)

        class SurpriseStream:
            """Steady hot-set arrivals, then one wave of 128 cold unique
            keys — far past the frozen plan's miss capacity."""

            def __init__(self):
                self.n = 0
                self.by_rid = {}

            def arrivals(self, rnd):
                if rnd == 4:
                    keys = [np.arange(1000 + 16 * i, 1016 + 16 * i)
                            for i in range(8)]
                else:
                    keys = [np.arange(1, 17) for _ in range(8)]
                out = []
                for k in keys:
                    req = ServeRequest(self.n, k)
                    self.by_rid[self.n] = k
                    self.n += 1
                    out.append(req)
                return out

        stream = SurpriseStream()
        rt = ServingRuntime(table, cfg)
        res = rt.run(stream, rounds=14, warmup_backlog=1,
                     collect_outputs=True)
        assert res.requeues > 0, "surprise wave should overflow the plan"
        assert res.overflow_batches > 0
        assert res.zero_served == 0
        # the overflow fed back into a replan that fit the cold keys
        assert res.replans >= 2
        # every surprise request was eventually served with exact rows
        surprise_rids = [rid for rid, k in stream.by_rid.items()
                         if k[0] >= 1000]
        served_surprise = [rid for rid in surprise_rids
                           if rid in res.outputs]
        assert served_surprise, "surprise requests never served"
        for rid in res.outputs:
            np.testing.assert_allclose(
                res.outputs[rid], table[stream.by_rid[rid]],
                rtol=1e-6)

    def test_collected_outputs_match_table_rows(self):
        """End-to-end exactness under rotation: every served request got
        exactly its table rows (the global never-serve-zeros check)."""
        rng = np.random.default_rng(0)
        table = rng.normal(size=(2048, 8)).astype(np.float32)
        cfg = ServeConfig(vocab=2048, batch_requests=16,
                          keys_per_request=8, cache_capacity=256,
                          replan_every=6)
        live = DriftingZipfStream(2048, 8, zipf_a=1.2, arrival_rate=16,
                                  scenario="rotate", rotate_every=10,
                                  seed=5)
        replay = ReplayStream.record(live, 50)
        rid_to_keys = {r.rid: r.keys for per in replay.per_round
                       for r in per}
        rt = ServingRuntime(table, cfg)
        res = rt.run(replay, rounds=30, collect_outputs=True)
        assert res.zero_served == 0
        assert res.served > 300
        for rid, rows in res.outputs.items():
            np.testing.assert_allclose(rows, table[rid_to_keys[rid]],
                                       rtol=1e-6)


class TestFusedPrefill:
    @pytest.mark.parametrize("arch", ["smollm-135m", "falcon-mamba-7b"])
    def test_fused_prefill_matches_token_loop(self, arch):
        from repro.configs.registry import get_config
        from repro.data.batches import make_batch
        from repro.models.model import init_cache, init_model
        from repro.train.steps import (make_prefill_decode_step,
                                       make_serve_step)
        cfg = get_config(arch, smoke=True)
        params = init_model(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        B, P = 2, 10
        batch = make_batch(cfg, B, P, rng)
        cache0 = init_cache(cfg, B, max_seq=P + 4)
        serve = jax.jit(make_serve_step(cfg))
        cache = dict(cache0)
        for t in range(P):
            logits_ref, cache = serve(params, cache,
                                      batch["tokens"][:, t:t + 1])
        prefill = jax.jit(make_prefill_decode_step(cfg))
        logits, cache_f = prefill(params, dict(cache0), batch["tokens"])
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(logits_ref),
                                   rtol=1e-4, atol=1e-4)
        assert int(cache_f["len"]) == int(cache["len"])
        # continuing decode from the fused cache matches the loop's cache
        tok = jnp.argmax(logits_ref, axis=-1)[:, None].astype(jnp.int32)
        l1, _ = serve(params, cache, tok)
        l2, _ = serve(params, cache_f, tok)
        np.testing.assert_allclose(np.asarray(l2), np.asarray(l1),
                                   rtol=1e-4, atol=1e-4)
