"""Per-kernel validation: Pallas (interpret mode on CPU) vs the pure-jnp
oracle in `repro.kernels.ref`, swept over shapes and dtypes, plus
hypothesis property tests for the duplicate-aggregation helper."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.adagrad_rows import adagrad_row_update
from repro.kernels.embed_gather import embed_gather
from repro.kernels.pm_forward import pm_combine, probe_and_compact
from repro.kernels.scatter_rows import scatter_rows

SHAPES = [
    # (V, D, n, block_d)
    (64, 128, 8, 128),
    (1024, 256, 32, 128),
    (512, 512, 64, 512),
    (256, 384, 16, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("V,D,n,block_d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_embed_gather_matches_ref(V, D, n, block_d, dtype):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=dtype)
    ids = jnp.asarray(rng.integers(0, V, size=(n,)), dtype=jnp.int32)
    out = embed_gather(table, ids, block_d=block_d, interpret=True)
    expected = ref.embed_gather_ref(table, ids)
    assert out.dtype == table.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))


@pytest.mark.parametrize("V,D,n,block_d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_adagrad_rows_matches_ref(V, D, n, block_d, dtype):
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=dtype)
    accum = jnp.asarray(rng.uniform(0.01, 1.0, size=(V, D)), dtype=dtype)
    ids = jnp.asarray(
        rng.choice(V, size=(n,), replace=False), dtype=jnp.int32)
    grads = jnp.asarray(rng.normal(size=(n, D)), dtype=jnp.float32)
    new_t, new_a = adagrad_row_update(table, accum, ids, grads,
                                      lr=0.05, eps=1e-8, block_d=block_d,
                                      interpret=True)
    exp_t, exp_a = ref.adagrad_row_update_ref(table, accum, ids, grads,
                                              lr=0.05, eps=1e-8)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(new_t, dtype=np.float32),
                               np.asarray(exp_t, dtype=np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(new_a, dtype=np.float32),
                               np.asarray(exp_a, dtype=np.float32),
                               rtol=tol, atol=tol)
    # untouched rows must be bit-identical (in-place aliasing semantics)
    mask = np.ones(V, dtype=bool)
    mask[np.asarray(ids)] = False
    np.testing.assert_array_equal(np.asarray(new_t)[mask],
                                  np.asarray(table)[mask])


def test_adagrad_accumulates_over_steps():
    """Two sequential updates shrink the effective step (AdaGrad)."""
    V, D = 32, 128
    table = jnp.ones((V, D), dtype=jnp.float32)
    accum = jnp.zeros((V, D), dtype=jnp.float32)
    ids = jnp.asarray([3], dtype=jnp.int32)
    g = jnp.ones((1, D), dtype=jnp.float32)
    t1, a1 = adagrad_row_update(table, accum, ids, g, lr=1.0, interpret=True)
    step1 = float(table[3, 0] - t1[3, 0])
    t2, a2 = adagrad_row_update(t1, a1, ids, g, lr=1.0, interpret=True)
    step2 = float(t1[3, 0] - t2[3, 0])
    assert step1 == pytest.approx(1.0, rel=1e-4)       # 1/sqrt(1)
    assert step2 == pytest.approx(1 / np.sqrt(2), rel=1e-4)
    assert step2 < step1


@given(
    n=st.integers(1, 64),
    v=st.integers(4, 128),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=30, deadline=None)
def test_segment_rows_property(n, v, seed):
    """segment_rows aggregates duplicates exactly (vs numpy oracle) and the
    downstream kernel update equals a dense scatter-add AdaGrad step."""
    D = 8
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, v, size=(n,)), dtype=jnp.int32)
    grads = jnp.asarray(rng.normal(size=(n, D)), dtype=jnp.float32)
    slot_ids, slot_g = ops.segment_rows(ids, grads, n_slots=n)
    # every original (id, grad) mass is preserved per id
    dense = np.zeros((v, D), dtype=np.float64)
    np.add.at(dense, np.asarray(ids), np.asarray(grads, dtype=np.float64))
    dense_from_slots = np.zeros((v, D), dtype=np.float64)
    np.add.at(dense_from_slots, np.asarray(slot_ids),
              np.asarray(slot_g, dtype=np.float64))
    np.testing.assert_allclose(dense, dense_from_slots, rtol=1e-5, atol=1e-5)


class TestProbeAndCompact:
    def test_dedup_unique_ids_fill_slots(self):
        cache = jnp.asarray([10, 20, 30], jnp.int32)
        tok = jnp.asarray([5, 20, 5, 7, 5, 10], jnp.int32)
        pc = probe_and_compact(cache, tok, 4)
        assert int(pc.n_miss) == 2                      # unique: {5, 7}
        np.testing.assert_array_equal(np.asarray(pc.hit),
                                      [False, True, False, False, False,
                                       True])
        in_buf = sorted(int(i) for i in np.asarray(pc.buf_ids)[:2])
        assert in_buf == [5, 7]
        # every duplicate of 5 shares one slot
        slots5 = np.asarray(pc.buf_slot)[[0, 2, 4]]
        assert len(set(slots5.tolist())) == 1
        assert not np.any(np.asarray(pc.overflow))

    def test_overflow_marks_unique_beyond_capacity(self):
        cache = jnp.asarray([100], jnp.int32)
        tok = jnp.asarray([1, 2, 3, 1], jnp.int32)
        pc = probe_and_compact(cache, tok, 2)
        assert int(pc.n_miss) == 3
        assert int(np.asarray(pc.overflow).sum()) >= 1
        # overflowed tokens route to the trash slot M
        over = np.asarray(pc.overflow)
        assert np.all(np.asarray(pc.buf_slot)[over] == 2)

    @given(seed=st.integers(0, 2**16), t=st.integers(1, 64),
           m=st.sampled_from([1, 4, 16]))
    @settings(max_examples=30, deadline=None)
    def test_property_slots_consistent(self, seed, t, m):
        """Every non-overflow miss points at a slot holding its own id."""
        rng = np.random.default_rng(seed)
        cache = jnp.asarray(np.sort(rng.choice(64, 8, replace=False)),
                            jnp.int32)
        tok = jnp.asarray(rng.integers(0, 64, size=(t,)), jnp.int32)
        pc = probe_and_compact(cache, tok, m)
        buf = np.concatenate([np.asarray(pc.buf_ids), [-1]])
        tok_np, hit = np.asarray(tok), np.asarray(pc.hit)
        served = ~hit & ~np.asarray(pc.overflow)
        np.testing.assert_array_equal(
            buf[np.asarray(pc.buf_slot)[served]], tok_np[served])
        assert int(pc.n_miss) == \
            np.setdiff1d(np.unique(tok_np), np.asarray(cache)).size


@pytest.mark.parametrize("dtype", DTYPES)
def test_pm_combine_matches_ref(dtype):
    rng = np.random.default_rng(3)
    C, M, T, D = 8, 4, 32, 256
    cache_rows = jnp.asarray(rng.normal(size=(C, D)), dtype=dtype)
    buf_rows = jnp.asarray(rng.normal(size=(M + 1, D)), dtype=dtype)
    hit = jnp.asarray(rng.integers(0, 2, size=(T,)).astype(bool))
    cache_slot = jnp.asarray(rng.integers(0, C, size=(T,)), jnp.int32)
    buf_slot = jnp.asarray(rng.integers(0, M + 1, size=(T,)), jnp.int32)
    out = pm_combine(hit, cache_slot, buf_slot, cache_rows, buf_rows,
                     block_d=128, interpret=True)
    exp = ref.pm_combine_ref(hit, cache_slot, buf_slot, cache_rows,
                             buf_rows)
    assert out.dtype == cache_rows.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("dtype", DTYPES)
def test_scatter_rows_matches_ref(dtype):
    rng = np.random.default_rng(4)
    R, n, D = 64, 16, 256
    base = jnp.zeros((R, D), dtype=dtype)
    ids = jnp.asarray(rng.choice(R, size=(n,), replace=False), jnp.int32)
    rows = jnp.asarray(rng.normal(size=(n, D)), dtype=dtype)
    out = scatter_rows(base, ids, rows, block_d=128, interpret=True)
    exp = ref.scatter_rows_ref(base, ids, rows)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    # untouched rows keep the aliased base content
    mask = np.ones(R, bool)
    mask[np.asarray(ids)] = False
    assert not np.any(np.asarray(out)[mask])


def test_scatter_rows_trash_collisions_safe():
    """Pad slots collide on a trash row with zero rows — the real rows
    must be untouched (managed-lookup backward pattern)."""
    R, D = 17, 128                      # rows 0..15 real, row 16 trash
    base = jnp.zeros((R, D), jnp.float32)
    ids = jnp.asarray([3, 9, 16, 16, 16], jnp.int32)
    rows = jnp.concatenate([jnp.ones((2, D)), jnp.zeros((3, D))])
    out = np.asarray(scatter_rows(base, ids, rows, block_d=128,
                                  interpret=True))
    assert np.all(out[3] == 1.0) and np.all(out[9] == 1.0)
    assert not np.any(out[16])


def test_segment_rows_pad_id_sentinel():
    ids = jnp.asarray([7, 7, 3], jnp.int32)
    grads = jnp.ones((3, 4), jnp.float32)
    slot_ids, slot_g = ops.segment_rows(ids, grads, n_slots=5, pad_id=99)
    np.testing.assert_array_equal(np.asarray(slot_ids), [3, 7, 99, 99, 99])
    np.testing.assert_allclose(np.asarray(slot_g)[:2].sum(axis=1),
                               [4.0, 8.0])
    assert not np.any(np.asarray(slot_g)[2:])


def test_ops_fallback_matches_pallas():
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(128, 256)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(0, 128, size=(16,)), dtype=jnp.int32)
    a = ops.embed_gather(table, ids, use_pallas=True)
    b = ops.embed_gather(table, ids, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
