"""Per-kernel validation: Pallas (interpret mode on CPU) vs the pure-jnp
oracle in `repro.kernels.ref`, swept over shapes and dtypes, plus
hypothesis property tests for the duplicate-aggregation helper."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.adagrad_rows import adagrad_row_update
from repro.kernels.embed_gather import embed_gather

SHAPES = [
    # (V, D, n, block_d)
    (64, 128, 8, 128),
    (1024, 256, 32, 128),
    (512, 512, 64, 512),
    (256, 384, 16, 128),
]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("V,D,n,block_d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_embed_gather_matches_ref(V, D, n, block_d, dtype):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=dtype)
    ids = jnp.asarray(rng.integers(0, V, size=(n,)), dtype=jnp.int32)
    out = embed_gather(table, ids, block_d=block_d, interpret=True)
    expected = ref.embed_gather_ref(table, ids)
    assert out.dtype == table.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))


@pytest.mark.parametrize("V,D,n,block_d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_adagrad_rows_matches_ref(V, D, n, block_d, dtype):
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=dtype)
    accum = jnp.asarray(rng.uniform(0.01, 1.0, size=(V, D)), dtype=dtype)
    ids = jnp.asarray(
        rng.choice(V, size=(n,), replace=False), dtype=jnp.int32)
    grads = jnp.asarray(rng.normal(size=(n, D)), dtype=jnp.float32)
    new_t, new_a = adagrad_row_update(table, accum, ids, grads,
                                      lr=0.05, eps=1e-8, block_d=block_d,
                                      interpret=True)
    exp_t, exp_a = ref.adagrad_row_update_ref(table, accum, ids, grads,
                                              lr=0.05, eps=1e-8)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(new_t, dtype=np.float32),
                               np.asarray(exp_t, dtype=np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(new_a, dtype=np.float32),
                               np.asarray(exp_a, dtype=np.float32),
                               rtol=tol, atol=tol)
    # untouched rows must be bit-identical (in-place aliasing semantics)
    mask = np.ones(V, dtype=bool)
    mask[np.asarray(ids)] = False
    np.testing.assert_array_equal(np.asarray(new_t)[mask],
                                  np.asarray(table)[mask])


def test_adagrad_accumulates_over_steps():
    """Two sequential updates shrink the effective step (AdaGrad)."""
    V, D = 32, 128
    table = jnp.ones((V, D), dtype=jnp.float32)
    accum = jnp.zeros((V, D), dtype=jnp.float32)
    ids = jnp.asarray([3], dtype=jnp.int32)
    g = jnp.ones((1, D), dtype=jnp.float32)
    t1, a1 = adagrad_row_update(table, accum, ids, g, lr=1.0, interpret=True)
    step1 = float(table[3, 0] - t1[3, 0])
    t2, a2 = adagrad_row_update(t1, a1, ids, g, lr=1.0, interpret=True)
    step2 = float(t1[3, 0] - t2[3, 0])
    assert step1 == pytest.approx(1.0, rel=1e-4)       # 1/sqrt(1)
    assert step2 == pytest.approx(1 / np.sqrt(2), rel=1e-4)
    assert step2 < step1


@given(
    n=st.integers(1, 64),
    v=st.integers(4, 128),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=30, deadline=None)
def test_segment_rows_property(n, v, seed):
    """segment_rows aggregates duplicates exactly (vs numpy oracle) and the
    downstream kernel update equals a dense scatter-add AdaGrad step."""
    D = 8
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, v, size=(n,)), dtype=jnp.int32)
    grads = jnp.asarray(rng.normal(size=(n, D)), dtype=jnp.float32)
    slot_ids, slot_g = ops.segment_rows(ids, grads, n_slots=n)
    # every original (id, grad) mass is preserved per id
    dense = np.zeros((v, D), dtype=np.float64)
    np.add.at(dense, np.asarray(ids), np.asarray(grads, dtype=np.float64))
    dense_from_slots = np.zeros((v, D), dtype=np.float64)
    np.add.at(dense_from_slots, np.asarray(slot_ids),
              np.asarray(slot_g, dtype=np.float64))
    np.testing.assert_allclose(dense, dense_from_slots, rtol=1e-5, atol=1e-5)


def test_ops_fallback_matches_pallas():
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(128, 256)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(0, 128, size=(16,)), dtype=jnp.int32)
    a = ops.embed_gather(table, ids, use_pallas=True)
    b = ops.embed_gather(table, ids, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
