"""Tests for the observability layer (DESIGN.md §14): ring-buffered span
tracer, plan-vs-actual attribution, Prometheus/JSONL export, the report
CLI, per-tenant serve accounting, and shared-bus threading."""

import json

import numpy as np
import pytest

from repro.obs import (ATTRIBUTION_SCHEMA, SCHEMA_VERSION, JsonlSink,
                       PlanAttribution, Reservoir, SpanTracer, Telemetry,
                       make_tracer, prometheus_text, read_jsonl)
from repro.obs.report import main as report_main
from repro.obs.report import render_report, validate_chrome
from repro.serve import (DriftingZipfStream, RequestQueue, ServeConfig,
                         ServeRequest, ServingRuntime)
from repro.serve.scheduler import MicroBatchScheduler


class TestSpanTracer:
    def test_span_nesting_and_ordering(self):
        tr = SpanTracer()
        with tr.span("outer", a=1):
            with tr.span("inner", a=2):
                pass
        evs = tr.events()
        # inner closes (and records) first; both held oldest-first
        assert [e["name"] for e in evs] == ["inner", "outer"]
        inner, outer = evs
        assert outer["t0_ns"] <= inner["t0_ns"]
        assert inner["t1_ns"] <= outer["t1_ns"]
        assert (inner["a"], outer["a"]) == (2, 1)

    def test_ring_eviction_under_overflow(self):
        tr = SpanTracer(capacity=8)
        for i in range(20):
            t = tr.now_ns()
            tr.record("s", t, t + 1, a=i)
        assert tr.count == 20
        assert tr.dropped == 12
        evs = tr.events()
        assert len(evs) == 8
        # oldest held span first: 12..19 survive, 0..11 were evicted
        assert [e["a"] for e in evs] == list(range(12, 20))

    def test_disabled_tracer_emits_nothing(self):
        tr = SpanTracer(enabled=False)
        # one shared no-op context manager: no per-call allocation
        assert tr.span("x") is tr.span("y")
        with tr.span("x", a=1):
            pass
        tr.record("y", 0, 5)
        tr.point("z")
        assert tr.count == 0
        assert tr.events() == []
        assert tr.to_chrome()["traceEvents"] == []

    def test_sampling_is_deterministic_per_id(self):
        tr = SpanTracer(sample=0.5)
        first = [tr.sampled(i) for i in range(1000)]
        assert first == [tr.sampled(i) for i in range(1000)]
        frac = sum(first) / 1000.0
        assert 0.3 < frac < 0.7
        assert all(SpanTracer(sample=1.0).sampled(i) for i in range(50))
        assert not any(SpanTracer(sample=0.0).sampled(i) for i in range(50))

    def test_chrome_export_is_valid_trace_event_json(self):
        tr = SpanTracer()
        with tr.span("serve.dispatch", tid=3, a=7, b=9):
            pass
        tr.point("serve.requeue", a=4)
        doc = tr.to_chrome()
        events = validate_chrome(doc)          # raises on missing fields
        json.dumps(doc)
        by_name = {e["name"]: e for e in events}
        x = by_name["serve.dispatch"]
        assert x["ph"] == "X" and x["dur"] > 0 and x["tid"] == 3
        assert x["args"] == {"a": 7, "b": 9}
        inst = by_name["serve.requeue"]
        assert inst["ph"] == "i" and inst["s"] == "t"
        assert [e["ts"] for e in events] == sorted(e["ts"] for e in events)
        assert doc["otherData"]["spans_recorded"] == 2

    def test_make_tracer_injected_instance_wins(self):
        mine = SpanTracer(sample=0.25)
        assert make_tracer(False, tracer=mine) is mine
        assert not make_tracer(False).enabled
        assert make_tracer(True, sample=0.5).sample == 0.5


class TestAttribution:
    def test_hand_computed_record(self):
        """V=4 over 2 owner shards (block=2): tokens [0,1,2,2,3] with
        hits [T,T,F,F,F] miss 3/5 accesses, all on owner shard 1."""
        bus = Telemetry()
        at = PlanAttribution(owner_shards=2, vocab=4, telemetry=bus)
        at.note_batch(np.array([0, 1, 2, 2, 3]),
                      np.array([True, True, False, False, False]))
        rec = at.flush(rnd=5, plan=None, cause="drift",
                       knobs={"cache_capacity": 64}, capacity=64,
                       miss_capacity=16)
        assert rec.plan_version == 0           # no plan yet
        assert rec.predicted_miss_rate == 0.0
        assert rec.realized_miss_rate == pytest.approx(3 / 5)
        assert rec.miss_rate_error == pytest.approx(3 / 5)
        assert rec.per_owner_misses == {1: 3}
        assert rec.top_keys == [(2, 2), (3, 1)]
        assert (rec.batches, rec.tokens, rec.misses) == (1, 5, 3)
        j = rec.to_json()
        assert j["schema"] == ATTRIBUTION_SCHEMA
        json.dumps(j)
        assert bus.events("attr.replan")[0]["realized"] == \
            pytest.approx(3 / 5)

    def test_flush_resets_and_windows_decisions(self):
        bus = Telemetry()
        at = PlanAttribution(telemetry=bus)
        bus.event("ctl.force", knob="cache_capacity", value=128,
                  cause="demand", target=100)
        bus.event("serve.replan", round=1)     # not a decision: excluded
        at.note_batch(np.array([7]), np.array([False]))
        r1 = at.flush(rnd=1, plan=None, cause="cadence", knobs={},
                      capacity=64)
        assert [d["_name"] for d in r1.decisions] == ["ctl.force"]
        # the window advanced and the accumulators reset
        r2 = at.flush(rnd=2, plan=None, cause="cadence", knobs={},
                      capacity=64)
        assert r2.decisions == []
        assert r2.realized_miss_rate is None   # no batch in tenure 2
        assert r2.miss_rate_error is None
        assert len(at.records) == 2

    def test_no_owner_accounting_without_shards(self):
        at = PlanAttribution()                 # owner_shards=0
        at.note_batch(np.array([1, 2]), np.array([False, False]))
        rec = at.flush(rnd=0, plan=None, cause="x", knobs={}, capacity=8)
        assert rec.per_owner_misses == {}
        assert rec.misses == 2


class TestExportSurfaces:
    def test_reservoir_empty_is_well_defined(self):
        r = Reservoir()
        assert r.stats() == {"count": 0, "mean": 0.0, "p50": 0.0,
                             "p99": 0.0}
        assert r.percentile(99) == 0.0
        assert r.mean() == 0.0

    def test_snapshot_strictly_json_dumpable(self):
        bus = Telemetry()
        bus.inc("serve.requests", tenant="tenant münchen, a=b")
        bus.set("gauge.nan", float("nan"))
        bus.observe("lat", np.float64(1.5), shard=np.int64(3))
        bus.event("ev", arr=np.arange(3), flag=np.bool_(True))
        snap = bus.snapshot()
        json.dumps(snap)                       # must not raise
        assert snap["gauges"]["gauge.nan"] is None

    def test_prometheus_one_type_line_per_family(self):
        bus = Telemetry()
        bus.inc("serve.requests", tenant="a b")
        bus.inc("serve.requests", tenant="c\"d")
        bus.set("serve.miss_rate", 0.25)
        bus.observe("serve.latency", 2.0)
        bus.observe("serve.latency", 4.0)
        text = prometheus_text(bus)
        lines = text.strip().split("\n")
        assert lines.count("# TYPE serve_requests counter") == 1
        # one TYPE for the whole summary family — _count/_sum samples
        # must not get their own
        assert sum(1 for ln in lines if ln.startswith("# TYPE "
                                                      "serve_latency")) == 1
        assert 'serve_requests{tenant="a b"} 1.0' in lines
        assert 'serve_requests{tenant="c\\"d"} 1.0' in lines
        assert 'serve_latency{quantile="0.99"}' in text
        assert any(ln.startswith("serve_latency_count") for ln in lines)
        # snapshot-dict fallback renders too (best-effort labels)
        assert "serve_miss_rate 0.25" in prometheus_text(bus.snapshot())

    def test_jsonl_sink_roundtrip(self, tmp_path):
        bus = Telemetry()
        bus.inc("serve.requests", tenant="default")
        bus.event("ctl.force", knob="k", value=8, cause="demand")
        at = PlanAttribution(telemetry=bus)
        at.note_batch(np.array([3]), np.array([False]))
        at.flush(rnd=0, plan=None, cause="drift", knobs={}, capacity=4)
        path = str(tmp_path / "metrics.jsonl")
        with JsonlSink(path, flush_every=2) as sink:
            sink.write_bus(bus, label="test")
            sink.write_attribution(at.records)
        records = read_jsonl(path)
        kinds = [r["kind"] for r in records]
        assert kinds.count("snapshot") == 1
        assert kinds.count("attribution") == 1
        assert "event" in kinds
        snap = records[0]
        assert snap["schema"] == SCHEMA_VERSION
        attr = [r for r in records if r["kind"] == "attribution"][0]
        assert attr["schema"] == ATTRIBUTION_SCHEMA
        assert attr["realized_miss_rate"] == 1.0
        ev = [r for r in records if r["kind"] == "event"][0]
        assert ev["name"] == "ctl.force" and "event_seq" in ev

    def test_read_jsonl_rejects_corrupt_lines(self, tmp_path):
        p = tmp_path / "bad.jsonl"
        p.write_text('{"ok": 1}\nnot json\n')
        with pytest.raises(ValueError, match="bad.jsonl:2"):
            read_jsonl(str(p))


class TestTenantAccounting:
    def test_per_tenant_counters_and_latency(self):
        bus = Telemetry()
        sched = MicroBatchScheduler(batch_requests=4, keys_per_request=2,
                                    telemetry=bus)
        q = RequestQueue()
        q.enqueue(ServeRequest(0, np.array([1]), tenant="alpha"), now=0.0)
        q.enqueue(ServeRequest(1, np.array([2]), tenant="alpha"), now=0.0)
        q.enqueue(ServeRequest(2, np.array([3])), now=0.0)   # default
        batch = sched.admit(q)
        sched.note_served(batch.reqs, now=0.5)
        assert bus.counter_value("serve.requests", tenant="alpha") == 2
        assert bus.counter_value("serve.requests", tenant="default") == 1
        assert bus.latency("serve.latency", tenant="alpha").count == 2
        json.dumps(bus.snapshot())


def _traced_run(rounds=28, **cfg_kw):
    rng = np.random.default_rng(0)
    table = rng.normal(size=(2048, 8)).astype(np.float32)
    kw = dict(vocab=2048, batch_requests=16, keys_per_request=8,
              cache_capacity=256, replan_every=6, trace=True)
    kw.update(cfg_kw)
    cfg = ServeConfig(**kw)
    stream = DriftingZipfStream(2048, kw["keys_per_request"],
                                zipf_a=1.2,
                                arrival_rate=kw["batch_requests"],
                                scenario="rotate", rotate_every=10,
                                seed=5)
    rt = ServingRuntime(table, cfg)
    res = rt.run(stream, rounds)
    return rt, res


class TestTracedServe:
    def test_one_attribution_record_per_replan(self):
        rt, res = _traced_run()
        assert rt.attribution is not None
        assert len(rt.attribution.records) == res.replans >= 2
        # every measured tenure's realized rate is a proper rate
        for rec in rt.attribution.records:
            if rec.realized_miss_rate is not None:
                assert 0.0 <= rec.realized_miss_rate <= 1.0

    def test_request_spans_cover_every_served_request(self):
        rt, res = _traced_run()
        doc = rt.tracer.to_chrome()
        events = validate_chrome(doc)
        req_spans = [e for e in events if e["name"] == "serve.request"]
        assert len(req_spans) == rt.scheduler.n_served > 0
        rids = sorted(e["args"]["a"] for e in req_spans)
        assert rids == sorted(set(rids))       # each request exactly once
        phases = {e["name"] for e in events}
        assert {"serve.round", "serve.enqueue", "serve.plan",
                "serve.probe", "serve.dispatch"} <= phases
        assert rt.report().startswith("===")

    def test_untraced_runtime_records_nothing(self):
        rt, _ = _traced_run(rounds=8, trace=False)
        assert rt.attribution is None
        assert rt.tracer.count == 0


class TestSharedBusThreading:
    def test_train_loop_shares_one_bus_and_traces_phases(self):
        from repro.configs.registry import get_config
        from repro.train.loop import LoopConfig, train_loop

        bus = Telemetry()
        tr = SpanTracer()
        cfg = get_config("smollm-135m", smoke=True)
        train_loop(cfg, LoopConfig(steps=6, batch=2, seq=16, pm=True,
                                   cache_capacity=64, n_shards=2,
                                   log_every=0, seed=3),
                   telemetry=bus, tracer=tr)
        # the planner published onto the SAME bus the loop was handed
        assert bus.events("plan.built")
        assert bus.gauge_value("plan.version") >= 1
        names = {e["name"] for e in tr.to_chrome()["traceEvents"]}
        assert {"train.signal", "train.plan", "train.refresh",
                "train.step"} <= names


class TestReportCLI:
    def test_render_sections(self):
        tr = SpanTracer()
        t = tr.now_ns()
        tr.record("serve.request", t, t + 2_000_000, a=0, b=1)
        tr.record("serve.plan", t, t + 500_000)
        recs = [{"kind": "attribution", "round": 3, "plan_version": 1,
                 "cause": "drift", "batches": 2, "tokens": 10,
                 "misses": 1, "predicted_miss_rate": 0.08,
                 "realized_miss_rate": 0.1,
                 "per_owner_misses": {"1": 1}, "top_keys": [[7, 1]],
                 "decisions": [{"_seq": 4, "_name": "ctl.force",
                                "knob": "k", "value": 8}]},
                {"kind": "event", "name": "ctl.trial", "event_seq": 9,
                 "fields": {"knob": "replan_every", "accepted": True}},
                {"kind": "snapshot", "counters": {"serve.requests": 5},
                 "latencies": {}}]
        text = render_report(tr.to_chrome()["traceEvents"], recs)
        assert "requests traced: 1" in text
        assert "miss attribution" in text and "0.1000" in text
        assert "shard1:1" in text
        assert "ctl.force" in text and "ctl.trial" in text
        assert "serve.requests=5" in text

    def test_cli_on_real_artifacts(self, tmp_path, capsys):
        rt, _ = _traced_run(rounds=16)
        trace = str(tmp_path / "trace.json")
        metrics = str(tmp_path / "metrics.jsonl")
        rt.tracer.dump(trace)
        with JsonlSink(metrics) as sink:
            sink.write_bus(rt.telemetry, label="test run")
            sink.write_attribution(rt.attribution.records)
        assert report_main([trace, metrics]) == 0
        out = capsys.readouterr().out
        assert "request latency (trace)" in out
        assert "miss attribution" in out

    def test_empty_inputs_still_render(self):
        assert "no spans or records" in render_report(None, None)
