"""Tests for the JAX PM layer: intent-managed embedding + host planner."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.pm.embedding import (EmbedPMState, make_state, pm_lookup,
                                plain_lookup, refresh_cache)
from repro.pm.planner import IntentPlanner, PlacementPlan

V, D, C = 256, 32, 16


def setup_state(seed=0, cache_ids=None):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=jnp.float32)
    if cache_ids is None:
        cache_ids = np.sort(rng.choice(V, size=C, replace=False))
    cache_ids = jnp.asarray(cache_ids, dtype=jnp.int32)
    return make_state(table, cache_ids), rng


class TestPMLookup:
    def test_matches_plain_lookup_fresh_cache(self):
        """With a synchronized cache, managed == unmanaged, for any mix of
        hits and misses."""
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(4, 8)), jnp.int32)
        out = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, 64)
        exp = plain_lookup(state.table, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-6)

    def test_overflow_fallback_correct(self):
        """Misses beyond the planned capacity must still read exact rows."""
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(4, 16)), jnp.int32)
        out = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, 2)   # absurdly small miss buffer
        exp = plain_lookup(state.table, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-6)

    def test_cache_hit_uses_cache_value(self):
        """Stale replicas serve reads (bounded staleness, §B.1.2): if the
        cache holds a different value, hits return it."""
        state, rng = setup_state()
        poisoned = state.cache_rows.at[:].set(7.0)
        hit_id = int(state.cache_ids[0])
        tokens = jnp.full((1, 4), hit_id, dtype=jnp.int32)
        out = pm_lookup(state.table, state.cache_ids, poisoned, tokens, 8)
        np.testing.assert_allclose(np.asarray(out), 7.0)

    def test_gradients_flow_to_table_only(self):
        """Replica write-back: all row grads reach the owner table; the
        cache gets none (it is re-gathered, not trained)."""
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(2, 6)), jnp.int32)

        def loss(table, rows):
            out = pm_lookup(table, state.cache_ids, rows, tokens, 16)
            return jnp.sum(out ** 2)

        gt, gr = jax.grad(loss, argnums=(0, 1))(state.table,
                                                state.cache_rows)
        # equivalent plain-embedding gradient
        gt_ref = jax.grad(
            lambda t: jnp.sum(plain_lookup(t, tokens) ** 2))(state.table)
        np.testing.assert_allclose(np.asarray(gt), np.asarray(gt_ref),
                                   rtol=1e-5)
        assert float(jnp.max(jnp.abs(gr))) == 0.0

    def test_refresh_restores_equivalence(self):
        """After a table update, one refresh round resynchronizes replicas
        (staleness bounded by one round)."""
        state, rng = setup_state()
        new_table = state.table * 2.0
        stale = EmbedPMState(new_table, state.cache_ids, state.cache_rows)
        fresh = refresh_cache(stale)
        hit_id = int(state.cache_ids[3])
        tokens = jnp.full((1, 1), hit_id, dtype=jnp.int32)
        out = pm_lookup(fresh.table, fresh.cache_ids, fresh.cache_rows,
                        tokens, 4)
        np.testing.assert_allclose(
            np.asarray(out)[0, 0], np.asarray(new_table[hit_id]), rtol=1e-6)

    @given(seed=st.integers(0, 2**16), b=st.integers(1, 4),
           s=st.integers(1, 32), m=st.sampled_from([1, 4, 16, 128]))
    @settings(max_examples=40, deadline=None)
    def test_property_exactness_any_capacity(self, seed, b, s, m):
        """pm_lookup == plain lookup for every (batch, seq, capacity)."""
        state, rng = setup_state(seed)
        tokens = jnp.asarray(rng.integers(0, V, size=(b, s)), jnp.int32)
        out = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, m)
        exp = plain_lookup(state.table, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-6)


class TestMissDedup:
    """ISSUE 2 regression: duplicate missed tokens must share one miss
    slot — `intent_miss_bound` counts unique ids, so per-duplicate slots
    silently overflowed the "exact" bound and strict lookups read zeros."""

    def test_strict_duplicates_within_unique_capacity(self):
        """4 missed tokens, 2 unique, capacity 2: every read exact under
        strict=True (pre-fix: the 3rd duplicate and token 7 read zeros)."""
        state, rng = setup_state(cache_ids=np.arange(100, 100 + C))
        tokens = jnp.asarray([[5, 5, 5, 7]], jnp.int32)   # all misses
        out = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, 2, True)
        exp = plain_lookup(state.table, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-6)

    def test_strict_unique_overflow_still_zeros(self):
        """strict=True truly overflowed (unique misses > M) keeps the
        documented no-fallback semantics: overflow slots read zeros."""
        state, rng = setup_state(cache_ids=np.arange(100, 100 + C))
        tokens = jnp.asarray([[3, 5, 7, 9]], jnp.int32)   # 4 unique misses
        out = np.asarray(pm_lookup(state.table, state.cache_ids,
                                   state.cache_rows, tokens, 2, True))
        exp = np.asarray(plain_lookup(state.table, tokens))
        # two unique ids fit; the overflowed remainder reads zeros
        fit = [np.allclose(out[0, i], exp[0, i]) for i in range(4)]
        assert sum(fit) == 2
        assert np.count_nonzero(out) == 2 * D

    def test_nonstrict_unique_overflow_falls_back(self):
        state, rng = setup_state(cache_ids=np.arange(100, 100 + C))
        tokens = jnp.asarray([[3, 5, 7, 9, 3, 5]], jnp.int32)
        out = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, 2, False)
        exp = plain_lookup(state.table, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-6)

    @given(seed=st.integers(0, 2**16), b=st.integers(1, 4),
           s=st.integers(1, 16))
    @settings(max_examples=25, deadline=None)
    def test_strict_exact_when_unique_misses_fit(self, seed, b, s):
        """Property: whenever unique misses <= M, strict == plain even
        with arbitrary duplication (the planner bound is exact again)."""
        state, rng = setup_state(seed)
        tokens = jnp.asarray(rng.integers(0, V, size=(b, s)), jnp.int32)
        uniq = np.unique(np.asarray(tokens))
        n_miss = np.setdiff1d(uniq, np.asarray(state.cache_ids)).size
        m = max(1, int(n_miss))
        out = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, m, True)
        exp = plain_lookup(state.table, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-6)


class TestKernelPath:
    """The Pallas-backed managed lookup (interpret mode on CPU) against the
    jnp reference path."""

    @pytest.mark.parametrize("m", [1, 4, 16, 128])
    def test_forward_bitwise_matches_jnp(self, m):
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(4, 8)), jnp.int32)
        ref = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, m)
        ker = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, m, False, True)
        np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))

    def test_forward_bitwise_strict(self):
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(2, 16)), jnp.int32)
        ref = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, 8, True)
        ker = pm_lookup(state.table, state.cache_ids, state.cache_rows,
                        tokens, 8, True, True)
        np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))

    def test_backward_scatter_matches_jnp(self):
        """Kernel backward (segment + blocked scatter) == dense scatter-add
        (tolerance only for duplicate-sum association order)."""
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(2, 12)), jnp.int32)

        def loss(t, kernel):
            out = pm_lookup(t, state.cache_ids, state.cache_rows, tokens,
                            16, False, kernel)
            return jnp.sum(out ** 2)

        g_ref = jax.grad(lambda t: loss(t, False))(state.table)
        g_ker = jax.grad(lambda t: loss(t, True))(state.table)
        np.testing.assert_allclose(np.asarray(g_ker), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-6)
        assert float(jnp.max(jnp.abs(g_ker))) > 0.0

    def test_kernel_cache_grads_zero(self):
        state, rng = setup_state()
        tokens = jnp.asarray(rng.integers(0, V, size=(2, 6)), jnp.int32)
        gr = jax.grad(lambda r: jnp.sum(pm_lookup(
            state.table, state.cache_ids, r, tokens, 16, False, True) ** 2))(
            state.cache_rows)
        assert float(jnp.max(jnp.abs(gr))) == 0.0


class TestPlanner:
    def test_multi_shard_keys_replicated(self):
        pl = IntentPlanner(vocab_size=1000, cache_capacity=8, n_shards=4)
        for step in range(6):
            for shard in range(4):
                # keys 1,2,3 hit by all shards; 100+shard unique per shard
                pl.signal(step, shard, np.array([1, 2, 3, 100 + shard]))
        plan = pl.plan(0)
        cached = set(int(i) for i in plan.cache_ids if i < 1000)
        assert {1, 2, 3} <= cached
        assert all(k not in cached for k in (100, 101, 102, 103))

    def test_miss_capacity_from_intent_exact(self):
        pl = IntentPlanner(vocab_size=1000, cache_capacity=4, n_shards=2)
        for step in range(4):
            pl.signal(step, 0, np.array([1, 2, 50, 51, 52]))
            pl.signal(step, 1, np.array([1, 2, 60, 61]))
        plan = pl.plan(0)
        cached = set(int(i) for i in plan.cache_ids if i < 1000)
        # worst per-shard miss count is 3 (50,51,52) -> bucket >= 3
        assert plan.miss_capacity >= 3
        assert {1, 2} <= cached

    def test_replan_follows_algorithm1_horizon(self):
        pl = IntentPlanner(vocab_size=100, cache_capacity=4, n_shards=2,
                           lam0=5.0)
        for s in range(200):
            for sh in range(2):
                pl.signal(s, sh, np.array([1, 2]))
        plan = pl.plan(0)
        assert not pl.should_replan(0, plan)
        # after the window is nearly consumed, a replan is required
        late = plan.window[1]
        assert pl.should_replan(late, plan)

    def test_plan_version_monotone(self):
        pl = IntentPlanner(vocab_size=100, cache_capacity=4, n_shards=2)
        pl.signal(0, 0, np.array([1]))
        v1 = pl.plan(0).version
        v2 = pl.plan(0).version
        assert v2 > v1
