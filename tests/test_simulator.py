"""Integration + property tests for the cluster simulator and the PM
baselines, checking the paper's qualitative claims at test scale."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.api import CostModel
from repro.core.baselines import (NuPSStatic, SelectiveReplicationSSP,
                                  StaticFullReplication, StaticPartitioning)
from repro.core.manager import AdaPM
from repro.core.simulator import (SimConfig, Workload, simulate,
                                  single_node_epoch_time)
from repro.data.workloads import make_workload


def tiny_workload(n_nodes=2, wpn=1, n_batches=30, n_keys=500, kpb=8, seed=0):
    rng = np.random.default_rng(seed)
    streams = [[[np.unique(rng.integers(0, n_keys, size=kpb))
                 for _ in range(n_batches)]
                for _ in range(wpn)]
               for _ in range(n_nodes)]
    return Workload("tiny", n_keys, streams)


def total_accesses(wl):
    return sum(len(b) for ns in wl.streams for s in ns for b in s)


COST = CostModel()
CFG = SimConfig(signal_offset=20)


class TestSimulatorInvariants:
    def test_all_accesses_processed(self):
        wl = tiny_workload()
        m = simulate(AdaPM(2, COST), wl, CFG)
        assert m.n_accesses == total_accesses(wl)
        assert m.epoch_time > 0
        assert m.rounds > 0

    def test_static_partitioning_remote_share(self):
        """Hash partitioning: ~ (n-1)/n of uniform accesses are remote."""
        wl = tiny_workload(n_nodes=4, n_batches=50, n_keys=2000)
        m = simulate(StaticPartitioning(4, COST), wl, CFG)
        assert m.remote_fraction == pytest.approx(0.75, abs=0.08)

    def test_full_replication_all_local_but_stale(self):
        wl = tiny_workload()
        m = simulate(StaticFullReplication(2, COST, wl.n_keys), wl, CFG)
        assert m.remote_fraction == 0.0
        assert m.mean_staleness > 0.0

    def test_full_replication_oom_flag(self):
        cost = CostModel(node_mem_bytes=1024)  # absurdly small node
        wl = tiny_workload()
        pol = StaticFullReplication(2, cost, wl.n_keys)
        assert pol.metrics.oom

    def test_adapm_avoids_remote_accesses(self):
        """The paper's headline mechanism: with intent signaled early and
        adaptive timing, (almost) no synchronous remote accesses remain."""
        wl = tiny_workload(n_nodes=2, n_batches=60)
        m = simulate(AdaPM(2, COST), wl, SimConfig(signal_offset=30))
        assert m.remote_fraction < 0.05

    def test_adapm_beats_static_partitioning(self):
        wl = make_workload("KGE", n_nodes=2, wpn=2, scale=0.2)
        m_ada = simulate(AdaPM(2, COST), wl, CFG)
        m_sp = simulate(StaticPartitioning(2, COST), wl, CFG)
        assert m_ada.epoch_time < m_sp.epoch_time
        assert m_ada.remote_fraction < m_sp.remote_fraction

    def test_single_node_time_positive(self):
        wl = tiny_workload()
        assert single_node_epoch_time(wl, COST) > 0

    @given(seed=st.integers(0, 2**16), n_nodes=st.sampled_from([2, 3, 4]),
           kpb=st.integers(2, 16))
    @settings(max_examples=15, deadline=None)
    def test_property_epoch_completes_and_metrics_sane(self, seed, n_nodes,
                                                       kpb):
        wl = tiny_workload(n_nodes=n_nodes, n_batches=15, n_keys=300,
                           kpb=kpb, seed=seed)
        for policy in (AdaPM(n_nodes, COST),
                       StaticPartitioning(n_nodes, COST),
                       SelectiveReplicationSSP(n_nodes, COST, 10)):
            m = simulate(policy, wl, SimConfig(signal_offset=10))
            assert m.n_accesses == total_accesses(wl)
            assert 0.0 <= m.remote_fraction <= 1.0
            assert np.isfinite(m.epoch_time) and m.epoch_time > 0
            assert m.total_bytes >= 0


class TestPaperClaims:
    """Scaled-down checks of §5's qualitative results."""

    def test_mf_relocation_benefit(self):
        """Table 2 / §5.5: on the locality-heavy MF task, AdaPM (with
        relocation) communicates substantially less than replication-only
        AdaPM, and is not slower."""
        wl = make_workload("MF", n_nodes=4, wpn=2, scale=0.4)
        m_full = simulate(AdaPM(4, COST), wl, SimConfig(signal_offset=60))
        m_norel = simulate(AdaPM(4, COST, relocation=False), wl,
                           SimConfig(signal_offset=60))
        assert m_full.total_bytes < 0.7 * m_norel.total_bytes
        assert m_full.epoch_time <= 1.3 * m_norel.epoch_time

    def test_relocation_only_slow_on_hotspots(self):
        """§5.5: AdaPM w/o replication is inefficient (hot spots)."""
        wl = make_workload("CTR", n_nodes=4, wpn=2, scale=0.25)
        m_full = simulate(AdaPM(4, COST), wl, SimConfig(signal_offset=60))
        m_norep = simulate(AdaPM(4, COST, replication=False), wl,
                           SimConfig(signal_offset=60))
        assert m_norep.epoch_time > 1.5 * m_full.epoch_time
        assert m_norep.remote_fraction > m_full.remote_fraction

    def test_adapm_staleness_below_full_replication(self):
        wl = make_workload("KGE", n_nodes=2, wpn=2, scale=0.2)
        m_ada = simulate(AdaPM(2, COST), wl, CFG)
        m_fr = simulate(StaticFullReplication(2, COST, wl.n_keys), wl, CFG)
        assert m_ada.mean_staleness < m_fr.mean_staleness

    def test_nups_hot_keys_always_local(self):
        wl = make_workload("WV", n_nodes=2, wpn=1, scale=0.2)
        hot = wl.hot_keys(0.05)
        pol = NuPSStatic(2, COST, wl.n_keys, hot, reloc_offset=50)
        simulate(pol, wl, SimConfig(signal_offset=60))
        for k in list(hot)[:10]:
            assert pol.access(0, 0, k, 0.0).local
            assert pol.access(1, 0, k, 0.0).local


class TestQualityHarness:
    def test_staleness_degrades_convergence(self):
        """Figure 6's quality axis: per-round replica sync (AdaPM's bound)
        converges like the oracle; infrequent dense sync stagnates."""
        import sys, os
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
        from benchmarks.quality_mf import run_mf
        tight = run_mf(sync_every=1, rounds=40)
        loose = run_mf(sync_every=20, rounds=40)
        assert tight[-1] < 0.1
        assert loose[-1] > 1.5 * tight[-1]
