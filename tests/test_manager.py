"""Scenario tests for AdaPM's adaptive choice of technique (paper §4.1,
Figure 4) and its communication discipline (§B.2.4)."""

import pytest

from repro.core.api import CostModel
from repro.core.intent import Intent
from repro.core.manager import AdaPM
from repro.core.ownership import home_node


def key_with_home(node: int, n_nodes: int, start: int = 0) -> int:
    k = start
    while home_node(k, n_nodes) != node:
        k += 1
    return k


def mk(n_nodes=3, **kw):
    kw.setdefault("lam0", 1.0)
    return AdaPM(n_nodes, CostModel(), **kw)


def set_clock(pm, node, worker, clock):
    pm.advance_clock(node, worker, clock)


class TestFig4Scenarios:
    def test_4b_nonoverlapping_relocation(self):
        """Two nodes, non-overlapping intents: relocate to the first, keep
        it there after expiry, relocate to the second before activation."""
        pm = mk()
        k = key_with_home(0, 3)
        w1, w2 = 100, 200
        set_clock(pm, 1, w1, 0)
        set_clock(pm, 2, w2, 0)
        pm.signal_intent(1, Intent(keys=(k,), c_start=2, c_end=4,
                                   worker_id=w1), 0.0)
        pm.signal_intent(2, Intent(keys=(k,), c_start=60, c_end=62,
                                   worker_id=w2), 0.0)
        pm.run_round(0.0, 1e-3)
        assert pm.dir.owner_of(k) == 1          # relocated to node 1
        assert k not in pm._repl or not pm._repl[k].holders
        # node 1's intent expires; parameter stays where it is (§4.1)
        set_clock(pm, 1, w1, 5)
        pm.run_round(1e-3, 1e-3)
        assert pm.dir.owner_of(k) == 1
        # node 2 approaches its window; relocation happens proactively
        set_clock(pm, 2, w2, 55)
        pm.run_round(2e-3, 1e-3)
        pm.run_round(3e-3, 1e-3)
        assert pm.dir.owner_of(k) == 2
        assert pm.metrics.n_relocations == 2
        assert pm.metrics.n_replica_creates == 0

    def test_4c_partial_overlap_replica_then_relocate(self):
        """Partial overlap: relocate to first, replica on second during the
        overlap, relocate to second after the first's intent expires."""
        pm = mk()
        k = key_with_home(0, 3)
        w1, w2 = 100, 200
        set_clock(pm, 1, w1, 0)
        set_clock(pm, 2, w2, 0)
        pm.signal_intent(1, Intent(keys=(k,), c_start=0, c_end=10,
                                   worker_id=w1), 0.0)
        pm.signal_intent(2, Intent(keys=(k,), c_start=5, c_end=15,
                                   worker_id=w2), 0.0)
        pm.run_round(0.0, 1e-3)
        assert pm.dir.owner_of(k) == 1
        assert pm._repl[k].holders == {2}       # replica during overlap
        # node 1 expires while node 2 is still active -> relocate to node 2
        set_clock(pm, 1, w1, 10)
        set_clock(pm, 2, w2, 7)
        pm.run_round(1e-3, 1e-3)
        assert pm.dir.owner_of(k) == 2
        assert not pm._repl.get(k, None) or not pm._repl[k].holders

    def test_4d_concurrent_replicas_everywhere(self):
        """Multiple concurrent intents: replicas exactly on active nodes."""
        pm = mk(n_nodes=4)
        k = key_with_home(0, 4)
        for node in range(4):
            w = 100 + node
            set_clock(pm, node, w, 0)
            pm.signal_intent(node, Intent(keys=(k,), c_start=0, c_end=10,
                                          worker_id=w), 0.0)
        pm.run_round(0.0, 1e-3)
        assert pm.dir.owner_of(k) == 0           # owner keeps it (own intent)
        assert pm._repl[k].holders == {1, 2, 3}
        # expiry destroys replicas precisely when intent ends (§4.1)
        for node in range(1, 4):
            set_clock(pm, node, 100 + node, 10)
        pm.run_round(1e-3, 1e-3)
        assert not pm._repl.get(k, None) or not pm._repl[k].holders


class TestCommunicationDiscipline:
    def test_no_relocation_while_replicas_exist(self):
        """§B.2.4: concurrent active intent -> replication, never relocation
        (even when a later activation is the only non-owner one)."""
        pm = mk(n_nodes=3)
        k = key_with_home(0, 3)
        set_clock(pm, 0, 10, 0)
        set_clock(pm, 1, 11, 0)
        set_clock(pm, 2, 12, 0)
        pm.signal_intent(0, Intent(keys=(k,), c_start=0, c_end=20,
                                   worker_id=10), 0.0)
        pm.signal_intent(1, Intent(keys=(k,), c_start=0, c_end=20,
                                   worker_id=11), 0.0)
        pm.run_round(0.0, 1e-3)
        owner_before = pm.dir.owner_of(k)
        pm.signal_intent(2, Intent(keys=(k,), c_start=1, c_end=5,
                                   worker_id=12), 0.0)
        pm.run_round(1e-3, 1e-3)
        assert pm.dir.owner_of(k) == owner_before
        assert 2 in pm._repl[k].holders
        assert pm.metrics.n_relocations == 0

    def test_optional_intent_remote_access(self):
        """Accesses without intent work, but are synchronous+remote (§4)."""
        pm = mk(n_nodes=2)
        k = key_with_home(0, 2)
        res = pm.access(1, 0, k, 0.0)
        assert not res.local
        assert pm.metrics.n_remote == 1
        res = pm.access(0, 0, k, 0.0)
        assert res.local

    def test_replica_access_counts_staleness(self):
        pm = mk(n_nodes=2)
        k = key_with_home(0, 2)
        set_clock(pm, 0, 0, 0)
        set_clock(pm, 1, 1, 0)
        pm.signal_intent(0, Intent(keys=(k,), c_start=0, c_end=9,
                                   worker_id=0), 0.0)
        pm.signal_intent(1, Intent(keys=(k,), c_start=0, c_end=9,
                                   worker_id=1), 0.0)
        pm.run_round(0.0, 1e-3)
        res = pm.access(1, 1, k, 5e-3)
        assert res.local and res.staleness == pytest.approx(5e-3)

    def test_ablation_no_replication_falls_back_to_remote(self):
        pm = mk(n_nodes=3, replication=False)
        k = key_with_home(0, 3)
        for node in (1, 2):
            w = 10 + node
            set_clock(pm, node, w, 0)
            pm.signal_intent(node, Intent(keys=(k,), c_start=0, c_end=9,
                                          worker_id=w), 0.0)
        pm.run_round(0.0, 1e-3)
        # exactly one of the two got the parameter; the other goes remote
        owner = pm.dir.owner_of(k)
        assert owner in (1, 2)
        other = 3 - owner
        assert pm.access(owner, 0, k, 0.0).local
        assert not pm.access(other, 0, k, 0.0).local
        assert pm.metrics.n_replica_creates == 0

    def test_ablation_no_relocation_keeps_home(self):
        pm = mk(n_nodes=3, relocation=False)
        k = key_with_home(0, 3)
        set_clock(pm, 1, 11, 0)
        pm.signal_intent(1, Intent(keys=(k,), c_start=0, c_end=9,
                                   worker_id=11), 0.0)
        pm.run_round(0.0, 1e-3)
        assert pm.dir.owner_of(k) == 0           # never relocates
        assert pm._repl[k].holders == {1}        # replicates instead
        assert pm.metrics.n_relocations == 0

    def test_trace_records_events(self):
        pm = AdaPM(2, CostModel(), lam0=1.0, trace_keys={5})
        k = 5
        node = 1 - home_node(k, 2)
        set_clock(pm, node, 0, 0)
        pm.signal_intent(node, Intent(keys=(k,), c_start=0, c_end=3,
                                      worker_id=0), 0.0)
        pm.run_round(0.0, 1e-3)
        assert any(ev in ("relocate-in", "replica-create")
                   for (_, _, _, ev) in pm.trace)
