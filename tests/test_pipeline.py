"""Tests for the intent-signaling data pipeline (paper §3, Figure 2)."""

import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import IntentSignalingLoader, SyntheticCorpus
from repro.pm.planner import IntentPlanner


def small_cfg():
    return get_config("smollm-135m", smoke=True)


class TestIntentCoversEveryRow:
    def test_trailing_rows_signaled(self):
        """ISSUE 2 regression: B % n_shards trailing rows were silently
        dropped from intent signaling, breaking the exact miss bound for
        their tokens.  The last shard must take the remainder."""
        cfg = small_cfg()
        planner = IntentPlanner(cfg.vocab_size, 32, n_shards=2)
        loader = IntentSignalingLoader(cfg, 7, 8, n_shards=2, prefetch=1,
                                       planner=planner)
        step, batch = next(iter(loader))
        toks = np.asarray(batch["tokens"])
        assert toks.shape == (7, 8)
        signaled = np.concatenate(
            [ids for ids in planner._intents[step] if ids is not None])
        missing = np.setdiff1d(np.unique(toks), signaled)
        assert missing.size == 0, f"unsignaled token ids: {missing}"

    def test_shard_partition_covers_batch_exactly(self):
        """Per-shard signals = per-shard row slices; shard 1 of B=7 gets
        rows 3..6 (the remainder), not rows 3..5."""
        cfg = small_cfg()
        planner = IntentPlanner(cfg.vocab_size, 32, n_shards=2)
        loader = IntentSignalingLoader(cfg, 7, 8, n_shards=2, prefetch=1,
                                       planner=planner)
        step, batch = next(iter(loader))
        toks = np.asarray(batch["tokens"])
        per_shard = planner._intents[step]
        np.testing.assert_array_equal(per_shard[0], np.unique(toks[0:3]))
        np.testing.assert_array_equal(per_shard[1], np.unique(toks[3:7]))

    def test_more_shards_than_rows(self):
        """Degenerate n_shards > B keeps every row signaled exactly once
        and never indexes past the batch."""
        cfg = small_cfg()
        planner = IntentPlanner(cfg.vocab_size, 32, n_shards=4)
        loader = IntentSignalingLoader(cfg, 2, 8, n_shards=4, prefetch=1,
                                       planner=planner)
        step, batch = next(iter(loader))
        toks = np.asarray(batch["tokens"])
        signaled = np.concatenate(
            [ids for ids in planner._intents[step] if ids is not None])
        assert np.setdiff1d(np.unique(toks), signaled).size == 0


class TestCorpus:
    def test_zipf_marginals_skewed(self):
        c = SyntheticCorpus(1000, zipf_a=1.1, seed=0)
        toks = c.tokens((64, 64)).ravel()
        _, counts = np.unique(toks, return_counts=True)
        # heavy head: the most frequent token dwarfs the median
        assert counts.max() > 10 * np.median(counts)
