"""Per-architecture smoke tests: REDUCED variants (<=2 layers, d_model<=128,
<=4 experts) run a real forward + one train-grad step + one decode step on
CPU, asserting output shapes and the absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.batches import make_batch
from repro.models.model import forward, init_cache, init_model, loss_fn

B, S = 2, 16


def setup_arch(arch_id):
    cfg = get_config(arch_id, smoke=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S, np.random.default_rng(0))
    return cfg, params, batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(arch_id):
    cfg, params, batch = setup_arch(arch_id)
    logits, aux, _ = forward(params, cfg, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_grad_step(arch_id):
    cfg, params, batch = setup_arch(arch_id)

    def loss(p):
        logits, aux, _ = forward(p, cfg, batch)
        return loss_fn(logits, batch["labels"], aux)

    val, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(val))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # gradients actually flow into the embedding and into every layer stack
    assert float(jnp.max(jnp.abs(grads["embed"]))) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id):
    cfg, params, batch = setup_arch(arch_id)
    cache = init_cache(cfg, B, max_seq=32)
    if cfg.family == "encdec":
        # prefill the encoder output into the cache (stub frontend)
        from repro.models.model import _encoder
        cache["enc_out"] = _encoder(params, cfg, batch["frames"])
    cache["len"] = jnp.asarray(1, dtype=jnp.int32)  # writing position 0
    tok = batch["tokens"][:, :1]
    step = {"tokens": tok}
    logits, _, new_cache = forward(params, cfg, step, cache=cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # a second step with the updated cache also works
    new_cache["len"] = new_cache["len"] + 1
    logits2, _, _ = forward(params, cfg, step, cache=new_cache)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_decode_matches_prefill_dense():
    """Decoding token-by-token must reproduce the teacher-forced logits of
    the full forward pass (numerics: fp32, tolerance loose for the online
    softmax)."""
    cfg, params, batch = setup_arch("smollm-135m")
    logits_full, _, _ = forward(params, cfg, batch, remat=False)
    cache = init_cache(cfg, B, max_seq=S)
    outs = []
    for t in range(S):
        cache["len"] = jnp.asarray(t + 1, dtype=jnp.int32)
        step = {"tokens": batch["tokens"][:, t:t + 1]}
        lg, _, cache = forward(params, cfg, step, cache=cache)
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_dec), rtol=2e-3, atol=2e-3)


def test_sliding_window_restricts_attention():
    """With a sliding window, distant tokens must not influence logits."""
    cfg = get_config("mixtral-8x22b", smoke=True)
    assert cfg.sliding_window > 0
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=4, n_experts=0, top_k=0,
                              d_ff=128)
    params = init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, 1, 12, rng)
    logits_a, _, _ = forward(params, cfg, batch, remat=False)
    # perturb a token far outside the window of the last position
    toks = np.asarray(batch["tokens"]).copy()
    toks[0, 0] = (toks[0, 0] + 1) % cfg.vocab_size
    batch2 = dict(batch, tokens=jnp.asarray(toks))
    logits_b, _, _ = forward(params, cfg, batch2, remat=False)
    np.testing.assert_allclose(np.asarray(logits_a[0, -1]),
                               np.asarray(logits_b[0, -1]), atol=1e-5)
    # ...but it does influence positions inside its window
    assert not np.allclose(np.asarray(logits_a[0, 1]),
                           np.asarray(logits_b[0, 1]), atol=1e-5)


def test_param_counts_reasonable():
    """Analytic param_count tracks the real init within 25%."""
    for arch_id in ("smollm-135m", "falcon-mamba-7b", "zamba2-1.2b"):
        cfg = get_config(arch_id, smoke=True)
        params = init_model(cfg, jax.random.PRNGKey(0))
        n_real = sum(np.prod(p.shape) for p in
                     jax.tree_util.tree_leaves(params))
        n_pred = cfg.param_count()
        assert 0.75 < n_pred / n_real < 1.33, (arch_id, n_pred, n_real)
