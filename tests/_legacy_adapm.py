"""Frozen copy of the seed (pre-engine) dict-and-heap AdaPM (paper §4, §B).

Faithful mechanisms:
  * per-worker logical clocks and intent tables (§3);
  * Algorithm 1 adaptive action timing on the *signaling* node: inactive
    intents are held locally and announced (as "active") to the owner only
    when the Poisson soft upper bound says the worker may reach the start
    clock within the next two rounds (§4.2, §B.2.1 aggregated intent);
  * owner-side decision rule (§4.1): exactly-one active node and no replicas
    -> relocate; concurrent active intent -> selective replicas exactly while
    intent is active; relocation never happens while replicas exist (§B.2.4);
  * responsibility follows allocation: the owner decides and is the replica
    sync hub; ownership (and decision state) moves on relocation (§B.1);
  * versioned delta replica sync, batched per round in grouped
    request/response messages (§B.1.2, §B.2.2);
  * home-node fallback routing with location caches (§B.2.3) — stale caches
    cost forwarding hops, charged per message;
  * intent is optional: un-signaled accesses fall back to synchronous remote
    access (§4 "Optional intent").

Ablation variants (paper §5.5, §5.8): ``relocation=False`` (replication
only), ``replication=False`` (relocation only), ``immediate_action=True``
(skip Algorithm 1, act on signals as soon as they arrive).
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.api import AccessResult, CostModel, PMPolicy
from repro.core.intent import Intent
from repro.core.ownership import OwnershipDirectory, home_node
from repro.core.timing import ActionTimer


@dataclass
class _ReplicaState:
    """Owner-side view of one replicated key."""

    holders: Set[int] = field(default_factory=set)
    version: int = 0
    # per-holder: (version last synced to holder, sim time of last sync)
    holder_sync: Dict[int, Tuple[int, float]] = field(default_factory=dict)
    dirty_nodes: Set[int] = field(default_factory=set)  # wrote since last round


class LegacyAdaPM(PMPolicy):
    name = "AdaPM"

    def __init__(self, n_nodes: int, cost: CostModel, *,
                 relocation: bool = True, replication: bool = True,
                 immediate_action: bool = False,
                 alpha: float = 0.1, p: float = 0.9999, lam0: float = 10.0,
                 trace_keys: Optional[Set[int]] = None):
        super().__init__(n_nodes, cost)
        self.relocation = relocation
        self.replication = replication
        self.immediate = immediate_action
        if not relocation:
            self.name = "AdaPM w/o relocation"
        if not replication:
            self.name = "AdaPM w/o replication"
        if immediate_action:
            self.name = "AdaPM immediate action"
        self.dir = OwnershipDirectory(n_nodes)
        self.timers = [ActionTimer(alpha=alpha, p=p, lam0=lam0)
                       for _ in range(n_nodes)]
        self.clocks: List[Dict[int, int]] = [dict() for _ in range(n_nodes)]
        # node-local pending (inactive, not yet announced) intents:
        # heap of (c_start, key, worker, c_end)
        self._pending: List[List[Tuple[int, int, int, int]]] = [
            [] for _ in range(n_nodes)]
        # node-local announced keys -> list of (worker, c_end) windows
        self._announced: List[Dict[int, List[Tuple[int, int]]]] = [
            dict() for _ in range(n_nodes)]
        # owner-side: which nodes announced active intent per key
        self._active: Dict[int, Set[int]] = {}
        self._repl: Dict[int, _ReplicaState] = {}
        # per-node owned-key count for memory accounting (keys start at home)
        self._owned_extra: List[int] = [0] * n_nodes  # relocated-in minus out
        self._n_keys_hint = 0
        self.trace_keys = trace_keys or set()
        self.trace: List[Tuple[float, int, int, str]] = []  # (t, key, node, ev)

    # ------------------------------------------------------------------ util
    def _is_local(self, node: int, key: int) -> bool:
        if self.dir.owner_of(key) == node:
            return True
        st = self._repl.get(key)
        return st is not None and node in st.holders

    def _trace(self, now: float, key: int, node: int, ev: str):
        if key in self.trace_keys:
            self.trace.append((now, key, node, ev))

    # ------------------------------------------------------------ sim hooks
    def signal_intent(self, node: int, intent: Intent, now: float) -> None:
        pend = self._pending[node]
        for k in intent.keys:
            heapq.heappush(
                pend, (intent.c_start, k, intent.worker_id, intent.c_end))

    def advance_clock(self, node: int, worker: int, clock: int) -> None:
        self.clocks[node][worker] = clock

    def access(self, node: int, worker: int, key: int,
               now: float, write: bool = True) -> AccessResult:
        self.metrics.n_accesses += 1
        owner = self.dir.owner_of(key)
        if owner == node:
            return AccessResult(local=True, staleness=0.0)
        st = self._repl.get(key)
        if st is not None and node in st.holders:
            if write:
                st.dirty_nodes.add(node)
                st.version += 1
            _, t_sync = st.holder_sync.get(node, (0, now))
            stale = max(0.0, now - t_sync)
            self.metrics.staleness_sum += stale
            self.metrics.n_replica_reads += 1
            return AccessResult(local=True, staleness=stale)
        # synchronous remote access (no intent was acted on): round trip to
        # the owner, routed via location cache / home node.
        hops = self.dir.route(node, key)
        nbytes = 2 * self.cost.value_bytes + hops * 64
        self.metrics.n_remote += 1
        self.ledger.charge(node, nbytes, nmsgs=1 + hops)
        return AccessResult(local=False)

    # -------------------------------------------------------------- rounds
    def run_round(self, now: float, round_duration_hint: float) -> None:
        c = self.cost
        # 1) per-worker rate estimates (Algorithm 1 lines 1-6)
        for node in range(self.n_nodes):
            for w, clk in self.clocks[node].items():
                self.timers[node].observe_round(w, clk)

        # 2) node-local: decide which pending intents to announce (Alg. 1),
        #    and which announced intents expired (§B.2.1 aggregated intent).
        for node in range(self.n_nodes):
            pend = self._pending[node]
            ann = self._announced[node]
            clocks = self.clocks[node]
            newly: List[Tuple[int, int, int]] = []  # (key, worker, c_end)
            # Scan all pending intents whose start clock is below the most
            # optimistic horizon on this node; re-stash the ones whose own
            # worker's Algorithm-1 bound says a later round still suffices.
            if self.immediate:
                scan_bound = float("inf")
            else:
                scan_bound = max(
                    (clocks.get(w, 0) + self.timers[node].horizon(w)
                     for w in clocks), default=self.timers[node].horizon(0))
            stash: List[Tuple[int, int, int, int]] = []
            while pend and pend[0][0] < scan_bound:
                c_start, k, w, c_end = heapq.heappop(pend)
                clk = clocks.get(w, 0)
                if c_end <= clk:
                    continue                     # expired before ever acted on
                act = self.immediate or self.timers[node].should_act(
                    w, clk, c_start)
                if act:
                    newly.append((k, w, c_end))
                else:
                    stash.append((c_start, k, w, c_end))
            for item in stash:
                heapq.heappush(pend, item)
            # expirations: all windows of an announced key expired
            expired: List[int] = []
            for k, windows in ann.items():
                windows[:] = [(w, e) for (w, e) in windows
                              if clocks.get(w, 0) < e]
                if not windows:
                    expired.append(k)
            # 3) send grouped messages to owners & process owner decisions
            dests: Set[int] = set()
            for k, w, c_end in newly:
                first = k not in ann
                ann.setdefault(k, []).append((w, c_end))
                if first:
                    owner = self.dir.owner_of(k)
                    if owner != node:
                        hops = self.dir.route(node, k)
                        self.ledger.charge(node, c.signal_bytes * hops)
                        dests.add(owner)
                    self._owner_on_activate(k, node, now)
                else:
                    pass  # extension of an already-announced intent: no msg
            for k in expired:
                del ann[k]
                owner = self.dir.owner_of(k)
                if owner != node:
                    hops = self.dir.route(node, k)
                    self.ledger.charge(node, c.signal_bytes * hops)
                    dests.add(owner)
                self._owner_on_expire(k, node, now)
            # grouped request/response message overhead (§B.2.2):
            # one request + one response per peer communicated with
            self.ledger.charge(node, 0.0, nmsgs=2 * len(dests))

        # 4) replica synchronization via the owner hub (§B.1.2): versioned
        #    deltas, batched; upstream pushes then downstream fan-out.
        for k, st in list(self._repl.items()):
            if not st.holders:
                del self._repl[k]
                continue
            owner = self.dir.owner_of(k)
            for h in st.dirty_nodes:
                if h == owner:
                    continue
                self.ledger.charge(h, c.value_bytes, nmsgs=0)
            st.dirty_nodes.clear()
            for h in st.holders:
                ver, _t = st.holder_sync.get(h, (-1, now))
                if ver < st.version:
                    self.ledger.charge(owner, c.value_bytes, nmsgs=0)
                    st.holder_sync[h] = (st.version, now)
        self.metrics.rounds += 1

    # ------------------------------------------------------ owner decisions
    def _owner_on_activate(self, key: int, node: int, now: float) -> None:
        """§4.1 decision, executed at the owner when ``node`` announces
        active intent for ``key``."""
        c = self.cost
        active = self._active.setdefault(key, set())
        active.add(node)
        owner = self.dir.owner_of(key)
        if node == owner:
            self._trace(now, key, node, "own-local")
            return
        st = self._repl.get(key)
        has_replicas = st is not None and len(st.holders) > 0
        others_active = [n for n in active if n != node]
        if (self.relocation and not has_replicas
                and len(others_active) == 0):
            # exactly one node with active intent -> relocate (§4.1, §B.2.4)
            self._relocate(key, owner, node, now)
        elif self.replication:
            # concurrent intent -> replica exactly where needed (§4.1)
            self._create_replica(key, owner, node, now)
        # replication disabled & multiple active: non-owners fall back to
        # synchronous remote access (charged in access()).

    def _owner_on_expire(self, key: int, node: int, now: float) -> None:
        active = self._active.get(key)
        if active is None:
            return
        active.discard(node)
        st = self._repl.get(key)
        if st is not None and node in st.holders:
            # destroy replica when the holder's intent expires (§4.1)
            st.holders.discard(node)
            st.holder_sync.pop(node, None)
            st.dirty_nodes.discard(node)
            self._trace(now, key, node, "replica-destroy")
        owner = self.dir.owner_of(key)
        if not active:
            self._active.pop(key, None)
            return
        if self.relocation and len(active) == 1:
            (m,) = tuple(active)
            has_replicas = st is not None and len(st.holders) > 0
            if m != owner and (not has_replicas or
                               (st is not None and st.holders == {m})):
                # single remaining active node -> relocate to it (Fig. 4d/11)
                self._relocate(key, owner, m, now)

    def _relocate(self, key: int, src: int, dst: int, now: float) -> None:
        c = self.cost
        st = self._repl.get(key)
        if st is not None and dst in st.holders:
            # dst already holds the value: transfer ownership + fresh delta
            st.holders.discard(dst)
            st.holder_sync.pop(dst, None)
            nbytes = c.value_bytes  # delta + ownership/intent state
        else:
            nbytes = c.value_bytes + 64
        self.ledger.charge(src, nbytes)  # grouped (§B.2.2)
        self.dir.relocate(key, dst)
        self._owned_extra[src] -= 1
        self._owned_extra[dst] += 1
        self.metrics.n_relocations += 1
        self._trace(now, key, dst, "relocate-in")
        if st is not None and st.holders:
            # remaining holders now sync against the new owner; location
            # updates piggyback on the next sync round (§B.2.3).
            pass

    def _create_replica(self, key: int, owner: int, node: int,
                        now: float) -> None:
        c = self.cost
        st = self._repl.setdefault(key, _ReplicaState())
        if node in st.holders:
            return
        st.holders.add(node)
        st.holder_sync[node] = (st.version, now)
        self.ledger.charge(owner, c.value_bytes)  # grouped (§B.2.2)
        self.metrics.n_replica_creates += 1
        self._trace(now, key, node, "replica-create")

    # ------------------------------------------------------------- memory
    def mem_bytes(self, node: int) -> float:
        n_repl = sum(1 for st in self._repl.values() if node in st.holders)
        base = self._n_keys_hint / self.n_nodes
        return (base + self._owned_extra[node] + n_repl) * self.cost.value_bytes
