"""Tests for the intent-lead-time prefetch pipeline (DESIGN.md §15):
plan-ahead candidates, generation-keyed probe views, delta replica
refresh, and the N-deep serving pipeline — every one an *exactness*
claim: the pipelined path must be byte-identical to the synchronous
path it overlaps, because prefetch is a wall-clock transform, never a
semantics change.

Mesh cases follow tests/test_collectives.py: string-form skipifs so
collection never freezes the jax device count."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.obs.telemetry import Telemetry
from repro.pm.collectives import EmulatedBackend, MeshBackend
from repro.pm.controller import Knob, OnlineController
from repro.pm.embedding import CacheProbeView, make_state, probe_host
from repro.pm.planner import IntentPlanner
from repro.serve import (DriftingZipfStream, ReplayStream, ServeConfig,
                         ServingRuntime)
from repro.train.loop import LoopConfig, train_loop

V, D, C = 256, 32, 16


def needs(n):
    return pytest.mark.skipif(
        f"len(jax.devices()) < {n}",
        reason=f"needs {n} devices (XLA_FLAGS="
        f"--xla_force_host_platform_device_count={n})")


def mesh_backend(n):
    from repro.launch.mesh import make_model_mesh
    return MeshBackend(make_model_mesh(n))


def pm_cfg():
    # untied embeddings: the delta-refresh gate requires no dense head
    # gradient on the table (tied heads touch every row every step)
    return get_config("smollm-135m", smoke=True).reduced(
        tie_embeddings=False, n_heads=3, n_kv_heads=3)


# --------------------------------------------------------------------------
# plan-ahead candidates (pm/planner.py)
# --------------------------------------------------------------------------
class TestPlanAhead:
    def _planner(self):
        p = IntentPlanner(V, C, n_nodes=2, plan_every=4)
        rng = np.random.default_rng(0)
        for s in range(12):
            p.signal(s, 0, rng.integers(0, V, size=32))
        return p

    def test_candidate_adopt_identical_to_sync_plan(self):
        a, b = self._planner(), self._planner()
        cand = a.plan_candidate(a.plan_window(8))
        adopted = a.adopt(cand, 8)
        sync = b.plan(8)
        assert adopted is not None
        np.testing.assert_array_equal(adopted.cache_ids, sync.cache_ids)
        assert adopted.window == sync.window
        assert adopted.version == sync.version
        assert adopted.predicted_miss_rate == sync.predicted_miss_rate

    def test_candidate_does_not_commit(self):
        p = self._planner()
        v0 = p.plan(4).version
        p.plan_candidate(p.plan_window(8))       # built, never adopted
        assert p.plan(8).version == v0 + 1       # no version hole

    def test_stale_window_rejected(self):
        """A candidate built for the wrong step (the horizon shifted
        between submission and the boundary) is refused — the boundary
        falls back to a synchronous plan()."""
        p = self._planner()
        cand = p.plan_candidate(p.plan_window(6))
        assert p.adopt(cand, 8) is None
        assert p.adopt(None, 8) is None
        assert p.adopt(cand, 6) is not None


# --------------------------------------------------------------------------
# generation-keyed probe view (pm/embedding.py, satellite 1)
# --------------------------------------------------------------------------
class TestCacheProbeView:
    def _check(self, owner_shards=0, route_capacity=0, cap=C, seed=0):
        rng = np.random.default_rng(seed)
        cache_ids = np.sort(rng.choice(V, size=cap, replace=False)) \
            if cap else np.zeros(0, np.int64)
        view = CacheProbeView(cache_ids, V)
        for _ in range(10):
            tok = rng.integers(0, V, size=32)
            for m in (4, 8, 16):
                ref = probe_host(cache_ids, tok, m,
                                 owner_shards=owner_shards,
                                 route_capacity=route_capacity, vocab=V)
                got = view.probe(tok, m, owner_shards=owner_shards,
                                 route_capacity=route_capacity)
                for f in ref._fields:
                    r, g = getattr(ref, f), getattr(got, f)
                    if isinstance(r, np.ndarray):
                        assert g.dtype == r.dtype, f
                        np.testing.assert_array_equal(g, r, err_msg=f)
                    else:
                        assert g == r, f

    def test_matches_probe_host(self):
        self._check()

    def test_matches_probe_host_routed(self):
        self._check(owner_shards=8, route_capacity=2, cap=64, seed=1)

    def test_empty_cache(self):
        self._check(cap=0, seed=2)


# --------------------------------------------------------------------------
# delta replica refresh (pm/collectives.py)
# --------------------------------------------------------------------------
class TestDeltaRefresh:
    def _run(self, backend):
        rng = np.random.default_rng(3)
        table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
        if hasattr(backend, "mesh"):
            table = backend.place_table(table)
        cache_ids = np.sort(rng.choice(V, size=C, replace=False))
        stale = jnp.asarray(rng.normal(size=(C, D)), jnp.float32)
        touched = np.sort(rng.choice(cache_ids, size=7, replace=False))
        n = 8
        ids = np.full(n, V, np.int32)
        ids[:7] = touched
        slots = np.full(n, C, np.int32)
        slots[:7] = np.searchsorted(cache_ids, touched)
        got = backend.refresh_rows_delta(table, stale, jnp.asarray(ids),
                                         jnp.asarray(slots))
        want = np.array(stale)
        want[np.searchsorted(cache_ids, touched)] = \
            np.asarray(table)[touched]
        np.testing.assert_array_equal(np.asarray(got), want)

    def test_emulated(self):
        self._run(EmulatedBackend(2))

    @pytest.mark.parametrize("n", [pytest.param(2, marks=needs(2)),
                                   pytest.param(8, marks=needs(8))])
    def test_mesh(self, n):
        self._run(mesh_backend(n))


# --------------------------------------------------------------------------
# pipelined training == synchronous training, byte-identical
# --------------------------------------------------------------------------
class TestPrefetchedTrainEquivalence:
    """The tentpole exactness claim: a 50-step trace with the prefetch
    pipeline on (plan-ahead thread, delta refresh, deferred loss blocks)
    is byte-identical to the synchronous loop — same losses, same plan
    and refresh counts."""

    def _trace(self, depth, **kw):
        bus = Telemetry()
        # capacity well above the 64-row delta bucket floor: a 32-token
        # step's touched set must stay a SMALL fraction of the cache or
        # the near-full-delta fallback takes the one full gather instead
        base = dict(steps=50, batch=2, seq=16, pm=True, cache_capacity=256,
                    refresh_every=1, log_every=0, seed=3,
                    pipeline_depth=depth)
        base.update(kw)
        res = train_loop(pm_cfg(), LoopConfig(**base), telemetry=bus)
        return res, bus

    @pytest.mark.parametrize("kernel", [False, True])
    @pytest.mark.parametrize("n_shards", [1, 2])
    def test_emulated(self, n_shards, kernel):
        sync, _ = self._trace(0, n_shards=n_shards, kernel=kernel)
        pipe, bus = self._trace(2, n_shards=n_shards, kernel=kernel)
        assert pipe.losses == sync.losses            # bitwise float eq
        assert pipe.plans == sync.plans
        assert pipe.refreshes == sync.refreshes
        # the pipelined run really took the delta path (not vacuous)
        assert bus.counter_value("train.delta_refreshes") > 0

    @pytest.mark.parametrize("kernel", [False, True])
    @pytest.mark.parametrize("n", [pytest.param(2, marks=needs(2)),
                                   pytest.param(8, marks=needs(8))])
    def test_mesh(self, n, kernel):
        kw = dict(collective="mesh", model_shards=n, kernel=kernel)
        sync, _ = self._trace(0, **kw)
        pipe, bus = self._trace(2, **kw)
        assert pipe.losses == sync.losses
        assert pipe.refreshes == sync.refreshes
        assert bus.counter_value("train.delta_refreshes") > 0

    def test_tied_embeddings_disable_delta_but_stay_exact(self):
        """Tied heads put dense gradients on every table row: the delta
        gate must self-disable (full refresh) and the trace still match."""
        cfg = get_config("smollm-135m", smoke=True)
        assert cfg.tie_embeddings
        base = dict(steps=20, batch=2, seq=16, pm=True, cache_capacity=256,
                    refresh_every=1, log_every=0, seed=3)
        bus = Telemetry()
        sync = train_loop(cfg, LoopConfig(**base, pipeline_depth=0))
        pipe = train_loop(cfg, LoopConfig(**base, pipeline_depth=2),
                          telemetry=bus)
        assert pipe.losses == sync.losses
        assert bus.counter_value("train.delta_refreshes") == 0


# --------------------------------------------------------------------------
# pipelined serving == sequential serving
# --------------------------------------------------------------------------
class TestPipelinedServeEquivalence:
    """The N-deep admission pipeline plus the tenure staging prefetch is
    a pure wall-clock transform: served values, requeue sets, replans
    and miss traces all match the depth-0 sequential loop on a drifting
    replay."""

    def _run(self, replay, depth):
        rng = np.random.default_rng(0)
        table = rng.normal(size=(2048, 8)).astype(np.float32)
        cfg = ServeConfig(vocab=2048, batch_requests=16,
                          keys_per_request=8, cache_capacity=256,
                          replan_every=6, pipeline_depth=depth)
        rt = ServingRuntime(table, cfg)
        return rt.run(replay, rounds=30, collect_outputs=True)

    def test_depths_identical_to_sequential(self):
        live = DriftingZipfStream(2048, 8, zipf_a=1.2, arrival_rate=16,
                                  scenario="rotate", rotate_every=10,
                                  seed=5)
        replay = ReplayStream.record(live, 50)
        base = self._run(replay, 0)
        assert base.zero_served == 0
        for depth in (1, 2, 4):
            got = self._run(replay, depth)
            assert got.served == base.served
            assert got.requeues == base.requeues
            assert got.replans == base.replans
            assert got.replan_rounds == base.replan_rounds
            assert got.miss_trace == base.miss_trace
            assert got.zero_served == 0
            assert set(got.outputs) == set(base.outputs)
            for rid in base.outputs:
                np.testing.assert_array_equal(got.outputs[rid],
                                              base.outputs[rid])

    def test_double_buffer_alias_maps_to_depth(self):
        """Back-compat: the PR-6 one-slot flag is now an alias for
        pipeline_depth 1/0, readable as a derived property."""
        table = np.zeros((64, 4), np.float32)
        rt1 = ServingRuntime(table, ServeConfig(
            vocab=64, cache_capacity=16, double_buffer=True))
        rt0 = ServingRuntime(table, ServeConfig(
            vocab=64, cache_capacity=16, double_buffer=False))
        assert rt1.pipeline_depth == 1 and rt1.double_buffer
        assert rt0.pipeline_depth == 0 and not rt0.double_buffer
        rt2 = ServingRuntime(table, ServeConfig(
            vocab=64, cache_capacity=16, pipeline_depth=4))
        assert rt2.pipeline_depth == 4 and rt2.double_buffer


# --------------------------------------------------------------------------
# controller event schema (satellite 2)
# --------------------------------------------------------------------------
class TestForceEventSchema:
    def test_ctl_force_always_carries_target(self):
        """Every ctl.force event renders on the report knob timeline:
        knob, value, cause AND the triggering target ride every emit
        (the serve runtime's overlap calibration goes through
        force_at_least like every other signal rule)."""
        bus = Telemetry()
        ctl = OnlineController(
            [Knob("pipeline_depth", (0, 1, 2, 4), prefer_low=True)],
            telemetry=bus)
        assert ctl.force_at_least("pipeline_depth", 2,
                                  cause="overlap") == 2
        assert ctl.force_at_least("pipeline_depth", 2) is None  # no-op
        evs = bus.events("ctl.force")
        assert len(evs) == 1
        for ev in evs:
            for f in ("knob", "value", "cause", "target"):
                assert f in ev, f
        assert evs[0]["target"] == 2 and evs[0]["cause"] == "overlap"

    def test_runtime_emits_no_bare_force(self):
        """Grep-level guard: every ctl.force on the bus from a serve run
        carries the unified schema."""
        rng = np.random.default_rng(0)
        table = rng.normal(size=(512, 8)).astype(np.float32)
        stream = DriftingZipfStream(512, 8, zipf_a=1.1, arrival_rate=8,
                                    seed=1)
        bus = Telemetry()
        cfg = ServeConfig(vocab=512, batch_requests=8, keys_per_request=8,
                          cache_capacity=64, replan_every=4)
        ServingRuntime(table, cfg, telemetry=bus).run(stream, rounds=12)
        for ev in bus.events("ctl.force"):
            assert {"knob", "value", "cause", "target"} <= set(ev)
