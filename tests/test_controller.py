"""Tests for the zero-tuning control plane (DESIGN.md §13): the telemetry
bus, the online controller (signal rules + hill-climb + settling), and the
mid-run replica-cache capacity resize — byte-identical serving results
across every resize boundary, with the per-bucket jit cache never
recompiling a revisited bucket."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import blocking
from repro.obs import Reservoir, Telemetry, default_bus
from repro.pm.controller import (AUTO, Knob, OnlineController,
                                 capacity_ladder, is_auto, overlap_pays,
                                 pow2_ladder, resolve_knob)
from repro.serve import (DriftingZipfStream, ReplayStream, ServeConfig,
                         ServeRequest, ServingRuntime)


class TestTelemetry:
    def test_counter_gauge_reservoir_roundtrip(self):
        bus = Telemetry()
        bus.inc("serve.replans")
        bus.inc("serve.replans", 2)
        assert bus.counter_value("serve.replans") == 3
        assert bus.counter_value("never.touched") == 0
        bus.set("serve.miss_rate", 0.25)
        bus.set("serve.miss_rate", 0.5)          # last write wins
        assert bus.gauge_value("serve.miss_rate") == 0.5
        assert bus.gauge_value("never.touched", default=7.0) == 7.0
        bus.observe("serve.round_ms", 1.0)
        bus.observe("serve.round_ms", 3.0)
        r = bus.latency("serve.round_ms")
        assert r.count == 2
        assert r.percentile(50) == 2.0

    def test_labels_are_distinct_keys_not_aggregated(self):
        bus = Telemetry()
        bus.inc("serve.replans", cause="drift")
        bus.inc("serve.replans", cause="cadence")
        bus.inc("serve.replans", cause="cadence")
        assert bus.counter_value("serve.replans", cause="drift") == 1
        assert bus.counter_value("serve.replans", cause="cadence") == 2
        # the label-free parent is NOT implicitly summed
        assert bus.counter_value("serve.replans") == 0

    def test_events_ordered_and_filterable(self):
        bus = Telemetry()
        bus.event("ctl.force", knob="cache_capacity", value=512)
        bus.event("serve.replan", round=3)
        bus.event("ctl.force", knob="cache_capacity", value=1024)
        forces = bus.events("ctl.force")
        assert [e["value"] for e in forces] == [512, 1024]
        assert forces[0]["_seq"] < forces[1]["_seq"]
        assert len(bus.events()) == 3

    def test_reservoir_bounds_memory_not_count(self):
        r = Reservoir(maxlen=8)
        for v in range(100):
            r.record(float(v))
        assert r.count == 100
        assert len(r._vals) == 8
        assert 0.0 <= r.percentile(50) <= 99.0

    def test_snapshot_and_summary_line(self):
        bus = Telemetry()
        bus.inc("a.count", 4)
        bus.set("b.gauge", 1.5)
        bus.observe("c.lat_ms", 2.0)
        snap = bus.snapshot()
        assert snap["counters"]["a.count"] == 4
        assert snap["gauges"]["b.gauge"] == 1.5
        assert snap["latencies"]["c.lat_ms"]["count"] == 1
        line = bus.summary_line(prefix="test")
        assert line.startswith("[test] ")
        assert "a.count=4" in line and "b.gauge=1.5" in line
        assert "c.lat_ms[p50=" in line


class TestKnobHelpers:
    def test_auto_sentinel_and_resolution(self):
        assert is_auto(AUTO) and is_auto("auto")
        assert not is_auto(64) and not is_auto(True)
        assert resolve_knob(AUTO, 64) == 64
        assert resolve_knob(512, 64) == 512

    def test_ladders_are_pow2_buckets(self):
        assert pow2_ladder(8, 256) == (8, 16, 32, 64, 128, 256)
        lad = capacity_ladder(65536)
        assert lad[0] == 64 and lad[-1] == 8192
        assert all(b == 2 * a for a, b in zip(lad, lad[1:]))
        # tiny vocab: ladder never collapses below the floor bucket
        assert capacity_ladder(128) == (64,)

    def test_overlap_pays_rule(self):
        assert not overlap_pays(None)
        assert not overlap_pays(1.1)
        assert overlap_pays(1.2)
        assert overlap_pays(1.05, threshold=1.0)


def _ctl(knobs, **kw):
    bus = Telemetry()
    kw.setdefault("epsilon", 0.0)        # deterministic cycle for units
    return OnlineController(knobs, bus, **kw), bus


class TestControllerSignalRules:
    def test_force_at_least_jumps_to_covering_bucket(self):
        ctl, bus = _ctl([Knob("cache_capacity", (64, 128, 256, 512),
                              adapt=False)])
        assert ctl.force_at_least("cache_capacity", 200) == 256
        assert ctl.value("cache_capacity") == 256
        # already covered: no move, no event
        assert ctl.force_at_least("cache_capacity", 100) is None
        # beyond the top: clamps to the last bucket
        assert ctl.force_at_least("cache_capacity", 10_000) == 512
        assert [e["value"] for e in bus.events("ctl.force")] == [256, 512]

    def test_steer_capacity_grows_now_shrinks_patiently(self):
        ctl, bus = _ctl([Knob("cache_capacity", (64, 256, 1024, 4096),
                              adapt=False, prefer_low=True)],
                        shrink_patience=2)
        # hard signal: demand jumps straight to the covering bucket
        assert ctl.steer_capacity("cache_capacity", 900) == 1024
        # low demand with >= 4x gap: first sighting only starts the streak
        assert ctl.steer_capacity("cache_capacity", 40) is None
        assert ctl.value("cache_capacity") == 1024
        # second consecutive low replan: the shrink lands
        assert ctl.steer_capacity("cache_capacity", 40) == 64
        causes = [e["cause"] for e in bus.events("ctl.force")]
        assert causes == ["demand", "demand_low"]

    def test_demand_spike_resets_the_shrink_streak(self):
        ctl, _ = _ctl([Knob("cache_capacity", (64, 256, 1024),
                            adapt=False)], shrink_patience=2)
        ctl.steer_capacity("cache_capacity", 1000)
        ctl.steer_capacity("cache_capacity", 10)       # streak = 1
        ctl.steer_capacity("cache_capacity", 900)      # spike: streak reset
        assert ctl.steer_capacity("cache_capacity", 10) is None
        assert ctl.value("cache_capacity") == 1024

    def test_mild_demand_drop_never_shrinks(self):
        # hysteresis: shrink needs a >= 4x gap, not just "lower"
        ctl, _ = _ctl([Knob("cache_capacity", (64, 256, 1024),
                            adapt=False)], shrink_patience=1)
        ctl.steer_capacity("cache_capacity", 1000)
        for _ in range(5):
            assert ctl.steer_capacity("cache_capacity", 400) is None
        assert ctl.value("cache_capacity") == 1024


class TestControllerHillClimb:
    def test_accept_keeps_move_revert_restores(self):
        ctl, bus = _ctl([Knob("replan_every", (2, 4, 8, 16), index=1)])
        assert ctl.observe(100.0) == {"replan_every": 8}   # propose up
        assert ctl.observe(120.0) == {}                    # improved: keep
        assert ctl.value("replan_every") == 8
        assert ctl.observe(120.0) == {"replan_every": 16}  # next trial
        assert ctl.observe(90.0) == {"replan_every": 8}    # worse: revert
        trials = bus.events("ctl.trial")
        assert [t["accepted"] for t in trials] == [True, False]

    def test_prefer_low_accepts_a_tie_downward(self):
        k = Knob("cache_capacity", (64, 128, 256), index=2, prefer_low=True)
        ctl, _ = _ctl([k], tol=0.05)
        ctl._last_dir["cache_capacity"] = -1
        assert ctl.observe(100.0) == {"cache_capacity": 128}
        # same throughput for less resource: the downward move sticks
        assert ctl.observe(98.0) == {}
        assert ctl.value("cache_capacity") == 128

    def test_ladder_edges_bounce_direction(self):
        ctl, _ = _ctl([Knob("b", (8, 16), index=1)])
        ctl._last_dir["b"] = 1
        assert ctl.observe(1.0) == {"b": 8}    # up blocked: bounces down
        ctl.observe(2.0)

    def test_settles_after_consecutive_reverts_and_unsettles_on_signal(self):
        ctl, bus = _ctl([Knob("replan_every", (2, 4, 8), index=1),
                         Knob("cache_capacity", (64, 256), adapt=False)],
                        settle_after=2)
        for _ in range(2):                     # two trials, both worse
            assert ctl.observe(100.0) != {}
            assert ctl.observe(50.0) == {"replan_every": 4}
        assert len(bus.events("ctl.settle")) == 1
        # settled: no further proposals tax steady-state throughput
        for _ in range(4):
            assert ctl.observe(100.0) == {}
        # a signal-rule move changes the regime: exploration reopens
        ctl.force_at_least("cache_capacity", 256)
        assert ctl.observe(100.0) == {"replan_every": 8}

    def test_adapt_false_knobs_never_hill_climbed(self):
        ctl, _ = _ctl([Knob("cache_capacity", (64, 256, 1024),
                            adapt=False)])
        for r in (1.0, 2.0, 3.0, 4.0):
            assert ctl.observe(r) == {}
        assert ctl.value("cache_capacity") == 64

    def test_same_seed_same_trajectory(self):
        def run(seed):
            ctl = OnlineController(
                [Knob("a", (2, 4, 8), index=1), Knob("b", (8, 16, 32))],
                Telemetry(), epsilon=0.3, seed=seed)
            rewards = [10, 12, 11, 13, 9, 14, 14, 8, 15, 15]
            return [dict(ctl.observe(float(r))) for r in rewards], \
                ctl.values()
        assert run(3) == run(3)


# --------------------------------------------------------------------------
# Mid-run capacity resize: exactness, zero-served, and jit-bucket reuse
# --------------------------------------------------------------------------

V, D, K, B = 2048, 16, 8, 16
BUCKETS = (64, 256, 1024)


def _record_trace(rounds, seed, rid_offset=0):
    stream = DriftingZipfStream(V, K, zipf_a=1.2, arrival_rate=B,
                                scenario="steady", seed=seed)
    per_round = [[ServeRequest(r.rid + rid_offset, r.keys)
                  for r in stream.arrivals(rnd)] for rnd in range(rounds)]
    by_rid = {r.rid: r.keys for row in per_round for r in row}
    return per_round, by_rid


def _drain_rounds(n_arrival_rounds, rt):
    # arrivals stop after the trace; extra empty rounds let the scheduler
    # drain the warm-up backlog so every segment ends with an empty queue
    return n_arrival_rounds + rt.replan_every + 6


class TestMidRunCapacityResize:
    @pytest.mark.parametrize("kernel", [False, True],
                             ids=["nokernel", "kernel"])
    def test_resize_across_buckets_byte_identical(self, kernel):
        """Segments served at capacities {64, 256, 1024} (and back down),
        resized mid-run via the public hook: every served row stays a
        byte-identical copy of the table row, no batch is ever
        zero-served, and revisiting a capacity bucket re-uses the jitted
        executables compiled on the first visit."""
        rng = np.random.default_rng(0)
        table = rng.normal(size=(V, D)).astype(np.float32)
        cfg = ServeConfig(vocab=V, batch_requests=B, keys_per_request=K,
                          cache_capacity=BUCKETS[0], replan_every=4,
                          refresh_every=0, double_buffer=False,
                          kernel=kernel, summary=False)
        rt = ServingRuntime(table, cfg)

        # pass 1 visits each bucket on a fresh trace; pass 2 revisits the
        # SAME key traces (fresh rids) at the same capacities, so every
        # (capacity, miss-capacity) shape repeats and the jit caches must
        # already hold it
        arrival_rounds = 10
        plan_pass = [(cap, i) for i, cap in enumerate(BUCKETS)]
        segments = plan_pass + [(cap, i + len(BUCKETS))
                                for i, cap in enumerate(BUCKETS)]
        traces, refs = [], {}
        for si, (cap, seed) in enumerate(segments):
            per_round, by_rid = _record_trace(
                arrival_rounds, seed=segments[si % len(plan_pass)][1],
                rid_offset=si * 100_000)
            traces.append(ReplayStream(per_round))
            refs.update(by_rid)

        sizes_after_first_pass = None
        for si, ((cap, _), replay) in enumerate(zip(segments, traces)):
            if rt.cache_capacity != cap:
                rt.resize_capacity(cap)
            res = rt.run(replay, rounds=_drain_rounds(arrival_rounds, rt),
                         collect_outputs=True)
            assert rt.cache_capacity == cap
            # exactness across the resize boundary: managed serving is a
            # pure gather no matter which rows the replica cache holds
            assert res.zero_served == 0, f"segment {si} (cap={cap})"
            assert res.outputs, f"segment {si} served nothing"
            for rid, rows in res.outputs.items():
                np.testing.assert_array_equal(
                    np.asarray(rows), table[refs[rid]],
                    err_msg=f"segment {si} cap={cap} rid={rid}")
            assert len(rt.queue) == 0    # drained: segments independent
            if si == len(plan_pass) - 1:
                sizes_after_first_pass = rt._managed_fn(0)._cache_size()
        # repeat pass saw only already-compiled buckets
        assert rt._managed_fn(0)._cache_size() == sizes_after_first_pass
        assert rt.telemetry.counter_value("serve.capacity_resizes") \
            == len(segments) - 1

    def test_controller_steered_resize_stays_exact(self):
        """cache_capacity="auto": the intent signal grows the bucket from
        the untuned floor mid-run, and the resize never costs a
        zero-served batch or an inexact row."""
        rng = np.random.default_rng(1)
        table = rng.normal(size=(V, D)).astype(np.float32)
        cfg = ServeConfig(vocab=V, batch_requests=B, keys_per_request=K,
                          cache_capacity=AUTO, replan_every=4,
                          refresh_every=0, double_buffer=False,
                          summary=False)
        rt = ServingRuntime(table, cfg)
        assert rt.cache_capacity == capacity_ladder(V)[0]  # untuned floor
        per_round, by_rid = _record_trace(24, seed=9)
        res = rt.run(ReplayStream(per_round), rounds=30,
                     collect_outputs=True)
        assert res.capacity_resizes >= 1
        assert res.capacity_trace[0][1] > capacity_ladder(V)[0]
        assert res.zero_served == 0
        for rid, rows in res.outputs.items():
            np.testing.assert_array_equal(np.asarray(rows), table[by_rid[rid]])
        # the steer is on the bus with its cause
        assert any(e["cause"] == "demand"
                   for e in rt.telemetry.events("ctl.force"))


class TestOverlapCalibrationTelemetry:
    def test_calibration_is_a_bus_record_not_a_startup_print(self, capsys):
        rng = np.random.default_rng(2)
        table = rng.normal(size=(V, D)).astype(np.float32)
        cfg = ServeConfig(vocab=V, batch_requests=B, keys_per_request=K,
                          cache_capacity=256, replan_every=4,
                          summary=False)
        rt = ServingRuntime(table, cfg)
        per_round, _ = _record_trace(6, seed=3)
        rt.run(ReplayStream(per_round), rounds=8)
        assert capsys.readouterr().out == ""     # silent run
        # ... but the measurement landed on the bus
        assert rt.overlap_ratio is not None
        assert rt.telemetry.gauge_value("serve.overlap_ratio") \
            == pytest.approx(rt.overlap_ratio)
        assert rt.telemetry.gauge_value("serve.overlap_host_ms") > 0

    def test_summary_prints_one_shutdown_line(self, capsys):
        rng = np.random.default_rng(2)
        table = rng.normal(size=(V, D)).astype(np.float32)
        cfg = ServeConfig(vocab=V, batch_requests=B, keys_per_request=K,
                          cache_capacity=256, replan_every=4,
                          summary=True)
        rt = ServingRuntime(table, cfg)
        per_round, _ = _record_trace(6, seed=3)
        rt.run(ReplayStream(per_round), rounds=8)
        rt.run(ReplayStream([]), rounds=2)       # second run: no re-print
        out = capsys.readouterr().out.strip().splitlines()
        assert len(out) == 1
        assert out[0].startswith("[serve] ") and "overlap~" in out[0]


class TestAutotuneTelemetry:
    def test_fresh_tile_decision_lands_on_default_bus_once(self):
        blocking.clear_autotune_cache()
        bus = default_bus()
        before = len(bus.events("autotune.blocks"))
        br, bd = blocking.pick_blocks("testkind", 96, 384)
        after_first = bus.events("autotune.blocks")[before:]
        assert len(after_first) == 1
        ev = after_first[0]
        assert ev["source"] in ("measured", "heuristic")
        assert (ev["block_r"], ev["block_d"]) == (br, bd)
        # cache re-hit: no duplicate event
        blocking.pick_blocks("testkind", 96, 384)
        assert len(bus.events("autotune.blocks")) == before + 1
