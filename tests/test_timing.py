"""Unit + property tests for adaptive action timing (paper §4.2, Alg. 1)."""

import math

import pytest
from _hyp import given, settings, st

from repro.core.timing import ActionTimer, poisson_quantile


class TestPoissonQuantile:
    def test_zero_rate(self):
        assert poisson_quantile(0.0, 0.9999) == 0

    def test_exact_small(self):
        # lam=1: cdf(0)=.3679, cdf(1)=.7358, cdf(2)=.9197, cdf(3)=.9810,
        # cdf(4)=.99634, cdf(5)=.99941, cdf(6)=.999917
        assert poisson_quantile(1.0, 0.5) == 1
        assert poisson_quantile(1.0, 0.9) == 2
        assert poisson_quantile(1.0, 0.99) == 4
        assert poisson_quantile(1.0, 0.9999) == 6

    def test_median_near_rate(self):
        for lam in [2.0, 5.0, 20.0, 50.0]:
            q = poisson_quantile(lam, 0.5)
            assert abs(q - lam) <= max(2, 0.2 * lam)

    @given(lam=st.floats(min_value=0.01, max_value=500.0),
           p=st.sampled_from([0.9, 0.99, 0.999, 0.9999]))
    @settings(max_examples=200, deadline=None)
    def test_upper_bound_property(self, lam, p):
        """High quantiles sit above the mean and grow with p and lam."""
        q = poisson_quantile(lam, p)
        assert q >= math.floor(lam)
        assert poisson_quantile(lam, 0.9999) >= poisson_quantile(lam, 0.9)
        assert poisson_quantile(2 * lam, p) >= q

    @given(lam=st.floats(min_value=0.1, max_value=63.0))
    @settings(max_examples=100, deadline=None)
    def test_exact_region_is_true_quantile(self, lam):
        """In the exact-summation region the result is the true quantile."""
        p = 0.999
        q = poisson_quantile(lam, p)
        # CDF(q) >= p and CDF(q-1) < p
        def cdf(k):
            pmf = math.exp(-lam)
            tot = pmf
            for i in range(1, k + 1):
                pmf *= lam / i
                tot += pmf
            return tot
        assert cdf(q) >= p - 1e-12
        if q > 0:
            assert cdf(q - 1) < p


class TestActionTimer:
    def test_smoothing_update(self):
        t = ActionTimer(alpha=0.1, lam0=10.0)
        t.observe_round(0, 20)  # delta 20
        assert t.rate(0) == pytest.approx(0.9 * 10.0 + 0.1 * 20.0)

    def test_no_update_on_zero_delta(self):
        """§4.2.2: paused workers must not shrink the estimate."""
        t = ActionTimer(alpha=0.1, lam0=10.0)
        t.observe_round(0, 5)
        lam = t.rate(0)
        for _ in range(50):
            t.observe_round(0, 5)  # clock stuck
        assert t.rate(0) == pytest.approx(lam)

    def test_max_heuristic_escapes_slow_regime(self):
        """If the last observed delta exceeds the estimate, the horizon uses
        the observation (Alg. 1 ``max(lam_hat, Delta)``)."""
        t = ActionTimer(alpha=0.1, lam0=1.0)
        t.observe_round(0, 100)  # sudden jump: delta=100 >> lam_hat
        lam_used = 2.0 * max(t.rate(0), 100)
        from repro.core.timing import poisson_quantile as q
        assert t.horizon(0) == q(lam_used, t.p)

    def test_should_act_boundary(self):
        t = ActionTimer(lam0=10.0)
        h = t.horizon(0)
        clock = 50
        t._est(0).last_clock = clock
        assert t.should_act(0, clock, clock + h - 1)
        assert not t.should_act(0, clock, clock + h)

    def test_act_early_not_late(self):
        """With a steady clock rate, the horizon must cover at least two
        rounds of advancement at any reasonable quantile (err-early bias)."""
        t = ActionTimer(alpha=0.1, p=0.9999, lam0=10.0)
        clock = 0
        for _ in range(100):
            clock += 10
            t.observe_round(0, clock)
        assert t.horizon(0) >= 20  # 2 rounds' worth of clocks

    @given(deltas=st.lists(st.integers(min_value=0, max_value=200),
                           min_size=1, max_size=100))
    @settings(max_examples=100, deadline=None)
    def test_estimate_bounded_by_observations(self, deltas):
        """The smoothed rate stays within [min_obs, max(lam0, max_obs)]."""
        t = ActionTimer(alpha=0.1, lam0=10.0)
        clock = 0
        for d in deltas:
            clock += d
            t.observe_round(0, clock)
        pos = [d for d in deltas if d > 0]
        if pos:
            lo = min(min(pos), 10.0)
            hi = max(max(pos), 10.0)
            assert lo - 1e-9 <= t.rate(0) <= hi + 1e-9

    def test_monotone_clock_enforced(self):
        t = ActionTimer()
        t.observe_round(0, 10)
        with pytest.raises(ValueError):
            t.observe_round(0, 5)
