"""Property tests for the numerical core: blocked (flash-style) attention
vs a naive softmax oracle, decode attention vs the same oracle, and the
chunked linear scan vs a sequential reference."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models.layers import decode_attention, flash_attention
from repro.models.ssm import _chunked_linear_scan


def naive_attention(q, k, v, causal, window, kv_valid=None):
    B, Sq, H, hd = q.shape
    Skv, KvH = k.shape[1], k.shape[2]
    rep = H // KvH
    k = np.repeat(np.asarray(k), rep, axis=2)
    v = np.repeat(np.asarray(v), rep, axis=2)
    q = np.asarray(q)
    s = np.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    qp = np.arange(Sq)[:, None]
    kp = np.arange(Skv)[None, :]
    mask = np.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    s = np.where(mask[None, None], s, -np.inf)
    s = s - np.max(s, axis=-1, keepdims=True)
    p = np.exp(s)
    p = np.where(mask[None, None], p, 0.0)
    p = p / np.maximum(p.sum(-1, keepdims=True), 1e-20)
    return np.einsum("bhqk,bkhd->bqhd", p, v)


@given(
    sq=st.integers(1, 33),
    skv_extra=st.integers(0, 17),
    h=st.sampled_from([1, 2, 4]),
    kv_ratio=st.sampled_from([1, 2]),
    causal=st.booleans(),
    window=st.sampled_from([0, 3, 8]),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=40, deadline=None)
def test_flash_matches_naive(sq, skv_extra, h, kv_ratio, causal, window,
                             seed):
    if h % kv_ratio:
        kv_ratio = 1
    skv = sq + skv_extra if not causal else sq
    rng = np.random.default_rng(seed)
    hd = 8
    q = jnp.asarray(rng.normal(size=(2, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, skv, h // kv_ratio, hd)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, skv, h // kv_ratio, hd)),
                    jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=8, kv_block=16)
    exp = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), exp, rtol=2e-4, atol=2e-5)


@given(cache_len=st.integers(1, 20), window=st.sampled_from([0, 4]),
       seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_decode_matches_naive(cache_len, window, seed):
    rng = np.random.default_rng(seed)
    B, S, H, KvH, hd = 2, 24, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, KvH, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, KvH, hd)), jnp.float32)
    out = decode_attention(q, kc, vc, jnp.asarray(cache_len),
                           window=window)
    valid = np.arange(S) < cache_len
    if window:
        valid &= np.arange(S) >= cache_len - window
    exp = naive_attention(q, kc, vc, causal=False, window=0,
                          kv_valid=valid)
    np.testing.assert_allclose(np.asarray(out), exp, rtol=2e-4, atol=2e-5)


@given(s=st.integers(1, 70), chunk=st.sampled_from([1, 4, 16, 64]),
       seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_chunked_scan_matches_sequential(s, chunk, seed):
    rng = np.random.default_rng(seed)
    B, D = 2, 3
    a = jnp.asarray(rng.uniform(0.5, 1.0, size=(B, s, D)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, s, D)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    h, h_last = _chunked_linear_scan(a, b, h0, chunk)
    # sequential reference
    hs = []
    cur = np.asarray(h0)
    for t in range(s):
        cur = np.asarray(a[:, t]) * cur + np.asarray(b[:, t])
        hs.append(cur.copy())
    exp = np.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h), exp, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_last), exp[:, -1],
                               rtol=2e-4, atol=2e-5)


def test_flash_q_offset_decode_consistency():
    """q_offset shifts the causal mask (prefill continuation)."""
    rng = np.random.default_rng(0)
    B, S, H, hd = 1, 8, 2, 8
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, 1, H, hd)), jnp.float32)
    # a query at absolute position S-1 sees everything
    out = flash_attention(q, k, v, causal=True, q_offset=S - 1)
    exp = naive_attention(q, k, v, causal=False, window=0)
    np.testing.assert_allclose(np.asarray(out), exp, rtol=2e-4, atol=2e-5)
