"""Tests for the collective-backend layer (DESIGN.md §10): the mesh-real
`shard_map` data path vs the emulated single-device reference vs a plain
dense lookup, across shard counts, overflow, kernel on/off, and full
train-loop loss traces.

The mesh cases need a multi-device host — CI provides one with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the mesh smoke
job; in the full tier-1 run `tests/test_dryrun.py`'s import-time flag
provides 512); on a single-device host they skip.  The skip conditions
are string-form on purpose: pytest evaluates those lazily at run time,
so collecting this module never initializes the jax backend (which would
freeze the device count before other modules' import-time XLA_FLAGS take
effect)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pm.collectives import EMULATED, EmulatedBackend, MeshBackend
from repro.pm.embedding import (combine_miss_buffer, make_state, pm_lookup,
                                plain_lookup, plain_serve_lookup,
                                planned_serve_lookup, probe_host,
                                serve_lookup, shard_partial_sum)

V, D, C = 256, 32, 16


def needs(n):
    return pytest.mark.skipif(
        f"len(jax.devices()) < {n}",
        reason=f"needs {n} devices (XLA_FLAGS="
        f"--xla_force_host_platform_device_count={n})")


SHARD_COUNTS = [pytest.param(1),
                pytest.param(2, marks=needs(2)),
                pytest.param(8, marks=needs(8))]


def mesh_backend(n: int) -> MeshBackend:
    from repro.launch.mesh import make_model_mesh
    return MeshBackend(make_model_mesh(n))


def setup(seed=0, cache_ids=None):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=jnp.float32)
    if cache_ids is None:
        cache_ids = np.sort(rng.choice(V, size=C, replace=False))
    cache_ids = jnp.asarray(cache_ids, dtype=jnp.int32)
    return table, cache_ids, rng


class TestEmulatedBackendRefactor:
    """The refactor is behavior-preserving: the explicit EmulatedBackend
    is bitwise the legacy n_shards/kernel paths (single device)."""

    def test_default_backend_is_emulated_reference(self):
        table, cache_ids, rng = setup()
        st = make_state(table, cache_ids)
        tokens = jnp.asarray(rng.integers(0, V, size=(4, 8)), jnp.int32)
        a = pm_lookup(table, st.cache_ids, st.cache_rows, tokens, 16)
        b = pm_lookup(table, st.cache_ids, st.cache_rows, tokens, 16,
                      False, False, EMULATED)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shard_partial_sum_alias(self):
        """The legacy entry point is the EmulatedBackend gather (barrier
        partials preserved: same rows for every shard count)."""
        table, _, rng = setup()
        ids = jnp.asarray(rng.integers(0, V, size=24), jnp.int32)
        direct = EmulatedBackend(4).gather_rows(table, ids)
        legacy = shard_partial_sum(table, ids, 4)
        np.testing.assert_array_equal(np.asarray(direct),
                                      np.asarray(legacy))
        np.testing.assert_array_equal(
            np.asarray(direct), np.asarray(jnp.take(table, ids, axis=0)))

    def test_one_shared_data_path(self):
        """All three managed variants produce identical rows for the same
        probe — they are thin wrappers over `combine_miss_buffer`."""
        table, cache_ids, rng = setup()
        st = make_state(table, cache_ids)
        tokens = rng.integers(0, V, size=(4, 6)).astype(np.int32)
        # capacity T: every unique miss fits, so all four variants agree
        # with the dense lookup too (no overflow semantics in play)
        hp = probe_host(np.asarray(cache_ids), tokens.reshape(-1), 24)
        shared = combine_miss_buffer(
            EMULATED, table, st.cache_rows, jnp.asarray(hp.hit),
            jnp.asarray(hp.cache_slot), jnp.asarray(hp.buf_ids),
            jnp.asarray(hp.buf_slot))
        planned = planned_serve_lookup(
            table, st.cache_rows, jnp.asarray(hp.buf_ids),
            jnp.asarray(hp.hit.astype(np.int32)),
            jnp.asarray(hp.cache_slot), jnp.asarray(hp.buf_slot))
        srv = serve_lookup(table, st.cache_ids, st.cache_rows,
                           jnp.asarray(tokens), 24)
        trn = pm_lookup(table, st.cache_ids, st.cache_rows,
                        jnp.asarray(tokens), 24)
        np.testing.assert_array_equal(np.asarray(shared),
                                      np.asarray(planned))
        np.testing.assert_array_equal(
            np.asarray(shared).reshape(4, 6, D), np.asarray(srv.out))
        np.testing.assert_array_equal(
            np.asarray(shared).reshape(4, 6, D), np.asarray(trn))

    def test_refresh_rows_pads_zero(self):
        table, _, _ = setup()
        ids = jnp.asarray([3, 7, V, V], jnp.int32)   # two pad slots
        rows = EMULATED.refresh_rows(table, ids)
        np.testing.assert_allclose(np.asarray(rows[:2]),
                                   np.asarray(table[jnp.asarray([3, 7])]))
        np.testing.assert_array_equal(np.asarray(rows[2:]), 0.0)


class TestMeshBackendEquivalence:
    """MeshBackend vs EmulatedBackend vs plain dense lookup, across shard
    counts, overflow slots and kernel on/off (the ISSUE 4 acceptance
    matrix)."""

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    @pytest.mark.parametrize("kernel", [False, True])
    def test_forward_matches_emulated_and_plain(self, n, kernel):
        table, cache_ids, rng = setup()
        be = mesh_backend(n)
        ts = be.place_table(table)
        st = make_state(ts, cache_ids, be)
        tokens = jnp.asarray(rng.integers(0, V, size=(4, 8)), jnp.int32)
        out = pm_lookup(ts, st.cache_ids, st.cache_rows, tokens, 64,
                        False, kernel, be)
        emu = pm_lookup(table, st.cache_ids,
                        EMULATED.refresh_rows(table, st.cache_ids),
                        tokens, 64, False, kernel)
        exp = plain_lookup(table, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(emu),
                                   rtol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    @pytest.mark.parametrize("kernel", [False, True])
    def test_backward_matches_emulated_and_plain(self, n, kernel):
        table, cache_ids, rng = setup()
        be = mesh_backend(n)
        ts = be.place_table(table)
        st = make_state(ts, cache_ids, be)
        tokens = jnp.asarray(rng.integers(0, V, size=(2, 12)), jnp.int32)

        def loss(t, backend, k):
            rows = st.cache_rows if backend is not None else \
                EMULATED.refresh_rows(table, st.cache_ids)
            out = pm_lookup(t, st.cache_ids, rows, tokens, 16, False, k,
                            backend)
            return jnp.sum(out ** 2)

        g_mesh = jax.grad(lambda t: loss(t, be, kernel))(ts)
        g_emu = jax.grad(lambda t: loss(t, None, kernel))(table)
        g_ref = jax.grad(
            lambda t: jnp.sum(plain_lookup(t, tokens) ** 2))(table)
        np.testing.assert_allclose(np.asarray(g_mesh), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(g_mesh), np.asarray(g_emu),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_overflow_fallback_and_strict_zeros(self, n):
        """Overflow slots behave identically on the mesh: non-strict falls
        back to the dense (backend) gather, strict reads zeros."""
        table, _, rng = setup()
        cache_ids = jnp.asarray(np.arange(100, 100 + C), jnp.int32)
        be = mesh_backend(n)
        ts = be.place_table(table)
        st = make_state(ts, cache_ids, be)
        tokens = jnp.asarray([[3, 5, 7, 9, 3, 5]], jnp.int32)  # 4 uniq miss
        out = pm_lookup(ts, st.cache_ids, st.cache_rows, tokens, 2,
                        False, False, be)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(plain_lookup(table, tokens)),
                                   rtol=1e-6)
        strict = np.asarray(pm_lookup(ts, st.cache_ids, st.cache_rows,
                                      tokens, 2, True, False, be))
        strict_emu = np.asarray(pm_lookup(
            table, st.cache_ids, EMULATED.refresh_rows(table, st.cache_ids),
            tokens, 2, True))
        np.testing.assert_allclose(strict, strict_emu, rtol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_serve_lookup_flags_match(self, n):
        table, _, rng = setup(cache_ids=np.arange(100, 100 + C))
        cache_ids = jnp.asarray(np.arange(100, 100 + C), jnp.int32)
        be = mesh_backend(n)
        ts = be.place_table(table)
        st = make_state(ts, cache_ids, be)
        tokens = jnp.asarray([[3, 5, 7, 9]], jnp.int32)
        r_mesh = serve_lookup(ts, st.cache_ids, st.cache_rows, tokens, 2,
                              backend=be)
        r_emu = serve_lookup(table, st.cache_ids,
                             EMULATED.refresh_rows(table, st.cache_ids),
                             tokens, 2)
        np.testing.assert_allclose(np.asarray(r_mesh.out),
                                   np.asarray(r_emu.out), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(r_mesh.overflow),
                                      np.asarray(r_emu.overflow))
        assert int(r_mesh.n_miss) == int(r_emu.n_miss)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_plain_serve_lookup_dense_psum(self, n):
        table, _, rng = setup()
        be = mesh_backend(n)
        ts = be.place_table(table)
        tokens = jnp.asarray(rng.integers(0, V, size=(3, 5)), jnp.int32)
        out = plain_serve_lookup(ts, tokens, backend=be)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(plain_lookup(table, tokens)),
                                   rtol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_refresh_grouped_allgather(self, n):
        """Replica sync through the mesh backend == the emulated gather,
        pad slots (id V) zero."""
        table, cache_ids, _ = setup()
        ids = jnp.concatenate([cache_ids[:C - 2],
                               jnp.full((2,), V, jnp.int32)])
        be = mesh_backend(n)
        ts = be.place_table(table)
        mesh_rows = be.refresh_rows(ts, ids)
        emu_rows = EMULATED.refresh_rows(table, ids)
        np.testing.assert_allclose(np.asarray(mesh_rows),
                                   np.asarray(emu_rows), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(mesh_rows[-2:]), 0.0)

    @needs(8)
    def test_vocab_divisibility_enforced(self):
        table = jnp.zeros((V + 4, D))   # 260 % 8 != 0
        be = mesh_backend(8)
        with pytest.raises(ValueError, match="divide"):
            be.gather_rows(table, jnp.asarray([1], jnp.int32))


class TestMeshTrainLoop:
    """The whole training stack over the mesh backend: identical losses
    to the single-device managed path, zero overflow fallbacks."""

    @needs(8)
    def test_50_step_loss_trace_matches_single_device(self):
        from repro.configs.registry import get_config
        from repro.train.loop import LoopConfig, train_loop
        cfg = get_config("smollm-135m", smoke=True)
        base = dict(steps=50, batch=4, seq=32, pm=True, cache_capacity=64,
                    log_every=0, seed=3)
        r_emu = train_loop(cfg, LoopConfig(**base))
        r_mesh = train_loop(cfg, LoopConfig(**base, collective="mesh",
                                            model_shards=8))
        np.testing.assert_allclose(r_mesh.losses, r_emu.losses,
                                   rtol=1e-4, atol=1e-5)
        assert r_mesh.overflows == 0
        assert r_mesh.plans >= 1

    @needs(8)
    @pytest.mark.slow
    def test_200_step_mesh_zero_overflow(self):
        """ISSUE 4 acceptance: the intent-derived per-shard capacity is
        exact on the mesh path too — 200 steps, no dense fallback."""
        from repro.configs.registry import get_config
        from repro.train.loop import LoopConfig, train_loop
        cfg = get_config("smollm-135m", smoke=True)
        res = train_loop(cfg, LoopConfig(steps=200, batch=4, seq=32,
                                         pm=True, cache_capacity=64,
                                         refresh_every=4, log_every=0,
                                         seed=5, collective="mesh",
                                         model_shards=8))
        assert res.overflows == 0
        assert res.plans > 1
        assert all(np.isfinite(res.losses))


class TestMeshServingRuntime:
    """End-to-end serving over the mesh backend: every served request
    gets exactly its table rows through the real psum data path."""

    @needs(8)
    def test_served_rows_exact_over_mesh(self):
        from repro.serve import (DriftingZipfStream, ReplayStream,
                                 ServeConfig, ServingRuntime)
        rng = np.random.default_rng(0)
        table = rng.normal(size=(2048, 8)).astype(np.float32)
        live = DriftingZipfStream(2048, 8, zipf_a=1.2, arrival_rate=16,
                                  scenario="rotate", rotate_every=10,
                                  seed=5)
        replay = ReplayStream.record(live, 40)
        rid_to_keys = {r.rid: r.keys for per in replay.per_round
                       for r in per}
        cfg = ServeConfig(vocab=2048, batch_requests=16,
                          keys_per_request=8, cache_capacity=256,
                          replan_every=6, collective="mesh",
                          model_shards=8)
        rt = ServingRuntime(table, cfg)
        res = rt.run(replay, rounds=20, collect_outputs=True)
        assert res.zero_served == 0
        assert res.served > 100
        for rid, rows in res.outputs.items():
            np.testing.assert_allclose(rows, table[rid_to_keys[rid]],
                                       rtol=1e-6)
