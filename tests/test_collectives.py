"""Tests for the collective-backend layer (DESIGN.md §10): the mesh-real
`shard_map` data path vs the emulated single-device reference vs a plain
dense lookup, across shard counts, overflow, kernel on/off, and full
train-loop loss traces.

The mesh cases need a multi-device host — CI provides one with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the mesh smoke
job; in the full tier-1 run `tests/test_dryrun.py`'s import-time flag
provides 512); on a single-device host they skip.  The skip conditions
are string-form on purpose: pytest evaluates those lazily at run time,
so collecting this module never initializes the jax backend (which would
freeze the device count before other modules' import-time XLA_FLAGS take
effect)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.pm.collectives import (EMULATED, EmulatedBackend, MeshBackend,
                                  route_block_cap)
from repro.pm.embedding import (combine_miss_buffer, make_state, pm_lookup,
                                plain_lookup, plain_serve_lookup,
                                planned_serve_lookup, probe_host,
                                serve_lookup, shard_partial_sum)

V, D, C = 256, 32, 16


def needs(n):
    return pytest.mark.skipif(
        f"len(jax.devices()) < {n}",
        reason=f"needs {n} devices (XLA_FLAGS="
        f"--xla_force_host_platform_device_count={n})")


SHARD_COUNTS = [pytest.param(1),
                pytest.param(2, marks=needs(2)),
                pytest.param(8, marks=needs(8))]


def mesh_backend(n: int) -> MeshBackend:
    from repro.launch.mesh import make_model_mesh
    return MeshBackend(make_model_mesh(n))


def setup(seed=0, cache_ids=None):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=jnp.float32)
    if cache_ids is None:
        cache_ids = np.sort(rng.choice(V, size=C, replace=False))
    cache_ids = jnp.asarray(cache_ids, dtype=jnp.int32)
    return table, cache_ids, rng


class TestEmulatedBackendRefactor:
    """The refactor is behavior-preserving: the explicit EmulatedBackend
    is bitwise the legacy n_shards/kernel paths (single device)."""

    def test_default_backend_is_emulated_reference(self):
        table, cache_ids, rng = setup()
        st = make_state(table, cache_ids)
        tokens = jnp.asarray(rng.integers(0, V, size=(4, 8)), jnp.int32)
        a = pm_lookup(table, st.cache_ids, st.cache_rows, tokens, 16)
        b = pm_lookup(table, st.cache_ids, st.cache_rows, tokens, 16,
                      False, False, EMULATED)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shard_partial_sum_alias(self):
        """The legacy entry point is the EmulatedBackend gather (barrier
        partials preserved: same rows for every shard count)."""
        table, _, rng = setup()
        ids = jnp.asarray(rng.integers(0, V, size=24), jnp.int32)
        direct = EmulatedBackend(4).gather_rows(table, ids)
        legacy = shard_partial_sum(table, ids, 4)
        np.testing.assert_array_equal(np.asarray(direct),
                                      np.asarray(legacy))
        np.testing.assert_array_equal(
            np.asarray(direct), np.asarray(jnp.take(table, ids, axis=0)))

    def test_one_shared_data_path(self):
        """All three managed variants produce identical rows for the same
        probe — they are thin wrappers over `combine_miss_buffer`."""
        table, cache_ids, rng = setup()
        st = make_state(table, cache_ids)
        tokens = rng.integers(0, V, size=(4, 6)).astype(np.int32)
        # capacity T: every unique miss fits, so all four variants agree
        # with the dense lookup too (no overflow semantics in play)
        hp = probe_host(np.asarray(cache_ids), tokens.reshape(-1), 24)
        shared = combine_miss_buffer(
            EMULATED, table, st.cache_rows, jnp.asarray(hp.hit),
            jnp.asarray(hp.cache_slot), jnp.asarray(hp.buf_ids),
            jnp.asarray(hp.buf_slot))
        planned = planned_serve_lookup(
            table, st.cache_rows, jnp.asarray(hp.buf_ids),
            jnp.asarray(hp.hit.astype(np.int32)),
            jnp.asarray(hp.cache_slot), jnp.asarray(hp.buf_slot))
        srv = serve_lookup(table, st.cache_ids, st.cache_rows,
                           jnp.asarray(tokens), 24)
        trn = pm_lookup(table, st.cache_ids, st.cache_rows,
                        jnp.asarray(tokens), 24)
        np.testing.assert_array_equal(np.asarray(shared),
                                      np.asarray(planned))
        np.testing.assert_array_equal(
            np.asarray(shared).reshape(4, 6, D), np.asarray(srv.out))
        np.testing.assert_array_equal(
            np.asarray(shared).reshape(4, 6, D), np.asarray(trn))

    def test_refresh_rows_pads_zero(self):
        table, _, _ = setup()
        ids = jnp.asarray([3, 7, V, V], jnp.int32)   # two pad slots
        rows = EMULATED.refresh_rows(table, ids)
        np.testing.assert_allclose(np.asarray(rows[:2]),
                                   np.asarray(table[jnp.asarray([3, 7])]))
        np.testing.assert_array_equal(np.asarray(rows[2:]), 0.0)


class TestMeshBackendEquivalence:
    """MeshBackend vs EmulatedBackend vs plain dense lookup, across shard
    counts, overflow slots and kernel on/off (the ISSUE 4 acceptance
    matrix)."""

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    @pytest.mark.parametrize("kernel", [False, True])
    def test_forward_matches_emulated_and_plain(self, n, kernel):
        table, cache_ids, rng = setup()
        be = mesh_backend(n)
        ts = be.place_table(table)
        st = make_state(ts, cache_ids, be)
        tokens = jnp.asarray(rng.integers(0, V, size=(4, 8)), jnp.int32)
        out = pm_lookup(ts, st.cache_ids, st.cache_rows, tokens, 64,
                        False, kernel, be)
        emu = pm_lookup(table, st.cache_ids,
                        EMULATED.refresh_rows(table, st.cache_ids),
                        tokens, 64, False, kernel)
        exp = plain_lookup(table, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(emu),
                                   rtol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    @pytest.mark.parametrize("kernel", [False, True])
    def test_backward_matches_emulated_and_plain(self, n, kernel):
        table, cache_ids, rng = setup()
        be = mesh_backend(n)
        ts = be.place_table(table)
        st = make_state(ts, cache_ids, be)
        tokens = jnp.asarray(rng.integers(0, V, size=(2, 12)), jnp.int32)

        def loss(t, backend, k):
            rows = st.cache_rows if backend is not None else \
                EMULATED.refresh_rows(table, st.cache_ids)
            out = pm_lookup(t, st.cache_ids, rows, tokens, 16, False, k,
                            backend)
            return jnp.sum(out ** 2)

        g_mesh = jax.grad(lambda t: loss(t, be, kernel))(ts)
        g_emu = jax.grad(lambda t: loss(t, None, kernel))(table)
        g_ref = jax.grad(
            lambda t: jnp.sum(plain_lookup(t, tokens) ** 2))(table)
        np.testing.assert_allclose(np.asarray(g_mesh), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(g_mesh), np.asarray(g_emu),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_overflow_fallback_and_strict_zeros(self, n):
        """Overflow slots behave identically on the mesh: non-strict falls
        back to the dense (backend) gather, strict reads zeros."""
        table, _, rng = setup()
        cache_ids = jnp.asarray(np.arange(100, 100 + C), jnp.int32)
        be = mesh_backend(n)
        ts = be.place_table(table)
        st = make_state(ts, cache_ids, be)
        tokens = jnp.asarray([[3, 5, 7, 9, 3, 5]], jnp.int32)  # 4 uniq miss
        out = pm_lookup(ts, st.cache_ids, st.cache_rows, tokens, 2,
                        False, False, be)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(plain_lookup(table, tokens)),
                                   rtol=1e-6)
        strict = np.asarray(pm_lookup(ts, st.cache_ids, st.cache_rows,
                                      tokens, 2, True, False, be))
        strict_emu = np.asarray(pm_lookup(
            table, st.cache_ids, EMULATED.refresh_rows(table, st.cache_ids),
            tokens, 2, True))
        np.testing.assert_allclose(strict, strict_emu, rtol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_serve_lookup_flags_match(self, n):
        table, _, rng = setup(cache_ids=np.arange(100, 100 + C))
        cache_ids = jnp.asarray(np.arange(100, 100 + C), jnp.int32)
        be = mesh_backend(n)
        ts = be.place_table(table)
        st = make_state(ts, cache_ids, be)
        tokens = jnp.asarray([[3, 5, 7, 9]], jnp.int32)
        r_mesh = serve_lookup(ts, st.cache_ids, st.cache_rows, tokens, 2,
                              backend=be)
        r_emu = serve_lookup(table, st.cache_ids,
                             EMULATED.refresh_rows(table, st.cache_ids),
                             tokens, 2)
        np.testing.assert_allclose(np.asarray(r_mesh.out),
                                   np.asarray(r_emu.out), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(r_mesh.overflow),
                                      np.asarray(r_emu.overflow))
        assert int(r_mesh.n_miss) == int(r_emu.n_miss)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_plain_serve_lookup_dense_psum(self, n):
        table, _, rng = setup()
        be = mesh_backend(n)
        ts = be.place_table(table)
        tokens = jnp.asarray(rng.integers(0, V, size=(3, 5)), jnp.int32)
        out = plain_serve_lookup(ts, tokens, backend=be)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(plain_lookup(table, tokens)),
                                   rtol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_refresh_grouped_allgather(self, n):
        """Replica sync through the mesh backend == the emulated gather,
        pad slots (id V) zero."""
        table, cache_ids, _ = setup()
        ids = jnp.concatenate([cache_ids[:C - 2],
                               jnp.full((2,), V, jnp.int32)])
        be = mesh_backend(n)
        ts = be.place_table(table)
        mesh_rows = be.refresh_rows(ts, ids)
        emu_rows = EMULATED.refresh_rows(table, ids)
        np.testing.assert_allclose(np.asarray(mesh_rows),
                                   np.asarray(emu_rows), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(mesh_rows[-2:]), 0.0)

    @needs(8)
    def test_vocab_divisibility_enforced(self):
        table = jnp.zeros((V + 4, D))   # 260 % 8 != 0
        be = mesh_backend(8)
        with pytest.raises(ValueError, match="divide"):
            be.gather_rows(table, jnp.asarray([1], jnp.int32))


class TestMeshTrainLoop:
    """The whole training stack over the mesh backend: identical losses
    to the single-device managed path, zero overflow fallbacks."""

    @needs(8)
    def test_50_step_loss_trace_matches_single_device(self):
        from repro.configs.registry import get_config
        from repro.train.loop import LoopConfig, train_loop
        cfg = get_config("smollm-135m", smoke=True)
        base = dict(steps=50, batch=4, seq=32, pm=True, cache_capacity=64,
                    log_every=0, seed=3)
        r_emu = train_loop(cfg, LoopConfig(**base))
        r_mesh = train_loop(cfg, LoopConfig(**base, collective="mesh",
                                            model_shards=8))
        np.testing.assert_allclose(r_mesh.losses, r_emu.losses,
                                   rtol=1e-4, atol=1e-5)
        assert r_mesh.overflows == 0
        assert r_mesh.plans >= 1

    @needs(8)
    @pytest.mark.slow
    def test_200_step_mesh_zero_overflow(self):
        """ISSUE 4 acceptance: the intent-derived per-shard capacity is
        exact on the mesh path too — 200 steps, no dense fallback."""
        from repro.configs.registry import get_config
        from repro.train.loop import LoopConfig, train_loop
        cfg = get_config("smollm-135m", smoke=True)
        res = train_loop(cfg, LoopConfig(steps=200, batch=4, seq=32,
                                         pm=True, cache_capacity=64,
                                         refresh_every=4, log_every=0,
                                         seed=5, collective="mesh",
                                         model_shards=8))
        assert res.overflows == 0
        assert res.plans > 1
        assert all(np.isfinite(res.losses))


class TestRoutedMissPath:
    """ISSUE 6 unit matrix: the destination-compacted routed primitives
    against the replicated-psum legacy path and the dense reference."""

    def test_route_block_cap_rule(self):
        # 2x-headroom even split, pow2-rounded, clamped to m
        assert route_block_cap(16, 1) == 16
        assert route_block_cap(16, 2) == 16
        assert route_block_cap(16, 8) == 4
        assert route_block_cap(24, 8) == 8
        assert route_block_cap(256, 8) == 64
        assert route_block_cap(1, 8) == 1

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    @pytest.mark.parametrize("kernel", [False, True])
    def test_routed_gather_matches_take(self, n, kernel):
        table, _, rng = setup()
        be = mesh_backend(n)
        ts = be.place_table(table)
        M, nv = 24, 17
        ids = np.full(M, V, np.int32)
        ids[:nv] = np.sort(rng.choice(V, nv, replace=False))
        for cap in (0, M):    # derived cap (cond arm for n=8) and pinned
            out = be.gather_rows_routed(ts, jnp.asarray(ids),
                                        jnp.int32(nv), route_cap=cap,
                                        kernel=kernel)
            np.testing.assert_allclose(
                np.asarray(out[:nv]),
                np.asarray(jnp.take(table, jnp.asarray(ids[:nv]), axis=0)),
                rtol=1e-6)
            # pad slots come back ZERO (stronger than gather_rows, which
            # returns row `pad_id` — callers read neither)
            np.testing.assert_array_equal(np.asarray(out[nv:]), 0.0)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    def test_routed_gather_skew_falls_back_to_psum(self, n):
        """Worst-case skew — every miss owned by shard 0 — exceeds a tiny
        pinned cap and must take the replicated-psum cond arm, still
        byte-correct with zero pad slots."""
        table, _, _ = setup()
        be = mesh_backend(n)
        ts = be.place_table(table)
        M, nv = 32, 20
        ids = np.full(M, V, np.int32)
        ids[:nv] = np.arange(nv)
        out = be.gather_rows_routed(ts, jnp.asarray(ids), jnp.int32(nv),
                                    route_cap=8)
        np.testing.assert_allclose(np.asarray(out[:nv]),
                                   np.asarray(table[:nv]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(out[nv:]), 0.0)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    @pytest.mark.parametrize("segmented", [False, True])
    def test_routed_scatter_matches_psum_and_dense(self, n, segmented):
        table, _, rng = setup()
        be = mesh_backend(n)
        T = 40
        tok = jnp.asarray(rng.integers(0, V, T), jnp.int32)
        g = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
        if segmented:
            ids, gg = ops.segment_rows(tok, g, n_slots=T, pad_id=V)
            args = (ids, gg.astype(g.dtype))
        else:
            args = (tok, g)
        routed = be.scatter_row_grads(*args, V, segmented=segmented)
        legacy = be.scatter_row_grads_psum(*args, V, segmented=segmented)
        dense = jnp.zeros((V, D), jnp.float32).at[tok].add(g)
        np.testing.assert_allclose(np.asarray(routed), np.asarray(dense),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(routed), np.asarray(legacy),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    @pytest.mark.parametrize("kernel", [False, True])
    def test_update_rows_matches_emulated(self, n, kernel):
        """The on-shard fused AdaGrad through the all_to_all router ==
        the single-device emulated update, untouched rows bit-identical."""
        table, _, rng = setup()
        accum = jnp.asarray(rng.uniform(0.01, 1.0, size=(V, D)),
                            jnp.float32)
        T = 48
        tok = jnp.asarray(rng.integers(0, V, T), jnp.int32)
        g = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
        seg_ids, seg_g = ops.segment_rows(tok, g, n_slots=T, pad_id=V)
        seg_g = seg_g.astype(jnp.float32)
        be = mesh_backend(n)
        mt, ma = be.update_rows(be.place_table(table),
                                be.place_table(accum), seg_ids, seg_g,
                                lr=0.05, kernel=kernel)
        et, ea = EMULATED.update_rows(table, accum, seg_ids, seg_g,
                                      lr=0.05, kernel=kernel)
        np.testing.assert_allclose(np.asarray(mt), np.asarray(et),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ma), np.asarray(ea),
                                   rtol=1e-5, atol=1e-6)
        mask = np.ones(V, bool)
        mask[np.asarray(tok)] = False
        np.testing.assert_array_equal(np.asarray(mt)[mask],
                                      np.asarray(table)[mask])


def _sorts_in(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "sort":
            n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                if isinstance(x, jax.core.ClosedJaxpr):
                    n += _sorts_in(x.jaxpr)
                elif isinstance(x, jax.core.Jaxpr):
                    n += _sorts_in(x)
    return n


def _dense_rows_in(jaxpr, vocab: int) -> list:
    """Shapes of broadcast-materialized buffers with a leading dim >= the
    full vocab — the dense (V, D) partials the routed path must never
    build.  `cond` bodies are exempt: the skew fallback arm is allowed to
    be dense."""
    bad = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "cond":
            continue
        if eqn.primitive.name == "broadcast_in_dim":
            shp = eqn.outvars[0].aval.shape
            if shp and isinstance(shp[0], int) and shp[0] >= vocab:
                bad.append(shp)
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                if isinstance(x, jax.core.ClosedJaxpr):
                    bad += _dense_rows_in(x.jaxpr, vocab)
                elif isinstance(x, jax.core.Jaxpr):
                    bad += _dense_rows_in(x, vocab)
    return bad


def _fused_setup():
    from repro.configs.registry import get_config
    from repro.models.model import init_model
    from repro.train.steps import make_opt_init
    cfg = get_config("smollm-135m", smoke=True).reduced(
        tie_embeddings=False, n_heads=3, n_kv_heads=3)
    rng = np.random.default_rng(0)
    params = init_model(cfg, jax.random.PRNGKey(0))
    opt = make_opt_init("adagrad")(params)
    tokens = rng.integers(0, cfg.vocab_size, size=(2, 16)).astype(np.int32)
    cache_ids = np.sort(rng.choice(cfg.vocab_size, 32,
                                   replace=False)).astype(np.int32)
    return cfg, params, opt, tokens, cache_ids


def _fused_batch(tokens, cache_ids, emb, be=None):
    st = make_state(emb, jnp.asarray(cache_ids), be)
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(tokens),
            "pm_cache_ids": st.cache_ids, "pm_cache_rows": st.cache_rows}


class TestMeshFusedStep:
    """ISSUE 6 tentpole acceptance: the managed train step over the mesh
    backend takes the routed fused sparse path — equal losses/params to
    the emulated fused AND emulated dense steps, exactly one sort in its
    jaxpr, no dense (V, D) buffer outside the fallback cond, and donated
    sharded table/accumulator."""

    M = 16

    def _step(self, cfg, kernel, be=None):
        from repro.train.steps import make_train_step
        return make_train_step(cfg, pm_miss_capacity=self.M,
                               pm_kernel=kernel, pm_backend=be, lr=0.05)

    def _placed(self, be, params, opt):
        mp = dict(params, embed=be.place_table(params["embed"]))
        mo = type(opt)(dict(opt.accum,
                            embed=be.place_table(opt.accum["embed"])))
        return mp, mo

    @pytest.mark.parametrize("n", SHARD_COUNTS)
    @pytest.mark.parametrize("kernel", [False, True])
    def test_matches_emulated_fused_and_dense(self, n, kernel):
        cfg, params, opt, tokens, cache_ids = _fused_setup()
        emb = params["embed"]
        l_dense, p_dense, _ = self._step(cfg, False)(
            params, opt, _fused_batch(tokens, cache_ids, emb))
        l_fused, p_fused, s_fused = self._step(cfg, True)(
            params, opt, _fused_batch(tokens, cache_ids, emb))
        assert np.allclose(float(l_fused), float(l_dense), rtol=1e-5)
        be = mesh_backend(n)
        mp, mo = self._placed(be, params, opt)
        lm, pm, sm = self._step(cfg, kernel, be)(
            mp, mo, _fused_batch(tokens, cache_ids, mp["embed"], be))
        assert np.allclose(float(lm), float(l_fused), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(pm["embed"]),
                                   np.asarray(p_fused["embed"]),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(pm["embed"]),
                                   np.asarray(p_dense["embed"]),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(sm.accum["embed"]),
                                   np.asarray(s_fused.accum["embed"]),
                                   atol=1e-5)

    @needs(8)
    @pytest.mark.parametrize("kernel", [False, True])
    def test_one_sort_and_no_dense_vocab_buffer(self, kernel):
        cfg, params, opt, tokens, cache_ids = _fused_setup()
        be = mesh_backend(8)
        mp, mo = self._placed(be, params, opt)
        batch = _fused_batch(tokens, cache_ids, mp["embed"], be)
        jaxpr = jax.make_jaxpr(self._step(cfg, kernel, be))(mp, mo, batch)
        assert _sorts_in(jaxpr.jaxpr) == 1
        assert _dense_rows_in(jaxpr.jaxpr, cfg.vocab_size) == []

    @needs(2)
    def test_donation_engages_re_feed_raises(self):
        """The guard `train.loop` relies on: donated sharded buffers are
        really consumed, so re-feeding the pre-step table is an error —
        the loop must thread the returned arrays, never the originals."""
        cfg, params, opt, tokens, cache_ids = _fused_setup()
        be = mesh_backend(2)
        mp, mo = self._placed(be, params, opt)
        batch = _fused_batch(tokens, cache_ids, mp["embed"], be)
        step = jax.jit(self._step(cfg, False, be), donate_argnums=(0, 1))
        _, new_p, _ = step(mp, mo, batch)
        jax.block_until_ready(new_p["embed"])
        with pytest.raises(RuntimeError):
            np.asarray(mp["embed"])

    @needs(8)
    def test_50_step_fused_trace_matches_emulated_dense(self):
        """Untied smoke config: the mesh loop runs the routed FUSED
        optimizer while the emulated loop runs the dense reference —
        identical loss traces, zero overflow fallbacks."""
        from repro.configs.registry import get_config
        from repro.train.loop import LoopConfig, train_loop
        cfg = get_config("smollm-135m", smoke=True).reduced(
            tie_embeddings=False, n_heads=3, n_kv_heads=3)
        base = dict(steps=50, batch=4, seq=32, pm=True, cache_capacity=64,
                    log_every=0, seed=3)
        r_emu = train_loop(cfg, LoopConfig(**base))
        r_mesh = train_loop(cfg, LoopConfig(**base, collective="mesh",
                                            model_shards=8))
        np.testing.assert_allclose(r_mesh.losses, r_emu.losses,
                                   rtol=1e-4, atol=1e-5)
        assert r_mesh.overflows == 0


class TestPerOwnerAdmission:
    """Serving admission for the routed miss path: `probe_host` flags
    per-owner overflow (DESIGN.md §12) and the planner publishes the
    matching `route_capacity` bound."""

    def test_probe_flags_per_owner_overflow(self):
        cache = np.full(4, V, np.int32)          # empty cache: all miss
        tok = np.asarray([1, 2, 3, 100, 3], np.int32)
        base = probe_host(cache, tok, 8)
        assert not base.overflow.any()
        # owner blocks of 32: ids {1,2,3} are owner 0 ranks 0..2, id 100
        # is owner 3 rank 0 — cap 2 overflows exactly id 3's tokens
        pr = probe_host(cache, tok, 8, owner_shards=8, route_capacity=2,
                        vocab=V)
        np.testing.assert_array_equal(np.asarray(pr.overflow), tok == 3)
        np.testing.assert_array_equal(np.asarray(pr.buf_ids),
                                      np.asarray(base.buf_ids))
        assert pr.n_miss == base.n_miss
        ok = probe_host(cache, tok, 8, owner_shards=8, route_capacity=3,
                        vocab=V)
        assert not ok.overflow.any()

    def test_probe_per_owner_off_without_mesh_args(self):
        cache = np.full(4, V, np.int32)
        tok = np.arange(20, dtype=np.int32)      # 20 misses in owner 0
        pr = probe_host(cache, tok, 32)          # no owner accounting
        assert not pr.overflow.any()

    def test_planner_publishes_route_capacity(self):
        from repro.pm.planner import IntentPlanner
        pl = IntentPlanner(vocab_size=256, cache_capacity=4, n_shards=2,
                           owner_shards=8)
        # ids 0..19 all live in owner 0 (block 32): the worst
        # per-(step, owner) unique-miss count is 20
        for step in range(4):
            pl.signal(step, 0, np.arange(20))
            pl.signal(step, 1, np.asarray([40, 41]))
        plan = pl.plan(0)
        assert plan.route_capacity >= 20
        # without owner accounting the field stays 0 (non-mesh backends)
        pl0 = IntentPlanner(vocab_size=256, cache_capacity=4, n_shards=2)
        pl0.signal(0, 0, np.asarray([1, 2]))
        assert pl0.plan(0).route_capacity == 0


class TestMeshServingRuntime:
    """End-to-end serving over the mesh backend: every served request
    gets exactly its table rows through the real psum data path."""

    @needs(8)
    def test_served_rows_exact_over_mesh(self):
        from repro.serve import (DriftingZipfStream, ReplayStream,
                                 ServeConfig, ServingRuntime)
        rng = np.random.default_rng(0)
        table = rng.normal(size=(2048, 8)).astype(np.float32)
        live = DriftingZipfStream(2048, 8, zipf_a=1.2, arrival_rate=16,
                                  scenario="rotate", rotate_every=10,
                                  seed=5)
        replay = ReplayStream.record(live, 40)
        rid_to_keys = {r.rid: r.keys for per in replay.per_round
                       for r in per}
        cfg = ServeConfig(vocab=2048, batch_requests=16,
                          keys_per_request=8, cache_capacity=256,
                          replan_every=6, collective="mesh",
                          model_shards=8)
        rt = ServingRuntime(table, cfg)
        res = rt.run(replay, rounds=20, collect_outputs=True)
        assert res.zero_served == 0
        assert res.served > 100
        for rid, rows in res.outputs.items():
            np.testing.assert_allclose(rows, table[rid_to_keys[rid]],
                                       rtol=1e-6)
