"""Tests for intent signaling primitives (paper §3)."""

import pytest
from _hyp import given, settings, st

from repro.core.intent import Intent, IntentTable, IntentType, LogicalClock
from repro.core.ownership import OwnershipDirectory, home_node


class TestIntent:
    def test_states(self):
        it = Intent(keys=(13, 16), c_start=2, c_end=3, worker_id=0)
        assert it.state(1) == "inactive"
        assert it.state(2) == "active"
        assert it.state(3) == "expired"

    def test_empty_window_rejected(self):
        with pytest.raises(ValueError):
            Intent(keys=(1,), c_start=5, c_end=5, worker_id=0)

    def test_types_exist(self):
        for t in (IntentType.READ, IntentType.WRITE, IntentType.READ_WRITE):
            Intent(keys=(1,), c_start=0, c_end=1, worker_id=0, type=t)


class TestLogicalClock:
    def test_advance(self):
        c = LogicalClock()
        assert c.advance() == 1
        assert c.advance(5) == 6
        with pytest.raises(ValueError):
            c.advance(-1)


class TestIntentTable:
    def test_active_and_future(self):
        t = IntentTable()
        t.signal(Intent(keys=(7,), c_start=2, c_end=4, worker_id=0))
        t.signal(Intent(keys=(7,), c_start=10, c_end=11, worker_id=1))
        clocks = {0: 3, 1: 0}
        assert t.has_active(7, clocks)
        assert t.active_workers(7, clocks) == {0}
        assert t.earliest_future_start(7, clocks) == (10, 1)

    def test_overlapping_intents_combine(self):
        """Workers can extend intents by signaling again (§3)."""
        t = IntentTable()
        t.signal(Intent(keys=(1,), c_start=0, c_end=2, worker_id=0))
        t.signal(Intent(keys=(1,), c_start=1, c_end=5, worker_id=0))
        assert t.has_active(1, {0: 3})     # covered by the extension
        assert not t.has_active(1, {0: 5})

    def test_gc(self):
        t = IntentTable()
        t.signal(Intent(keys=(1, 2), c_start=0, c_end=2, worker_id=0))
        t.gc({0: 2})
        assert len(t) == 0

    @given(windows=st.lists(
        st.tuples(st.integers(0, 20), st.integers(1, 10), st.integers(0, 3)),
        min_size=1, max_size=30),
        clock=st.integers(0, 40))
    @settings(max_examples=100, deadline=None)
    def test_active_matches_bruteforce(self, windows, clock):
        t = IntentTable()
        for (s, dur, w) in windows:
            t.signal(Intent(keys=(0,), c_start=s, c_end=s + dur, worker_id=w))
        clocks = {w: clock for _, _, w in windows}
        expected = {w for (s, dur, w) in windows if s <= clock < s + dur}
        assert t.active_workers(0, clocks) == expected
        assert t.has_active(0, clocks) == bool(expected)


class TestOwnership:
    def test_home_node_stable_and_spread(self):
        homes = [home_node(k, 8) for k in range(10_000)]
        assert homes == [home_node(k, 8) for k in range(10_000)]
        counts = [homes.count(n) for n in range(8)]
        assert min(counts) > 0.5 * 10_000 / 8  # roughly balanced

    def test_route_direct_after_cache_refresh(self):
        d = OwnershipDirectory(4)
        key = 42
        owner0 = d.owner_of(key)
        other = (owner0 + 1) % 4
        d.relocate(key, other)
        # first message goes via a stale view, later ones are direct
        hops1 = d.route((other + 1) % 4, key)
        hops2 = d.route((other + 1) % 4, key)
        assert hops1 >= hops2 == 1

    def test_owner_routes_free(self):
        d = OwnershipDirectory(4)
        k = 7
        assert d.route(d.owner_of(k), k) == 0

    @given(moves=st.lists(st.integers(0, 7), min_size=0, max_size=20))
    @settings(max_examples=50, deadline=None)
    def test_home_always_knows_owner(self, moves):
        """The home-node fallback is always correct: routing terminates with
        a bounded hop count no matter how often the key relocated."""
        d = OwnershipDirectory(8)
        k = 1234
        for m in moves:
            d.relocate(k, m)
        for src in range(8):
            assert d.route(src, k) <= 3
            # after one round trip the cache is fresh
            assert d.route(src, k) <= 1
