"""Tests for the dry-run machinery: HLO collective parser (synthetic
inputs), skip logic, input specs, sharding rules, and — once per test
session — one real lower+compile on the production mesh in a subprocess
(the 512-device XLA flag must be set before jax initializes)."""

import json
import subprocess
import sys

import numpy as np
import pytest

# NOTE: this module must not import jax-device-state-dependent parts of
# dryrun at module scope in-process; parser helpers are pure.
from repro.launch.dryrun import (_split_computations, collective_bytes,
                                 skip_reason)
from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES


SYNTH_HLO = """\
%region_0.1_spmd (param: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %all-gather = f32[64,512]{0,1} all-gather(%copy), channel_id=1
  ROOT %t = (s32[], f32[64,128]) tuple(%a, %b)
}
%region_1.2_spmd (param.1: (s32[], f32[64,128])) -> pred[] {
  %constant.18 = s32[] constant(7)
  ROOT %cmp = pred[] compare(%gte, %constant.18), direction=LT
}
ENTRY %main.4_spmd (param.2: f32[64,512]) -> f32[] {
  %while.8 = (s32[], f32[64,128]) while(%tuple.4), condition=%region_1.2_spmd, body=%region_0.1_spmd
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%x), channel_id=3
  ROOT %r = f32[] reduce(%y)
}
"""


class TestCollectiveParser:
    def test_split_computations(self):
        blocks = _split_computations(SYNTH_HLO)
        assert set(blocks) == {"%region_0.1_spmd", "%region_1.2_spmd",
                               "%main.4_spmd"}

    def test_trip_count_scaling(self):
        per_op = collective_bytes(SYNTH_HLO, default_trip=99.0)
        # all-gather inside the while body: 64*512*4 bytes x trip 7
        assert per_op["all-gather"] == pytest.approx(64 * 512 * 4 * 7)
        # all-reduce in main: 2x result bytes, no trip scaling
        assert per_op["all-reduce"] == pytest.approx(2 * 128 * 256 * 4)

    def test_default_trip_fallback(self):
        hlo = SYNTH_HLO.replace("%constant.18 = s32[] constant(7)", "")
        per_op = collective_bytes(hlo, default_trip=5.0)
        assert per_op["all-gather"] == pytest.approx(64 * 512 * 4 * 5)

    def test_bf16_and_tuple_shapes(self):
        hlo = ("ENTRY %main (p: bf16[4,8]) -> bf16[4,8] {\n"
               "  %all-to-all = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) "
               "all-to-all(%a, %b), channel_id=1\n}\n")
        per_op = collective_bytes(hlo)
        assert per_op["all-to-all"] == pytest.approx(2 * 4 * 8 * 2)


class TestSkipLogic:
    def test_long_500k_skips_full_attention(self):
        for arch in ("llama3-405b", "granite-20b", "whisper-medium",
                     "qwen3-moe-30b-a3b"):
            assert skip_reason(get_config(arch), SHAPES["long_500k"])

    def test_long_500k_runs_subquadratic(self):
        for arch in ("falcon-mamba-7b", "zamba2-1.2b", "mixtral-8x22b"):
            assert skip_reason(get_config(arch), SHAPES["long_500k"]) is None

    def test_all_other_shapes_never_skip(self):
        for arch in ARCH_IDS:
            for s in ("train_4k", "prefill_32k", "decode_32k"):
                assert skip_reason(get_config(arch), SHAPES[s]) is None


class TestShardingRules:
    def test_param_specs_divisibility(self):
        """No spec ever assigns a mesh axis to a non-dividing dim."""
        import jax
        from repro.launch.mesh import make_production_mesh
        from repro.launch.sharding import param_pspecs
        from repro.launch.dryrun import params_specs
        mesh = make_production_mesh()
        sizes = dict(mesh.shape)
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            sds = params_specs(cfg)
            specs = param_pspecs(sds, cfg, mesh)

            def check(path, leaf_spec, leaf_sds):
                for dim, ax in zip(leaf_sds.shape, tuple(leaf_spec)):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = 1
                    for a in axes:
                        n *= sizes[a]
                    assert dim % n == 0, (arch, path, leaf_sds.shape,
                                          tuple(leaf_spec))

            jax.tree_util.tree_map_with_path(check, specs, sds)

    def test_vocab_sharded_when_divisible(self):
        import jax
        from repro.launch.mesh import make_production_mesh
        from repro.launch.sharding import param_pspecs
        from repro.launch.dryrun import params_specs
        mesh = make_production_mesh()
        cfg = get_config("nemotron-4-15b")  # V=256000 divides 16
        specs = param_pspecs(params_specs(cfg), cfg, mesh,
                             zero_embed_head=False)
        assert tuple(specs["embed"]) [0] == "model"
        cfg_w = get_config("whisper-medium")  # V=51865 does not divide
        specs_w = param_pspecs(params_specs(cfg_w), cfg_w, mesh,
                               zero_embed_head=False)
        assert tuple(specs_w["embed"])[0] is None


@pytest.mark.slow
def test_real_dryrun_one_pair_subprocess(tmp_path):
    """One real lower+compile on the 16x16 production mesh (subprocess so
    the 512-host-device XLA flag applies before jax init)."""
    out = tmp_path / "dr.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "smollm-135m", "--shape", "decode_32k", "--out", str(out)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    rec = json.loads(out.read_text())[0]
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["collective_bytes"] > 0
