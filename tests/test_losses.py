"""Correctness of the shard_map vocab-parallel CE (§Perf iteration 3):
loss value and gradients must match the plain GSPMD loss.  Runs on a real
(2 data x 2 model) mesh of forced host devices in a subprocess."""

import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.models.losses import vocab_parallel_ce
    from repro.models.model import loss_fn

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    B, S, D, V = 4, 8, 16, 64
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    head = jnp.asarray(rng.normal(size=(D, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, size=(B, S)), jnp.int32)

    def vp(h, head):
        return vocab_parallel_ce(h, head, labels, mesh,
                                 batch_axes=("data",))

    def plain(h, head):
        return loss_fn(h @ head, labels, aux=0.0, aux_weight=0.0)

    ns = lambda s: jax.NamedSharding(mesh, s)
    with mesh:
        f_vp = jax.jit(jax.value_and_grad(vp, argnums=(0, 1)),
                       in_shardings=(ns(P(("data",), None, None)),
                                     ns(P(None, "model"))))
        f_pl = jax.jit(jax.value_and_grad(plain, argnums=(0, 1)))
        (l1, (gh1, gw1)) = f_vp(h, head)
        (l2, (gh2, gw2)) = f_pl(h, head)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gh1), np.asarray(gh2),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2),
                               rtol=2e-4, atol=2e-5)
    print("VP_CE_OK", float(l1))
""")


@pytest.mark.slow
def test_vocab_parallel_ce_matches_plain():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"})
    assert proc.returncode == 0, (proc.stdout[-1000:], proc.stderr[-2000:])
    assert "VP_CE_OK" in proc.stdout
