"""Integration tests: training loop (with and without intent-managed
embeddings), serve steps, optimizers, and checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.configs.registry import get_config
from repro.data.batches import make_batch
from repro.models.model import forward, init_cache, init_model
from repro.optim.optimizers import (adagrad_init, adagrad_update, adam_init,
                                    adam_update)
from repro.train.loop import LoopConfig, train_loop
from repro.train.steps import make_prefill_step, make_serve_step, \
    make_train_step, make_opt_init


def small_cfg():
    return get_config("smollm-135m", smoke=True)


class TestOptimizers:
    def test_adagrad_decreasing_steps(self):
        params = {"w": jnp.ones((4,))}
        st = adagrad_init(params)
        g = {"w": jnp.ones((4,))}
        p1, st = adagrad_update(g, st, params, lr=1.0)
        p2, _ = adagrad_update(g, st, p1, lr=1.0)
        d1 = float(params["w"][0] - p1["w"][0])
        d2 = float(p1["w"][0] - p2["w"][0])
        assert d1 == pytest.approx(1.0, rel=1e-5)
        assert d2 < d1

    def test_adam_bias_correction(self):
        params = {"w": jnp.zeros((2,))}
        st = adam_init(params)
        g = {"w": jnp.ones((2,))}
        p1, st = adam_update(g, st, params, lr=0.1)
        # first step with bias correction ~ full lr step
        assert float(p1["w"][0]) == pytest.approx(-0.1, rel=1e-3)


class TestTrainLoop:
    def test_loss_decreases(self):
        cfg = small_cfg()
        res = train_loop(cfg, LoopConfig(steps=12, batch=4, seq=32,
                                         pm=False, log_every=0))
        assert len(res.losses) == 12
        assert all(np.isfinite(res.losses))
        assert res.losses[-1] < res.losses[0]

    def test_pm_loop_matches_plain(self):
        """With refresh-every-round replica sync, the intent-managed
        embedding path is numerically identical to the plain path."""
        cfg = small_cfg()
        r_plain = train_loop(cfg, LoopConfig(steps=8, batch=4, seq=32,
                                             pm=False, log_every=0, seed=3))
        r_pm = train_loop(cfg, LoopConfig(steps=8, batch=4, seq=32, pm=True,
                                          cache_capacity=64, n_shards=2,
                                          log_every=0, seed=3))
        np.testing.assert_allclose(r_plain.losses, r_pm.losses,
                                   rtol=1e-4, atol=1e-5)
        assert r_pm.plans >= 1

    def test_refresh_only_on_replan_rounds(self):
        """ISSUE 2 regression: the loop used to re-gather the whole replica
        cache from the table EVERY step.  With refresh_every=0 the cache is
        synchronized exactly once per replan round (pm/embedding.py's
        once-per-refresh-round design)."""
        cfg = small_cfg()
        res = train_loop(cfg, LoopConfig(steps=40, batch=4, seq=32, pm=True,
                                         cache_capacity=64, n_shards=2,
                                         refresh_every=0, log_every=0,
                                         seed=3))
        assert res.refreshes == res.plans
        # planning rounds come at most every plan_every=8 steps (+1 for
        # the initial plan), so refreshes must be bounded accordingly
        assert res.refreshes <= 40 // 8 + 1

    def test_staleness_bounded_loss(self):
        """Replicas at most one refresh round stale: the loss trajectory
        with a sparse refresh cadence stays within a tight envelope of the
        refresh-every-step trajectory."""
        cfg = small_cfg()
        base = dict(steps=40, batch=4, seq=32, pm=True, cache_capacity=64,
                    n_shards=2, log_every=0, seed=3)
        r1 = train_loop(cfg, LoopConfig(**base))
        r6 = train_loop(cfg, LoopConfig(**base, refresh_every=6))
        assert r6.refreshes < r1.refreshes
        np.testing.assert_allclose(r6.losses, r1.losses, atol=0.05)

    @pytest.mark.slow
    def test_exact_bound_zero_overflow_200_steps(self):
        """The planner's intent-derived miss capacity is exact again: over
        200 steps not a single lookup needs the dense overflow fallback."""
        cfg = small_cfg()
        res = train_loop(cfg, LoopConfig(steps=200, batch=4, seq=32,
                                         pm=True, cache_capacity=64,
                                         n_shards=2, refresh_every=4,
                                         log_every=0, seed=5))
        assert res.overflows == 0
        assert res.plans > 1
        assert all(np.isfinite(res.losses))

    def test_kernel_loop_matches_jnp_loop(self):
        """LoopConfig.kernel routes lookup + sparse row update through the
        Pallas kernels (interpret mode here) with identical losses."""
        cfg = small_cfg().reduced(tie_embeddings=False, n_heads=3,
                                  n_kv_heads=3)
        base = dict(steps=4, batch=2, seq=16, pm=True, cache_capacity=64,
                    n_shards=2, log_every=0, seed=3)
        r_jnp = train_loop(cfg, LoopConfig(**base))
        r_ker = train_loop(cfg, LoopConfig(**base, kernel=True))
        np.testing.assert_allclose(r_ker.losses, r_jnp.losses,
                                   rtol=1e-4, atol=1e-5)

    def test_sparse_rows_pad_cannot_cancel_row0(self):
        """Regression: pad slots are remapped to row 0 with zero grads; a
        pad program running AFTER row 0's real update would overwrite it
        with the stale row.  The reversed slot order guarantees the real
        update lands last — sparse == dense AdaGrad even when token 0 and
        duplicates coexist."""
        from repro.kernels import ops
        V, D = 16, 128
        table = jnp.ones((V, D), jnp.float32)
        accum = jnp.zeros((V, D), jnp.float32)
        tok = jnp.asarray([0, 3, 5, 3], jnp.int32)   # dup -> pad slot
        dense_g = jnp.zeros((V, D)).at[tok].add(jnp.ones((4, D)))
        ids = ops.unique_rows(tok, n_slots=4, pad_id=V)[::-1]
        valid = ids < V
        ids = jnp.where(valid, ids, 0)
        rows_g = jnp.take(dense_g, ids, axis=0) * valid[:, None]
        new_t, new_a = ops.adagrad_row_update(table, accum, ids, rows_g,
                                              lr=0.1)
        a_ref = accum + dense_g * dense_g
        t_ref = table - 0.1 * dense_g / (jnp.sqrt(a_ref) + 1e-8)
        np.testing.assert_allclose(np.asarray(new_t), np.asarray(t_ref),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(new_a), np.asarray(a_ref),
                                   rtol=1e-6)

    def test_pm_cache_actually_hits(self):
        """The planner must place genuinely multi-shard-hot rows: with a
        Zipf corpus the hot tokens dominate, so cache hit count is high."""
        from repro.data.pipeline import IntentSignalingLoader, SyntheticCorpus
        from repro.pm.planner import IntentPlanner
        cfg = small_cfg()
        planner = IntentPlanner(cfg.vocab_size, 128, n_shards=4)
        loader = IntentSignalingLoader(cfg, 8, 32, n_shards=4,
                                       prefetch=24, planner=planner)
        it = iter(loader)
        step, batch = next(it)
        plan = planner.plan(0)
        hot = set(int(i) for i in plan.cache_ids if i < cfg.vocab_size)
        assert len(hot) > 16
        toks = np.asarray(batch["tokens"]).ravel()
        hits = sum(1 for t in toks if int(t) in hot)
        assert hits / len(toks) > 0.3


class TestServe:
    def test_prefill_then_decode(self):
        cfg = small_cfg()
        params = init_model(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = make_batch(cfg, 2, 8, rng)
        prefill = make_prefill_step(cfg)
        logits = prefill(params, batch)
        assert logits.shape == (2, cfg.vocab_size)

        serve = jax.jit(make_serve_step(cfg))
        cache = init_cache(cfg, 2, max_seq=16)
        tok = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 1)),
                          jnp.int32)
        for _ in range(4):
            logits, cache = serve(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        assert int(cache["len"]) == 4
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_ssm_decode_constant_state(self):
        """SSM decode state size is independent of context length — the
        property that qualifies falcon-mamba for long_500k."""
        cfg = get_config("falcon-mamba-7b", smoke=True)
        c_short = init_cache(cfg, 1, max_seq=16)
        c_long = init_cache(cfg, 1, max_seq=8192)
        assert c_short["h"].shape == c_long["h"].shape
        assert c_short["conv"].shape == c_long["conv"].shape

    def test_swa_cache_bounded_by_window(self):
        cfg = get_config("mixtral-8x22b", smoke=True)
        assert cfg.sliding_window == 64
        cache = init_cache(cfg, 1, max_seq=100_000)
        assert cache["k"].shape[2] == 64


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = small_cfg()
        params = init_model(cfg, jax.random.PRNGKey(0))
        opt = make_opt_init("adagrad")(params)
        d = str(tmp_path / "step_0000010")
        checkpoint.save(d, {"params": params, "opt": opt}, 10,
                        extra={"arch": cfg.arch_id})
        like = {"params": init_model(cfg, jax.random.PRNGKey(1)),
                "opt": make_opt_init("adagrad")(params)}
        restored, step = checkpoint.load(d, like)
        assert step == 10
        a = jax.tree_util.tree_leaves(params)
        b = jax.tree_util.tree_leaves(restored["params"])
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_roundtrip_through_train_loop(self, tmp_path):
        """Checkpoints written by train_loop restore back into it: the
        manifest step survives and the restored table is the trained one,
        not a fresh init."""
        cfg = small_cfg()
        ck = str(tmp_path / "ck")
        train_loop(cfg, LoopConfig(steps=6, batch=2, seq=16, pm=False,
                                   ckpt_dir=ck, ckpt_every=4, log_every=0,
                                   seed=3))
        latest = checkpoint.latest_step(ck)
        assert latest is not None and latest.endswith("step_0000004")
        # init_from accepts the checkpoint ROOT too (newest step resolved)
        res = train_loop(cfg, LoopConfig(steps=2, batch=2, seq=16, pm=False,
                                         init_from=ck, log_every=0,
                                         seed=3))
        assert res.start_step == 4
        assert len(res.losses) == 2 and all(np.isfinite(res.losses))
        # restored params differ from a fresh seed-3 init (training stuck)
        fresh = init_model(cfg, jax.random.PRNGKey(3))
        like = {"params": init_model(cfg, jax.random.PRNGKey(0)),
                "opt": make_opt_init("adagrad")(fresh)}
        restored, step = checkpoint.load(latest, like)
        assert step == 4
        assert not np.allclose(np.asarray(restored["params"]["embed"]),
                               np.asarray(fresh["embed"]))

    def test_latest_step(self, tmp_path):
        for s in (1, 5, 12):
            os.makedirs(tmp_path / f"step_{s:07d}")
        assert checkpoint.latest_step(str(tmp_path)).endswith("step_0000012")

    def test_shape_mismatch_rejected(self, tmp_path):
        d = str(tmp_path / "c")
        checkpoint.save(d, {"w": jnp.zeros((3,))}, 0)
        with pytest.raises(ValueError):
            checkpoint.load(d, {"w": jnp.zeros((4,))})
