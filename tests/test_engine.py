"""Tests for the unified vectorized intent engine (`repro.core.engine`).

Pins the engine's observable behavior to the seed implementation:
  * vectorized intent activation == `Intent.state` semantics (seeded-random
    sweep; runs with or without hypothesis);
  * engine-backed AdaPM == the frozen dict-and-heap seed AdaPM
    (`tests/_legacy_adapm.py`) on seeded workloads — decisions, traffic,
    and final placement;
  * baseline policy metrics pinned on a fixed-seed workload
    (`tests/data/seed_metrics.json`; StaticPartitioning/FullReplication to
    exact seed values, the timing-sensitive baselines to the vectorized
    implementation);
  * the planner's window classification matches the seed Counter logic.
"""

import json
import os
from collections import Counter

import numpy as np
import pytest

from _legacy_adapm import LegacyAdaPM

from repro.core.api import CostModel
from repro.core.baselines import (NuPSStatic, SelectiveReplicationSSP,
                                  StaticFullReplication, StaticPartitioning)
from repro.core.engine import (IntentStore, OwnerTable, concurrent_intent,
                               home_nodes, intent_miss_bound)
from repro.core.intent import Intent
from repro.core.manager import AdaPM
from repro.core.ownership import home_node
from repro.core.simulator import SimConfig, Workload, simulate

SEED_METRICS = os.path.join(os.path.dirname(__file__), "data",
                            "seed_metrics.json")


def tiny_workload(n_nodes=2, wpn=1, n_batches=30, n_keys=500, kpb=8, seed=0):
    rng = np.random.default_rng(seed)
    streams = [[[np.unique(rng.integers(0, n_keys, size=kpb))
                 for _ in range(n_batches)]
                for _ in range(wpn)]
               for _ in range(n_nodes)]
    return Workload("tiny", n_keys, streams)


class TestVectorizedPrimitives:
    def test_home_nodes_matches_scalar_hash(self):
        rng = np.random.default_rng(0)
        keys = np.concatenate([np.arange(2000),
                               rng.integers(0, 2 ** 31, size=2000)])
        for n in (2, 3, 5, 8, 16, 64):
            ref = np.array([home_node(int(k), n) for k in keys])
            assert np.array_equal(home_nodes(keys, n), ref)

    def test_intent_activation_matches_intent_state(self):
        """Vectorized window activation == `Intent.state` for random
        windows/clocks (seeded-random property sweep)."""
        rng = np.random.default_rng(42)
        for trial in range(25):
            store = IntentStore()
            intents = []
            for _ in range(rng.integers(1, 30)):
                s = int(rng.integers(0, 20))
                e = s + int(rng.integers(1, 10))
                w = int(rng.integers(0, 4))
                keys = rng.integers(0, 8, size=rng.integers(1, 5))
                store.signal(keys, s, e, w)
                for k in keys:
                    intents.append(Intent(keys=(int(k),), c_start=s,
                                          c_end=e, worker_id=w))
            clocks = {w: int(rng.integers(0, 40)) for w in range(4)}
            states = store.states(clocks)
            names = np.array(["inactive", "active", "expired"])[states]
            expected = [it.state(clocks[it.worker_id]) for it in intents]
            assert list(names) == expected
            # per-key active-worker sets against brute force
            for k in range(8):
                exp = {it.worker_id for it in intents
                       if it.keys == (k,)
                       and it.state(clocks[it.worker_id]) == "active"}
                assert store.active_workers(k, clocks) == exp
                assert store.has_active(k, clocks) == bool(exp)

    def test_owner_table_matches_directory_semantics(self):
        t = OwnerTable(4, capacity=128)
        k = 42
        home = int(home_nodes(np.array([k]), 4)[0])
        assert t.owner_of(k) == home
        other = (home + 1) % 4
        t.relocate_batch(np.array([k]), np.array([other]))
        src = (other + 1) % 4
        hops1 = int(t.route_batch(src, np.array([k]))[0])
        hops2 = int(t.route_batch(src, np.array([k]))[0])
        assert hops1 >= hops2 == 1
        assert int(t.route_batch(other, np.array([k]))[0]) == 0


INT_METRICS = ("n_accesses", "n_remote", "n_relocations",
               "n_replica_creates", "n_replica_reads", "rounds")


class TestEngineLegacyEquivalence:
    """Engine placement decisions == legacy per-key AdaPM decisions."""

    @pytest.mark.parametrize("n_nodes,wpn,seed,kw", [
        (2, 1, 0, {}),
        (3, 2, 1, {}),
        (4, 2, 2, {}),
        (4, 1, 3, {"relocation": False}),
        (3, 2, 4, {"replication": False}),
        (4, 2, 5, {"immediate_action": True}),
        (8, 2, 6, {}),
    ])
    def test_simulated_epoch_equivalent(self, n_nodes, wpn, seed, kw):
        cfg = SimConfig(signal_offset=15)
        pol_new = AdaPM(n_nodes, CostModel(), **kw)
        m_new = simulate(pol_new, tiny_workload(n_nodes, wpn, 25, 400, 8,
                                                seed), cfg)
        pol_old = LegacyAdaPM(n_nodes, CostModel(), **kw)
        m_old = simulate(pol_old, tiny_workload(n_nodes, wpn, 25, 400, 8,
                                                seed), cfg)
        for name in INT_METRICS:
            assert getattr(m_new, name) == getattr(m_old, name), name
        assert m_new.total_bytes == m_old.total_bytes
        assert m_new.epoch_time == pytest.approx(m_old.epoch_time,
                                                 rel=1e-12)
        assert m_new.staleness_sum == pytest.approx(m_old.staleness_sum,
                                                    rel=1e-9, abs=1e-12)
        # placement decisions: final ownership + replica holder sets
        for k in range(400):
            assert pol_new.dir.owner_of(k) == pol_old.dir.owner_of(k)
            old_holders = (set(pol_old._repl[k].holders)
                           if k in pol_old._repl else set())
            assert pol_new.engine.holders(k) == old_holders

    def test_direct_drive_equivalent(self):
        """Hand-driven rounds (no simulator timing in the loop): identical
        relocation/replication decisions on a randomized intent schedule."""
        rng = np.random.default_rng(7)
        n_nodes, n_keys = 3, 60
        pols = (AdaPM(n_nodes, CostModel(), lam0=1.0),
                LegacyAdaPM(n_nodes, CostModel(), lam0=1.0))
        clocks = {(n, w): 0 for n in range(n_nodes) for w in range(2)}
        for (n, w) in clocks:
            for p in pols:
                p.advance_clock(n, 100 * n + w, 0)
        for rnd in range(30):
            for _ in range(rng.integers(0, 6)):
                n = int(rng.integers(0, n_nodes))
                w = int(rng.integers(0, 2))
                start = clocks[(n, w)] + int(rng.integers(0, 6))
                intent = Intent(
                    keys=tuple(int(k) for k in
                               rng.integers(0, n_keys,
                                            size=rng.integers(1, 6))),
                    c_start=start, c_end=start + int(rng.integers(1, 5)),
                    worker_id=100 * n + w)
                for p in pols:
                    p.signal_intent(n, intent, float(rnd))
            for (n, w) in clocks:
                if rng.random() < 0.7:
                    clocks[(n, w)] += int(rng.integers(0, 3))
                    for p in pols:
                        p.advance_clock(n, 100 * n + w, clocks[(n, w)])
            for p in pols:
                p.run_round(float(rnd), 1e-3)
            new, old = pols
            for k in range(n_keys):
                assert new.dir.owner_of(k) == old.dir.owner_of(k), (rnd, k)
                old_holders = (set(old._repl[k].holders)
                               if k in old._repl else set())
                assert new.engine.holders(k) == old_holders, (rnd, k)
        for name in ("n_relocations", "n_replica_creates"):
            assert getattr(new.metrics, name) == getattr(old.metrics, name)
        assert float(np.sum(new.ledger.bytes_out)) == pytest.approx(
            float(np.sum(old.ledger.bytes_out)))


class TestSeedPinnedBaselines:
    """Baseline policies report pinned metrics on a fixed-seed workload.

    static_partitioning / full_replication are pinned to values captured
    from the *seed* implementation (exact).  ssp20 / essp / nups are pinned
    to the vectorized implementation: their miss/refresh classification is
    timing-sensitive and the batched budget arithmetic shifts a handful of
    accesses across round boundaries at float-associativity level (decision
    counts still match the seed; see tests/data/seed_metrics.json)."""

    @pytest.fixture(scope="class")
    def seed_metrics(self):
        with open(SEED_METRICS) as f:
            return json.load(f)

    @pytest.mark.parametrize("name", ["static_partitioning",
                                      "full_replication", "ssp20", "essp",
                                      "nups"])
    def test_metrics_match_seed(self, seed_metrics, name):
        wl = tiny_workload(n_nodes=4, wpn=2, n_batches=40, n_keys=800,
                           kpb=8, seed=7)
        pol = {
            "static_partitioning":
                lambda: StaticPartitioning(4, CostModel()),
            "full_replication":
                lambda: StaticFullReplication(4, CostModel(), wl.n_keys),
            "ssp20":
                lambda: SelectiveReplicationSSP(4, CostModel(), 20),
            "essp":
                lambda: SelectiveReplicationSSP(4, CostModel(), None),
            "nups":
                lambda: NuPSStatic(4, CostModel(), wl.n_keys,
                                   wl.hot_keys(0.02), reloc_offset=32),
        }[name]()
        m = simulate(pol, wl, SimConfig(signal_offset=20))
        for key, ref in seed_metrics[name].items():
            got = getattr(m, key)
            if isinstance(ref, int):
                assert got == ref, key
            else:
                assert got == pytest.approx(ref, rel=1e-9, abs=1e-12), key


class TestSharedDecisionProcedure:
    """The planner consumes the engine's replication decisions: the
    vectorized window classifiers match the seed's Counter logic."""

    def test_concurrent_intent_matches_counter_bruteforce(self):
        rng = np.random.default_rng(3)
        for _ in range(20):
            m = int(rng.integers(1, 60))
            keys = rng.integers(0, 12, size=m)
            nodes = rng.integers(0, 4, size=m)
            clocks = rng.integers(0, 6, size=m)
            uniq, weight, single = concurrent_intent(keys, nodes, clocks)
            multi_ref, single_ref = Counter(), Counter()
            for c in np.unique(clocks):
                per_key = Counter()
                seen = set()
                for k, n, cc in zip(keys, nodes, clocks):
                    if cc == c and (k, n) not in seen:
                        seen.add((k, n))
                        per_key[int(k)] += 1
                for k, cnt in per_key.items():
                    if cnt >= 2:
                        multi_ref[k] += cnt
                    else:
                        single_ref[k] += 1
            got_multi = {int(k): int(w) for k, w in zip(uniq, weight)
                         if w > 0}
            got_single = {int(k): int(s) for k, s in zip(uniq, single)
                          if s > 0}
            assert got_multi == dict(multi_ref)
            assert got_single == dict(single_ref)

    def test_miss_bound_matches_bruteforce(self):
        rng = np.random.default_rng(4)
        keys = rng.integers(0, 30, size=80)
        nodes = rng.integers(0, 3, size=80)
        clocks = rng.integers(0, 5, size=80)
        cached = np.unique(rng.integers(0, 30, size=10))
        ref = 0
        for c in np.unique(clocks):
            for n in np.unique(nodes):
                sel = (clocks == c) & (nodes == n)
                ref = max(ref, int(np.count_nonzero(
                    ~np.isin(keys[sel], cached))))
        assert intent_miss_bound(keys, nodes, clocks, cached) == ref
