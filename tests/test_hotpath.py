"""Hot-path regression tests for the single-sort managed step (ISSUE 5):

  * jaxpr inspection — the jitted managed train step contains EXACTLY one
    `sort` primitive (the step residual), kernel path on or off: the
    forward compaction, backward pre-sum and fused sparse optimizer all
    reuse it instead of re-sorting;
  * multi-row (block_r, block_d) kernel tiles vs the pure-jnp oracle over
    odd shapes (rows not a multiple of block_r, feature dims that are not
    lane-aligned and are padded, never shrunk);
  * managed lookup fwd+bwd equivalence across kernel on/off and emulated
    shard counts {1, 2, 8};
  * the measured block autotuner: override precedence and per-key caching.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import blocking, ops, ref
from repro.kernels.adagrad_rows import adagrad_row_update
from repro.kernels.embed_gather import embed_gather
from repro.kernels.pm_forward import (pm_combine, probe_and_compact,
                                      step_residual)
from repro.kernels.scatter_rows import scatter_rows
from repro.pm.collectives import EmulatedBackend
from repro.pm.embedding import make_state, plain_lookup, pm_lookup


def _count_sorts(jaxpr) -> int:
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "sort":
            n += 1
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for x in vs:
                if isinstance(x, jax.core.ClosedJaxpr):
                    n += _count_sorts(x.jaxpr)
                elif isinstance(x, jax.core.Jaxpr):
                    n += _count_sorts(x)
    return n


class TestSingleSortStep:
    """The regression this PR exists to prevent: the managed train step
    used to run three independent argsorts over the same token ids
    (forward probe/compact, backward segment, optimizer row dedup)."""

    @pytest.mark.parametrize("kernel", [False, True])
    def test_managed_train_step_has_exactly_one_sort(self, kernel):
        from repro.configs.registry import get_config
        from repro.data.batches import make_batch
        from repro.models.model import init_model
        from repro.train.steps import make_opt_init, make_train_step
        cfg = get_config("smollm-135m", smoke=True).reduced(
            tie_embeddings=False, n_heads=3, n_kv_heads=3)
        params = init_model(cfg, jax.random.PRNGKey(0))
        opt = make_opt_init("adagrad")(params)
        batch = make_batch(cfg, 2, 16, np.random.default_rng(0))
        C = 32
        batch = dict(batch,
                     pm_cache_ids=jnp.asarray(np.arange(C), jnp.int32),
                     pm_cache_rows=jnp.zeros((C, cfg.d_model), jnp.float32))
        step = make_train_step(cfg, pm_miss_capacity=16, pm_kernel=kernel)
        jaxpr = jax.make_jaxpr(step)(params, opt, batch)
        assert _count_sorts(jaxpr.jaxpr) == 1

    def test_step_residual_is_one_sort(self):
        cache = jnp.asarray(np.arange(0, 64, 2), jnp.int32)
        tok = jnp.asarray(np.random.default_rng(0).integers(0, 64, 48),
                          jnp.int32)
        jaxpr = jax.make_jaxpr(
            lambda c, t: step_residual(c, t, 16))(cache, tok)
        assert _count_sorts(jaxpr.jaxpr) == 1

    def test_residual_fed_segment_matches_fresh_sort(self):
        rng = np.random.default_rng(3)
        cache = jnp.asarray(np.sort(rng.choice(128, 16, replace=False)),
                            jnp.int32)
        tok = jnp.asarray(rng.integers(0, 128, 50), jnp.int32)
        g = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
        res = step_residual(cache, tok, 16)
        ids_a, g_a = ops.segment_rows(tok, g, n_slots=50, pad_id=128)
        ids_b, g_b = ops.segment_rows(tok, g, n_slots=50, pad_id=128,
                                      residual=res.sort)
        np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))
        np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_b))
        np.testing.assert_array_equal(
            np.asarray(ops.unique_rows(tok, n_slots=50, pad_id=128)),
            np.asarray(ops.unique_rows(tok, n_slots=50, pad_id=128,
                                       residual=res.sort)))

    def test_residual_probe_matches_probe_and_compact(self):
        rng = np.random.default_rng(5)
        cache = jnp.asarray(np.sort(rng.choice(256, 16, replace=False)),
                            jnp.int32)
        tok = jnp.asarray(rng.integers(0, 256, 37), jnp.int32)
        res = step_residual(cache, tok, 8)
        pc = probe_and_compact(cache, tok, 8)
        for a, b in zip(res.probe, pc):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# odd-shape sweep: n not a multiple of any block_r candidate, feature dims
# off the 128-lane grid (padded inside the kernels, sliced back out)
ODD_SHAPES = [
    # (V, D, n, block_r)
    (64, 128, 8, 4),
    (97, 190, 13, 4),
    (256, 576, 31, 8),
    (33, 570, 5, 3),
    (128, 64, 7, 16),
]


class TestMultiRowTiles:
    @pytest.mark.parametrize("V,D,n,block_r", ODD_SHAPES)
    def test_gather_matches_ref(self, V, D, n, block_r):
        rng = np.random.default_rng(0)
        table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, V, size=(n,)), jnp.int32)
        out = embed_gather(table, ids, block_r=block_r, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref.embed_gather_ref(table, ids)))

    @pytest.mark.parametrize("V,D,n,block_r", ODD_SHAPES)
    def test_scatter_matches_ref(self, V, D, n, block_r):
        rng = np.random.default_rng(1)
        base = jnp.zeros((V, D), jnp.float32)
        ids = jnp.asarray(rng.choice(V, size=(n,), replace=False),
                          jnp.int32)
        rows = jnp.asarray(rng.normal(size=(n, D)), jnp.float32)
        out = scatter_rows(base, ids, rows, block_r=block_r,
                           interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref.scatter_rows_ref(base, ids,
                                                             rows)))

    @pytest.mark.parametrize("V,D,n,block_r", ODD_SHAPES)
    def test_adagrad_matches_ref(self, V, D, n, block_r):
        rng = np.random.default_rng(2)
        table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
        accum = jnp.asarray(rng.uniform(0.01, 1.0, size=(V, D)),
                            jnp.float32)
        ids = jnp.asarray(rng.choice(V, size=(n,), replace=False),
                          jnp.int32)
        grads = jnp.asarray(rng.normal(size=(n, D)), jnp.float32)
        new_t, new_a = adagrad_row_update(table, accum, ids, grads,
                                          lr=0.05, block_r=block_r,
                                          interpret=True)
        exp_t, exp_a = ref.adagrad_row_update_ref(table, accum, ids, grads,
                                                  lr=0.05)
        np.testing.assert_allclose(np.asarray(new_t), np.asarray(exp_t),
                                   rtol=2e-6, atol=2e-6)
        np.testing.assert_allclose(np.asarray(new_a), np.asarray(exp_a),
                                   rtol=2e-6, atol=2e-6)
        # untouched rows bit-identical (in-place aliasing semantics)
        mask = np.ones(V, bool)
        mask[np.asarray(ids)] = False
        np.testing.assert_array_equal(np.asarray(new_t)[mask],
                                      np.asarray(table)[mask])

    @pytest.mark.parametrize("V,D,n,block_r", ODD_SHAPES)
    def test_combine_matches_ref(self, V, D, n, block_r):
        rng = np.random.default_rng(3)
        C, M, T = 8, 4, max(3, n)
        cache_rows = jnp.asarray(rng.normal(size=(C, D)), jnp.float32)
        buf_rows = jnp.asarray(rng.normal(size=(M + 1, D)), jnp.float32)
        hit = jnp.asarray(rng.integers(0, 2, size=(T,)).astype(bool))
        cs = jnp.asarray(rng.integers(0, C, size=(T,)), jnp.int32)
        bs = jnp.asarray(rng.integers(0, M + 1, size=(T,)), jnp.int32)
        out = pm_combine(hit, cs, bs, cache_rows, buf_rows,
                         block_r=block_r, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out),
            np.asarray(ref.pm_combine_ref(hit, cs, bs, cache_rows,
                                          buf_rows)))


class TestShardKernelMatrix:
    """Managed lookup fwd+bwd across kernel on/off × emulated shard
    counts {1, 2, 8} (no multi-device host needed: the EmulatedBackend is
    the single-host collective cost model)."""

    V, D, C = 256, 96, 16    # D off the lane grid on purpose

    def _setup(self, seed=0):
        rng = np.random.default_rng(seed)
        table = jnp.asarray(rng.normal(size=(self.V, self.D)), jnp.float32)
        cache_ids = jnp.asarray(
            np.sort(rng.choice(self.V, size=self.C, replace=False)),
            jnp.int32)
        return make_state(table, cache_ids), rng

    @pytest.mark.parametrize("n", [1, 2, 8])
    @pytest.mark.parametrize("kernel", [False, True])
    def test_fwd_bwd_matches_plain(self, n, kernel):
        st, rng = self._setup()
        be = EmulatedBackend(n)
        tokens = jnp.asarray(rng.integers(0, self.V, size=(2, 12)),
                             jnp.int32)
        out = pm_lookup(st.table, st.cache_ids, st.cache_rows, tokens, 24,
                        False, kernel, be)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(plain_lookup(st.table, tokens)),
            rtol=1e-6)

        def loss(t):
            return jnp.sum(pm_lookup(t, st.cache_ids, st.cache_rows,
                                     tokens, 24, False, kernel, be) ** 2)

        g = jax.grad(loss)(st.table)
        g_ref = jax.grad(
            lambda t: jnp.sum(plain_lookup(t, tokens) ** 2))(st.table)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-5, atol=1e-6)


class TestBlockAutotuner:
    def test_pads_up_never_shrinks(self):
        # old rule: 576 -> 288, 570 -> 2.  Padding keeps full-lane tiles.
        assert blocking.pad_d(576) == 640
        assert blocking.pick_block_d(576, 512) == 128
        assert blocking.pick_block_d(570, 512) == 128
        assert blocking.pick_block_d(512, 512) == 512
        assert blocking.pick_block_d(1024, 512) == 512
        assert blocking.pick_block_d(64, 512) == 128

    def test_override_precedence(self):
        blocking.set_block_override(block_r=2, block_d=256)
        try:
            br, bd = blocking.pick_blocks("t", 64, 512, "f32")
            assert (br, bd) == (2, 256)
            # explicit args beat the override
            br, bd = blocking.pick_blocks("t", 64, 512, "f32", block_r=4)
            assert br == 4
        finally:
            blocking.set_block_override()

    def test_measured_path_caches_per_key(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "measure")
        blocking.clear_autotune_cache()
        calls = []

        def bench(br, bd):
            calls.append((br, bd))
            return {1: 5.0, 2: 1.0, 4: 3.0, 8: 9.0, 16: 9.0}[br]

        br, bd = blocking.pick_blocks("bench-test", 16, 256, "f32",
                                      bench=bench)
        assert br == 2 and bd == 256
        n_calls = len(calls)
        assert n_calls >= 2            # it really measured candidates
        br2, _ = blocking.pick_blocks("bench-test", 16, 256, "f32",
                                      bench=bench)
        assert br2 == 2 and len(calls) == n_calls   # second hit cached
        blocking.clear_autotune_cache()

    def test_cache_key_includes_table_rows(self, monkeypatch):
        """ISSUE 6 regression: the same (kind, n, d) measured against the
        full table and a shard-local V/n block must NOT share a cached
        tile — inside `shard_map` the DMA probe pattern spreads over a
        different row count, so `table_rows` is part of the key."""
        monkeypatch.setenv("REPRO_AUTOTUNE", "measure")
        blocking.clear_autotune_cache()
        calls = []

        def bench_full(br, bd):
            calls.append(("full", br))
            return {1: 5.0, 2: 1.0, 4: 3.0, 8: 9.0, 16: 9.0}[br]

        def bench_shard(br, bd):
            calls.append(("shard", br))
            return {1: 5.0, 2: 3.0, 4: 1.0, 8: 9.0, 16: 9.0}[br]

        br_full, _ = blocking.pick_blocks("rows-test", 16, 256, "f32",
                                          table_rows=1024,
                                          bench=bench_full)
        br_shard, _ = blocking.pick_blocks("rows-test", 16, 256, "f32",
                                           table_rows=128,
                                           bench=bench_shard)
        assert br_full == 2 and br_shard == 4   # measured independently
        n_calls = len(calls)
        assert blocking.pick_blocks("rows-test", 16, 256, "f32",
                                    table_rows=1024,
                                    bench=bench_full)[0] == 2
        assert blocking.pick_blocks("rows-test", 16, 256, "f32",
                                    table_rows=128,
                                    bench=bench_shard)[0] == 4
        assert len(calls) == n_calls            # both served from cache
        blocking.clear_autotune_cache()

    def test_heuristic_when_off(self, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE", "off")
        blocking.clear_autotune_cache()

        def bench(br, bd):              # must never be called
            raise AssertionError("measured in off mode")

        br, bd = blocking.pick_blocks("off-test", 64, 512, "f32",
                                      bench=bench)
        assert br == blocking.DEFAULT_BLOCK_R and bd == 512
        blocking.clear_autotune_cache()
