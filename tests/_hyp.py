"""Import shim: use hypothesis when available, degrade gracefully when not.

The tier-1 suite must *collect* (and the non-property tests must run) on
machines without hypothesis installed.  Test modules import ``given``,
``settings`` and ``st`` from here instead of from hypothesis directly; when
hypothesis is missing, ``@given`` turns the test into a ``pytest.skip`` and
``st``/``settings`` become inert placeholders.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Inert stand-in for ``hypothesis.strategies``: every attribute
        access / call returns itself so strategy expressions still parse."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            # plain *args/**kwargs signature so pytest does not look for
            # fixtures matching the hypothesis-bound parameters
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper
        return deco
