"""Figure 6: overall performance of AdaPM vs single node, manually tuned
NuPS (6 configs, best/worst reported), standard PM (full replication,
static partitioning), and single-technique ablations — on all five tasks.

Paper claims validated here (EXPERIMENTS.md §Paper-validation):
  * AdaPM achieves good speedups out of the box on every task;
  * AdaPM matches/outperforms the best NuPS configuration, while NuPS's
    spread between best and worst configuration is large (tuning burden);
  * static partitioning is slower than the single node;
  * full replication over-communicates (staleness) or OOMs on big models;
  * AdaPM w/o replication is poor everywhere; w/o relocation is fine
    except under locality (MF).
"""

from __future__ import annotations

from typing import List

from .common import (NUPS_CONFIGS, TASKS, default_cost, emit, run_one,
                     speedup_vs_single_node)

VARIANTS = (["adapm", "adapm_norel", "adapm_norep", "full_replication",
             "static_partitioning", "essp"]
            + [f"nups_{i}" for i in range(len(NUPS_CONFIGS))])


def run(scale: float = 0.5, n_nodes: int = 8, wpn: int = 4) -> List[str]:
    rows: List[str] = []
    for task in TASKS:
        for variant in VARIANTS:
            m = run_one(variant, task, n_nodes=n_nodes, wpn=wpn, scale=scale)
            sp = speedup_vs_single_node(task, m, n_nodes=n_nodes, wpn=wpn,
                                        scale=scale)
            emit(rows, "fig6", variant, task, "epoch_time_s",
                 round(m.epoch_time, 4))
            emit(rows, "fig6", variant, task, "speedup", round(sp, 2))
            emit(rows, "fig6", variant, task, "gb_per_node",
                 round(m.bytes_per_node / 1e9, 4))
            emit(rows, "fig6", variant, task, "remote_frac",
                 round(m.remote_fraction, 5))
            emit(rows, "fig6", variant, task, "staleness_ms",
                 round(m.mean_staleness * 1e3, 3))
    return rows


if __name__ == "__main__":
    run()
