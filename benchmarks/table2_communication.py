"""Table 2: per-epoch network communication and replica staleness, AdaPM vs
AdaPM w/o relocation, on all five tasks.

Claims validated: relocation reduces communicated data and staleness on
every task, most strongly under locality (MF, GNN — the paper reports up
to 9x less data)."""

from __future__ import annotations

from typing import List

from .common import TASKS, emit, run_one


def run(scale: float = 0.5, n_nodes: int = 8, wpn: int = 4) -> List[str]:
    rows: List[str] = []
    for task in TASKS:
        res = {}
        for variant in ("adapm", "adapm_norel"):
            m = run_one(variant, task, n_nodes=n_nodes, wpn=wpn, scale=scale)
            res[variant] = m
            emit(rows, "table2", variant, task, "gb_per_node",
                 round(m.bytes_per_node / 1e9, 4))
            emit(rows, "table2", variant, task, "staleness_ms",
                 round(m.mean_staleness * 1e3, 3))
        ratio = (res["adapm_norel"].bytes_per_node
                 / max(res["adapm"].bytes_per_node, 1.0))
        emit(rows, "table2", "ratio", task, "comm_reduction_x",
             round(ratio, 2))
    return rows


if __name__ == "__main__":
    run()
