"""Model-quality axis of Figure 6: real matrix-factorization SGD under
each PM policy's *staleness semantics*.

The cluster simulator measures time/communication; this harness closes the
loop on quality: N simulated nodes run synchronous-round MF SGD on row-
partitioned data, and replicated parameters (the shared column factors)
are synchronized according to the policy:

  AdaPM            : replica deltas merge every round (staleness <= 1)
  Full replication : deltas merge every ``sync_every`` rounds — the dense
                     model sync is slow, so rounds-per-sync is large
                     (paper: poor quality for KGE/CTR from infrequent sync)
  Static partition : no replicas; remote reads always fresh but every
                     access pays latency — quality per *round* is the
                     oracle's, quality per *second* collapses (time axis
                     handled by the simulator; here we show per-round
                     equivalence)

Reported: test RMSE after a fixed number of rounds.  Claim validated:
AdaPM's tight staleness bound preserves the single-node learning curve,
while infrequent full sync degrades it.
"""

from __future__ import annotations

from typing import List

import numpy as np


def make_mf_data(n_rows=400, n_cols=120, rank=6, n_obs=12_000, seed=0):
    rng = np.random.default_rng(seed)
    U = rng.normal(scale=1.0 / np.sqrt(rank), size=(n_rows, rank))
    V = rng.normal(scale=1.0 / np.sqrt(rank), size=(n_cols, rank))
    rows = rng.integers(0, n_rows, size=n_obs)
    cols = rng.integers(0, n_cols, size=n_obs)
    vals = np.sum(U[rows] * V[cols], axis=1) + rng.normal(
        scale=0.05, size=n_obs)
    n_train = int(0.9 * n_obs)
    return (rows[:n_train], cols[:n_train], vals[:n_train],
            rows[n_train:], cols[n_train:], vals[n_train:])


def run_mf(sync_every: int, n_nodes=4, rounds=60, rank=6, lr=0.08,
           seed=0) -> List[float]:
    """Row factors are node-local (MF locality); column factors are
    replicated and merged every ``sync_every`` rounds (delta averaging —
    the owner-hub merge of the paper, batched)."""
    (tr, tc, tv, er, ec, ev) = make_mf_data(rank=rank, seed=seed)
    rng = np.random.default_rng(seed + 1)
    n_rows = tr.max() + 1
    n_cols = tc.max() + 1
    U = rng.normal(scale=0.1, size=(n_rows, rank))
    V_global = rng.normal(scale=0.1, size=(n_cols, rank))
    V_rep = [V_global.copy() for _ in range(n_nodes)]
    node_of_row = tr % n_nodes

    rmse = []
    for rnd in range(rounds):
        for node in range(n_nodes):
            mask = node_of_row == node
            idx = np.nonzero(mask)[0]
            rng.shuffle(idx)
            Vl = V_rep[node]
            for i in idx:
                r, c, y = tr[i], tc[i], tv[i]
                e = y - U[r] @ Vl[c]
                gu = -e * Vl[c]
                gv = -e * U[r]
                U[r] -= lr * gu
                Vl[c] -= lr * gv
        if (rnd + 1) % sync_every == 0:
            # owner-hub merge (§B.1.2): every replica's accumulated delta
            # is applied to the owner copy, then redistributed
            V_global = V_global + sum(Vr - V_global for Vr in V_rep)
            V_rep = [V_global.copy() for _ in range(n_nodes)]
        pred = np.sum(U[er.clip(0, n_rows - 1)]
                      * V_global[ec.clip(0, n_cols - 1)], axis=1)
        rmse.append(float(np.sqrt(np.mean((ev - pred) ** 2))))
    return rmse


def run() -> List[str]:
    rows = []
    for name, sync_every in (("adapm_sync_every_round", 1),
                             ("full_repl_sync_every_8", 8),
                             ("full_repl_sync_every_24", 24)):
        curve = run_mf(sync_every)
        final = curve[-1]
        half = curve[len(curve) // 2]
        row = (f"quality_mf,{name},MF,rmse_mid_final,"
               f"{half:.4f};{final:.4f}")
        print(row)
        rows.append(row)
    return rows


if __name__ == "__main__":
    run()
