"""Managed train-step hot-path benchmark: the single-sort fused step
(ISSUE 5) vs a faithful replica of the PR-4 step, paired per shape.

What changed and what this measures
-----------------------------------
The PR-4 managed step paid its index arithmetic three times — the forward
`probe_and_compact`, the backward `segment_rows` pre-sum and the
optimizer's `unique_rows` dedup each ran an independent O(T log T) argsort
over the same token ids — and its backward materialized a dense (V, D)
gradient (zeros + row scatter) that the optimizer immediately re-gathered
from.  The fused step computes ONE `step_residual` and routes the compact
(T, D) row grads straight through the residual-fed segment into the
AdaGrad row update; no dense gradient buffer exists and the table/accum
buffers are donated.

Both variants here run the pure-jnp row data path (`kernels.ref`): on this
CPU container interpret-mode Pallas timings are meaningless, and the jnp
path isolates exactly what the PR changed — index work and memory traffic
— identically for both sides.  Paired medians: the two steps alternate
call-for-call on identical inputs and each reports its median latency.

Output: ``BENCH_hotpath.json`` at the repo root — full-scale entries plus
CI-scale ``quick_entries`` — with the headline speedup at zipf 1.0 across
D ∈ {64, 576, 1024}.

The ``auto`` section (PR 7, DESIGN.md §13) drops the hand-pinned replica
capacity: the intent signal's cache-worthy demand steers C onto the
power-of-two ladder (`controller.steer_capacity` — the same rule the
serve runtime and train loop run online), and the fused step is measured
at that steered bucket against the hand-tuned quick C, paired per shape.

CLI:
  python -m benchmarks.hotpath_bench [--quick]
  python -m benchmarks.hotpath_bench --quick --check-baseline BENCH_hotpath.json
  python -m benchmarks.hotpath_bench --auto --check-baseline BENCH_hotpath.json

``--check-baseline`` is the CI regression guard: it re-measures the quick
shapes and FAILS (exit 1) if the managed-step median regressed more than
15% against the committed baseline.  The comparison is machine-normalized
through the paired PR-4 replica — current speedup vs baseline speedup —
so absolute CPU-speed differences between CI hosts don't trip it, while a
real hot-path regression (which slows the fused step but not its paired
baseline) does.  With ``--auto`` the guard instead re-measures the
auto arm and fails if the steered-capacity step falls more than 15%
behind the hand-tuned capacity (paired medians in one process, so the
comparison is machine-normalized by construction).
"""

from __future__ import annotations

import json
import os
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticCorpus
from repro.kernels import ops, ref
from repro.kernels.pm_forward import probe_and_compact, step_residual
from repro.obs import JsonlSink, Telemetry, make_tracer
from repro.pm.collectives import EmulatedBackend
from repro.pm.controller import Knob, OnlineController, capacity_ladder
from repro.pm.planner import _bucket

from .common import paired_pooled_ratio

_REPO_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
_OUT = os.path.join(_REPO_ROOT, "BENCH_hotpath.json")

FULL = dict(V=65536, B=16, S=512, C=1024, iters=9)
QUICK = dict(V=16384, B=8, S=256, C=512, iters=7)
DIMS = (64, 576, 1024)
SKEWS_FULL = (1.0, 1.1, 1.5)
SKEWS_QUICK = (1.0, 1.1)
REGRESSION_TOL = 1.15          # CI guard: >15% median regression fails
AUTO_MIN_RATIO = 1 / REGRESSION_TOL  # steered C vs hand-tuned C, paired
# intent-lead-time pipeline arm (DESIGN.md §15): refresh-heavy rounds
# (refresh_every=1) where the synchronous loop re-gathers the WHOLE
# C-row replica every step and the pipelined loop re-gathers only the
# delta bucket (touched ∩ cached rows) and defers the host block
PIPE_C = 8192                  # replica capacity: refresh is a large
#                                fraction of the round at this C, which
#                                is the regime refresh_every=1 implies
PIPE_DIMS = (576, 1024)        # acceptance is stated over D >= 576
PIPE_ROUNDS = 8                # rounds per run (samples pool across reps)
PIPELINE_MIN_SPEEDUP = 1.15


def _make_steps(table, accum, cache_ids, cache_rows, tokens, M, V, lr=0.1):
    """Paired step functions over identical inputs.  Both share the same
    forward select and the same AdaGrad row math; they differ exactly in
    the index work and gradient materialization this PR removed."""
    B, S = tokens.shape
    T = B * S
    D = table.shape[1]
    tok = tokens.reshape(T).astype(jnp.int32)

    def _combine(table, pc):
        buf_rows = jnp.take(table, pc.buf_ids, axis=0)
        buffer = jnp.concatenate(
            [buf_rows, jnp.zeros((1, D), table.dtype)])
        return ref.pm_combine_ref(pc.hit, pc.cache_slot, pc.buf_slot,
                                  cache_rows, buffer)

    @jax.jit
    def legacy_step(table, accum):
        # PR-4 shape of the step: probe sort (fwd), segment sort + dense
        # (V+1, D) gradient materialization (bwd), unique sort + dense
        # re-gather (optimizer)
        pc = probe_and_compact(cache_ids, tok, M)              # sort 1
        out = _combine(table, pc)
        gt = 2.0 * out                                         # d sum(out^2)
        seg_ids, seg_g = ops.segment_rows(tok, gt, n_slots=T,
                                          pad_id=V)            # sort 2
        g_dense = ref.scatter_rows_ref(
            jnp.zeros((V + 1, D), jnp.float32), seg_ids, seg_g)[:V]
        ids = ops.unique_rows(tok, n_slots=T, pad_id=V)[::-1]  # sort 3
        valid = ids < V
        ids = jnp.where(valid, ids, 0)
        rows_g = jnp.take(g_dense, ids, axis=0) \
            * valid[:, None].astype(jnp.float32)
        return ref.adagrad_row_update_ref(table, accum, ids, rows_g, lr=lr)

    def fused_body(table, accum):
        res = step_residual(cache_ids, tok, M)                 # THE sort
        out = _combine(table, res.probe)
        gt = 2.0 * out
        seg_ids, seg_g = ops.segment_rows(tok, gt, n_slots=T, pad_id=V,
                                          residual=res.sort)   # no sort
        ids = seg_ids[::-1]
        valid = ids < V
        ids = jnp.where(valid, ids, 0)
        rows_g = seg_g[::-1] * valid[:, None].astype(jnp.float32)
        return ref.adagrad_row_update_ref(table, accum, ids, rows_g, lr=lr)

    # the fused step donates its hot buffers, matching `train.loop`'s
    # donate_argnums (real even on the XLA CPU backend: the timing loop
    # hands it fresh copies, prepared outside the timed region)
    fused_step = jax.jit(fused_body, donate_argnums=(0, 1))
    return legacy_step, fused_step


def _paired_medians(legacy, fused, table, accum, iters: int):
    """Alternate the two steps call-for-call on identical inputs and
    return (legacy_median_us, fused_median_us).  The fused step's inputs
    are donated, so each call gets fresh copies prepared (and blocked on)
    outside the timed region."""
    def fused_inputs():
        pair = (jnp.copy(table), jnp.copy(accum))
        jax.block_until_ready(pair)
        return pair

    jax.block_until_ready(legacy(table, accum))        # compile
    jax.block_until_ready(fused(*fused_inputs()))
    tl, tf = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(legacy(table, accum))
        tl.append(time.perf_counter() - t0)
        tc, ac = fused_inputs()
        t0 = time.perf_counter()
        jax.block_until_ready(fused(tc, ac))
        tf.append(time.perf_counter() - t0)
    return float(np.median(tl) * 1e6), float(np.median(tf) * 1e6)


def _bench_entries(dims: dict, skews, tracer=None, bus=None) -> List[dict]:
    V, B, S, C = dims["V"], dims["B"], dims["S"], dims["C"]
    tr = make_tracer(False, tracer=tracer)
    entries = []
    for zipf_a in skews:
        corpus = SyntheticCorpus(V, zipf_a=zipf_a, seed=0)
        tokens = jnp.asarray(corpus.tokens((B, S)))
        cache_np = np.sort(corpus.perm[:C]).astype(np.int32)
        cache_ids = jnp.asarray(cache_np)
        uniq = np.unique(np.asarray(tokens))
        n_miss = int(np.setdiff1d(uniq, cache_np).size)
        M = _bucket(max(1, n_miss))      # exact intent-derived bound
        for D in DIMS:
            rng = np.random.default_rng(1)
            table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
            accum = jnp.full((V, D), 0.1, jnp.float32)
            cache_rows = jnp.take(table, cache_ids, axis=0)
            legacy, fused = _make_steps(table, accum, cache_ids,
                                        cache_rows, tokens, M, V)
            # span args: a=D, b=zipf*10 (int slots — see obs.trace)
            with tr.span("hotpath.shape", a=D, b=int(zipf_a * 10)):
                lus, fus = _paired_medians(legacy, fused, table, accum,
                                           dims["iters"])
            if bus is not None:
                bus.set("hotpath.legacy_us", lus, zipf=zipf_a, D=D)
                bus.set("hotpath.fused_us", fus, zipf=zipf_a, D=D)
                bus.set("hotpath.speedup", lus / fus, zipf=zipf_a, D=D)
            entries.append(dict(zipf=zipf_a, D=D, V=V, T=B * S, M=M,
                                legacy_us=round(lus, 1),
                                fused_us=round(fus, 1),
                                speedup=round(lus / fus, 3)))
            print(f"hotpath,managed_step,zipf{zipf_a}_D{D},us_legacy,"
                  f"{lus:.1f}")
            print(f"hotpath,managed_step,zipf{zipf_a}_D{D},us_fused,"
                  f"{fus:.1f}")
            print(f"hotpath,managed_step,zipf{zipf_a}_D{D},speedup,"
                  f"{lus / fus:.2f}")
    return entries


def _steered_capacity(V: int, tokens) -> tuple:
    """The zero-tuning capacity for one step shape: the batch's
    cache-worthy demand (its unique rows — what the queued horizon's
    intent says is worth replicating) steers C onto the power-of-two
    ladder via the exact signal rule the runtimes run online."""
    ctl = OnlineController(
        [Knob("C", capacity_ladder(V), adapt=False, prefer_low=True)])
    demand = int(np.unique(np.asarray(tokens)).size)
    ctl.steer_capacity("C", demand)
    return int(ctl.value("C")), demand


def _measure_at_capacity(corpus, tokens, V: int, C: int, D: int,
                         iters: int) -> float:
    """Fused-step median (us) with a C-row replica of the corpus head."""
    cache_np = np.sort(corpus.perm[:C]).astype(np.int32)
    cache_ids = jnp.asarray(cache_np)
    uniq = np.unique(np.asarray(tokens))
    M = _bucket(max(1, int(np.setdiff1d(uniq, cache_np).size)))
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(V, D)), jnp.float32)
    accum = jnp.full((V, D), 0.1, jnp.float32)
    cache_rows = jnp.take(table, cache_ids, axis=0)
    legacy, fused = _make_steps(table, accum, cache_ids, cache_rows,
                                tokens, M, V)
    _, fus = _paired_medians(legacy, fused, table, accum, iters)
    return fus


def _auto_entries(dims: dict, skews, reps: int = 3) -> List[dict]:
    """The zero-tuning arm: fused step at the demand-steered capacity vs
    the hand-tuned quick C, paired per (zipf, D) shape.  Median of
    ``reps`` paired ratios with the measurement order alternated per rep
    (both sides run back-to-back in this process), so one-sided host
    noise cancels and the ratio is machine-normalized by construction."""
    V, B, S, C_tuned = dims["V"], dims["B"], dims["S"], dims["C"]
    entries = []
    for zipf_a in skews:
        corpus = SyntheticCorpus(V, zipf_a=zipf_a, seed=0)
        tokens = jnp.asarray(corpus.tokens((B, S)))
        C_auto, demand = _steered_capacity(V, tokens)
        for D in DIMS:
            pairs = []
            for rep in range(reps):
                order = ((C_auto, C_tuned) if rep % 2 == 0
                         else (C_tuned, C_auto))
                t = {c: _measure_at_capacity(corpus, tokens, V, c, D,
                                             dims["iters"])
                     for c in order}
                pairs.append((t[C_auto], t[C_tuned]))
            mid = int(np.argsort([b / a for a, b in pairs])[len(pairs)
                                                           // 2])
            fus_auto, fus_tuned = pairs[mid]
            ratio = fus_tuned / fus_auto      # >1: steered C is faster
            entries.append(dict(zipf=zipf_a, D=D, demand=demand,
                                auto_C=C_auto, tuned_C=C_tuned,
                                auto_us=round(fus_auto, 1),
                                tuned_us=round(fus_tuned, 1),
                                auto_vs_tuned_x=round(ratio, 3)))
            print(f"hotpath,auto,zipf{zipf_a}_D{D},auto_vs_tuned_x,"
                  f"{ratio:.3f}")
    return entries


def _pipeline_entries(dims: dict, reps: int = 4) -> List[dict]:
    """§15 pipeline arm: per-round latency of the fused step under
    refresh-every-step replica sync — synchronous (full C-row re-gather
    + per-round host block) vs pipelined (delta re-gather of the
    touched ∩ cached bucket + block deferred one round) — paired via
    `benchmarks.common.paired_pooled_ratio` (pooled per-round samples,
    alternating order, inline A/A drift).  The two arms run the
    IDENTICAL fused step; the delta is exact here for the same reason
    the train loop's gate demands (sparse AdaGrad touches only the
    batch's rows), so the speedup is pure refresh-work elimination."""
    V, B, S = dims["V"], dims["B"], dims["S"]
    C = min(PIPE_C, V // 2)
    backend = EmulatedBackend(1)
    entries = []
    for D in PIPE_DIMS:
        corpus = SyntheticCorpus(V, zipf_a=1.0, seed=0)
        tokens = jnp.asarray(corpus.tokens((B, S)))
        cache_np = np.sort(corpus.perm[:C]).astype(np.int32)
        cache_ids = jnp.asarray(cache_np)
        uniq = np.unique(np.asarray(tokens))
        M = _bucket(max(1, int(np.setdiff1d(uniq, cache_np).size)))
        rng = np.random.default_rng(1)
        table0 = np.asarray(rng.normal(size=(V, D)), np.float32)
        accum0 = np.full((V, D), 0.1, np.float32)
        _, fused = _make_steps(jnp.asarray(table0), jnp.asarray(accum0),
                               cache_ids, jnp.take(jnp.asarray(table0),
                                                   cache_ids, axis=0),
                               tokens, M, V)
        refresh_full = jax.jit(
            lambda t, ci=cache_ids: jnp.take(t, ci, axis=0))
        refresh_delta = jax.jit(backend.refresh_rows_delta,
                                donate_argnums=(1,))
        # the delta bucket: the step's touched rows that live in the
        # replica (precomputed once — the train loop gets this set free
        # from the loader's signal)
        touched = np.intersect1d(uniq.astype(np.int64),
                                 cache_np.astype(np.int64))
        n = max(64, 1 << max(0, int(touched.size) - 1).bit_length())
        ids_p = np.full(n, V, np.int32)
        ids_p[:touched.size] = touched
        slots_p = np.full(n, C, np.int32)
        slots_p[:touched.size] = np.searchsorted(cache_np, touched)
        ids_d, slots_d = jnp.asarray(ids_p), jnp.asarray(slots_p)

        def _fresh():
            st = (jnp.asarray(table0), jnp.asarray(accum0))
            cr = jnp.take(st[0], cache_ids, axis=0)
            jax.block_until_ready((st, cr))
            return st[0], st[1], cr

        def run_sync():
            table, accum, cache_rows = _fresh()
            out = []
            for _ in range(PIPE_ROUNDS):
                t0 = time.perf_counter()
                table, accum = fused(table, accum)
                cache_rows = refresh_full(table)
                jax.block_until_ready((table, cache_rows))  # per-step
                out.append((time.perf_counter() - t0) * 1e3)
            return out

        def run_pipe():
            table, accum, cache_rows = _fresh()
            pending = []
            out = []
            for _ in range(PIPE_ROUNDS):
                t0 = time.perf_counter()
                # deferred block from the previous round, drained BEFORE
                # this round's donating calls consume the arrays it holds
                # (fused donates table, refresh_delta the stale replica)
                if pending:
                    jax.block_until_ready(pending.pop(0))
                table, accum = fused(table, accum)
                cache_rows = refresh_delta(table, cache_rows, ids_d,
                                           slots_d)
                pending.append((table, cache_rows))
                out.append((time.perf_counter() - t0) * 1e3)
            jax.block_until_ready(pending)
            return out

        run_sync(), run_pipe()              # compile both arms
        r = paired_pooled_ratio(run_sync, run_pipe, reps=reps)
        speedup = 1.0 / r["ratio"]          # pipelined is the test arm
        entries.append(dict(
            zipf=1.0, D=D, C=C, delta_bucket=n,
            sync_round_ms=round(r["median_base"], 3),
            pipelined_round_ms=round(r["median_test"], 3),
            speedup=round(speedup, 3), aa_drift=round(r["drift"], 4)))
        print(f"hotpath,pipeline,zipf1.0_D{D},speedup,{speedup:.3f}")
    return entries


def _headline(entries: List[dict]) -> dict:
    at10 = [e["speedup"] for e in entries if e["zipf"] == 1.0]
    return {"speedup_zipf1.0_min": round(min(at10), 3),
            "speedup_zipf1.0_median": round(float(np.median(at10)), 3)}


def run(quick: bool = False, trace_path: str = None,
        metrics_path: str = None) -> List[str]:
    """Benchmark-harness entry point (also wired into `benchmarks.run`).
    Full runs refresh both the full-scale entries and the CI-scale quick
    entries; ``--quick`` refreshes only the quick section (preserving any
    committed full entries).  ``trace_path``/``metrics_path`` export
    per-shape measurement spans and the per-shape medians as Chrome
    trace / JSONL (DESIGN.md §14)."""
    tracer = make_tracer(bool(trace_path))
    bus = Telemetry() if metrics_path else None
    doc = {}
    if os.path.exists(_OUT):
        with open(_OUT) as f:
            doc = json.load(f)
    doc["bench"] = "hotpath"
    doc.setdefault("note", (
        "Single-sort fused managed step vs PR-4 replica (3 sorts + dense "
        "(V,D) grad), paired medians on the jnp data path; speedups are "
        "per identical (zipf, D) shape."))
    rows = []
    if not quick:
        doc["config"] = {k: v for k, v in FULL.items()}
        doc["entries"] = _bench_entries(FULL, SKEWS_FULL, tracer, bus)
        doc["headline"] = _headline(doc["entries"])
    doc["quick_config"] = {k: v for k, v in QUICK.items()}
    doc["quick_entries"] = _bench_entries(QUICK, SKEWS_QUICK, tracer, bus)
    doc["quick_headline"] = _headline(doc["quick_entries"])
    auto_entries = _auto_entries(QUICK, SKEWS_QUICK)
    doc["auto"] = {
        "note": ("Zero-tuning arm (DESIGN.md §13): the fused step at the "
                 "demand-steered replica capacity vs the hand-tuned "
                 "quick C, paired per shape."),
        "entries": auto_entries,
        "min_auto_vs_tuned_x": round(
            min(e["auto_vs_tuned_x"] for e in auto_entries), 3),
    }
    rows.append(f"hotpath,auto,min_auto_vs_tuned_x,"
                f"{doc['auto']['min_auto_vs_tuned_x']}")
    pipe_entries = _pipeline_entries(QUICK)
    doc["pipeline"] = {
        "note": ("Intent-lead-time pipeline arm (DESIGN.md §15): fused "
                 "step + replica refresh every round, synchronous full "
                 "C-row re-gather vs pipelined delta re-gather with a "
                 "one-round deferred block; paired pooled medians."),
        "entries": pipe_entries,
        "min_speedup": round(min(e["speedup"] for e in pipe_entries), 3),
        "min_speedup_required": PIPELINE_MIN_SPEEDUP,
    }
    rows.append(f"hotpath,pipeline,min_speedup,"
                f"{doc['pipeline']['min_speedup']}")
    with open(_OUT, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {os.path.relpath(_OUT)}")
    if trace_path:
        tracer.dump(trace_path)
        print(f"wrote {trace_path} ({tracer.count} spans)")
    if metrics_path:
        with JsonlSink(metrics_path) as sink:
            sink.write_bus(bus, label="hotpath_bench")
        print(f"wrote {metrics_path}")
    for e in doc.get("entries", []) + doc["quick_entries"]:
        rows.append(f"hotpath,managed_step,zipf{e['zipf']}_D{e['D']},"
                    f"speedup,{e['speedup']}")
    return rows


def check_auto(path: str) -> int:
    """CI guard for the zero-tuning arm: re-measure the steered-capacity
    step against the hand-tuned capacity on the quick shapes and fail if
    the paired median falls more than 15% behind (the two sides run
    back-to-back in this process — machine-normalized by construction).
    The committed baseline must already carry an ``auto`` section."""
    with open(path) as f:
        base = json.load(f)
    if not base.get("auto", {}).get("entries"):
        print(f"no auto section baseline in {path}")
        return 1

    def worst():
        return min(e["auto_vs_tuned_x"]
                   for e in _auto_entries(QUICK, SKEWS_QUICK))

    meas = worst()
    print(f"auto arm: min steered-vs-tuned paired median x{meas:.3f} "
          f"(floor x{AUTO_MIN_RATIO:.3f})")
    if meas < AUTO_MIN_RATIO:
        print("possible regression — re-measuring to filter host noise")
        meas = max(meas, worst())
        print(f"best-of-two: x{meas:.3f}")
    if meas < AUTO_MIN_RATIO:
        print(f"steered capacity regressed >15% vs hand-tuned ({path})")
        return 1
    print("steered capacity within 15% of hand-tuned")
    return 0


def check_pipeline(path: str) -> int:
    """CI guard for the §15 pipeline arm: re-measure the pipelined vs
    synchronous refresh rounds on the quick shapes and fail when the
    paired pooled-median speedup falls more than 15% behind the
    committed one (machine-normalized: both arms run in this process).
    The committed baseline must already carry a ``pipeline`` section
    whose entries meet ``min_speedup_required``."""
    with open(path) as f:
        base = json.load(f)
    base_entries = {e["D"]: e
                    for e in base.get("pipeline", {}).get("entries", [])}
    if not base_entries:
        print(f"no pipeline section baseline in {path}")
        return 1

    def measure():
        ratios = {}
        for e in _pipeline_entries(QUICK):
            if e["D"] not in base_entries:
                continue
            then = base_entries[e["D"]]["speedup"]
            ratios[e["D"]] = then / e["speedup"]   # >1 = slower now
            print(f"pipeline D{e['D']}: speedup now x{e['speedup']:.3f} "
                  f"vs committed x{then:.3f}")
        return ratios

    ratios = measure()
    if not ratios:
        print("no overlapping pipeline entries with the baseline")
        return 1
    geo = float(np.exp(np.mean(np.log(list(ratios.values())))))
    print(f"pipelined-vs-sync speedup vs baseline: x{1 / geo:.3f} "
          f"(geomean over {len(ratios)} dims, tolerance "
          f"x{REGRESSION_TOL})")
    if geo > REGRESSION_TOL:
        print("possible regression — re-measuring to filter host noise")
        second = measure()
        best = {k: min(v, second.get(k, v)) for k, v in ratios.items()}
        geo = float(np.exp(np.mean(np.log(list(best.values())))))
        print(f"best-of-two: x{1 / geo:.3f}")
    if geo > REGRESSION_TOL:
        print(f"pipeline speedup regressed >15% vs {path}")
        return 1
    print("pipeline speedup within 15% of the committed baseline")
    return 0


def check_baseline(path: str) -> int:
    """CI regression guard: re-measure the quick shapes and compare each
    (zipf, D) pair's fused-step median against the committed baseline,
    normalized through the paired legacy replica (machine-independent).
    Returns a process exit code."""
    with open(path) as f:
        base = json.load(f)
    base_entries = {(e["zipf"], e["D"]): e
                    for e in base.get("quick_entries", [])}
    if not base_entries:
        print(f"no quick_entries baseline in {path}")
        return 1
    def measure_ratios():
        """Per-shape fused median in units of its paired legacy median,
        relative to the committed baseline (>1 = slower than committed)."""
        ratios = {}
        for e in _bench_entries(QUICK, SKEWS_QUICK):
            key = (e["zipf"], e["D"])
            if key not in base_entries:
                continue
            b = base_entries[key]
            now = e["fused_us"] / e["legacy_us"]
            then = b["fused_us"] / b["legacy_us"]
            ratios[key] = now / then
            print(f"zipf{key[0]}_D{key[1]}: fused/legacy now {now:.3f} vs "
                  f"baseline {then:.3f} (x{now / then:.2f})")
        return ratios

    def geomean(vals):
        return float(np.exp(np.mean(np.log(list(vals)))))

    ratios = measure_ratios()
    if not ratios:
        print("no overlapping (zipf, D) entries with the baseline")
        return 1
    # a real hot-path regression slows the fused step on EVERY shape and
    # in EVERY run, so the verdict (a) aggregates across shapes (geomean)
    # and (b) on a first-pass trip, re-measures and keeps each shape's
    # best-of-two — one-sided scheduler noise on a shared CI host doesn't
    # reproduce, a genuine regression does
    geo = geomean(ratios.values())
    print(f"normalized managed-step median vs baseline: x{geo:.3f} "
          f"(geomean over {len(ratios)} shapes, tolerance "
          f"x{REGRESSION_TOL})")
    if geo > REGRESSION_TOL:
        print("possible regression — re-measuring to filter host noise")
        second = measure_ratios()
        best = {k: min(v, second.get(k, v)) for k, v in ratios.items()}
        geo = geomean(best.values())
        print(f"best-of-two normalized median: x{geo:.3f}")
    if geo > REGRESSION_TOL:
        print(f"managed-step median regressed >15% vs {path}")
        return 1
    print("hot-path median within 15% of the committed baseline")
    return 0


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized shapes only")
    ap.add_argument("--check-baseline", metavar="JSON", default=None,
                    help="regression guard: compare against a committed "
                    "BENCH_hotpath.json instead of writing results")
    ap.add_argument("--auto", action="store_true",
                    help="with --check-baseline: guard the zero-tuning "
                    "arm (demand-steered capacity vs hand-tuned, paired)")
    ap.add_argument("--pipeline", action="store_true",
                    help="with --check-baseline: guard the §15 pipeline "
                    "arm (pipelined vs synchronous refresh, paired)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write per-shape measurement spans as Chrome "
                         "trace JSON")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write per-shape medians as JSONL telemetry")
    args = ap.parse_args()
    if args.check_baseline:
        if args.pipeline:
            raise SystemExit(check_pipeline(args.check_baseline))
        raise SystemExit(check_auto(args.check_baseline) if args.auto
                         else check_baseline(args.check_baseline))
    run(quick=args.quick, trace_path=args.trace,
        metrics_path=args.metrics_out)
