"""Mesh-real collective benchmark: managed vs plain lookup over the
`shard_map` psum data path (DESIGN.md §10), on an 8-device host mesh.

This is the acceptance measurement for the collective-backend layer: with
the table vocab-sharded over a real ``("model",)`` mesh, the managed path
moves only the compact ``(M+1, D)`` intent-planned miss buffer through
the psum while the plain vocab-parallel baseline moves every token's row
— the ``(T, D)`` dense partial-sum.  Reported per Zipf skew:

  * device time of the managed data path (`planned_serve_lookup` over
    `MeshBackend`; the index stage runs at admission, host-side) vs the
    plain dense lookup (`plain_serve_lookup` over the same mesh);
  * the wire story: rows through the collective, managed vs plain;
  * the training closure: fwd+bwd time of `pm_lookup` (psum forward,
    psum_scatter backward) vs a dense lookup's gather/scatter.

Needs a multi-device host; when launched on a single-device one (e.g.
from ``benchmarks.run``) it re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the flag only
takes effect before jax initializes.  Writes ``BENCH_mesh.json`` at the
repo root next to the other BENCH_* trajectories.

CLI: ``python -m benchmarks.mesh_bench [--quick]``.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List

import numpy as np

_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                    "BENCH_mesh.json")

N_DEV = 8
V, D = 32768, 256
B, K = 16, 256           # T = 4096 tokens per batch
C = 4096                 # replica-cache capacity (holds the Zipf head)
ITERS = 20


def _rows(summary) -> List[str]:
    from .common import emit
    rows: List[str] = []
    for e in summary["entries"]:
        tag = f"zipf{e['zipf']}"
        emit(rows, "mesh", "managed", tag, "lookup_us", e["managed_us"])
        emit(rows, "mesh", "plain", tag, "lookup_us", e["plain_us"])
        emit(rows, "mesh", "managed", tag, "speedup_x", e["speedup_x"])
        emit(rows, "mesh", "managed", tag, "collective_rows",
             e["buffer_rows"])
        emit(rows, "mesh", "plain", tag, "collective_rows",
             e["dense_rows"])
        emit(rows, "mesh", "managed", tag, "train_fwd_bwd_us",
             e["train_fwd_bwd_us"])
    emit(rows, "mesh", "managed", "ALL", "managed_faster_at_zipf_ge_1",
         int(summary["managed_faster_at_zipf_ge_1"]))
    return rows


def _reexec(quick: bool) -> List[str]:
    """Re-launch this module under a forced multi-device host platform
    (XLA flags are read once at jax init, so the parent process cannot
    grow devices in place).  The marker env var bounds this to ONE
    attempt: on hosts where the flag cannot raise the device count (e.g.
    a single-GPU default backend) the child fails loudly instead of
    forking an endless re-exec chain."""
    if os.environ.get("_MESH_BENCH_REEXEC"):
        raise RuntimeError(
            f"still fewer than {N_DEV} devices after forcing "
            f"--xla_force_host_platform_device_count={N_DEV}; this host's "
            "default jax backend does not honor the flag — run on CPU or "
            f"a host with >= {N_DEV} devices")
    env = dict(os.environ, _MESH_BENCH_REEXEC="1")
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={N_DEV}").strip()
    cmd = [sys.executable, "-m", "benchmarks.mesh_bench"]
    if quick:
        cmd.append("--quick")
    subprocess.run(cmd, check=True, env=env,
                   cwd=os.path.join(os.path.dirname(
                       os.path.abspath(__file__)), ".."))
    with open(_OUT) as f:
        return _rows(json.load(f))


def _run_local(quick: bool):
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import SyntheticCorpus
    from repro.launch.mesh import make_model_mesh
    from repro.pm.collectives import MeshBackend
    from repro.pm.embedding import (make_state, plain_serve_lookup,
                                    planned_serve_lookup, pm_lookup,
                                    probe_host)

    from .common import time_fn

    t_start = time.time()
    backend = MeshBackend(make_model_mesh(N_DEV))
    rng = np.random.default_rng(0)
    table = backend.place_table(
        jnp.asarray(rng.normal(size=(V, D)), jnp.float32))

    managed_fn = jax.jit(lambda t, cr, bi, h, cs, bs: planned_serve_lookup(
        t, cr, bi, h, cs, bs, backend=backend))
    plain_fn = jax.jit(lambda t, tok: plain_serve_lookup(
        t, tok, backend=backend))

    def bucket(n, floor=64):
        b = floor
        while b < n:
            b *= 2
        return b

    skews = [1.0, 1.1] if quick else [1.0, 1.1, 1.5]
    iters = ITERS // 2 if quick else ITERS
    entries = []
    for zipf_a in skews:
        corpus = SyntheticCorpus(V, zipf_a=zipf_a, seed=3)
        tokens = corpus.tokens((B, K))
        # the plan: cache the Zipf head (rank < C through the corpus
        # permutation), size the buffer by the observed unique miss count
        # — what `IntentPlanner` would derive from the signaled window
        cache_ids = np.sort(corpus.perm[:C]).astype(np.int32)
        probe = probe_host(cache_ids, tokens.reshape(-1), B * K)
        M = bucket(max(1, probe.n_miss))
        probe = probe_host(cache_ids, tokens.reshape(-1), M)
        assert not probe.overflow.any()
        st = make_state(table, jnp.asarray(cache_ids), backend)
        idx = [jnp.asarray(a) for a in
               (probe.buf_ids, probe.hit.astype(np.int32),
                probe.cache_slot, probe.buf_slot)]
        tok_dev = jnp.asarray(tokens)
        managed_us = time_fn(
            lambda: managed_fn(table, st.cache_rows, *idx),
            iters=iters, block=jax.block_until_ready)
        plain_us = time_fn(lambda: plain_fn(table, tok_dev),
                           iters=iters, block=jax.block_until_ready)

        # training closure: fwd+bwd through the mesh VJP (psum forward,
        # psum_scatter backward) vs the dense gather/scatter
        grad_m = jax.jit(jax.grad(lambda t: jnp.sum(pm_lookup(
            t, st.cache_ids, st.cache_rows, tok_dev, M, True, False,
            backend) ** 2)))
        grad_p = jax.jit(jax.grad(lambda t: jnp.sum(
            jnp.take(t, tok_dev.reshape(-1), axis=0) ** 2)))
        train_m_us = time_fn(lambda: grad_m(table), iters=max(3, iters // 4),
                             block=jax.block_until_ready)
        train_p_us = time_fn(lambda: grad_p(table), iters=max(3, iters // 4),
                             block=jax.block_until_ready)

        entries.append({
            "zipf": zipf_a,
            "miss_capacity": M,
            "unique_misses": int(probe.n_miss),
            "miss_rate": round(float(1.0 - probe.hit.mean()), 4),
            "managed_us": round(managed_us, 1),
            "plain_us": round(plain_us, 1),
            "speedup_x": round(plain_us / max(managed_us, 1e-9), 2),
            "buffer_rows": M + 1,        # what the managed psum moves
            "dense_rows": B * K,         # what the plain psum moves
            "train_fwd_bwd_us": round(train_m_us, 1),
            "train_fwd_bwd_plain_us": round(train_p_us, 1),
        })

    summary = {
        "config": {"vocab": V, "dim": D, "tokens_per_batch": B * K,
                   "cache_capacity": C, "devices": N_DEV,
                   "iters": iters, "quick": quick},
        "entries": entries,
        "managed_faster_at_zipf_ge_1": all(
            e["speedup_x"] > 1.0 for e in entries if e["zipf"] >= 1.0),
        "wall_clock_s": round(time.time() - t_start, 2),
    }
    with open(_OUT, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"wrote {os.path.normpath(_OUT)}")
    return summary


def run(quick: bool = False) -> List[str]:
    import jax
    if len(jax.devices()) < N_DEV:
        return _reexec(quick)
    return _rows(_run_local(quick))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized smoke (2 skews, half the iters)")
    run(quick=ap.parse_args().quick)
