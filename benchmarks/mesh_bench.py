"""Mesh-real collective benchmark: managed vs plain lookup over the
`shard_map` psum data path (DESIGN.md §10), on an 8-device host mesh.

This is the acceptance measurement for the collective-backend layer: with
the table vocab-sharded over a real ``("model",)`` mesh, the managed path
moves only the compact ``(M+1, D)`` intent-planned miss buffer through
the psum while the plain vocab-parallel baseline moves every token's row
— the ``(T, D)`` dense partial-sum.  Reported per Zipf skew:

  * device time of the managed data path (`planned_serve_lookup` over
    `MeshBackend`; the index stage runs at admission, host-side) vs the
    plain dense lookup (`plain_serve_lookup` over the same mesh);
  * the wire story: rows through the collective, managed vs plain;
  * the training closure: fwd+bwd time of `pm_lookup` (psum forward,
    psum_scatter backward) vs a dense lookup's gather/scatter;
  * the ``fused`` arm (ISSUE 6): the routed fused managed step —
    destination-compacted `all_to_all` miss gather + on-shard sparse
    AdaGrad (`MeshBackend.gather_rows_routed` / `update_rows`, donated
    buffers) — paired call-for-call against a faithful replica of the
    PR-4 mesh step (replicated psum gather, dense ``(V, D)`` partial +
    psum_scatter backward, dense optimizer sweep over the sharded
    table).  Both sides run the pure-jnp row math: on this CPU container
    interpret-mode Pallas timings are meaningless, and the jnp path
    isolates exactly what the PR changed — collective layout and memory
    traffic.

Needs a multi-device host; when launched on a single-device one (e.g.
from ``benchmarks.run``) it re-execs itself in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the flag only
takes effect before jax initializes.  Writes ``BENCH_mesh.json`` at the
repo root next to the other BENCH_* trajectories.

CLI:
  python -m benchmarks.mesh_bench [--quick]
  python -m benchmarks.mesh_bench --check-baseline BENCH_mesh.json
  python -m benchmarks.mesh_bench --pipeline --check-baseline BENCH_mesh.json

``--check-baseline`` is the CI regression guard for the fused arm: it
re-measures the quick skews and FAILS (exit 1) if the fused step's
median regressed more than 15% against the committed baseline; with
``--pipeline`` it guards the §15 pipelined-refresh arm instead.  The
comparison is normalized through the paired legacy replica (current
fused/legacy ratio vs the committed one), so absolute CPU-speed
differences between CI hosts don't trip it while a real routed-path
regression does.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import List

import numpy as np

_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                    "BENCH_mesh.json")

N_DEV = 8
V, D = 32768, 256
B, K = 16, 256           # T = 4096 tokens per batch
C = 4096                 # replica-cache capacity (holds the Zipf head)
ITERS = 20
FUSED_ITERS = 9          # paired-median iters of the fused-step arm
SKEWS_FULL = (1.0, 1.1, 1.5)
SKEWS_QUICK = (1.0, 1.1)
REGRESSION_TOL = 1.15    # CI guard: >15% normalized regression fails
# §15 pipeline arm: refresh-every-round replica sync over the mesh —
# synchronous full C-row psum re-gather vs the routed delta re-gather
# (touched ∩ cached bucket) with a one-round deferred block.  The arm
# runs in the refresh-heavy regime refresh_every=1 implies: a smaller
# per-round batch (the step's touched set stays far below C) and a
# replica holding half the vocab, so the full re-gather is a real
# fraction of the round instead of rounding error under the step
PIPE_ROUNDS = 6
PIPE_B = 4               # pipeline-arm batch: T = PIPE_B * K tokens
PIPE_C = V // 2          # pipeline-arm replica capacity
PIPELINE_MIN_SPEEDUP = 1.15


def _rows(summary) -> List[str]:
    from .common import emit
    rows: List[str] = []
    for e in summary["entries"]:
        tag = f"zipf{e['zipf']}"
        emit(rows, "mesh", "managed", tag, "lookup_us", e["managed_us"])
        emit(rows, "mesh", "plain", tag, "lookup_us", e["plain_us"])
        emit(rows, "mesh", "managed", tag, "speedup_x", e["speedup_x"])
        emit(rows, "mesh", "managed", tag, "collective_rows",
             e["buffer_rows"])
        emit(rows, "mesh", "plain", tag, "collective_rows",
             e["dense_rows"])
        emit(rows, "mesh", "managed", tag, "train_fwd_bwd_us",
             e["train_fwd_bwd_us"])
    for e in summary.get("fused", {}).get("entries", []):
        tag = f"zipf{e['zipf']}"
        emit(rows, "mesh", "fused_step", tag, "legacy_us",
             e["legacy_step_us"])
        emit(rows, "mesh", "fused_step", tag, "fused_us",
             e["fused_step_us"])
        emit(rows, "mesh", "fused_step", tag, "speedup_x", e["speedup"])
    pl = summary.get("pipeline")
    if pl:
        emit(rows, "mesh", "pipeline", "zipf1.0", "speedup_x",
             pl["speedup"])
    emit(rows, "mesh", "managed", "ALL", "managed_faster_at_zipf_ge_1",
         int(summary["managed_faster_at_zipf_ge_1"]))
    return rows


def _reexec(quick: bool, trace_path=None, metrics_path=None) -> List[str]:
    """Re-launch this module under a forced multi-device host platform
    (XLA flags are read once at jax init, so the parent process cannot
    grow devices in place).  The marker env var bounds this to ONE
    attempt: on hosts where the flag cannot raise the device count (e.g.
    a single-GPU default backend) the child fails loudly instead of
    forking an endless re-exec chain.  Observability paths ride along as
    absolute paths — the child runs with cwd at the repo root, which may
    differ from the caller's."""
    if os.environ.get("_MESH_BENCH_REEXEC"):
        raise RuntimeError(
            f"still fewer than {N_DEV} devices after forcing "
            f"--xla_force_host_platform_device_count={N_DEV}; this host's "
            "default jax backend does not honor the flag — run on CPU or "
            f"a host with >= {N_DEV} devices")
    env = dict(os.environ, _MESH_BENCH_REEXEC="1")
    flags = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={N_DEV}").strip()
    cmd = [sys.executable, "-m", "benchmarks.mesh_bench"]
    if quick:
        cmd.append("--quick")
    if trace_path:
        cmd += ["--trace", os.path.abspath(trace_path)]
    if metrics_path:
        cmd += ["--metrics-out", os.path.abspath(metrics_path)]
    subprocess.run(cmd, check=True, env=env,
                   cwd=os.path.join(os.path.dirname(
                       os.path.abspath(__file__)), ".."))
    with open(_OUT) as f:
        return _rows(json.load(f))


def _bucket(n, floor=64):
    b = floor
    while b < n:
        b *= 2
    return b


def _make_step_pair(backend, cache_ids, cache_rows, tokens, M, lr=0.1):
    """Paired mesh train-step replicas over identical inputs.  Both share
    the single-sort index stage, the jnp row math and the AdaGrad update
    rule; they differ exactly in the collective layout ISSUE 6 changed:

      legacy : PR-4 data movement — replicated psum of the (M+1, D) miss
               buffer forward, dense (V, D) partial + tiled psum_scatter
               backward, dense optimizer sweep over the sharded table;
      fused  : destination-compacted routing — per-owner all-gather of
               the miss rows forward, all_to_all routed (id, grad-row)
               pairs applied on-shard, donated table/accum, no dense
               (V, D) buffer anywhere.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref
    from repro.kernels.pm_forward import step_residual

    T = tokens.size
    tok = tokens.reshape(-1).astype(jnp.int32)
    Dm = cache_rows.shape[1]

    def _combine(buf_rows, pc):
        buffer = jnp.concatenate(
            [buf_rows, jnp.zeros((1, Dm), buf_rows.dtype)])
        return ref.pm_combine_ref(pc.hit, pc.cache_slot, pc.buf_slot,
                                  cache_rows, buffer)

    def _row_grads(res, buf_rows):
        out = _combine(buf_rows, res.probe)
        gt = 2.0 * out                    # d sum(out^2) / d out
        seg_ids, seg_g = ops.segment_rows(tok, gt, n_slots=T, pad_id=V,
                                          residual=res.sort)
        return seg_ids, seg_g.astype(jnp.float32)

    def legacy_step(table, accum):
        res = step_residual(cache_ids, tok, M)
        buf_rows = backend.gather_rows(table, res.probe.buf_ids)
        seg_ids, seg_g = _row_grads(res, buf_rows)
        g = backend.scatter_row_grads_psum(seg_ids, seg_g, V,
                                           segmented=True)
        new_accum = accum + g * g         # dense sweep over (V/n, D)
        new_table = table - lr * g / (jnp.sqrt(new_accum) + 1e-8)
        return new_table, new_accum

    def fused_step(table, accum):
        res = step_residual(cache_ids, tok, M)
        buf_rows = backend.gather_rows_routed(table, res.probe.buf_ids,
                                              res.probe.n_miss)
        seg_ids, seg_g = _row_grads(res, buf_rows)
        return backend.update_rows(table, accum, seg_ids, seg_g, lr=lr)

    return (jax.jit(legacy_step),
            jax.jit(fused_step, donate_argnums=(0, 1)))


def _paired_step_medians(legacy, fused, table, accum, iters: int):
    """Alternate the two steps call-for-call and report each side's
    median latency (us).  The fused step donates its buffers, so every
    call receives fresh sharded copies prepared — and blocked on —
    outside the timed region."""
    import jax
    import jax.numpy as jnp

    def fused_inputs():
        pair = (jnp.copy(table), jnp.copy(accum))
        jax.block_until_ready(pair)
        return pair

    jax.block_until_ready(legacy(table, accum))        # compile
    jax.block_until_ready(fused(*fused_inputs()))
    tl, tf = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(legacy(table, accum))
        tl.append(time.perf_counter() - t0)
        tc, ac = fused_inputs()
        t0 = time.perf_counter()
        jax.block_until_ready(fused(tc, ac))
        tf.append(time.perf_counter() - t0)
    return float(np.median(tl) * 1e6), float(np.median(tf) * 1e6)


def _fused_arm(quick: bool, tracer=None, bus=None):
    """The ISSUE 6 acceptance measurement: routed fused step vs the PR-4
    replica, per Zipf skew, on the 8-device mesh."""
    import jax.numpy as jnp

    from repro.data.pipeline import SyntheticCorpus
    from repro.launch.mesh import make_model_mesh
    from repro.obs import make_tracer
    from repro.pm.collectives import MeshBackend
    from repro.pm.embedding import make_state, probe_host

    tr = make_tracer(False, tracer=tracer)
    backend = MeshBackend(make_model_mesh(N_DEV))
    rng = np.random.default_rng(0)
    table = backend.place_table(
        jnp.asarray(rng.normal(size=(V, D)), jnp.float32))
    accum = backend.place_table(jnp.full((V, D), 0.1, jnp.float32))
    skews = SKEWS_QUICK if quick else SKEWS_FULL
    iters = max(3, FUSED_ITERS // 2) if quick else FUSED_ITERS
    entries = []
    for zipf_a in skews:
        corpus = SyntheticCorpus(V, zipf_a=zipf_a, seed=3)
        tokens = corpus.tokens((B, K))
        cache_ids = np.sort(corpus.perm[:C]).astype(np.int32)
        probe = probe_host(cache_ids, tokens.reshape(-1), B * K)
        M = _bucket(max(1, probe.n_miss))
        st = make_state(table, jnp.asarray(cache_ids), backend)
        legacy, fused = _make_step_pair(backend, jnp.asarray(cache_ids),
                                        st.cache_rows,
                                        jnp.asarray(tokens), M)
        with tr.span("mesh.fused_skew", a=int(zipf_a * 10), b=M):
            lus, fus = _paired_step_medians(legacy, fused, table, accum,
                                            iters)
        if bus is not None:
            bus.set("mesh.fused_legacy_us", round(lus, 1), zipf=zipf_a)
            bus.set("mesh.fused_us", round(fus, 1), zipf=zipf_a)
            bus.set("mesh.fused_speedup", round(lus / fus, 3),
                    zipf=zipf_a)
        entries.append(dict(zipf=zipf_a, M=M,
                            legacy_step_us=round(lus, 1),
                            fused_step_us=round(fus, 1),
                            speedup=round(lus / fus, 3)))
        print(f"mesh,fused_step,zipf{zipf_a},us_legacy,{lus:.1f}")
        print(f"mesh,fused_step,zipf{zipf_a},us_fused,{fus:.1f}")
        print(f"mesh,fused_step,zipf{zipf_a},speedup,{lus / fus:.2f}")
    return entries


def _pipeline_arm(quick: bool) -> dict:
    """§15 pipeline arm (DESIGN.md §15): the fused routed step under
    refresh-every-round replica sync, synchronous (full C-row replicated
    psum re-gather + per-round block) vs pipelined (routed delta
    re-gather of the touched ∩ cached bucket, block deferred one round)
    — paired via `benchmarks.common.paired_pooled_ratio`.  Both arms run
    the identical fused step; the delta is exact for the same reason the
    train loop's gate demands (sparse AdaGrad touches only the batch's
    rows), so the speedup is refresh traffic eliminated from the mesh."""
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import SyntheticCorpus
    from repro.launch.mesh import make_model_mesh
    from repro.pm.collectives import MeshBackend
    from repro.pm.embedding import make_state, probe_host

    from .common import paired_pooled_ratio

    backend = MeshBackend(make_model_mesh(N_DEV))
    rng = np.random.default_rng(0)
    table0 = np.asarray(rng.normal(size=(V, D)), np.float32)
    accum0 = np.full((V, D), 0.1, np.float32)
    corpus = SyntheticCorpus(V, zipf_a=1.0, seed=3)
    tokens = corpus.tokens((PIPE_B, K))
    cache_np = np.sort(corpus.perm[:PIPE_C]).astype(np.int32)
    cache_ids = jnp.asarray(cache_np)
    probe = probe_host(cache_np, tokens.reshape(-1), PIPE_B * K)
    M = _bucket(max(1, probe.n_miss))
    st = make_state(backend.place_table(jnp.asarray(table0)), cache_ids,
                    backend)
    _, fused = _make_step_pair(backend, cache_ids, st.cache_rows,
                               jnp.asarray(tokens), M)
    refresh_full = jax.jit(lambda t: backend.gather_rows(t, cache_ids))
    refresh_delta = jax.jit(backend.refresh_rows_delta,
                            donate_argnums=(1,))
    # the delta bucket: the step's touched rows that live in the replica
    # (the train loop gets this set free from the loader's signal)
    touched = np.intersect1d(np.unique(tokens).astype(np.int64),
                             cache_np.astype(np.int64))
    n = _bucket(max(1, int(touched.size)))
    ids_p = np.full(n, V, np.int32)
    ids_p[:touched.size] = touched
    slots_p = np.full(n, PIPE_C, np.int32)
    slots_p[:touched.size] = np.searchsorted(cache_np, touched)
    ids_d, slots_d = jnp.asarray(ids_p), jnp.asarray(slots_p)

    def _fresh():
        t = backend.place_table(jnp.asarray(table0))
        a = backend.place_table(jnp.asarray(accum0))
        cr = refresh_full(t)
        jax.block_until_ready((t, a, cr))
        return t, a, cr

    def run_sync():
        table, accum, cache_rows = _fresh()
        out = []
        for _ in range(PIPE_ROUNDS):
            t0 = time.perf_counter()
            table, accum = fused(table, accum)
            cache_rows = refresh_full(table)
            jax.block_until_ready((table, cache_rows))   # per-round
            out.append((time.perf_counter() - t0) * 1e3)
        return out

    def run_pipe():
        table, accum, cache_rows = _fresh()
        pending = []
        out = []
        for _ in range(PIPE_ROUNDS):
            t0 = time.perf_counter()
            # deferred block from the previous round, drained BEFORE
            # this round's donating calls consume the arrays it holds
            if pending:
                jax.block_until_ready(pending.pop(0))
            table, accum = fused(table, accum)
            cache_rows = refresh_delta(table, cache_rows, ids_d, slots_d)
            pending.append((table, cache_rows))
            out.append((time.perf_counter() - t0) * 1e3)
        jax.block_until_ready(pending)
        return out

    run_sync(), run_pipe()                               # compile
    r = paired_pooled_ratio(run_sync, run_pipe,
                            reps=3 if quick else 4)
    speedup = 1.0 / r["ratio"]
    print(f"mesh,pipeline,zipf1.0,speedup,{speedup:.3f}")
    return dict(
        note=("Fused routed step + replica refresh every round: "
              "synchronous full C-row psum re-gather vs routed delta "
              "re-gather with a one-round deferred block; paired "
              "pooled medians (DESIGN.md §15)."),
        zipf=1.0, C=PIPE_C, tokens_per_round=PIPE_B * K,
        delta_bucket=n, rounds=PIPE_ROUNDS,
        sync_round_ms=round(r["median_base"], 3),
        pipelined_round_ms=round(r["median_test"], 3),
        speedup=round(speedup, 3), aa_drift=round(r["drift"], 4),
        min_speedup_required=PIPELINE_MIN_SPEEDUP)


def _geomean(vals):
    return float(np.exp(np.mean(np.log(list(vals)))))


def _run_local(quick: bool, trace_path=None, metrics_path=None):
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import SyntheticCorpus
    from repro.launch.mesh import make_model_mesh
    from repro.obs import JsonlSink, Telemetry, make_tracer
    from repro.pm.collectives import MeshBackend
    from repro.pm.embedding import (make_state, plain_serve_lookup,
                                    planned_serve_lookup, pm_lookup,
                                    probe_host)

    from .common import time_fn

    tracer = make_tracer(bool(trace_path))
    bus = Telemetry() if metrics_path else None
    t_start = time.time()
    backend = MeshBackend(make_model_mesh(N_DEV))
    rng = np.random.default_rng(0)
    table = backend.place_table(
        jnp.asarray(rng.normal(size=(V, D)), jnp.float32))

    managed_fn = jax.jit(lambda t, cr, bi, h, cs, bs: planned_serve_lookup(
        t, cr, bi, h, cs, bs, backend=backend))
    plain_fn = jax.jit(lambda t, tok: plain_serve_lookup(
        t, tok, backend=backend))

    skews = list(SKEWS_QUICK if quick else SKEWS_FULL)
    iters = ITERS // 2 if quick else ITERS
    entries = []
    for zipf_a in skews:
        corpus = SyntheticCorpus(V, zipf_a=zipf_a, seed=3)
        tokens = corpus.tokens((B, K))
        # the plan: cache the Zipf head (rank < C through the corpus
        # permutation), size the buffer by the observed unique miss count
        # — what `IntentPlanner` would derive from the signaled window
        cache_ids = np.sort(corpus.perm[:C]).astype(np.int32)
        probe = probe_host(cache_ids, tokens.reshape(-1), B * K)
        M = _bucket(max(1, probe.n_miss))
        probe = probe_host(cache_ids, tokens.reshape(-1), M)
        assert not probe.overflow.any()
        st = make_state(table, jnp.asarray(cache_ids), backend)
        idx = [jnp.asarray(a) for a in
               (probe.buf_ids, probe.hit.astype(np.int32),
                probe.cache_slot, probe.buf_slot)]
        tok_dev = jnp.asarray(tokens)
        with tracer.span("mesh.lookup_skew", a=int(zipf_a * 10), b=M):
            managed_us = time_fn(
                lambda: managed_fn(table, st.cache_rows, *idx),
                iters=iters, block=jax.block_until_ready)
            plain_us = time_fn(lambda: plain_fn(table, tok_dev),
                               iters=iters, block=jax.block_until_ready)
        if bus is not None:
            bus.set("mesh.managed_us", round(managed_us, 1), zipf=zipf_a)
            bus.set("mesh.plain_us", round(plain_us, 1), zipf=zipf_a)
            bus.set("mesh.speedup",
                    round(plain_us / max(managed_us, 1e-9), 2),
                    zipf=zipf_a)

        # training closure: fwd+bwd through the mesh VJP (psum forward,
        # psum_scatter backward) vs the dense gather/scatter
        grad_m = jax.jit(jax.grad(lambda t: jnp.sum(pm_lookup(
            t, st.cache_ids, st.cache_rows, tok_dev, M, True, False,
            backend) ** 2)))
        grad_p = jax.jit(jax.grad(lambda t: jnp.sum(
            jnp.take(t, tok_dev.reshape(-1), axis=0) ** 2)))
        train_m_us = time_fn(lambda: grad_m(table), iters=max(3, iters // 4),
                             block=jax.block_until_ready)
        train_p_us = time_fn(lambda: grad_p(table), iters=max(3, iters // 4),
                             block=jax.block_until_ready)

        entries.append({
            "zipf": zipf_a,
            "miss_capacity": M,
            "unique_misses": int(probe.n_miss),
            "miss_rate": round(float(1.0 - probe.hit.mean()), 4),
            "managed_us": round(managed_us, 1),
            "plain_us": round(plain_us, 1),
            "speedup_x": round(plain_us / max(managed_us, 1e-9), 2),
            "buffer_rows": M + 1,        # what the managed psum moves
            "dense_rows": B * K,         # what the plain psum moves
            "train_fwd_bwd_us": round(train_m_us, 1),
            "train_fwd_bwd_plain_us": round(train_p_us, 1),
        })

    fused_entries = _fused_arm(quick, tracer=tracer, bus=bus)
    pipeline = _pipeline_arm(quick)
    summary = {
        "config": {"vocab": V, "dim": D, "tokens_per_batch": B * K,
                   "cache_capacity": C, "devices": N_DEV,
                   "iters": iters, "quick": quick},
        "entries": entries,
        "managed_faster_at_zipf_ge_1": all(
            e["speedup_x"] > 1.0 for e in entries if e["zipf"] >= 1.0),
        "fused": {
            "note": ("Routed fused managed step (all_to_all miss routing "
                     "+ on-shard sparse AdaGrad, donated buffers) vs a "
                     "PR-4 replica (replicated psum gather, dense (V, D) "
                     "partial + psum_scatter, dense optimizer sweep); "
                     "paired medians on the jnp data path."),
            "entries": fused_entries,
            "headline": {"speedup_geomean": round(_geomean(
                [e["speedup"] for e in fused_entries]), 3)},
        },
        "pipeline": pipeline,
        "wall_clock_s": round(time.time() - t_start, 2),
    }
    with open(_OUT, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"wrote {os.path.normpath(_OUT)}")
    if trace_path:
        tracer.dump(trace_path)
        print(f"wrote {trace_path} ({tracer.count} spans)")
    if metrics_path:
        with JsonlSink(metrics_path) as sink:
            sink.write_bus(bus, label="mesh_bench")
        print(f"wrote {metrics_path}")
    return summary


def run(quick: bool = False, trace_path=None,
        metrics_path=None) -> List[str]:
    import jax
    if len(jax.devices()) < N_DEV:
        return _reexec(quick, trace_path, metrics_path)
    return _rows(_run_local(quick, trace_path, metrics_path))


def check_baseline(path: str, pipeline: bool = False) -> int:
    """CI regression guard: re-measure the quick fused-arm skews (or,
    with ``pipeline``, the §15 pipelined-vs-synchronous refresh rounds)
    and compare against the committed baseline, normalized through the
    paired in-process counterpart (machine-independent).  Returns a
    process exit code."""
    import jax
    if len(jax.devices()) < N_DEV:
        # same one-attempt re-exec contract as `run` (see _reexec), but
        # propagating the guard's exit code instead of raising
        if os.environ.get("_MESH_BENCH_REEXEC"):
            print(f"still fewer than {N_DEV} devices after forcing the "
                  "host platform device count")
            return 1
        env = dict(os.environ, _MESH_BENCH_REEXEC="1")
        flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (f"{flags} --xla_force_host_platform_device_"
                            f"count={N_DEV}").strip()
        return subprocess.run(
            [sys.executable, "-m", "benchmarks.mesh_bench",
             "--check-baseline", os.path.abspath(path)]
            + (["--pipeline"] if pipeline else []),
            env=env, cwd=os.path.join(os.path.dirname(
                os.path.abspath(__file__)), "..")).returncode

    with open(path) as f:
        base = json.load(f)
    if pipeline:
        committed = base.get("pipeline", {}).get("speedup")
        if not committed:
            print(f"no pipeline section baseline in {path}")
            return 1
        meas = _pipeline_arm(quick=True)["speedup"]
        print(f"pipeline arm: speedup now x{meas:.3f} vs committed "
              f"x{committed:.3f} (tolerance x{REGRESSION_TOL})")
        if committed / meas > REGRESSION_TOL:
            print("possible regression — re-measuring to filter noise")
            meas = max(meas, _pipeline_arm(quick=True)["speedup"])
            print(f"best-of-two: x{meas:.3f}")
        if committed / meas > REGRESSION_TOL:
            print(f"pipeline speedup regressed >15% vs {path}")
            return 1
        print("pipeline speedup within 15% of the committed baseline")
        return 0
    base_entries = {e["zipf"]: e
                    for e in base.get("fused", {}).get("entries", [])}
    if not base_entries:
        print(f"no fused entries baseline in {path}")
        return 1

    def measure_ratios():
        """Per-skew fused median in units of its paired legacy median,
        relative to the committed baseline (>1 = slower than
        committed)."""
        ratios = {}
        for e in _fused_arm(quick=True):
            if e["zipf"] not in base_entries:
                continue
            b = base_entries[e["zipf"]]
            now = e["fused_step_us"] / e["legacy_step_us"]
            then = b["fused_step_us"] / b["legacy_step_us"]
            ratios[e["zipf"]] = now / then
            print(f"zipf{e['zipf']}: fused/legacy now {now:.3f} vs "
                  f"baseline {then:.3f} (x{now / then:.2f})")
        return ratios

    ratios = measure_ratios()
    if not ratios:
        print("no overlapping zipf entries with the baseline")
        return 1
    geo = _geomean(ratios.values())
    print(f"normalized fused-step median vs baseline: x{geo:.3f} "
          f"(geomean over {len(ratios)} skews, tolerance "
          f"x{REGRESSION_TOL})")
    if geo > REGRESSION_TOL:
        # one-sided scheduler noise on a shared CI host doesn't
        # reproduce; a genuine routed-path regression does
        print("possible regression — re-measuring to filter host noise")
        second = measure_ratios()
        best = {k: min(v, second.get(k, v)) for k, v in ratios.items()}
        geo = _geomean(best.values())
        print(f"best-of-two normalized median: x{geo:.3f}")
    if geo > REGRESSION_TOL:
        print(f"fused mesh step regressed >15% vs {path}")
        return 1
    print("fused mesh step within 15% of the committed baseline")
    return 0


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized smoke (2 skews, half the iters)")
    ap.add_argument("--check-baseline", metavar="JSON", default=None,
                    help="regression guard: compare the fused arm "
                    "against a committed BENCH_mesh.json instead of "
                    "writing results")
    ap.add_argument("--pipeline", action="store_true",
                    help="with --check-baseline: guard the §15 pipeline "
                    "arm (pipelined vs synchronous refresh, paired)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write per-skew spans as Chrome trace-event "
                    "JSON to PATH")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write per-skew gauges as schema-versioned "
                    "JSONL to PATH")
    args = ap.parse_args()
    if args.check_baseline:
        raise SystemExit(check_baseline(args.check_baseline,
                                        pipeline=args.pipeline))
    run(quick=args.quick, trace_path=args.trace,
        metrics_path=args.metrics_out)
