"""Reproducible §Perf hillclimb ladders for the three selected pairs.

Runs every iteration of each ladder (lower + compile + collective-byte
measurement) and prints the before/after table that EXPERIMENTS.md §Perf
records.  ~15 compiles, a few minutes on CPU.

Run:  PYTHONPATH=src:. python -m benchmarks.hillclimb [--out results.json]
"""

import argparse
import json

# dryrun sets the 512-device XLA flag at import time (must precede jax)
from repro.launch.dryrun import dryrun_one


LADDERS = {
    ("nemotron-4-15b", "train_4k"): [
        ("baseline (naive ZeRO everywhere, GSPMD loss)", {}),
        ("it1: un-ZeRO embed/head (kill logits partial-sum AR)",
         dict(zero_embed_head=False)),
        ("it3: shard_map vocab-parallel CE (kill dlogits gather)",
         dict(zero_embed_head=False, vp_loss=True)),
        ("it4: intent-managed embedding (paper technique)",
         dict(zero_embed_head=False, vp_loss=True, pm_miss_capacity=8192)),
        ("it6: auto-ZeRO (weights TP-only when they fit)",
         dict(zero_embed_head=False, vp_loss=True, pm_miss_capacity=8192,
              zero_layers=None)),
        ("it5: remat dots (compute term: 4x -> ~3x fwd)",
         dict(zero_embed_head=False, vp_loss=True, pm_miss_capacity=8192,
              zero_layers=None, remat_policy="dots")),
    ],
    ("qwen3-moe-30b-a3b", "train_4k"): [
        ("baseline", {}),
        ("it1: un-ZeRO embed/head", dict(zero_embed_head=False)),
        ("it3: shard_map vocab-parallel CE",
         dict(zero_embed_head=False, vp_loss=True)),
        ("it4: intent-managed embedding",
         dict(zero_embed_head=False, vp_loss=True, pm_miss_capacity=8192)),
        ("it6: auto-ZeRO",
         dict(zero_embed_head=False, vp_loss=True, pm_miss_capacity=8192,
              zero_layers=None)),
    ],
    ("whisper-medium", "prefill_32k"): [
        ("baseline", {}),
        ("it1a: un-ZeRO embed/head (refuted for whisper: V=51865 "
         "never sharded)", dict(zero_embed_head=False)),
        ("it1b: last-position-only head matmul",
         dict(zero_embed_head=False, prefill_last_only=True)),
        ("it2: pad vocab to shard the head (refuted: keep off)",
         dict(zero_embed_head=False, prefill_last_only=True,
              pad_vocab=True)),
    ],
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/results/hillclimb.json")
    args = ap.parse_args(argv)
    results = []
    for (arch, shape), ladder in LADDERS.items():
        print(f"\n### {arch} x {shape}")
        prev = None
        for label, opts in ladder:
            rec = dryrun_one(arch, shape, verbose=False, **opts)
            assert rec["status"] == "ok", rec
            gb = rec["collective_bytes"] / 1e9
            delta = "" if prev is None else f"  ({prev/gb:5.1f}x vs prev)"
            print(f"  {gb:9.2f} GB/dev collective  {label}{delta}")
            results.append({"arch": arch, "shape": shape, "label": label,
                            **{k: rec[k] for k in
                               ("collective_bytes",
                                "collective_bytes_per_op", "flops_raw",
                                "memory", "compile_s")}})
            prev = gb
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
