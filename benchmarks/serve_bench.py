"""Serving benchmark: the intent-signaled online runtime vs plain lookup.

Measures end-to-end request throughput and p50/p99 latency of the
managed serving runtime (`repro.serve`) against the unmanaged
vocab-parallel baseline across Zipf skews and hot-set drift rates, plus
a drift-adaptation section and a zero-tuning section that check the
acceptance invariants:

  (a) managed serving >= 1.5x plain-lookup throughput at Zipf skew >= 1.0;
  (b) after a hot-set rotation the miss rate returns to within 2x of the
      pre-rotation steady state within one replan round;
  (c) zero silently-dropped (zero-served) requests across the run;
  (d) the online controller, starting from UNTUNED defaults
      (capacity at the ladder floor, short cadence), reaches >= 0.9x the
      frozen hand-tuned managed throughput within a single bench run at
      every measured skew — with zero zero-served tokens across every
      mid-run capacity resize.

The operating config carries NO hand-set runtime knobs: capacity, replan
cadence, refresh cadence and double-buffered admission are all ``"auto"``
(DESIGN.md §13).  The PR-6 hand-tuned values survive only as the frozen
``HAND_TUNED`` reference arm that the auto section compares against —
the serving analogue of the hotpath bench's frozen legacy replica.

Cost model: the embedding is vocab-sharded ``N_SHARDS`` ways and every
row fetched from a non-local shard moves through the emulated
vocab-parallel collective (`pm.embedding.shard_partial_sum`: one
materialized (n, D) partial per shard — the single-host stand-in for the
all-reduce's wire bytes).  The plain baseline moves EVERY token's row
through it; the managed path moves only the compact intent-planned miss
buffer and serves cache hits locally.

Both variants serve identical replayed request traces through the same
queue/scheduler stack, run back-to-back per repetition; the reported
speedup is the median of per-rep throughput ratios (paired to cancel
this container's bursty co-tenant noise).  Writes ``BENCH_serve.json``
at the repo root next to BENCH_quick/BENCH_scale.

CLI: ``python -m benchmarks.serve_bench [--quick] [--auto]
[--check-baseline BENCH_serve.json]`` — ``--check-baseline`` re-measures
a CI-sized arm and fails on a >15% paired regression vs the committed
numbers (with ``--auto``: the auto-vs-tuned ratio arm instead of the
managed-vs-plain arms).

Observability (DESIGN.md §14): ``--trace PATH`` / ``--metrics-out PATH``
run one extra fully-traced managed arm after the measured sections (so
tracing never perturbs the headline numbers) and write the Chrome trace
and the JSONL metrics/attribution sink — the artifacts ``python -m
repro.obs.report`` renders and CI uploads.  ``--check-trace-overhead``
measures tracing's enabled cost: alternating traced-vs-untraced runs on
the frozen tuned config (controller nondeterminism excluded), pooling
every run's per-round latencies per arm and comparing pooled medians
against ``TRACE_OVERHEAD_TOL`` (2%) discounted by an inline A/A drift
measurement (see ``check_trace_overhead``).
"""

from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import replace
from typing import Dict, List

import numpy as np

from repro.obs import JsonlSink, SpanTracer
from repro.pm.controller import AUTO
from repro.serve import (DriftingZipfStream, ReplayStream, ServeConfig,
                         ServingRuntime)

from .common import emit

_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                    "BENCH_serve.json")

# deployment-scale cost model: a 64-way vocab-sharded table (the intent
# engine's own node cap) — managed wins scale with the shard count
# because only the miss buffer pays the collective
N_SHARDS = 64
V, D = 65536, 512
B, K = 64, 64            # requests per micro-batch x keys per request —
#                          workload geometry (arrival rate = B), not a
#                          tuned runtime knob
REPS = 7
ROUNDS = 32
MEASURE_FROM = 4
# zero-tuning arm: the acceptance is that the controller REACHES the
# hand-tuned throughput within a single run, so its measured window is
# the post-convergence segment — a longer run with the adaptation
# transient (~3-4 controller decisions) excluded from the clock, same
# window for both arms (the tuned arm is steady throughout, so the
# deeper measure_from does not advantage either side)
ROUNDS_AUTO = 64
MEASURE_FROM_AUTO = 24
BACKLOG = 10             # warmup backlog rounds enqueued before round 0:
#                          pinned (not derived from the replan cadence,
#                          which is now controller-owned and moves) so
#                          every arm replays the identical trace alignment
STEADY_WINDOW = 5        # rounds of pre-rotation steady state
REGRESSION_TOL = 1.15    # --check-baseline: fail beyond a 15% slowdown
AUTO_MIN_RATIO = 0.9     # acceptance (d): auto >= 0.9x hand-tuned
TRACE_OVERHEAD_TOL = 1.02  # --check-trace-overhead: tracing at default
#                            sampling may cost at most 2% pooled-median
#                            round latency (DESIGN.md §14 overhead budget)

# The PR-6 hand-set values, FROZEN as the zero-tuning section's reference
# arm only — the operating config below carries no tuned knobs.  Do not
# "retune" these: the point of the comparison is that the controller
# starting blind matches what an operator once found by hand.
HAND_TUNED: Dict[str, object] = {
    "cache_capacity": 8192, "replan_every": 8, "refresh_every": 0,
    "double_buffer": False,
}


def _auto_cfg() -> ServeConfig:
    """The operating config: every runtime knob controller-owned."""
    return ServeConfig(vocab=V, batch_requests=B, keys_per_request=K,
                       cache_capacity=AUTO, replan_every=AUTO,
                       refresh_every=AUTO, double_buffer=AUTO,
                       n_shards=N_SHARDS, summary=False)


def _tuned_cfg() -> ServeConfig:
    """The frozen hand-tuned reference arm (see HAND_TUNED)."""
    return replace(_auto_cfg(), **HAND_TUNED)


def _run_once(table, cfg: ServeConfig, replay: ReplayStream, warm,
              rounds: int = ROUNDS, measure_from: int = MEASURE_FROM):
    rt = ServingRuntime(table, cfg)
    rt._managed_fn = warm._managed_fn
    rt._plain_fn = warm._plain_fn
    return rt.run(replay, rounds, warmup_backlog=BACKLOG,
                  measure_from=measure_from)


def _paired_runs(table, cfg_a: ServeConfig, cfg_b: ServeConfig,
                 replay: ReplayStream, reps: int, warm):
    """Interleaved A/B reps on the same replayed trace.

    The container's 2 CPUs see bursty co-tenant noise that can slow a
    whole run 2x; running the pair back-to-back and taking the *median of
    per-rep throughput ratios* cancels that common-mode noise, which
    separate medians cannot."""
    pairs = []
    for _ in range(reps):
        a = _run_once(table, cfg_a, replay, warm)
        b = _run_once(table, cfg_b, replay, warm)
        pairs.append((a.throughput_rps / max(b.throughput_rps, 1e-9), a, b))
    pairs.sort(key=lambda t: t[0])
    return pairs[len(pairs) // 2]


def _warm(table, cfg: ServeConfig, replay: ReplayStream):
    rt = ServingRuntime(table, cfg)
    rt.run(replay, max(10, MEASURE_FROM + 4), warmup_backlog=BACKLOG,
           measure_from=2)
    return rt


def _record(zipf_a: float, rot: int, extra: int = 4) -> ReplayStream:
    scenario = "rotate" if rot else "steady"
    stream = DriftingZipfStream(V, K, zipf_a=zipf_a, arrival_rate=B,
                                scenario=scenario, rotate_every=rot or 32,
                                seed=3)
    return ReplayStream.record(stream, ROUNDS + BACKLOG + extra)


def _drift_metrics(res, rotation_rounds: List[int]) -> List[Dict]:
    """Per-rotation recovery analysis over the runtime's miss trace.

    A rotation at stream round R changes arrivals enqueued at runtime
    round R - backlog, which reach the scheduler ~backlog rounds later —
    so its effect on *served* traffic starts at runtime round ~R (the
    steady-state queue depth equals the warmup backlog).  Replans may
    adapt even earlier, from the rotated intent still queued."""
    trace = dict(res.miss_trace)
    out = []
    rots = list(rotation_rounds)
    for i, rot in enumerate(rots):
        if rot <= STEADY_WINDOW or rot >= res.rounds - 2:
            continue
        nxt = rots[i + 1] if i + 1 < len(rots) else res.rounds
        pre = res.steady_miss_rate(rot - STEADY_WINDOW, rot)
        replans = [r for r in res.replan_rounds if r >= rot]
        if pre is None or not replans:
            continue
        rr = replans[0]
        spike = max((trace[r] for r in range(rot, rr + 1) if r in trace),
                    default=pre)
        rec_hi = min(nxt, rr + 1 + STEADY_WINDOW)
        recovered = res.steady_miss_rate(rr + 1, rec_hi)
        if recovered is None:
            # no executed batch between the replan and the next rotation:
            # nothing measured, so nothing may be claimed — skip, and the
            # headline bool below requires at least one measured entry
            continue
        ratio = recovered / max(pre, 1e-9)
        out.append({
            "rotation_round": rot,
            "pre_rotation_miss": round(pre, 4),
            "spike_miss": round(spike, 4),
            "recovered_miss": round(recovered, 4),
            "recovery_ratio_vs_pre": round(ratio, 3),
            "replan_lag_rounds": rr - rot,
            "recovered_within_one_replan": bool(ratio <= 2.0),
        })
    return out


def _auto_pairs(table, replay: ReplayStream, reps: int, warm):
    """Paired auto-vs-tuned reps over the converged window.

    The two arms differ by only a few percent, so two bias sources the
    big managed-vs-plain margins shrug off matter here: run ORDER within
    a pair (allocator/cache spillover worth ~3-10%) is cancelled by
    alternating which arm runs first, and the adaptation transient is
    excluded by the MEASURE_FROM_AUTO window."""
    pairs = []
    for i in range(reps):
        if i % 2 == 0:
            a = _run_once(table, _auto_cfg(), replay, warm,
                          rounds=ROUNDS_AUTO,
                          measure_from=MEASURE_FROM_AUTO)
            t = _run_once(table, _tuned_cfg(), replay, warm,
                          rounds=ROUNDS_AUTO,
                          measure_from=MEASURE_FROM_AUTO)
        else:
            t = _run_once(table, _tuned_cfg(), replay, warm,
                          rounds=ROUNDS_AUTO,
                          measure_from=MEASURE_FROM_AUTO)
            a = _run_once(table, _auto_cfg(), replay, warm,
                          rounds=ROUNDS_AUTO,
                          measure_from=MEASURE_FROM_AUTO)
        pairs.append((a.throughput_rps / max(t.throughput_rps, 1e-9),
                      a, t))
    pairs.sort(key=lambda t: t[0])
    return pairs[len(pairs) // 2]


def _auto_section(table, skews: List[float], reps: int) -> Dict:
    """Zero-tuning acceptance arm: the controller starting from untuned
    defaults (ladder-floor capacity, short cadence) vs the frozen
    hand-tuned reference, paired on the same trace per skew."""
    entries = []
    warm = None
    for zipf_a in skews:
        replay = _record(zipf_a, 0, extra=ROUNDS_AUTO - ROUNDS + 4)
        if warm is None:
            # one shared compile cache across arms (same jit fns, shapes
            # re-specialize per capacity bucket); the throwaway tuned run
            # routes through warm's fns so the tuned shapes compile
            # outside the measured reps
            warm = _warm(table, _auto_cfg(), replay)
            _run_once(table, _tuned_cfg(), replay, warm)
        ratio, a, t = _auto_pairs(table, replay, reps, warm)
        entries.append({
            "zipf": zipf_a,
            "auto_rps": round(a.throughput_rps, 1),
            "tuned_rps": round(t.throughput_rps, 1),
            "auto_vs_tuned_x": round(ratio, 3),
            "meets_min_ratio": bool(ratio >= AUTO_MIN_RATIO),
            "final_knobs": a.knobs,
            "capacity_resizes": a.capacity_resizes,
            "capacity_trace": a.capacity_trace,
            "zero_served": a.zero_served,
            "replans": a.replans,
        })
    return {
        "untuned_start": {"cache_capacity": 64, "replan_every": 4,
                          "refresh_every": 0, "double_buffer": False},
        "hand_tuned_reference": HAND_TUNED,
        "min_ratio_required": AUTO_MIN_RATIO,
        "rounds": ROUNDS_AUTO,
        "measured_from_round": MEASURE_FROM_AUTO,
        "entries": entries,
        "all_meet_min_ratio": all(e["meets_min_ratio"] for e in entries),
        "zero_served_across_resizes": sum(
            e["zero_served"] for e in entries),
        "total_capacity_resizes": sum(
            e["capacity_resizes"] for e in entries),
    }


def _traced_arm(table, trace_path, metrics_path) -> None:
    """One fully-traced managed run on a drifting trace, AFTER the
    measured sections: writes the Chrome trace and the JSONL
    metrics/attribution sink (the report CLI's and CI's artifacts)."""
    replay = _record(1.1, 12)
    tracer = SpanTracer()
    rt = ServingRuntime(table, _tuned_cfg(), tracer=tracer)
    res = rt.run(replay, ROUNDS, warmup_backlog=BACKLOG,
                 measure_from=MEASURE_FROM)
    assert len(rt.attribution.records) == res.replans, \
        "one attribution record per replan boundary"
    if trace_path:
        tracer.dump(trace_path)
        print(f"wrote {trace_path} ({tracer.count} spans, "
              f"{tracer.dropped} dropped)")
    if metrics_path:
        with JsonlSink(metrics_path) as sink:
            sink.write_bus(rt.telemetry, label="serve_bench traced arm")
            sink.write_attribution(rt.attribution.records)
        print(f"wrote {metrics_path}")


def check_trace_overhead(reps: int = 6) -> None:
    """CI guard for the §14 overhead budget: tracing enabled at default
    sampling must cost < 2% paired-median serve round latency.

    Estimator: both arms run the frozen tuned config (no controller
    nondeterminism) on the same replayed trace in alternating order, and
    every run's per-round ``serve.round_ms`` samples are POOLED per arm —
    the verdict is the ratio of pooled medians.  Per-run aggregates
    (throughput, per-run p50) were A/A-calibrated on this container at a
    multi-percent noise floor — they cannot resolve a 2% effect; pooling
    ~`reps x ROUNDS` rounds per arm tightens the median substantially.
    The residual session noise is measured inline by splitting the
    untraced runs into two interleaved halves (an A/A ratio): a real
    tracing regression shows up in A/B but not A/A, so the pass bound is
    discounted by the measured drift.  One best-of-two retry rides out
    co-tenant bursts."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(V, D)).astype(np.float32)
    replay = _record(1.1, 0)
    warm = _warm(table, _tuned_cfg(), replay)

    def rounds_ms(traced: bool) -> List[float]:
        rt = ServingRuntime(table, replace(_tuned_cfg(), trace=traced))
        rt._managed_fn = warm._managed_fn
        rt._plain_fn = warm._plain_fn
        rt.run(replay, ROUNDS, warmup_backlog=BACKLOG,
               measure_from=MEASURE_FROM)
        return rt.telemetry.latency("serve.round_ms").values()

    def measure():
        traced_pool: List[float] = []
        untraced_halves = ([], [])      # interleaved split: the A/A floor
        for i in range(reps):
            if i % 2 == 0:
                traced_pool += rounds_ms(True)
                un = rounds_ms(False)
            else:
                un = rounds_ms(False)
                traced_pool += rounds_ms(True)
            untraced_halves[i % 2].extend(un)
        untraced_pool = untraced_halves[0] + untraced_halves[1]
        ab = float(np.median(traced_pool) / np.median(untraced_pool))
        aa = float(np.median(untraced_halves[0])
                   / np.median(untraced_halves[1]))
        return ab, max(aa, 1.0 / aa)

    ab, noise = measure()
    bound = TRACE_OVERHEAD_TOL * noise
    if ab > bound:                       # best-of-two: co-tenant bursts
        ab2, noise2 = measure()
        if ab2 <= TRACE_OVERHEAD_TOL * noise2:
            ab, noise, bound = ab2, noise2, TRACE_OVERHEAD_TOL * noise2
    if ab > bound:
        raise SystemExit(
            f"trace overhead regression: traced/untraced pooled-median "
            f"round latency {ab:.4f}x > {bound:.4f}x "
            f"(budget {TRACE_OVERHEAD_TOL:.2f}x, measured A/A drift "
            f"{noise:.4f}x)")
    print(f"trace overhead ok: traced/untraced pooled-median round "
          f"latency {ab:.4f}x (bound {bound:.4f}x = budget "
          f"{TRACE_OVERHEAD_TOL:.2f}x * A/A drift {noise:.4f}x)")


def run(quick: bool = False, trace_path: str = None,
        metrics_path: str = None) -> List[str]:
    t_start = time.time()
    rows: List[str] = []
    skews = [1.0, 1.1] if quick else [1.0, 1.1, 1.5]
    drift_rates = [0, 12] if quick else [0, 12, 20]   # rotate_every rounds
    reps = REPS if quick else REPS + 2
    # acceptance (d) is stated over all three skews — measure them even in
    # quick mode (the auto arm is cheap: one steady trace per skew)
    auto_skews = [1.0, 1.1, 1.5]

    rng = np.random.default_rng(0)
    table = rng.normal(size=(V, D)).astype(np.float32)
    base = _auto_cfg()

    throughput = []
    drift_entries = []
    zero_served_total = 0
    served_total = 0
    requeues_total = 0

    warm = None
    for zipf_a in skews:
        for rot in drift_rates:
            replay = _record(zipf_a, rot)
            tag = f"zipf{zipf_a}_rot{rot}"
            if warm is None:
                warm = _warm(table, base, replay)
                pwarm = _warm(table, replace(base, managed=False), replay)
                warm._plain_fn = pwarm._plain_fn

            speedup, m, p = _paired_runs(
                table, base, replace(base, managed=False), replay, reps,
                warm)
            zero_served_total += m.zero_served
            served_total += m.served + p.served
            requeues_total += m.requeues
            plain_rps, plain_p50, plain_p99 = (
                p.throughput_rps, p.p50_ms, p.p99_ms)
            emit(rows, "serve", "managed", tag, "throughput_rps",
                 round(m.throughput_rps, 1))
            emit(rows, "serve", "plain", tag, "throughput_rps",
                 round(plain_rps, 1))
            emit(rows, "serve", "managed", tag, "speedup_x",
                 round(speedup, 2))
            emit(rows, "serve", "managed", tag, "p50_ms",
                 round(m.p50_ms, 2))
            emit(rows, "serve", "managed", tag, "p99_ms",
                 round(m.p99_ms, 2))
            throughput.append({
                "zipf": zipf_a, "rotate_every": rot,
                "managed_rps": round(m.throughput_rps, 1),
                "plain_rps": round(plain_rps, 1),
                "speedup_x": round(speedup, 2),
                "managed_p50_ms": round(m.p50_ms, 2),
                "managed_p99_ms": round(m.p99_ms, 2),
                "plain_p50_ms": round(plain_p50, 2),
                "plain_p99_ms": round(plain_p99, 2),
                "steady_miss_rate": round(
                    m.steady_miss_rate(MEASURE_FROM, m.rounds) or 0.0, 4),
                "requeues": m.requeues, "zero_served": m.zero_served,
                "final_knobs": m.knobs,
            })
            if rot:
                for entry in _drift_metrics(m, replay.rotation_rounds):
                    entry.update({"zipf": zipf_a, "rotate_every": rot})
                    drift_entries.append(entry)
                    emit(rows, "serve", "managed", tag,
                         "recovery_ratio_vs_pre",
                         entry["recovery_ratio_vs_pre"])

    # double-buffered admission (the probe-at-admission split means batch
    # t+1's whole index stage can run while the device executes batch t):
    # paired managed-vs-managed comparison, pipeline on vs off, same
    # trace, other knobs pinned to the frozen reference so the pipeline
    # is the only variable
    ov_replay = _record(1.1, 0)
    serial = _tuned_cfg()
    buffered = replace(serial, double_buffer=True)
    ov_win, ov_d, ov_s = _paired_runs(table, buffered, serial, ov_replay,
                                      reps, warm)
    emit(rows, "serve", "managed", "zipf1.1_steady", "overlap_win_x",
         round(ov_win, 3))
    overlap = {
        "double_buffer_rps": round(ov_d.throughput_rps, 1),
        "serial_rps": round(ov_s.throughput_rps, 1),
        "overlap_win_x": round(ov_win, 3),
        "double_buffer_p50_ms": round(ov_d.p50_ms, 2),
        "serial_p50_ms": round(ov_s.p50_ms, 2),
        # the telemetry record the runtime's own auto-enable rule reads
        "measured_overlap_ratio": round(warm.overlap_ratio, 3)
        if warm.overlap_ratio is not None else None,
    }

    auto = _auto_section(table, auto_skews, reps)
    for e in auto["entries"]:
        emit(rows, "serve", "auto", f"zipf{e['zipf']}", "auto_vs_tuned_x",
             e["auto_vs_tuned_x"])

    speedups = [t["speedup_x"] for t in throughput]
    summary = {
        "config": {"vocab": V, "dim": D, "batch_requests": B,
                   "keys_per_request": K,
                   "cache_capacity": AUTO, "replan_every": AUTO,
                   "refresh_every": AUTO, "double_buffer": AUTO,
                   "n_shards": N_SHARDS,
                   "reps": reps, "rounds": ROUNDS, "quick": quick},
        "throughput": throughput,
        "overlap": overlap,
        "auto": auto,
        "min_speedup_at_zipf_ge_1.0": min(speedups),
        "drift": drift_entries,
        # non-vacuous: requires at least one measured post-replan window
        "drift_all_recovered_within_one_replan": bool(drift_entries) and
        all(e["recovered_within_one_replan"] for e in drift_entries),
        "zero_served_total": zero_served_total,
        "requeues_total": requeues_total,
        "requests_served_total": served_total,
        "wall_clock_s": round(time.time() - t_start, 2),
    }
    with open(_OUT, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"wrote {os.path.normpath(_OUT)}")
    if trace_path or metrics_path:
        _traced_arm(table, trace_path, metrics_path)
    emit(rows, "serve", "managed", "ALL", "min_speedup_x",
         round(min(speedups), 2))
    emit(rows, "serve", "managed", "ALL", "zero_served", zero_served_total)
    emit(rows, "serve", "auto", "ALL", "min_auto_vs_tuned_x",
         round(min(e["auto_vs_tuned_x"] for e in auto["entries"]), 3))
    return rows


def check_baseline(path: str, auto: bool = False) -> None:
    """CI guard: re-measure a small arm and compare against the committed
    BENCH_serve.json.  Paired ratios normalize away absolute host speed;
    the guard trips only when today's ratio falls >15% below the
    committed one (geomean across arms, best-of-two on a first trip to
    ride out co-tenant bursts).

    Default arm: managed-vs-plain speedups at zipf {1.0, 1.1}, steady.
    ``auto=True``: the zero-tuning arm — auto-vs-tuned ratio at zipf 1.1,
    which additionally must clear the absolute AUTO_MIN_RATIO floor."""
    with open(path) as f:
        committed = json.load(f)
    rng = np.random.default_rng(0)
    table = rng.normal(size=(V, D)).astype(np.float32)
    reps = 3

    def measure() -> Dict[str, float]:
        if auto:
            replay = _record(1.1, 0, extra=ROUNDS_AUTO - ROUNDS + 4)
            warm = _warm(table, _auto_cfg(), replay)
            _run_once(table, _tuned_cfg(), replay, warm)
            ratio, a, _ = _auto_pairs(table, replay, reps, warm)
            if a.zero_served:
                raise SystemExit(f"auto arm served {a.zero_served} "
                                 "zeroed rows across capacity resizes")
            return {"auto_zipf1.1": ratio}
        out = {}
        warm = None
        for zipf_a in (1.0, 1.1):
            replay = _record(zipf_a, 0)
            if warm is None:
                warm = _warm(table, _auto_cfg(), replay)
                warm._plain_fn = _warm(
                    table, replace(_auto_cfg(), managed=False),
                    replay)._plain_fn
            ratio, _, _ = _paired_runs(
                table, _auto_cfg(), replace(_auto_cfg(), managed=False),
                replay, reps, warm)
            out[f"managed_zipf{zipf_a}"] = ratio
        return out

    def reference() -> Dict[str, float]:
        if auto:
            entries = committed.get("auto", {}).get("entries", [])
            ref = {f"auto_zipf{e['zipf']}": e["auto_vs_tuned_x"]
                   for e in entries if e["zipf"] == 1.1}
            if not ref:
                raise SystemExit("committed baseline has no auto section "
                                 "at zipf 1.1 — regenerate BENCH_serve"
                                 ".json")
            return ref
        ref = {}
        for t in committed["throughput"]:
            if t["rotate_every"] == 0 and t["zipf"] in (1.0, 1.1):
                ref[f"managed_zipf{t['zipf']}"] = t["speedup_x"]
        if not ref:
            raise SystemExit("committed baseline has no steady arms")
        return ref

    ref = reference()

    def verdict(meas: Dict[str, float]):
        rel = [meas[k] / ref[k] for k in ref if k in meas]
        geo = float(np.exp(np.mean(np.log(np.maximum(rel, 1e-9)))))
        floor_ok = (not auto) or all(
            meas[k] >= AUTO_MIN_RATIO for k in meas)
        return geo, geo * REGRESSION_TOL >= 1.0 and floor_ok

    meas = measure()
    geo, ok = verdict(meas)
    if not ok:
        # one retry: a co-tenant burst can eat a whole measurement pass
        meas2 = measure()
        meas = {k: max(meas[k], meas2[k]) for k in meas}
        geo, ok = verdict(meas)
    arm = "auto-vs-tuned" if auto else "managed-vs-plain"
    detail = " ".join(f"{k}={meas[k]:.2f}(ref {ref[k]:.2f})"
                      for k in sorted(ref) if k in meas)
    if not ok:
        raise SystemExit(
            f"serve {arm} regression: geomean {geo:.3f}x of committed "
            f"(tolerance {1 / REGRESSION_TOL:.3f}) — {detail}")
    print(f"serve {arm} baseline ok: geomean {geo:.3f}x of committed "
          f"— {detail}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized smoke (2 skews x 2 drift rates)")
    ap.add_argument("--auto", action="store_true",
                    help="with --check-baseline: guard the zero-tuning "
                         "arm instead of managed-vs-plain")
    ap.add_argument("--check-baseline", metavar="JSON", default=None,
                    help="re-measure a small arm and fail on a >15%% "
                         "paired regression vs the committed numbers")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a fully-traced arm's Chrome trace JSON")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the traced arm's telemetry + attribution "
                         "records as schema-versioned JSONL")
    ap.add_argument("--check-trace-overhead", action="store_true",
                    help="fail if tracing at default sampling costs >2%% "
                         "paired-median throughput")
    args = ap.parse_args()
    if args.check_baseline:
        check_baseline(args.check_baseline, auto=args.auto)
        sys.exit(0)
    if args.check_trace_overhead:
        check_trace_overhead()
        sys.exit(0)
    run(quick=args.quick, trace_path=args.trace,
        metrics_path=args.metrics_out)
