"""Serving benchmark: the intent-signaled online runtime vs plain lookup.

Measures end-to-end request throughput and p50/p99 latency of the
managed serving runtime (`repro.serve`) against the unmanaged
vocab-parallel baseline across Zipf skews and hot-set drift rates, plus
a drift-adaptation section and a zero-tuning section that check the
acceptance invariants:

  (a) managed serving >= 1.5x plain-lookup throughput at Zipf skew >= 1.0;
  (b) after a hot-set rotation the miss rate returns to within 2x of the
      pre-rotation steady state within one replan round;
  (c) zero silently-dropped (zero-served) requests across the run;
  (d) the online controller, starting from UNTUNED defaults
      (capacity at the ladder floor, short cadence), reaches >= 0.9x the
      frozen hand-tuned managed throughput within a single bench run at
      every measured skew — with zero zero-served tokens across every
      mid-run capacity resize.

The operating config carries NO hand-set runtime knobs: capacity, replan
cadence, refresh cadence and double-buffered admission are all ``"auto"``
(DESIGN.md §13).  The PR-6 hand-tuned values survive only as the frozen
``HAND_TUNED`` reference arm that the auto section compares against —
the serving analogue of the hotpath bench's frozen legacy replica.

Cost model: the embedding is vocab-sharded ``N_SHARDS`` ways and every
row fetched from a non-local shard moves through the emulated
vocab-parallel collective (`pm.embedding.shard_partial_sum`: one
materialized (n, D) partial per shard — the single-host stand-in for the
all-reduce's wire bytes).  The plain baseline moves EVERY token's row
through it; the managed path moves only the compact intent-planned miss
buffer and serves cache hits locally.

Both variants serve identical replayed request traces through the same
queue/scheduler stack, run back-to-back per repetition; the reported
speedup is the median of per-rep throughput ratios (paired to cancel
this container's bursty co-tenant noise).  Writes ``BENCH_serve.json``
at the repo root next to BENCH_quick/BENCH_scale.

CLI: ``python -m benchmarks.serve_bench [--quick] [--auto] [--pipeline]
[--check-baseline BENCH_serve.json]`` — ``--check-baseline`` re-measures
a CI-sized arm and fails on a >15% paired regression vs the committed
numbers (with ``--auto``: the auto-vs-tuned ratio arm instead of the
managed-vs-plain arms; with ``--pipeline``: the §15 pipelined-vs-
sequential arm, which must also stay >= 1.0x with unchanged requeue
semantics).

Observability (DESIGN.md §14): ``--trace PATH`` / ``--metrics-out PATH``
run one extra fully-traced managed arm after the measured sections (so
tracing never perturbs the headline numbers) and write the Chrome trace
and the JSONL metrics/attribution sink — the artifacts ``python -m
repro.obs.report`` renders and CI uploads.  ``--check-trace-overhead``
measures tracing's enabled cost: alternating traced-vs-untraced runs on
the frozen tuned config (controller nondeterminism excluded), pooling
every run's per-round latencies per arm and comparing pooled medians
against ``TRACE_OVERHEAD_TOL`` (2%) discounted by an inline A/A drift
measurement (see ``check_trace_overhead``).
"""

from __future__ import annotations

import json
import os
import sys
import time
from dataclasses import replace
from typing import Dict, List

import numpy as np

from repro.obs import JsonlSink, SpanTracer
from repro.pm.controller import AUTO
from repro.serve import (DriftingZipfStream, ReplayStream, ServeConfig,
                         ServingRuntime)

from .common import emit, paired_guard, paired_pooled_ratio

_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                    "BENCH_serve.json")

# deployment-scale cost model: a 64-way vocab-sharded table (the intent
# engine's own node cap) — managed wins scale with the shard count
# because only the miss buffer pays the collective
N_SHARDS = 64
V, D = 65536, 512
B, K = 64, 64            # requests per micro-batch x keys per request —
#                          workload geometry (arrival rate = B), not a
#                          tuned runtime knob
REPS = 7
ROUNDS = 32
MEASURE_FROM = 4
# zero-tuning arm: the acceptance is that the controller REACHES the
# hand-tuned throughput within a single run, so its measured window is
# the post-convergence segment — a longer run with the adaptation
# transient (~3-4 controller decisions) excluded from the clock, same
# window for both arms (the tuned arm is steady throughout, so the
# deeper measure_from does not advantage either side)
ROUNDS_AUTO = 64
MEASURE_FROM_AUTO = 24
BACKLOG = 10             # warmup backlog rounds enqueued before round 0:
#                          pinned (not derived from the replan cadence,
#                          which is now controller-owned and moves) so
#                          every arm replays the identical trace alignment
STEADY_WINDOW = 5        # rounds of pre-rotation steady state
REGRESSION_TOL = 1.15    # --check-baseline: fail beyond a 15% slowdown
AUTO_MIN_RATIO = 0.9     # acceptance (d): auto >= 0.9x hand-tuned
TRACE_OVERHEAD_TOL = 1.02  # --check-trace-overhead: tracing at default
#                            sampling may cost at most 2% pooled-median
#                            round latency (DESIGN.md §14 overhead budget)
PIPELINE_MIN_SPEEDUP = 1.1  # acceptance: the intent-lead-time pipeline
#                             (tenure staging prefetch + N-deep admission,
#                             DESIGN.md §15) >= 1.1x sequential served-rps
#                             at zipf >= 1.0
PIPE_CAPACITY = 512      # pipeline arms run capacity-constrained: the
#                          recurring hot band overflows the cache, which
#                          is the regime tenure staging eliminates work in
PIPE_ROUNDS = 64         # longer runs than the headline arms: the paired
#                          estimator pools PER-TENURE samples, so each run
#                          must span enough replan tenures to fill the pool

# The PR-6 hand-set values, FROZEN as the zero-tuning section's reference
# arm only — the operating config below carries no tuned knobs.  Do not
# "retune" these: the point of the comparison is that the controller
# starting blind matches what an operator once found by hand.
HAND_TUNED: Dict[str, object] = {
    "cache_capacity": 8192, "replan_every": 8, "refresh_every": 0,
    "double_buffer": False,
}


def _auto_cfg() -> ServeConfig:
    """The operating config: every runtime knob controller-owned."""
    return ServeConfig(vocab=V, batch_requests=B, keys_per_request=K,
                       cache_capacity=AUTO, replan_every=AUTO,
                       refresh_every=AUTO, double_buffer=AUTO,
                       n_shards=N_SHARDS, summary=False)


def _tuned_cfg() -> ServeConfig:
    """The frozen hand-tuned reference arm (see HAND_TUNED)."""
    return replace(_auto_cfg(), **HAND_TUNED)


def _run_once(table, cfg: ServeConfig, replay: ReplayStream, warm,
              rounds: int = ROUNDS, measure_from: int = MEASURE_FROM):
    rt = ServingRuntime(table, cfg)
    rt._managed_fn = warm._managed_fn
    rt._plain_fn = warm._plain_fn
    return rt.run(replay, rounds, warmup_backlog=BACKLOG,
                  measure_from=measure_from)


def _paired_runs(table, cfg_a: ServeConfig, cfg_b: ServeConfig,
                 replay: ReplayStream, reps: int, warm):
    """Interleaved A/B reps on the same replayed trace.

    The container's 2 CPUs see bursty co-tenant noise that can slow a
    whole run 2x; running the pair back-to-back and taking the *median of
    per-rep throughput ratios* cancels that common-mode noise, which
    separate medians cannot."""
    pairs = []
    for _ in range(reps):
        a = _run_once(table, cfg_a, replay, warm)
        b = _run_once(table, cfg_b, replay, warm)
        pairs.append((a.throughput_rps / max(b.throughput_rps, 1e-9), a, b))
    pairs.sort(key=lambda t: t[0])
    return pairs[len(pairs) // 2]


def _tenure_means(table, cfg: ServeConfig, replay: ReplayStream, warm,
                  sink: List = None) -> List[float]:
    """One pipeline-arm run, reduced to per-tenure mean round latencies.

    Per-RUN wall clocks on this 2-CPU container have a ~20-30% co-tenant
    noise floor, and per-ROUND medians are biased FOR the pipeline (the
    median drops the few replan-boundary rounds where staging's extra
    costs land).  Per-TENURE means are both: every boundary's plan/stage/
    refresh cost is inside exactly one sample, and a ~100ms tenure is
    short enough that pooling `reps x tenures` samples per arm lets the
    median shrug off bursts that per-run aggregates cannot."""
    rt = ServingRuntime(table, cfg)
    rt._managed_fn = warm._managed_fn
    rt._plain_fn = warm._plain_fn
    res = rt.run(replay, PIPE_ROUNDS, warmup_backlog=BACKLOG,
                 measure_from=MEASURE_FROM)
    if sink is not None:
        sink.append(res)
    ms = rt.telemetry.latency("serve.round_ms").values()
    bounds = ([MEASURE_FROM]
              + [r for r in res.replan_rounds if r >= MEASURE_FROM]
              + [PIPE_ROUNDS])
    return [float(np.mean(ms[lo:hi]))
            for lo, hi in zip(bounds, bounds[1:]) if hi - lo >= 2]


def _pipeline_arm(table, replay: ReplayStream, reps: int, warm):
    """The §15 paired arm: depth-1 pipelined runtime (tenure staging
    prefetch + deferred blocking) vs the depth-0 sequential loop, same
    frozen knobs, same capacity-constrained cache, same replayed trace.
    Returns (stats, pipe_results, seq_results) where ``stats`` is the
    `paired_pooled_ratio` dict over per-tenure latency samples (base =
    sequential, test = pipelined — speedup is median_base/median_test)."""
    seq_cfg = replace(_tuned_cfg(), pipeline_depth=0,
                      cache_capacity=PIPE_CAPACITY)
    pipe_cfg = replace(_tuned_cfg(), pipeline_depth=1,
                       cache_capacity=PIPE_CAPACITY)
    # throwaway full-length runs: every tenure's staged/residual bucket
    # shape compiles outside the measured reps
    _tenure_means(table, pipe_cfg, replay, warm)
    _tenure_means(table, seq_cfg, replay, warm)
    pipe_res: List = []
    seq_res: List = []
    stats = paired_pooled_ratio(
        lambda: _tenure_means(table, seq_cfg, replay, warm, seq_res),
        lambda: _tenure_means(table, pipe_cfg, replay, warm, pipe_res),
        reps=reps)
    return stats, pipe_res, seq_res


def _warm(table, cfg: ServeConfig, replay: ReplayStream):
    rt = ServingRuntime(table, cfg)
    rt.run(replay, max(10, MEASURE_FROM + 4), warmup_backlog=BACKLOG,
           measure_from=2)
    return rt


def _record(zipf_a: float, rot: int, extra: int = 4) -> ReplayStream:
    scenario = "rotate" if rot else "steady"
    stream = DriftingZipfStream(V, K, zipf_a=zipf_a, arrival_rate=B,
                                scenario=scenario, rotate_every=rot or 32,
                                seed=3)
    return ReplayStream.record(stream, ROUNDS + BACKLOG + extra)


def _drift_metrics(res, rotation_rounds: List[int]) -> List[Dict]:
    """Per-rotation recovery analysis over the runtime's miss trace.

    A rotation at stream round R changes arrivals enqueued at runtime
    round R - backlog, which reach the scheduler ~backlog rounds later —
    so its effect on *served* traffic starts at runtime round ~R (the
    steady-state queue depth equals the warmup backlog).  Replans may
    adapt even earlier, from the rotated intent still queued."""
    trace = dict(res.miss_trace)
    out = []
    rots = list(rotation_rounds)
    for i, rot in enumerate(rots):
        if rot <= STEADY_WINDOW or rot >= res.rounds - 2:
            continue
        nxt = rots[i + 1] if i + 1 < len(rots) else res.rounds
        pre = res.steady_miss_rate(rot - STEADY_WINDOW, rot)
        replans = [r for r in res.replan_rounds if r >= rot]
        if pre is None or not replans:
            continue
        rr = replans[0]
        spike = max((trace[r] for r in range(rot, rr + 1) if r in trace),
                    default=pre)
        rec_hi = min(nxt, rr + 1 + STEADY_WINDOW)
        recovered = res.steady_miss_rate(rr + 1, rec_hi)
        if recovered is None:
            # no executed batch between the replan and the next rotation:
            # nothing measured, so nothing may be claimed — skip, and the
            # headline bool below requires at least one measured entry
            continue
        ratio = recovered / max(pre, 1e-9)
        out.append({
            "rotation_round": rot,
            "pre_rotation_miss": round(pre, 4),
            "spike_miss": round(spike, 4),
            "recovered_miss": round(recovered, 4),
            "recovery_ratio_vs_pre": round(ratio, 3),
            "replan_lag_rounds": rr - rot,
            "recovered_within_one_replan": bool(ratio <= 2.0),
        })
    return out


def _auto_pairs(table, replay: ReplayStream, reps: int, warm):
    """Paired auto-vs-tuned reps over the converged window.

    The two arms differ by only a few percent, so two bias sources the
    big managed-vs-plain margins shrug off matter here: run ORDER within
    a pair (allocator/cache spillover worth ~3-10%) is cancelled by
    alternating which arm runs first, and the adaptation transient is
    excluded by the MEASURE_FROM_AUTO window."""
    pairs = []
    for i in range(reps):
        if i % 2 == 0:
            a = _run_once(table, _auto_cfg(), replay, warm,
                          rounds=ROUNDS_AUTO,
                          measure_from=MEASURE_FROM_AUTO)
            t = _run_once(table, _tuned_cfg(), replay, warm,
                          rounds=ROUNDS_AUTO,
                          measure_from=MEASURE_FROM_AUTO)
        else:
            t = _run_once(table, _tuned_cfg(), replay, warm,
                          rounds=ROUNDS_AUTO,
                          measure_from=MEASURE_FROM_AUTO)
            a = _run_once(table, _auto_cfg(), replay, warm,
                          rounds=ROUNDS_AUTO,
                          measure_from=MEASURE_FROM_AUTO)
        pairs.append((a.throughput_rps / max(t.throughput_rps, 1e-9),
                      a, t))
    pairs.sort(key=lambda t: t[0])
    return pairs[len(pairs) // 2]


def _auto_section(table, skews: List[float], reps: int) -> Dict:
    """Zero-tuning acceptance arm: the controller starting from untuned
    defaults (ladder-floor capacity, short cadence) vs the frozen
    hand-tuned reference, paired on the same trace per skew."""
    entries = []
    warm = None
    for zipf_a in skews:
        replay = _record(zipf_a, 0, extra=ROUNDS_AUTO - ROUNDS + 4)
        if warm is None:
            # one shared compile cache across arms (same jit fns, shapes
            # re-specialize per capacity bucket); the throwaway tuned run
            # routes through warm's fns so the tuned shapes compile
            # outside the measured reps
            warm = _warm(table, _auto_cfg(), replay)
            _run_once(table, _tuned_cfg(), replay, warm)
        ratio, a, t = _auto_pairs(table, replay, reps, warm)
        entries.append({
            "zipf": zipf_a,
            "auto_rps": round(a.throughput_rps, 1),
            "tuned_rps": round(t.throughput_rps, 1),
            "auto_vs_tuned_x": round(ratio, 3),
            "meets_min_ratio": bool(ratio >= AUTO_MIN_RATIO),
            "final_knobs": a.knobs,
            "capacity_resizes": a.capacity_resizes,
            "capacity_trace": a.capacity_trace,
            "zero_served": a.zero_served,
            "replans": a.replans,
        })
    return {
        "untuned_start": {"cache_capacity": 64, "replan_every": 4,
                          "refresh_every": 0, "double_buffer": False},
        "hand_tuned_reference": HAND_TUNED,
        "min_ratio_required": AUTO_MIN_RATIO,
        "rounds": ROUNDS_AUTO,
        "measured_from_round": MEASURE_FROM_AUTO,
        "entries": entries,
        "all_meet_min_ratio": all(e["meets_min_ratio"] for e in entries),
        "zero_served_across_resizes": sum(
            e["zero_served"] for e in entries),
        "total_capacity_resizes": sum(
            e["capacity_resizes"] for e in entries),
    }


def _traced_arm(table, trace_path, metrics_path) -> None:
    """One fully-traced managed run on a drifting trace, AFTER the
    measured sections: writes the Chrome trace and the JSONL
    metrics/attribution sink (the report CLI's and CI's artifacts)."""
    replay = _record(1.1, 12)
    tracer = SpanTracer()
    rt = ServingRuntime(table, _tuned_cfg(), tracer=tracer)
    res = rt.run(replay, ROUNDS, warmup_backlog=BACKLOG,
                 measure_from=MEASURE_FROM)
    assert len(rt.attribution.records) == res.replans, \
        "one attribution record per replan boundary"
    if trace_path:
        tracer.dump(trace_path)
        print(f"wrote {trace_path} ({tracer.count} spans, "
              f"{tracer.dropped} dropped)")
    if metrics_path:
        with JsonlSink(metrics_path) as sink:
            sink.write_bus(rt.telemetry, label="serve_bench traced arm")
            sink.write_attribution(rt.attribution.records)
        print(f"wrote {metrics_path}")


def check_trace_overhead(reps: int = 6) -> None:
    """CI guard for the §14 overhead budget: tracing enabled at default
    sampling must cost < 2% paired-median serve round latency.

    Estimator: `benchmarks.common.paired_guard` — both arms run the
    frozen tuned config (no controller nondeterminism) on the same
    replayed trace in alternating order, every run's per-round
    ``serve.round_ms`` samples pooled per arm, pooled-median ratio
    against ``TRACE_OVERHEAD_TOL`` discounted by the inline A/A drift
    split, best-of-two (the PR-8 methodology, since shared with the
    §15 pipeline guards)."""
    rng = np.random.default_rng(0)
    table = rng.normal(size=(V, D)).astype(np.float32)
    replay = _record(1.1, 0)
    warm = _warm(table, _tuned_cfg(), replay)

    def rounds_ms(traced: bool) -> List[float]:
        rt = ServingRuntime(table, replace(_tuned_cfg(), trace=traced))
        rt._managed_fn = warm._managed_fn
        rt._plain_fn = warm._plain_fn
        rt.run(replay, ROUNDS, warmup_backlog=BACKLOG,
               measure_from=MEASURE_FROM)
        return rt.telemetry.latency("serve.round_ms").values()

    paired_guard("trace overhead", lambda: rounds_ms(False),
                 lambda: rounds_ms(True), tol=TRACE_OVERHEAD_TOL,
                 reps=reps)


def run(quick: bool = False, trace_path: str = None,
        metrics_path: str = None) -> List[str]:
    t_start = time.time()
    rows: List[str] = []
    skews = [1.0, 1.1] if quick else [1.0, 1.1, 1.5]
    drift_rates = [0, 12] if quick else [0, 12, 20]   # rotate_every rounds
    reps = REPS if quick else REPS + 2
    # acceptance (d) is stated over all three skews — measure them even in
    # quick mode (the auto arm is cheap: one steady trace per skew)
    auto_skews = [1.0, 1.1, 1.5]

    rng = np.random.default_rng(0)
    table = rng.normal(size=(V, D)).astype(np.float32)
    base = _auto_cfg()

    throughput = []
    drift_entries = []
    zero_served_total = 0
    served_total = 0
    requeues_total = 0

    warm = None
    for zipf_a in skews:
        for rot in drift_rates:
            replay = _record(zipf_a, rot)
            tag = f"zipf{zipf_a}_rot{rot}"
            if warm is None:
                warm = _warm(table, base, replay)
                pwarm = _warm(table, replace(base, managed=False), replay)
                warm._plain_fn = pwarm._plain_fn

            speedup, m, p = _paired_runs(
                table, base, replace(base, managed=False), replay, reps,
                warm)
            zero_served_total += m.zero_served
            served_total += m.served + p.served
            requeues_total += m.requeues
            plain_rps, plain_p50, plain_p99 = (
                p.throughput_rps, p.p50_ms, p.p99_ms)
            emit(rows, "serve", "managed", tag, "throughput_rps",
                 round(m.throughput_rps, 1))
            emit(rows, "serve", "plain", tag, "throughput_rps",
                 round(plain_rps, 1))
            emit(rows, "serve", "managed", tag, "speedup_x",
                 round(speedup, 2))
            emit(rows, "serve", "managed", tag, "p50_ms",
                 round(m.p50_ms, 2))
            emit(rows, "serve", "managed", tag, "p99_ms",
                 round(m.p99_ms, 2))
            throughput.append({
                "zipf": zipf_a, "rotate_every": rot,
                "managed_rps": round(m.throughput_rps, 1),
                "plain_rps": round(plain_rps, 1),
                "speedup_x": round(speedup, 2),
                "managed_p50_ms": round(m.p50_ms, 2),
                "managed_p99_ms": round(m.p99_ms, 2),
                "plain_p50_ms": round(plain_p50, 2),
                "plain_p99_ms": round(plain_p99, 2),
                "steady_miss_rate": round(
                    m.steady_miss_rate(MEASURE_FROM, m.rounds) or 0.0, 4),
                "requeues": m.requeues, "zero_served": m.zero_served,
                "final_knobs": m.knobs,
            })
            if rot:
                for entry in _drift_metrics(m, replay.rotation_rounds):
                    entry.update({"zipf": zipf_a, "rotate_every": rot})
                    drift_entries.append(entry)
                    emit(rows, "serve", "managed", tag,
                         "recovery_ratio_vs_pre",
                         entry["recovery_ratio_vs_pre"])

    # double-buffered admission (the probe-at-admission split means batch
    # t+1's whole index stage can run while the device executes batch t):
    # paired managed-vs-managed comparison, pipeline on vs off, same
    # trace, other knobs pinned to the frozen reference so the pipeline
    # is the only variable
    ov_replay = _record(1.1, 0)
    serial = _tuned_cfg()
    buffered = replace(serial, double_buffer=True)
    ov_win, ov_d, ov_s = _paired_runs(table, buffered, serial, ov_replay,
                                      reps, warm)
    emit(rows, "serve", "managed", "zipf1.1_steady", "overlap_win_x",
         round(ov_win, 3))
    overlap = {
        "double_buffer_rps": round(ov_d.throughput_rps, 1),
        "serial_rps": round(ov_s.throughput_rps, 1),
        "overlap_win_x": round(ov_win, 3),
        "double_buffer_p50_ms": round(ov_d.p50_ms, 2),
        "serial_p50_ms": round(ov_s.p50_ms, 2),
        # the telemetry record the runtime's own auto-enable rule reads
        "measured_overlap_ratio": round(warm.overlap_ratio, 3)
        if warm.overlap_ratio is not None else None,
    }

    # intent-lead-time pipeline (DESIGN.md §15): tenure staging prefetch
    # + N-deep admission vs the depth-0 sequential loop, same knobs and
    # the same drifting zipf-1.0 trace, so the pipeline is the only
    # variable.  Both arms run CAPACITY-CONSTRAINED (cache far below the
    # recurring working set): that is the regime staging prefetch is
    # for — the hot band the plan cannot cache recurs in every batch's
    # miss bucket, and the staging buffer gathers it from the table once
    # per tenure instead of once per round.  The win on this single-core
    # host is that WORK ELIMINATION, not overlap.  (At the reference
    # capacity the planner caches all recurring intent and the staging
    # buffer degenerates to the count-1 tail — nothing to eliminate.)
    pl_replay = _record(1.0, 12, extra=PIPE_ROUNDS - ROUNDS + 4)
    pl, pl_pres, pl_sres = _pipeline_arm(table, pl_replay, reps, warm)
    pl_win = pl["median_base"] / pl["median_test"]
    # one extra instrumented run for the prefetch hit/stale counters
    irt = ServingRuntime(table, replace(_tuned_cfg(), pipeline_depth=1,
                                        cache_capacity=PIPE_CAPACITY))
    irt._managed_fn = warm._managed_fn
    irt._plain_fn = warm._plain_fn
    irt.run(pl_replay, PIPE_ROUNDS, warmup_backlog=BACKLOG,
            measure_from=MEASURE_FROM)
    ph = int(irt.telemetry.counter_value("serve.prefetch_hits"))
    ps = int(irt.telemetry.counter_value("serve.prefetch_stale"))
    emit(rows, "serve", "pipelined", "zipf1.0_rot12", "serve_win_x",
         round(pl_win, 3))
    pipeline = {
        "zipf": 1.0, "rotate_every": 12, "pipeline_depth": 1,
        "cache_capacity": PIPE_CAPACITY, "rounds": PIPE_ROUNDS,
        # served-req/s from the pooled per-tenure medians (B requests
        # served per round in both arms — verified by the semantics check
        # below — so rps is B over the pooled mean-round latency)
        "pipelined_rps": round(B * 1e3 / pl["median_test"], 1),
        "sequential_rps": round(B * 1e3 / pl["median_base"], 1),
        "serve_win_x": round(pl_win, 3),
        "min_speedup_required": PIPELINE_MIN_SPEEDUP,
        "meets_min_speedup": bool(pl_win >= PIPELINE_MIN_SPEEDUP),
        "sequential_tenure_ms": round(pl["median_base"], 3),
        "pipelined_tenure_ms": round(pl["median_test"], 3),
        "aa_drift": round(pl["drift"], 4),
        "samples_per_arm": pl["samples_per_arm"],
        "zero_served": sum(r.zero_served for r in pl_pres + pl_sres),
        "requeues_pipelined": sum(r.requeues for r in pl_pres),
        "requeues_sequential": sum(r.requeues for r in pl_sres),
        # same trace, same probe decisions: the pipeline may not change
        # WHAT is served or requeued, only when the host blocks
        "requeue_semantics_unchanged": bool(
            sum(r.requeues for r in pl_pres)
            == sum(r.requeues for r in pl_sres)
            and sum(r.served for r in pl_pres)
            == sum(r.served for r in pl_sres)),
        "prefetch_hits": ph, "prefetch_stale": ps,
        "staged_cover_rate": round(ph / max(ph + ps, 1), 4),
    }

    auto = _auto_section(table, auto_skews, reps)
    for e in auto["entries"]:
        emit(rows, "serve", "auto", f"zipf{e['zipf']}", "auto_vs_tuned_x",
             e["auto_vs_tuned_x"])

    speedups = [t["speedup_x"] for t in throughput]
    summary = {
        "config": {"vocab": V, "dim": D, "batch_requests": B,
                   "keys_per_request": K,
                   "cache_capacity": AUTO, "replan_every": AUTO,
                   "refresh_every": AUTO, "double_buffer": AUTO,
                   "n_shards": N_SHARDS,
                   "reps": reps, "rounds": ROUNDS, "quick": quick},
        "throughput": throughput,
        "overlap": overlap,
        "pipeline": pipeline,
        "auto": auto,
        "min_speedup_at_zipf_ge_1.0": min(speedups),
        "drift": drift_entries,
        # non-vacuous: requires at least one measured post-replan window
        "drift_all_recovered_within_one_replan": bool(drift_entries) and
        all(e["recovered_within_one_replan"] for e in drift_entries),
        "zero_served_total": zero_served_total,
        "requeues_total": requeues_total,
        "requests_served_total": served_total,
        "wall_clock_s": round(time.time() - t_start, 2),
    }
    with open(_OUT, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"wrote {os.path.normpath(_OUT)}")
    if trace_path or metrics_path:
        _traced_arm(table, trace_path, metrics_path)
    emit(rows, "serve", "managed", "ALL", "min_speedup_x",
         round(min(speedups), 2))
    emit(rows, "serve", "managed", "ALL", "zero_served", zero_served_total)
    emit(rows, "serve", "auto", "ALL", "min_auto_vs_tuned_x",
         round(min(e["auto_vs_tuned_x"] for e in auto["entries"]), 3))
    return rows


def check_baseline(path: str, auto: bool = False,
                   pipeline: bool = False) -> None:
    """CI guard: re-measure a small arm and compare against the committed
    BENCH_serve.json.  Paired ratios normalize away absolute host speed;
    the guard trips only when today's ratio falls >15% below the
    committed one (geomean across arms, best-of-two on a first trip to
    ride out co-tenant bursts).

    Default arm: managed-vs-plain speedups at zipf {1.0, 1.1}, steady.
    ``auto=True``: the zero-tuning arm — auto-vs-tuned ratio at zipf 1.1,
    which additionally must clear the absolute AUTO_MIN_RATIO floor.
    ``pipeline=True``: the §15 arm — pipelined-vs-sequential served-rps
    on the drifting zipf-1.0 trace, which additionally requires zero
    zero-served batches and unchanged requeue counts (the pipeline is a
    wall-clock transform, never a semantics change)."""
    with open(path) as f:
        committed = json.load(f)
    rng = np.random.default_rng(0)
    table = rng.normal(size=(V, D)).astype(np.float32)
    reps = 3

    def measure() -> Dict[str, float]:
        if pipeline:
            replay = _record(1.0, 12, extra=PIPE_ROUNDS - ROUNDS + 4)
            warm = _warm(
                table, replace(_tuned_cfg(), pipeline_depth=1,
                               cache_capacity=PIPE_CAPACITY), replay)
            stats, pres, sres = _pipeline_arm(table, replay, reps, warm)
            if any(r.zero_served for r in pres + sres):
                raise SystemExit("pipeline arm served zeroed batches")
            prq, srq = (sum(r.requeues for r in pres),
                        sum(r.requeues for r in sres))
            psv, ssv = (sum(r.served for r in pres),
                        sum(r.served for r in sres))
            if prq != srq or psv != ssv:
                raise SystemExit(
                    f"pipeline arm changed serve semantics: requeues "
                    f"{prq} vs {srq}, served {psv} vs {ssv}")
            return {"pipeline_zipf1.0":
                    stats["median_base"] / stats["median_test"]}
        if auto:
            replay = _record(1.1, 0, extra=ROUNDS_AUTO - ROUNDS + 4)
            warm = _warm(table, _auto_cfg(), replay)
            _run_once(table, _tuned_cfg(), replay, warm)
            ratio, a, _ = _auto_pairs(table, replay, reps, warm)
            if a.zero_served:
                raise SystemExit(f"auto arm served {a.zero_served} "
                                 "zeroed rows across capacity resizes")
            return {"auto_zipf1.1": ratio}
        out = {}
        warm = None
        for zipf_a in (1.0, 1.1):
            replay = _record(zipf_a, 0)
            if warm is None:
                warm = _warm(table, _auto_cfg(), replay)
                warm._plain_fn = _warm(
                    table, replace(_auto_cfg(), managed=False),
                    replay)._plain_fn
            ratio, _, _ = _paired_runs(
                table, _auto_cfg(), replace(_auto_cfg(), managed=False),
                replay, reps, warm)
            out[f"managed_zipf{zipf_a}"] = ratio
        return out

    def reference() -> Dict[str, float]:
        if pipeline:
            sec = committed.get("pipeline")
            if not sec:
                raise SystemExit("committed baseline has no pipeline "
                                 "section — regenerate BENCH_serve.json")
            return {"pipeline_zipf1.0": sec["serve_win_x"]}
        if auto:
            entries = committed.get("auto", {}).get("entries", [])
            ref = {f"auto_zipf{e['zipf']}": e["auto_vs_tuned_x"]
                   for e in entries if e["zipf"] == 1.1}
            if not ref:
                raise SystemExit("committed baseline has no auto section "
                                 "at zipf 1.1 — regenerate BENCH_serve"
                                 ".json")
            return ref
        ref = {}
        for t in committed["throughput"]:
            if t["rotate_every"] == 0 and t["zipf"] in (1.0, 1.1):
                ref[f"managed_zipf{t['zipf']}"] = t["speedup_x"]
        if not ref:
            raise SystemExit("committed baseline has no steady arms")
        return ref

    ref = reference()

    def verdict(meas: Dict[str, float]):
        rel = [meas[k] / ref[k] for k in ref if k in meas]
        geo = float(np.exp(np.mean(np.log(np.maximum(rel, 1e-9)))))
        floor_ok = True
        if auto:
            floor_ok = all(meas[k] >= AUTO_MIN_RATIO for k in meas)
        if pipeline:
            floor_ok = all(meas[k] >= 1.0 for k in meas)
        return geo, geo * REGRESSION_TOL >= 1.0 and floor_ok

    meas = measure()
    geo, ok = verdict(meas)
    if not ok:
        # one retry: a co-tenant burst can eat a whole measurement pass
        meas2 = measure()
        meas = {k: max(meas[k], meas2[k]) for k in meas}
        geo, ok = verdict(meas)
    arm = ("pipelined-vs-sequential" if pipeline
           else "auto-vs-tuned" if auto else "managed-vs-plain")
    detail = " ".join(f"{k}={meas[k]:.2f}(ref {ref[k]:.2f})"
                      for k in sorted(ref) if k in meas)
    if not ok:
        raise SystemExit(
            f"serve {arm} regression: geomean {geo:.3f}x of committed "
            f"(tolerance {1 / REGRESSION_TOL:.3f}) — {detail}")
    print(f"serve {arm} baseline ok: geomean {geo:.3f}x of committed "
          f"— {detail}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized smoke (2 skews x 2 drift rates)")
    ap.add_argument("--auto", action="store_true",
                    help="with --check-baseline: guard the zero-tuning "
                         "arm instead of managed-vs-plain")
    ap.add_argument("--pipeline", action="store_true",
                    help="with --check-baseline: guard the §15 "
                         "pipelined-vs-sequential arm")
    ap.add_argument("--check-baseline", metavar="JSON", default=None,
                    help="re-measure a small arm and fail on a >15%% "
                         "paired regression vs the committed numbers")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a fully-traced arm's Chrome trace JSON")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="write the traced arm's telemetry + attribution "
                         "records as schema-versioned JSONL")
    ap.add_argument("--check-trace-overhead", action="store_true",
                    help="fail if tracing at default sampling costs >2%% "
                         "paired-median throughput")
    args = ap.parse_args()
    if args.check_baseline:
        check_baseline(args.check_baseline, auto=args.auto,
                       pipeline=args.pipeline)
        sys.exit(0)
    if args.check_trace_overhead:
        check_trace_overhead()
        sys.exit(0)
    run(quick=args.quick, trace_path=args.trace,
        metrics_path=args.metrics_out)
