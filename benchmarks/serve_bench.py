"""Serving benchmark: the intent-signaled online runtime vs plain lookup.

Measures end-to-end request throughput and p50/p99 latency of the
managed serving runtime (`repro.serve`) against the unmanaged
vocab-parallel baseline across Zipf skews and hot-set drift rates, plus
a drift-adaptation section that checks the acceptance invariants:

  (a) managed serving >= 1.5x plain-lookup throughput at Zipf skew >= 1.0;
  (b) after a hot-set rotation the miss rate returns to within 2x of the
      pre-rotation steady state within one replan round;
  (c) zero silently-dropped (zero-served) requests across the run.

Cost model: the embedding is vocab-sharded ``N_SHARDS`` ways and every
row fetched from a non-local shard moves through the emulated
vocab-parallel collective (`pm.embedding.shard_partial_sum`: one
materialized (n, D) partial per shard — the single-host stand-in for the
all-reduce's wire bytes).  The plain baseline moves EVERY token's row
through it; the managed path moves only the compact intent-planned miss
buffer and serves cache hits locally.

Both variants serve identical replayed request traces through the same
queue/scheduler stack, run back-to-back per repetition; the reported
speedup is the median of per-rep throughput ratios (paired to cancel
this container's bursty co-tenant noise).  Writes ``BENCH_serve.json``
at the repo root next to BENCH_quick/BENCH_scale.

CLI: ``python -m benchmarks.serve_bench [--quick]``.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import replace
from typing import Dict, List

import numpy as np

from repro.serve import (DriftingZipfStream, ReplayStream, ServeConfig,
                         ServingRuntime)

from .common import emit

_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..",
                    "BENCH_serve.json")

# deployment-scale cost model: a 64-way vocab-sharded table (the intent
# engine's own node cap) — managed wins scale with the shard count
# because only the miss buffer pays the collective
N_SHARDS = 64
V, D = 65536, 512
B, K = 64, 64            # requests per micro-batch x keys per request
C = 8192                 # replica-cache capacity (deep enough to absorb a
#                          mixed old/new hot set across a rotation)
REPS = 7
ROUNDS = 32
MEASURE_FROM = 4
STEADY_WINDOW = 5        # rounds of pre-rotation steady state


def _run_once(table, cfg: ServeConfig, replay: ReplayStream, warm):
    rt = ServingRuntime(table, cfg)
    rt._managed_fn = warm._managed_fn
    rt._plain_fn = warm._plain_fn
    return rt.run(replay, ROUNDS, measure_from=MEASURE_FROM)


def _paired_runs(table, cfg: ServeConfig, replay: ReplayStream,
                 reps: int):
    """Interleaved managed/plain reps on the same replayed trace.

    The container's 2 CPUs see bursty co-tenant noise that can slow a
    whole run 2x; running the pair back-to-back and taking the *median of
    per-rep throughput ratios* cancels that common-mode noise, which
    separate medians cannot."""
    plain_cfg = replace(cfg, managed=False)
    warm = ServingRuntime(table, cfg)
    warm.run(replay, max(10, MEASURE_FROM + 4), measure_from=2)
    pwarm = ServingRuntime(table, plain_cfg)
    pwarm.run(replay, 6, measure_from=2)
    warm._plain_fn = pwarm._plain_fn
    pairs = []
    for _ in range(reps):
        m = _run_once(table, cfg, replay, warm)
        p = _run_once(table, plain_cfg, replay, warm)
        pairs.append((m.throughput_rps / max(p.throughput_rps, 1e-9), m, p))
    pairs.sort(key=lambda t: t[0])
    return pairs[len(pairs) // 2]


def _drift_metrics(res, rotation_rounds: List[int]) -> List[Dict]:
    """Per-rotation recovery analysis over the runtime's miss trace.

    A rotation at stream round R changes arrivals enqueued at runtime
    round R - backlog, which reach the scheduler ~backlog rounds later —
    so its effect on *served* traffic starts at runtime round ~R (the
    steady-state queue depth equals the warmup backlog).  Replans may
    adapt even earlier, from the rotated intent still queued."""
    trace = dict(res.miss_trace)
    out = []
    rots = list(rotation_rounds)
    for i, rot in enumerate(rots):
        if rot <= STEADY_WINDOW or rot >= res.rounds - 2:
            continue
        nxt = rots[i + 1] if i + 1 < len(rots) else res.rounds
        pre = res.steady_miss_rate(rot - STEADY_WINDOW, rot)
        replans = [r for r in res.replan_rounds if r >= rot]
        if pre is None or not replans:
            continue
        rr = replans[0]
        spike = max((trace[r] for r in range(rot, rr + 1) if r in trace),
                    default=pre)
        rec_hi = min(nxt, rr + 1 + STEADY_WINDOW)
        recovered = res.steady_miss_rate(rr + 1, rec_hi)
        if recovered is None:
            # no executed batch between the replan and the next rotation:
            # nothing measured, so nothing may be claimed — skip, and the
            # headline bool below requires at least one measured entry
            continue
        ratio = recovered / max(pre, 1e-9)
        out.append({
            "rotation_round": rot,
            "pre_rotation_miss": round(pre, 4),
            "spike_miss": round(spike, 4),
            "recovered_miss": round(recovered, 4),
            "recovery_ratio_vs_pre": round(ratio, 3),
            "replan_lag_rounds": rr - rot,
            "recovered_within_one_replan": bool(ratio <= 2.0),
        })
    return out


def run(quick: bool = False) -> List[str]:
    t_start = time.time()
    rows: List[str] = []
    skews = [1.0, 1.1] if quick else [1.0, 1.1, 1.5]
    drift_rates = [0, 12] if quick else [0, 12, 20]   # rotate_every rounds
    reps = REPS if quick else REPS + 2

    rng = np.random.default_rng(0)
    table = rng.normal(size=(V, D)).astype(np.float32)
    base = ServeConfig(vocab=V, batch_requests=B, keys_per_request=K,
                       cache_capacity=C, n_shards=N_SHARDS, replan_every=8)
    backlog = base.replan_every + 2

    throughput = []
    drift_entries = []
    zero_served_total = 0
    served_total = 0
    requeues_total = 0

    for zipf_a in skews:
        for rot in drift_rates:
            scenario = "rotate" if rot else "steady"
            stream = DriftingZipfStream(
                V, K, zipf_a=zipf_a, arrival_rate=B, scenario=scenario,
                rotate_every=rot or 32, seed=3)
            replay = ReplayStream.record(stream, ROUNDS + backlog + 4)
            tag = f"zipf{zipf_a}_rot{rot}"

            speedup, m, p = _paired_runs(table, base, replay, reps)
            zero_served_total += m.zero_served
            served_total += m.served + p.served
            requeues_total += m.requeues
            plain_rps, plain_p50, plain_p99 = (
                p.throughput_rps, p.p50_ms, p.p99_ms)
            emit(rows, "serve", "managed", tag, "throughput_rps",
                 round(m.throughput_rps, 1))
            emit(rows, "serve", "plain", tag, "throughput_rps",
                 round(plain_rps, 1))
            emit(rows, "serve", "managed", tag, "speedup_x",
                 round(speedup, 2))
            emit(rows, "serve", "managed", tag, "p50_ms",
                 round(m.p50_ms, 2))
            emit(rows, "serve", "managed", tag, "p99_ms",
                 round(m.p99_ms, 2))
            throughput.append({
                "zipf": zipf_a, "rotate_every": rot,
                "managed_rps": round(m.throughput_rps, 1),
                "plain_rps": round(plain_rps, 1),
                "speedup_x": round(speedup, 2),
                "managed_p50_ms": round(m.p50_ms, 2),
                "managed_p99_ms": round(m.p99_ms, 2),
                "plain_p50_ms": round(plain_p50, 2),
                "plain_p99_ms": round(plain_p99, 2),
                "steady_miss_rate": round(
                    m.steady_miss_rate(MEASURE_FROM, m.rounds) or 0.0, 4),
                "requeues": m.requeues, "zero_served": m.zero_served,
            })
            if rot:
                for entry in _drift_metrics(m, replay.rotation_rounds):
                    entry.update({"zipf": zipf_a, "rotate_every": rot})
                    drift_entries.append(entry)
                    emit(rows, "serve", "managed", tag,
                         "recovery_ratio_vs_pre",
                         entry["recovery_ratio_vs_pre"])

    # double-buffered admission (the probe-at-admission split means batch
    # t+1's whole index stage can run while the device executes batch t):
    # paired managed-vs-managed comparison, pipeline on vs off, same trace
    ov_stream = DriftingZipfStream(V, K, zipf_a=1.1, arrival_rate=B,
                                   scenario="steady", seed=3)
    ov_replay = ReplayStream.record(ov_stream, ROUNDS + backlog + 4)
    buffered = replace(base, double_buffer=True)
    warm = ServingRuntime(table, base)
    warm.run(ov_replay, max(10, MEASURE_FROM + 4), measure_from=2)
    ov_pairs = []
    for _ in range(reps):
        d = _run_once(table, buffered, ov_replay, warm)
        s = _run_once(table, base, ov_replay, warm)
        ov_pairs.append((d.throughput_rps / max(s.throughput_rps, 1e-9),
                         d, s))
    ov_pairs.sort(key=lambda t: t[0])
    ov_win, ov_d, ov_s = ov_pairs[len(ov_pairs) // 2]
    emit(rows, "serve", "managed", "zipf1.1_steady", "overlap_win_x",
         round(ov_win, 3))
    overlap = {
        "double_buffer_rps": round(ov_d.throughput_rps, 1),
        "serial_rps": round(ov_s.throughput_rps, 1),
        "overlap_win_x": round(ov_win, 3),
        "double_buffer_p50_ms": round(ov_d.p50_ms, 2),
        "serial_p50_ms": round(ov_s.p50_ms, 2),
    }

    speedups = [t["speedup_x"] for t in throughput]
    summary = {
        "config": {"vocab": V, "dim": D, "batch_requests": B,
                   "keys_per_request": K, "cache_capacity": C,
                   "n_shards": N_SHARDS, "replan_every": base.replan_every,
                   "reps": reps, "rounds": ROUNDS, "quick": quick},
        "throughput": throughput,
        "overlap": overlap,
        "min_speedup_at_zipf_ge_1.0": min(speedups),
        "drift": drift_entries,
        # non-vacuous: requires at least one measured post-replan window
        "drift_all_recovered_within_one_replan": bool(drift_entries) and
        all(e["recovered_within_one_replan"] for e in drift_entries),
        "zero_served_total": zero_served_total,
        "requeues_total": requeues_total,
        "requests_served_total": served_total,
        "wall_clock_s": round(time.time() - t_start, 2),
    }
    with open(_OUT, "w") as f:
        json.dump(summary, f, indent=1)
    print(f"wrote {os.path.normpath(_OUT)}")
    emit(rows, "serve", "managed", "ALL", "min_speedup_x",
         round(min(speedups), 2))
    emit(rows, "serve", "managed", "ALL", "zero_served", zero_served_total)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized smoke (2 skews x 2 drift rates)")
    run(quick=ap.parse_args().quick)
