"""Benchmark harness entry point: ``python -m benchmarks.run [--quick]``.

One module per paper table/figure (DESIGN.md §8):
  fig6_overall          — Figure 6  (overall vs baselines, 5 tasks)
  fig7_scalability      — Figure 7  (2..16 nodes)
  fig8_timing           — Figure 8  (adaptive action timing vs offsets)
  table2_communication  — Table 2   (communication + staleness)
  fig15_traces          — Figure 15 (per-key management traces)
  kernels_bench         — kernel micro-benches + TPU roofline bounds

Output: ``benchmark,variant,task,metric,value`` CSV rows on stdout and in
``benchmarks/results/benchmarks.csv``.  The roofline deliverable is
separate (``python -m benchmarks.roofline benchmarks/results/*.json``).
"""

from __future__ import annotations

import argparse
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload scale (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    from . import (fig6_overall, fig7_scalability, fig8_timing,
                   fig15_traces, kernels_bench, quality_mf,
                   table2_communication)

    scale = 0.2 if args.quick else 0.5
    benches = {
        "fig6": lambda: fig6_overall.run(scale=scale),
        "fig7": lambda: fig7_scalability.run(scale=min(scale, 0.35)),
        # fig8 needs epochs >> offset for the immediate-action degradation
        # to be visible (replica lifetimes scale with the offset)
        "fig8": lambda: fig8_timing.run(scale=1.0),
        "table2": lambda: table2_communication.run(scale=scale),
        "fig15": lambda: fig15_traces.run(scale=min(scale, 0.4)),
        "kernels": kernels_bench.run,
        "quality_mf": quality_mf.run,
    }
    only = set(args.only.split(",")) if args.only else None

    all_rows = ["benchmark,variant,task,metric,value"]
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"### {name} ###", flush=True)
        all_rows += fn()
        print(f"### {name} done in {time.time() - t0:.1f}s ###", flush=True)

    os.makedirs("benchmarks/results", exist_ok=True)
    with open("benchmarks/results/benchmarks.csv", "w") as f:
        f.write("\n".join(all_rows) + "\n")
    print(f"wrote {len(all_rows) - 1} rows to "
          "benchmarks/results/benchmarks.csv")


if __name__ == "__main__":
    main()
