"""Benchmark harness entry point: ``python -m benchmarks.run [--quick]``.

One module per paper table/figure (DESIGN.md §8):
  fig6_overall          — Figure 6  (overall vs baselines, 5 tasks)
  fig7_scalability      — Figure 7  (2..16 nodes, + engine key-scale sweep)
  fig8_timing           — Figure 8  (adaptive action timing vs offsets)
  table2_communication  — Table 2   (communication + staleness)
  fig15_traces          — Figure 15 (per-key management traces)
  kernels_bench         — kernel micro-benches + TPU roofline bounds
  scale_sweep           — key-count scaling of the vectorized intent engine
  serve_bench           — online serving runtime vs plain lookup
                          (throughput/latency + drift adaptation +
                          double-buffered-admission overlap,
                          BENCH_serve.json)
  mesh_bench            — managed vs plain over the mesh-real shard_map
                          psum path, 8-device host mesh (re-execs itself
                          with XLA_FLAGS when needed, BENCH_mesh.json)
  hotpath_bench         — single-sort fused managed step vs the PR-4
                          three-sort/dense-grad replica, paired medians
                          (BENCH_hotpath.json; also the CI regression
                          guard via --check-baseline)

Output: ``benchmark,variant,task,metric,value`` CSV rows on stdout and in
``benchmarks/results/benchmarks.csv``.  ``--quick`` additionally writes
``BENCH_quick.json`` (per-benchmark wall-clock + headline metric) at the
repo root for the perf trajectory.  The roofline deliverable is separate
(``python -m benchmarks.roofline benchmarks/results/*.json``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

_REPO_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")

# module-style aliases accepted by --only
_ALIASES = {
    "fig6_overall": "fig6",
    "fig7_scalability": "fig7",
    "fig8_timing": "fig8",
    "table2_communication": "table2",
    "fig15_traces": "fig15",
    "kernels_bench": "kernels",
    "serve_bench": "serve",
    "mesh_bench": "mesh",
    "hotpath_bench": "hotpath",
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload scale (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    from . import (fig6_overall, fig7_scalability, fig8_timing,
                   fig15_traces, hotpath_bench, kernels_bench, mesh_bench,
                   quality_mf, scale_sweep, serve_bench,
                   table2_communication)

    scale = 0.2 if args.quick else 0.5
    benches = {
        "fig6": lambda: fig6_overall.run(scale=scale),
        "fig7": lambda: fig7_scalability.run(
            scale=min(scale, 0.35),
            scale_keys=0 if args.quick else 100_000),
        # fig8 needs epochs >> offset for the immediate-action degradation
        # to be visible (replica lifetimes scale with the offset)
        "fig8": lambda: fig8_timing.run(scale=1.0),
        "table2": lambda: table2_communication.run(scale=scale),
        "fig15": lambda: fig15_traces.run(scale=min(scale, 0.4)),
        "kernels": lambda: kernels_bench.run(quick=args.quick),
        "quality_mf": quality_mf.run,
        "scale_sweep": lambda: scale_sweep.run(quick=args.quick),
        "serve": lambda: serve_bench.run(quick=args.quick),
        "mesh": lambda: mesh_bench.run(quick=args.quick),
        "hotpath": lambda: hotpath_bench.run(quick=args.quick),
    }
    only = None
    if args.only:
        only = {_ALIASES.get(name, name) for name in args.only.split(",")}
        unknown = only - set(benches)
        if unknown:
            ap.error(f"unknown benchmark(s): {sorted(unknown)}; "
                     f"known: {sorted(benches) + sorted(_ALIASES)}")

    all_rows = ["benchmark,variant,task,metric,value"]
    timings = {}
    for name, fn in benches.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"### {name} ###", flush=True)
        rows = fn()
        wall = time.time() - t0
        all_rows += rows
        timings[name] = {"wall_clock_s": round(wall, 2)}
        if rows:
            # headline metric: the benchmark's first emitted row
            _bench, variant, task, metric, value = rows[0].split(",", 4)
            timings[name]["headline"] = {
                "variant": variant, "task": task, "metric": metric,
                "value": value}
        print(f"### {name} done in {wall:.1f}s ###", flush=True)

    os.makedirs("benchmarks/results", exist_ok=True)
    with open("benchmarks/results/benchmarks.csv", "w") as f:
        f.write("\n".join(all_rows) + "\n")
    print(f"wrote {len(all_rows) - 1} rows to "
          "benchmarks/results/benchmarks.csv")
    if args.quick:
        out = os.path.join(_REPO_ROOT, "BENCH_quick.json")
        with open(out, "w") as f:
            json.dump(timings, f, indent=1)
        print(f"wrote {os.path.normpath(out)}")


if __name__ == "__main__":
    main()
