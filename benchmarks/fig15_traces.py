"""Figure 15 / Appendix E: how AdaPM manages individual parameters.

Traces keys across the hotness spectrum during one KGE epoch and summarizes
their management: extreme hot spots converge to (full) replication, cold
keys to one-off relocation, and keys in between get short-lived replicas /
relocations exactly when concurrently needed."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.api import CostModel
from repro.core.manager import AdaPM
from repro.core.simulator import SimConfig, simulate
from repro.data.workloads import make_workload


def run(n_nodes: int = 8, wpn: int = 4, scale: float = 0.4) -> List[str]:
    rows: List[str] = []
    wl = make_workload("KGE", n_nodes=n_nodes, wpn=wpn, scale=scale)
    freq = wl.key_frequencies()
    order = np.argsort(-freq)
    # pick keys across the spectrum: hottest, warm, median, cold
    picks = {
        "hottest": int(order[0]),
        "hot": int(order[50]),
        "warm": int(order[500]),
        "median": int(order[len(order) // 20]),
        "cold": int(order[np.nonzero(freq[order])[0][-1]]),
    }
    pol = AdaPM(n_nodes, CostModel(), trace_keys=set(picks.values()))
    simulate(pol, wl, SimConfig(signal_offset=100))
    by_key = {}
    for (t, key, node, ev) in pol.trace:
        by_key.setdefault(key, []).append((t, node, ev))
    for name, key in picks.items():
        evs = by_key.get(key, [])
        n_reloc = sum(1 for (_, _, e) in evs if e == "relocate-in")
        n_rep = sum(1 for (_, _, e) in evs if e == "replica-create")
        n_des = sum(1 for (_, _, e) in evs if e == "replica-destroy")
        row = (f"fig15,{name},KGE,events,"
               f"freq={int(freq[key])};reloc={n_reloc};"
               f"replica_create={n_rep};replica_destroy={n_des}")
        print(row)
        rows.append(row)
    return rows


if __name__ == "__main__":
    run()
