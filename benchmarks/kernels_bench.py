"""Kernel micro-benchmarks: interpret-mode Pallas correctness timing plus
the pure-jnp oracle (the CPU-speed reference; real perf is a TPU property,
see §Roofline for the bandwidth-bound analysis).

Also benches the intent-managed embedding hot path end to end (forward +
backward + row update) against the unmanaged `plain_lookup` baseline across
Zipf skews: the managed path probes the replica cache, compacts the
*unique* misses into the intent-sized buffer, and applies the optimizer to
exactly the touched rows — the plain path pays a dense (V, D) gradient
materialization and a dense optimizer sweep every step.  On TPU the managed
win is additionally the (M, D)-vs-(T, D) all-reduce; the CPU numbers here
capture the sparse-update side of the story.

CLI: ``python -m benchmarks.kernels_bench [--quick]``.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import SyntheticCorpus
from repro.kernels import ops, ref
from repro.pm.embedding import plain_lookup, pm_lookup
from repro.pm.planner import _bucket

from .common import time_fn


def _time(fn, *args, iters=5) -> float:
    return time_fn(lambda: fn(*args), iters=iters,
                   block=jax.block_until_ready)


def _managed_vs_plain(rows: List[str], *, V: int, D: int, B: int, S: int,
                      C: int, zipf_a: float, kernel_T: int) -> None:
    """Fwd+bwd+row-update step: managed (cache + deduped compact misses +
    sparse rows) vs plain (dense gather + dense grad + dense sweep)."""
    T = B * S
    corpus = SyntheticCorpus(V, zipf_a=zipf_a, seed=0)
    tokens = jnp.asarray(corpus.tokens((B, S)))
    tok = tokens.reshape(T).astype(jnp.int32)
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(V, D)), dtype=jnp.float32)
    accum = jnp.full((V, D), 0.1, dtype=jnp.float32)
    # the planner's replica cache: the C hottest rows of the skewed stream
    cache_ids = jnp.asarray(np.sort(corpus.perm[:C]), jnp.int32)
    cache_rows = jnp.take(table, cache_ids, axis=0)

    uniq = np.unique(np.asarray(tokens))
    n_miss = int(np.setdiff1d(uniq, np.asarray(cache_ids)).size)
    M = _bucket(max(1, n_miss))               # exact intent-derived bound
    hit_rate = float(np.isin(np.asarray(tok), np.asarray(cache_ids)).mean())

    @jax.jit
    def plain_step(table, accum):
        out = plain_lookup(table, tokens)
        gt = (2.0 * out).reshape(T, D)         # d/dtable of sum(out**2)
        grad = jnp.zeros((V, D), jnp.float32).at[tok].add(gt)
        a_new = accum + grad * grad            # dense AdaGrad sweep
        return table - 0.1 * grad / (jnp.sqrt(a_new) + 1e-8), a_new

    @jax.jit
    def managed_step(table, accum):
        out = pm_lookup(table, cache_ids, cache_rows, tokens, M, True)
        gt = (2.0 * out).reshape(T, D)
        # pad slots -> sentinel V: gathers clip, scatters drop (no-ops)
        ids, rows_g = ops.segment_rows(tok, gt, n_slots=T, pad_id=V)
        return ref.adagrad_row_update_ref(table, accum, ids, rows_g,
                                          lr=0.1, eps=1e-8)

    us_plain = _time(lambda: plain_step(table, accum), iters=10)
    us_managed = _time(lambda: managed_step(table, accum), iters=10)
    tag = f"zipf{zipf_a}_V{V}xD{D}xT{T}"
    rows.append(f"kernels,pm_plain_fwd_bwd,{tag},us_per_call,"
                f"{us_plain:.1f}")
    rows.append(f"kernels,pm_managed_fwd_bwd,{tag},us_per_call,"
                f"{us_managed:.1f}")
    rows.append(f"kernels,pm_managed_speedup,{tag},x,"
                f"{us_plain / us_managed:.2f}")
    rows.append(f"kernels,pm_hit_rate,{tag},frac,{hit_rate:.3f}")
    rows.append(f"kernels,pm_unique_miss,{tag},count,{n_miss}")

    # interpret-mode Pallas managed forward (correctness-path timing only;
    # native compilation is a TPU property) on a reduced token count
    ktok = tokens.reshape(T)[:kernel_T].reshape(1, kernel_T)

    @jax.jit
    def kernel_fwd(table):
        return pm_lookup(table, cache_ids, cache_rows, ktok, M, True, True)

    us_kernel = _time(lambda: kernel_fwd(table), iters=2)
    rows.append(f"kernels,pm_kernel_fwd_interp,{tag}_kT{kernel_T},"
                f"us_per_call,{us_kernel:.1f}")


def run(quick: bool = False) -> List[str]:
    rows: List[str] = []
    rng = np.random.default_rng(0)
    shapes = [(4096, 512, 256)] if quick else [(4096, 512, 256),
                                               (16384, 1024, 512)]
    for (V, D, n) in shapes:
        table = jnp.asarray(rng.normal(size=(V, D)), dtype=jnp.float32)
        accum = jnp.ones((V, D), dtype=jnp.float32)
        ids = jnp.asarray(rng.choice(V, size=(n,), replace=False),
                          dtype=jnp.int32)
        grads = jnp.asarray(rng.normal(size=(n, D)), dtype=jnp.float32)
        us_ref = _time(lambda: ref.embed_gather_ref(table, ids))
        rows.append(f"kernels,gather_ref,V{V}xD{D}xn{n},us_per_call,"
                    f"{us_ref:.1f}")
        us_ref2 = _time(lambda: ref.adagrad_row_update_ref(
            table, accum, ids, grads))
        rows.append(f"kernels,adagrad_ref,V{V}xD{D}xn{n},us_per_call,"
                    f"{us_ref2:.1f}")
        # analytic TPU bound: bytes over HBM bandwidth (gather: read+write
        # n*D; adagrad: 2 reads + 2 writes of n*D + grads read)
        gb = n * D * 4 * 2
        rows.append(f"kernels,gather_tpu_bound,V{V}xD{D}xn{n},us_roofline,"
                    f"{gb / 819e9 * 1e6:.2f}")
        ab = n * D * 4 * 5
        rows.append(f"kernels,adagrad_tpu_bound,V{V}xD{D}xn{n},us_roofline,"
                    f"{ab / 819e9 * 1e6:.2f}")

    # managed vs plain across Zipf skews (hotter skew -> higher hit rate
    # and fewer unique misses -> smaller compact buffer)
    if quick:
        dims = dict(V=32768, D=256, B=16, S=256, C=1024, kernel_T=64)
        skews = [1.1]
    else:
        dims = dict(V=65536, D=256, B=32, S=256, C=1024, kernel_T=128)
        skews = [1.05, 1.1, 1.5]
    for a in skews:
        _managed_vs_plain(rows, zipf_a=a, **dims)

    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized smoke (one shape, one skew)")
    run(quick=ap.parse_args().quick)
