"""Kernel micro-benchmarks: interpret-mode Pallas correctness timing plus
the pure-jnp oracle (the CPU-speed reference; real perf is a TPU property,
see §Roofline for the bandwidth-bound analysis)."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[str]:
    rows: List[str] = []
    rng = np.random.default_rng(0)
    for (V, D, n) in [(4096, 512, 256), (16384, 1024, 512)]:
        table = jnp.asarray(rng.normal(size=(V, D)), dtype=jnp.float32)
        accum = jnp.ones((V, D), dtype=jnp.float32)
        ids = jnp.asarray(rng.choice(V, size=(n,), replace=False),
                          dtype=jnp.int32)
        grads = jnp.asarray(rng.normal(size=(n, D)), dtype=jnp.float32)
        us_ref = _time(lambda: ref.embed_gather_ref(table, ids))
        rows.append(f"kernels,gather_ref,V{V}xD{D}xn{n},us_per_call,"
                    f"{us_ref:.1f}")
        us_ref2 = _time(lambda: ref.adagrad_row_update_ref(
            table, accum, ids, grads))
        rows.append(f"kernels,adagrad_ref,V{V}xD{D}xn{n},us_per_call,"
                    f"{us_ref2:.1f}")
        # analytic TPU bound: bytes over HBM bandwidth (gather: read+write
        # n*D; adagrad: 2 reads + 2 writes of n*D + grads read)
        gb = n * D * 4 * 2
        rows.append(f"kernels,gather_tpu_bound,V{V}xD{D}xn{n},us_roofline,"
                    f"{gb / 819e9 * 1e6:.2f}")
        ab = n * D * 4 * 5
        rows.append(f"kernels,adagrad_tpu_bound,V{V}xD{D}xn{n},us_roofline,"
                    f"{ab / 819e9 * 1e6:.2f}")
    for r in rows:
        print(r)
    return rows


if __name__ == "__main__":
    run()
