"""Figure 8/14: effect of adaptive action timing.

AdaPM (Algorithm 1) vs an ablation that acts immediately on every intent
signal, across signal offsets.  Claims validated: with adaptive timing the
performance is flat for any sufficiently large offset ("applications can
simply signal intent early"); with immediate action, large offsets degrade
run time / staleness (replicas maintained longer than needed) — i.e. the
offset becomes a tuning knob, which is exactly what AdaPM removes."""

from __future__ import annotations

from typing import List

from .common import emit, run_one

OFFSETS = (25, 50, 100, 200, 400, 800)


def run(task: str = "WV", scale: float = 0.5, n_nodes: int = 8,
        wpn: int = 4) -> List[str]:
    rows: List[str] = []
    for off in OFFSETS:
        for variant in ("adapm", "adapm_immediate"):
            m = run_one(variant, task, n_nodes=n_nodes, wpn=wpn,
                        scale=scale, signal_offset=off)
            emit(rows, "fig8", variant, task, f"epoch_time_off{off}",
                 round(m.epoch_time, 4))
            emit(rows, "fig8", variant, task, f"gb_per_node_off{off}",
                 round(m.bytes_per_node / 1e9, 4))
            emit(rows, "fig8", variant, task, f"staleness_ms_off{off}",
                 round(m.mean_staleness * 1e3, 3))
    return rows


if __name__ == "__main__":
    run()
