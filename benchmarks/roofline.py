"""Roofline analysis (deliverable g).

Three terms per (arch x shape x mesh), TPU v5e constants:

    compute    = FLOPs_per_device / 197 TFLOP/s (bf16)
    memory     = HBM_bytes_per_device / 819 GB/s
    collective = collective_bytes_per_device / 50 GB/s (ICI link)

Methodology (EXPERIMENTS.md §Dry-run): XLA's ``cost_analysis()`` counts
while bodies once (calibrated in-repo), so scanned layer stacks are
under-counted ~L-fold.  Collective bytes therefore come from the dry-run's
execution-count-aware HLO parser (`repro.launch.dryrun.collective_bytes`);
compute/memory come from the analytic model below (stated formulas, exact
for the dominant matmul terms), with the raw cost_analysis numbers reported
alongside for reference.

MODEL_FLOPS = 6*N*T (dense) / 6*N_active*T (MoE): the "useful" floor.  The
ratio MODEL_FLOPS / HLO_FLOPS exposes remat recompute (~4/3 for our
remat-everything policy) and attention/scan overhead.
"""

from __future__ import annotations

import argparse
import json
import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, InputShape

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link
BYTES_PARAM = 2            # bf16
BYTES_ACT = 2


def _attn_flops_fwd(cfg: ModelConfig, T: int, S_ctx: float) -> float:
    """qk + pv einsums, forward, all layers (0 for attention-free)."""
    if cfg.family == "ssm" or not cfg.n_heads:
        return 0.0
    L = cfg.n_layers if cfg.family != "hybrid" else _hybrid_apps(cfg)
    return 4.0 * L * T * S_ctx * cfg.n_heads * cfg.head_dim


def _hybrid_apps(cfg: ModelConfig) -> int:
    return -(-cfg.n_layers // cfg.attn_every) if cfg.attn_every else 0


def _ssm_scan_flops_fwd(cfg: ModelConfig, T: int) -> float:
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    return 8.0 * cfg.n_layers * T * cfg.d_inner * cfg.ssm_state


def _weight_flops_fwd(cfg: ModelConfig, T: int, T_enc: int = 0) -> float:
    """2 * active-matmul-params * tokens (embedding gather excluded)."""
    n_active = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
    if cfg.tie_embeddings:
        # tied head still does the (D, V) matmul
        n_active += cfg.vocab_size * cfg.d_model
    f = 2.0 * n_active * T
    if cfg.family == "hybrid" and cfg.attn_every:
        # the shared block's params run A times but are counted once
        hd = cfg.head_dim
        shared = (cfg.d_model * cfg.n_heads * hd
                  + 2 * cfg.d_model * cfg.n_kv_heads * hd
                  + cfg.n_heads * hd * cfg.d_model
                  + 3 * cfg.d_model * cfg.d_ff)
        f += 2.0 * shared * T * max(0, _hybrid_apps(cfg) - 1)
    if cfg.family == "encdec" and T_enc:
        e = cfg.encoder
        enc_params = e.n_layers * (4 * cfg.d_model ** 2
                                   + 2 * cfg.d_model * cfg.d_ff)
        f += 2.0 * enc_params * T_enc
        # cross-attention k/v projection of encoder states, per dec layer
        f += 2.0 * cfg.n_layers * T_enc * 2 * cfg.d_model \
            * cfg.n_heads * cfg.head_dim
        # cross-attention qk/pv
        f += 4.0 * cfg.n_layers * T * e.n_frames * cfg.n_heads * cfg.head_dim
    return f


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_device: float
    hbm_bytes_device: float
    coll_bytes_device: float
    model_flops_device: float

    @property
    def t_compute(self) -> float:
        return self.flops_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops_device / max(self.flops_device, 1.0)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound on the step time."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        return self.model_flops_device / max(self.step_time, 1e-12) \
            / PEAK_FLOPS

    def advice(self) -> str:
        b = self.bottleneck
        if b == "compute":
            if self.useful_ratio < 0.6:
                return ("compute-bound with low useful ratio: relax the "
                        "remat policy (checkpoint fewer tensors) and trim "
                        "attention/scan overhead")
            return ("compute-bound near useful flops: increase per-chip "
                    "batch or accept — this is the roofline")
        if b == "memory":
            return ("memory-bound: raise arithmetic intensity — larger "
                    "per-device batch, fuse elementwise chains, keep "
                    "params/cache in bf16, shard the KV cache wider")
        return ("collective-bound: re-shard to cut the dominant collective "
                "(vocab-parallel all-reduce -> intent-managed replica "
                "cache; gradient all-reduce -> reduce-scatter; overlap "
                "collectives with the layer scan)")


def analytic_roofline(cfg: ModelConfig, shape: InputShape, n_devices: int,
                      coll_bytes_device: float, mesh_name: str,
                      train_flops_mult: float = 4.0) -> Roofline:
    """``train_flops_mult``: fwd+bwd+remat-extra-fwd (4x fwd; 3x without
    remat) — our train step remats every layer."""
    B, S = shape.global_batch, shape.seq_len
    N = cfg.active_param_count()
    if shape.kind == "train":
        T = B * S
        S_ctx = (min(S, cfg.sliding_window) if cfg.sliding_window else S / 2)
        T_enc = B * cfg.encoder.n_frames if cfg.encoder else 0
        fwd = (_weight_flops_fwd(cfg, T, T_enc)
               + _attn_flops_fwd(cfg, T, S_ctx)
               + _ssm_scan_flops_fwd(cfg, T))
        flops = train_flops_mult * fwd
        model_flops = 6.0 * N * T
        # HBM: params (fwd read + bwd read + opt update rw, bf16 + f32
        # accum) + activations (remat: ~2 fwd writes + bwd reads) + logits
        param_traffic = (N / n_devices) * (3 * BYTES_PARAM + 2 * 4 + 4)
        act_traffic = (T / n_devices) * cfg.d_model * cfg.n_layers \
            * BYTES_ACT * 12
        logit_traffic = (T / n_devices) * cfg.vocab_size * BYTES_ACT * 3
        hbm = param_traffic + act_traffic + logit_traffic
    elif shape.kind == "prefill":
        T = B * S
        S_ctx = (min(S, cfg.sliding_window) if cfg.sliding_window else S / 2)
        T_enc = B * cfg.encoder.n_frames if cfg.encoder else 0
        fwd = (_weight_flops_fwd(cfg, T, T_enc)
               + _attn_flops_fwd(cfg, T, S_ctx)
               + _ssm_scan_flops_fwd(cfg, T))
        flops = fwd
        model_flops = 2.0 * N * T
        param_traffic = (N / n_devices) * BYTES_PARAM
        act_traffic = (T / n_devices) * cfg.d_model * cfg.n_layers \
            * BYTES_ACT * 6
        # KV cache writes
        kv = 2 * (T / n_devices) * cfg.n_layers * max(cfg.n_kv_heads, 1) \
            * max(cfg.head_dim, 1) * BYTES_ACT
        hbm = param_traffic + act_traffic + kv
    else:  # decode: one token, full cache context
        T = B
        S_ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
        T_enc = 0
        fwd = (_weight_flops_fwd(cfg, T)
               + _attn_flops_fwd(cfg, T, S_ctx)
               + _ssm_scan_flops_fwd(cfg, T))
        flops = fwd
        model_flops = 2.0 * N * T
        param_traffic = (N / n_devices) * BYTES_PARAM
        if cfg.family == "ssm":
            cache_traffic = (B * cfg.n_layers * cfg.d_inner
                             * cfg.ssm_state * 4 * 2) / n_devices
        elif cfg.family == "hybrid":
            cache_traffic = (B * cfg.n_layers * cfg.d_inner
                             * cfg.ssm_state * 4 * 2
                             + 2 * B * _hybrid_apps(cfg) * S_ctx
                             * cfg.n_kv_heads * cfg.head_dim * BYTES_ACT
                             ) / n_devices
        else:
            cache_traffic = (2 * B * cfg.n_layers * S_ctx
                             * max(cfg.n_kv_heads, 1)
                             * max(cfg.head_dim, 1) * BYTES_ACT) / n_devices
        hbm = param_traffic + cache_traffic
    return Roofline(
        arch=cfg.arch_id, shape=shape.name, mesh=mesh_name,
        flops_device=flops / n_devices,
        hbm_bytes_device=hbm,
        coll_bytes_device=coll_bytes_device,
        model_flops_device=model_flops / n_devices,
    )


def from_dryrun_json(paths) -> list:
    rows = []
    for path in paths:
        with open(path) as f:
            recs = json.load(f)
        for rec in recs:
            if rec.get("status") != "ok":
                rows.append(rec)
                continue
            cfg = get_config(rec["arch"])
            shape = SHAPES[rec["shape"]]
            rl = analytic_roofline(cfg, shape, rec["n_devices"],
                                   rec["collective_bytes"], rec["mesh"])
            rec = dict(rec)
            rec["roofline"] = {
                "t_compute_s": rl.t_compute,
                "t_memory_s": rl.t_memory,
                "t_collective_s": rl.t_collective,
                "bottleneck": rl.bottleneck,
                "model_flops_device": rl.model_flops_device,
                "hlo_flops_device": rl.flops_device,
                "useful_ratio": rl.useful_ratio,
                "mfu_bound": rl.mfu,
                "advice": rl.advice(),
            }
            rows.append(rec)
    return rows


def markdown_table(rows) -> str:
    out = ["| arch | shape | mesh | compute (ms) | memory (ms) | "
           "collective (ms) | bottleneck | useful | MFU bound |",
           "|---|---|---|---|---|---|---|---|---|"]
    for rec in rows:
        if rec.get("status") == "skipped":
            out.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} |"
                       f" — | — | — | skipped: {rec['reason'][:40]}… | | |")
            continue
        if rec.get("status") != "ok":
            out.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} |"
                       f" — | — | — | ERROR | | |")
            continue
        r = rec["roofline"]
        out.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | {r['bottleneck']} "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound']:.2f} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("dryrun_json", nargs="+")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    rows = from_dryrun_json(args.dryrun_json)
    print(markdown_table(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
